"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes, opcodes and operand distributions (including
the 16-bit edge values); every case must match the oracle bit-for-bit.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import fabric as F
from compile.kernels import ref


EDGE = [-32768, -32767, -1, 0, 1, 2, 255, 256, 32766, 32767]


def rand_words(rng, shape):
    """i16-ranged int32 values with edge cases sprinkled in."""
    vals = rng.integers(-32768, 32768, size=shape).astype(np.int32)
    mask = rng.random(shape) < 0.15
    edges = rng.choice(EDGE, size=shape).astype(np.int32)
    return np.where(mask, edges, vals)


def run_both(opcode, a, b, fire, block_b=F.BLOCK_B, block_n=F.BLOCK_N):
    got = F.fabric_alu_step(
        jnp.asarray(opcode),
        jnp.asarray(a),
        jnp.asarray(b),
        jnp.asarray(fire),
        block_b=block_b,
        block_n=block_n,
    )
    want = ref.ref_step(
        jnp.asarray(opcode), jnp.asarray(a), jnp.asarray(b), jnp.asarray(fire)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    return np.asarray(got)


@pytest.mark.parametrize("opcode", range(F.N_OPCODES))
def test_each_opcode_matches_ref(opcode):
    rng = np.random.default_rng(opcode)
    B, N = F.BLOCK_B, F.BLOCK_N
    ops = np.full((N,), opcode, dtype=np.int32)
    a = rand_words(rng, (B, N))
    b = rand_words(rng, (B, N))
    fire = (rng.random((B, N)) < 0.8).astype(np.int32)
    run_both(ops, a, b, fire)


def test_results_stay_in_16_bits():
    rng = np.random.default_rng(7)
    B, N = F.BLOCK_B, F.BLOCK_N
    ops = rng.integers(0, F.N_OPCODES, size=(N,)).astype(np.int32)
    a = rand_words(rng, (B, N))
    b = rand_words(rng, (B, N))
    fire = np.ones((B, N), dtype=np.int32)
    got = run_both(ops, a, b, fire)
    assert got.min() >= -32768 and got.max() <= 32767


def test_fire_mask_zeroes_output():
    B, N = F.BLOCK_B, F.BLOCK_N
    ops = np.zeros((N,), dtype=np.int32)
    a = np.full((B, N), 7, dtype=np.int32)
    b = np.full((B, N), 9, dtype=np.int32)
    fire = np.zeros((B, N), dtype=np.int32)
    got = run_both(ops, a, b, fire)
    assert (got == 0).all()


def test_div_by_zero_and_trunc_semantics():
    # C-style truncating division, matching Rust `wrapping_div`.
    B, N = F.BLOCK_B, F.BLOCK_N
    ops = np.full((N,), F.OP_DIV, dtype=np.int32)
    a = np.zeros((B, N), dtype=np.int32)
    b = np.zeros((B, N), dtype=np.int32)
    cases = [(7, 2, 3), (-7, 2, -3), (7, -2, -3), (-7, -2, 3), (5, 0, 0), (-32768, -1, -32768)]
    for i, (x, y, want) in enumerate(cases):
        a[0, i], b[0, i] = x, y
    fire = np.ones((B, N), dtype=np.int32)
    got = run_both(ops, a, b, fire)
    for i, (_, _, want) in enumerate(cases):
        # -32768 / -1 overflows; wrap16 keeps it at -32768 like wrapping_div
        assert got[0, i] == want, f"case {i}: {got[0, i]} != {want}"


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    bmul=st.integers(1, 3),
    nmul=st.integers(1, 2),
)
def test_hypothesis_shape_sweep(seed, bmul, nmul):
    """Random shapes (multiples of the block) and random everything else."""
    rng = np.random.default_rng(seed)
    B, N = F.BLOCK_B * bmul, F.BLOCK_N * nmul
    ops = rng.integers(0, F.N_OPCODES, size=(N,)).astype(np.int32)
    a = rand_words(rng, (B, N))
    b = rand_words(rng, (B, N))
    fire = (rng.random((B, N)) < 0.5).astype(np.int32)
    run_both(ops, a, b, fire)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_hypothesis_alt_block_shapes(seed):
    """The kernel must be block-shape independent (same math, any tile)."""
    rng = np.random.default_rng(seed)
    B, N = 16, 256
    ops = rng.integers(0, F.N_OPCODES, size=(N,)).astype(np.int32)
    a = rand_words(rng, (B, N))
    b = rand_words(rng, (B, N))
    fire = (rng.random((B, N)) < 0.5).astype(np.int32)
    z1 = F.fabric_alu_step(
        jnp.asarray(ops), jnp.asarray(a), jnp.asarray(b), jnp.asarray(fire),
        block_b=8, block_n=128,
    )
    z2 = F.fabric_alu_step(
        jnp.asarray(ops), jnp.asarray(a), jnp.asarray(b), jnp.asarray(fire),
        block_b=16, block_n=256,
    )
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


def test_shift_semantics_match_rust():
    # Shl wraps, Shr is arithmetic, counts masked to 4 bits.
    B, N = F.BLOCK_B, F.BLOCK_N
    a = np.zeros((B, N), dtype=np.int32)
    b = np.zeros((B, N), dtype=np.int32)
    fire = np.ones((B, N), dtype=np.int32)
    shl = np.full((N,), F.OP_SHL, dtype=np.int32)
    cases = [(1, 16, 1), (1, 4, 16), (-1, 1, -2), (0x4000, 1, -32768)]
    for i, (x, y, _) in enumerate(cases):
        a[0, i], b[0, i] = x, y
    got = run_both(shl, a, b, fire)
    for i, (_, _, want) in enumerate(cases):
        assert got[0, i] == want, f"shl case {i}"
    shr = np.full((N,), F.OP_SHR, dtype=np.int32)
    a[0, 0], b[0, 0] = -16, 2
    got = run_both(shr, a, b, fire)
    assert got[0, 0] == -4
