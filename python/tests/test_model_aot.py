"""L2 model shape checks and the AOT export path."""

import os
import subprocess
import sys
import tempfile

import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import fabric as F
from compile.kernels import ref


def test_fabric_step_shapes():
    B, N = F.BLOCK_B, F.BLOCK_N
    op = jnp.zeros((N,), jnp.int32)
    a = jnp.ones((B, N), jnp.int32)
    b = jnp.ones((B, N), jnp.int32)
    fire = jnp.ones((B, N), jnp.int32)
    z = model.fabric_step(op, a, b, fire)
    assert z.shape == (B, N)
    assert z.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(z), 2)


def test_fabric_step_k_matches_loop():
    rng = np.random.default_rng(3)
    K, B, N = 4, F.BLOCK_B, F.BLOCK_N
    op = rng.integers(0, F.N_OPCODES, size=(N,)).astype(np.int32)
    a = rng.integers(-1000, 1000, size=(K, B, N)).astype(np.int32)
    b = rng.integers(-1000, 1000, size=(K, B, N)).astype(np.int32)
    fire = np.ones((K, B, N), dtype=np.int32)
    zs = model.fabric_step_k(jnp.asarray(op), jnp.asarray(a), jnp.asarray(b), jnp.asarray(fire))
    for k in range(K):
        want = ref.ref_step(jnp.asarray(op), jnp.asarray(a[k]), jnp.asarray(b[k]), jnp.asarray(fire[k]))
        np.testing.assert_array_equal(np.asarray(zs[k]), np.asarray(want))


def test_aot_export_emits_hlo_text():
    with tempfile.TemporaryDirectory() as d:
        name = aot.export_shape(d, 8, 128)
        path = os.path.join(d, name)
        text = open(path).read()
        assert "HloModule" in text
        assert "s32[8,128]" in text


def test_aot_cli_writes_manifest():
    with tempfile.TemporaryDirectory() as d:
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", d, "--shapes", "8x128"],
            check=True,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
        )
        manifest = open(os.path.join(d, "manifest.txt")).read().strip().splitlines()
        assert manifest == ["8 128 fabric_step_b8_n128.hlo.txt"]
        assert os.path.exists(os.path.join(d, "fabric_step_b8_n128.hlo.txt"))
