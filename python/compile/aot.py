"""AOT export: lower `fabric_step` to HLO text for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate binds) rejects; the text
parser reassigns ids and round-trips cleanly.

Usage::

    python -m compile.aot --out-dir ../artifacts \
        --shapes 8x128,64x128,8x256

Each shape BxN produces `fabric_step_b{B}_n{N}.hlo.txt` plus a
`manifest.txt` the Rust artifact registry reads (one `B N filename` row
per line).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_shape(out_dir: str, batch: int, nodes: int) -> str:
    fn = lambda op, a, b, f: (model.fabric_step(op, a, b, f),)
    lowered = jax.jit(fn).lower(*model.example_args(batch, nodes))
    text = to_hlo_text(lowered)
    name = f"fabric_step_b{batch}_n{nodes}.hlo.txt"
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    return name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes",
        default="8x128,64x128,8x256",
        help="comma-separated BxN artifact shapes",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    rows = []
    for spec in args.shapes.split(","):
        b, n = spec.lower().split("x")
        batch, nodes = int(b), int(n)
        name = export_shape(args.out_dir, batch, nodes)
        rows.append(f"{batch} {nodes} {name}")
        print(f"wrote {name}")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(rows) + "\n")
    print(f"manifest: {len(rows)} artifacts")


if __name__ == "__main__":
    main()
