"""Layer 2 — the JAX fabric-step computation graph.

`fabric_step` is the computation the Rust coordinator AOT-loads and calls
on its hot path: one synchronous tick of the whole operator fabric for a
batch of graph instances. It wraps the Layer-1 Pallas kernel
(`kernels.fabric`) so the kernel lowers into the same HLO module.

`fabric_step_k` additionally rolls K ALU ticks into one XLA call with
`lax.scan`, consuming pre-gathered operand sequences — used by the
offload benchmark to amortize host↔PJRT round trips when the coordinator
can batch several deterministic ticks (pure pipeline segments).

Python in this package runs only at build time (`make artifacts`); the
request path is pure Rust + PJRT.
"""

import jax
import jax.numpy as jnp

from .kernels import fabric


def fabric_step(opcode, a, b, fire):
    """One fabric tick. See `kernels.fabric.fabric_alu_step`."""
    return fabric.fabric_alu_step(opcode, a, b, fire)


def fabric_step_k(opcode, a_seq, b_seq, fire_seq):
    """K pre-gathered fabric ticks in one call.

    Args:
      opcode: int32[N].
      a_seq, b_seq, fire_seq: int32[K, B, N].

    Returns:
      int32[K, B, N] results, one slice per tick.
    """

    def body(carry, xs):
        a, b, fire = xs
        z = fabric.fabric_alu_step(opcode, a, b, fire)
        return carry, z

    _, zs = jax.lax.scan(body, 0, (a_seq, b_seq, fire_seq))
    return zs


def example_args(batch, nodes):
    """ShapeDtypeStructs for AOT lowering of `fabric_step`."""
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((nodes,), i32),
        jax.ShapeDtypeStruct((batch, nodes), i32),
        jax.ShapeDtypeStruct((batch, nodes), i32),
        jax.ShapeDtypeStruct((batch, nodes), i32),
    )
