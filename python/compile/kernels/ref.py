"""Pure-jnp oracle for the fabric ALU kernel.

Deliberately written without Pallas and without lane tricks: a
straightforward per-opcode computation that the kernel must match
bit-for-bit. pytest + hypothesis drive the comparison across shapes,
edge values and opcodes.
"""

import jax.numpy as jnp

from . import fabric as F


def wrap16(x):
    return ((x + 0x8000) & 0xFFFF) - 0x8000


def ref_alu(opcode, a, b):
    """Reference ALU on int32 arrays; opcode broadcasts over batch."""
    opcode = jnp.broadcast_to(opcode[None, :], a.shape)
    shift = b & 0xF
    safe_b = jnp.where(b == 0, 1, b)
    q = jnp.where(b == 0, 0, jnp.trunc(a / safe_b).astype(jnp.int32))
    out = jnp.zeros_like(a)
    table = {
        F.OP_ADD: wrap16(a + b),
        F.OP_SUB: wrap16(a - b),
        F.OP_MUL: wrap16(a * b),
        F.OP_DIV: wrap16(q),
        F.OP_AND: a & b,
        F.OP_OR: a | b,
        F.OP_XOR: a ^ b,
        F.OP_SHL: wrap16(a << shift),
        F.OP_SHR: a >> shift,
        F.OP_GT: (a > b).astype(jnp.int32),
        F.OP_GE: (a >= b).astype(jnp.int32),
        F.OP_LT: (a < b).astype(jnp.int32),
        F.OP_LE: (a <= b).astype(jnp.int32),
        F.OP_EQ: (a == b).astype(jnp.int32),
        F.OP_DF: (a != b).astype(jnp.int32),
        F.OP_NOT: wrap16(~a),
        F.OP_PASS: a,
        F.OP_CONST: a,
    }
    for code, val in table.items():
        out = jnp.where(opcode == code, val, out)
    return out


def ref_step(opcode, a, b, fire):
    """Reference for `fabric_alu_step`."""
    z = ref_alu(opcode, a, b)
    return jnp.where(fire != 0, z, 0)
