"""Layer 1 — the Pallas fabric-ALU kernel.

The paper's FPGA evaluates every operator's function unit in parallel on
each clock edge. On a TPU-shaped target that spatial parallelism becomes
SIMD batch parallelism: one fabric tick is a dense elementwise update over
a ``(batch, nodes)`` block of operator state (see DESIGN.md
§Hardware-Adaptation).

This kernel computes, for every (instance, node) slot::

    z[i, n] = fire[i, n] ? alu(opcode[n], a[i, n], b[i, n]) : 0

with 16-bit two's-complement wrap-around semantics carried in int32 lanes
(int32 is the VPU-native width; the wrap keeps numerics identical to the
Rust coordinator's ``i16`` arithmetic — property-tested on both sides).

Tiling: the grid is ``(B/BLOCK_B, N/BLOCK_N)``; each program instance
loads one ``(BLOCK_B, BLOCK_N)`` tile of ``a``/``b``/``fire`` plus the
matching ``(BLOCK_N,)`` opcode row into VMEM, applies a branch-free
``jnp.select`` over the opcode lanes (the VPU has no divergent branches;
select lanes are the TPU idiom for the paper's per-operator function
decode), and stores the result tile.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU numbers are estimated structurally in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Opcode table — must match `Op::fabric_opcode` in rust/src/dfg/op.rs.
OP_ADD = 0
OP_SUB = 1
OP_MUL = 2
OP_DIV = 3
OP_AND = 4
OP_OR = 5
OP_XOR = 6
OP_SHL = 7
OP_SHR = 8
OP_GT = 9
OP_GE = 10
OP_LT = 11
OP_LE = 12
OP_EQ = 13
OP_DF = 14
OP_NOT = 15
OP_PASS = 16
OP_CONST = 17
N_OPCODES = 18

# Default VMEM tile: 8×128 is the VPU lane layout; a (8, 128) int32 tile
# is 4 KiB, and the kernel touches 4 input tiles + 1 output tile ≈ 20 KiB
# per grid step — far under the ~16 MiB VMEM budget, leaving room for
# double-buffering (see DESIGN.md §Perf).
BLOCK_B = 8
BLOCK_N = 128


def wrap16(x):
    """Wrap an int32 lane to 16-bit two's-complement."""
    return ((x + 0x8000) & 0xFFFF) - 0x8000


def alu_lanes(opcode, a, b):
    """Branch-free ALU: compute every opcode lane, select by opcode.

    `opcode` broadcasts over the batch dimension. Shift counts are masked
    to 4 bits, division by zero yields 0, and every arithmetic result is
    wrapped to 16 bits — identical to `Op::eval2` on the Rust side.
    """
    shift = b & 0xF
    safe_b = jnp.where(b == 0, 1, b)
    # Truncating division (C semantics), not floor division.
    q = jnp.where(b == 0, 0, jnp.trunc(a / safe_b).astype(jnp.int32))
    lanes = [
        wrap16(a + b),                         # ADD
        wrap16(a - b),                         # SUB
        wrap16(a * b),                         # MUL
        wrap16(q),                             # DIV
        a & b,                                 # AND
        a | b,                                 # OR
        a ^ b,                                 # XOR
        wrap16(a << shift),                    # SHL
        a >> shift,                            # SHR (arithmetic)
        (a > b).astype(jnp.int32),             # GT
        (a >= b).astype(jnp.int32),            # GE
        (a < b).astype(jnp.int32),             # LT
        (a <= b).astype(jnp.int32),            # LE
        (a == b).astype(jnp.int32),            # EQ
        (a != b).astype(jnp.int32),            # DF
        wrap16(~a),                            # NOT
        a,                                     # PASS
        a,                                     # CONST (value pre-loaded in a)
    ]
    return jnp.select([opcode == k for k in range(N_OPCODES)], lanes, 0)


def _fabric_kernel(op_ref, a_ref, b_ref, fire_ref, z_ref):
    """Pallas kernel body: one (BLOCK_B, BLOCK_N) tile."""
    opcode = op_ref[...][None, :]  # (1, BLOCK_N) broadcast over batch
    a = a_ref[...]
    b = b_ref[...]
    fire = fire_ref[...]
    z = alu_lanes(opcode, a, b)
    z_ref[...] = jnp.where(fire != 0, z, 0)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n"))
def fabric_alu_step(opcode, a, b, fire, *, block_b=BLOCK_B, block_n=BLOCK_N):
    """One fabric ALU tick over a (batch, nodes) state block.

    Args:
      opcode: int32[N] per-node opcode (see table above).
      a, b: int32[B, N] operand registers (``dadoa``/``dadob``).
      fire: int32[B, N] fire mask (1 where the operator's FSM is in S2).

    Returns:
      int32[B, N] result registers (``dadoz``), 0 where not fired.
    """
    bsz, n = a.shape
    assert n % block_n == 0 and bsz % block_b == 0, (bsz, n, block_b, block_n)
    grid = (bsz // block_b, n // block_n)
    return pl.pallas_call(
        _fabric_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.int32),
        interpret=True,  # CPU-PJRT cannot execute Mosaic custom-calls
    )(opcode, a, b, fire)
