//! Service-tier contract tests: scheduler fairness (a light tenant is
//! never starved by a 10× heavier one), shed-path correctness (an
//! oversubmitted queue sheds explicitly and loses nothing), cache-hit
//! byte-identity (warm-session results == cold results on all seven
//! benchmarks), and loadgen determinism (same seed ⇒ same request
//! trace ⇒ same dispatch schedule).

use dataflow_accel::bench_defs::BenchId;
use dataflow_accel::fabric::FabricTopology;
use dataflow_accel::serve::{
    burst_series, execute_batch, run_profile, standard_profile, tenant_trace, Arrival,
    LoadProfile, ServeCfg, ServeOptions, ServeRequest, SessionCache, TenantSpec, WorkKind,
};

fn bench_tenant(name: &str, weight: u32, window: usize, requests: usize) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        weight,
        quota: 64,
        window,
        mix: vec![
            WorkKind::Bench(BenchId::Fibonacci),
            WorkKind::Bench(BenchId::DotProd),
        ],
        requests,
    }
}

/// Two equal-weight tenants at 10:1 offered load: both make progress
/// throughout, the light tenant is served within a bounded gap while
/// it has work, and it finishes long before the heavy one.
#[test]
fn fairness_light_tenant_is_not_starved_by_heavy_offered_load() {
    let profile = LoadProfile {
        tenants: vec![
            bench_tenant("heavy", 1, 16, 100),
            bench_tenant("light", 1, 2, 10),
        ],
        arrival: Arrival::Closed,
        n: 3,
        seed: 41,
    };
    let opts = ServeOptions {
        cfg: ServeCfg {
            queue_cap: 256,
            max_batch: 4,
            // Always dispatch-ready: this test isolates the fairness of
            // the pick, not batching slack.
            deadline_ticks: 0,
        },
        ..ServeOptions::default()
    };
    let outcome = run_profile(&profile, &opts);
    let r = &outcome.report;
    assert_eq!(r.global.lost(), 0);
    for t in &r.tenants {
        assert_eq!(t.completed + t.shed(), t.submitted, "{}", t.name);
        assert_eq!(t.verified, t.completed, "{}", t.name);
        assert!(t.completed > 0, "{} starved outright", t.name);
    }

    let light_picks: Vec<usize> = outcome
        .dispatches
        .iter()
        .enumerate()
        .filter(|(_, d)| d.tenant == 1)
        .map(|(i, _)| i)
        .collect();
    assert!(!light_picks.is_empty());
    // Starvation bound: while the light tenant has work, weighted
    // round-robin credits (weights 1:1) serve it at least once every
    // sum(weights) dispatches; allow slack for ticks where its
    // closed-loop window was momentarily empty.
    let max_gap = light_picks
        .windows(2)
        .map(|w| w[1] - w[0])
        .max()
        .unwrap_or(1);
    assert!(max_gap <= 4, "light tenant waited {max_gap} dispatches");
    // 10× offered load: the light tenant must drain well before the
    // heavy one stops dispatching.
    let last_light = *light_picks.last().unwrap();
    let last_heavy = outcome
        .dispatches
        .iter()
        .rposition(|d| d.tenant == 0)
        .unwrap();
    assert!(
        last_light < last_heavy,
        "light finished at dispatch {last_light}, heavy at {last_heavy}"
    );
}

/// Open-loop oversubscription against a tiny queue: the scheduler
/// sheds explicitly (with reasons), never silently — submitted is
/// fully accounted as completed + shed, and everything completed
/// verifies.
#[test]
fn oversubmission_sheds_explicitly_and_loses_nothing() {
    let mut heavy = bench_tenant("flood", 1, 8, 120);
    heavy.quota = 6;
    let profile = LoadProfile {
        tenants: vec![heavy],
        arrival: Arrival::Open { burst: 12 },
        n: 3,
        seed: 23,
    };
    let opts = ServeOptions {
        cfg: ServeCfg {
            queue_cap: 8,
            max_batch: 4,
            deadline_ticks: 1,
        },
        ..ServeOptions::default()
    };
    let r = run_profile(&profile, &opts).report;
    let t = &r.tenants[0];
    assert_eq!(t.submitted, 120);
    assert!(t.shed() > 0, "oversubmission must shed");
    assert_eq!(t.completed + t.shed(), t.submitted, "no silent drops");
    assert_eq!(r.global.lost(), 0);
    assert_eq!(t.verified, t.completed);
    assert!(r.max_queue_depth <= 8, "queue bound violated");
}

/// Warm-session results are byte-identical to cold results on all
/// seven benchmarks (and a random DFG), and the warm run skips
/// compile/place — observable as cache hits with no new misses.
#[test]
fn warm_session_results_are_byte_identical_to_cold() {
    let kinds: Vec<WorkKind> = BenchId::ALL
        .iter()
        .map(|&b| WorkKind::Bench(b))
        .chain([WorkKind::Saxpy, WorkKind::Random { branchy: true }])
        .collect();
    let cache = SessionCache::new(FabricTopology::serving(), 2, 32);
    for (k, kind) in kinds.iter().enumerate() {
        // Seeds stride by 5 so `Random` requests stay in one
        // graph-family slot (one batch = one graph) while workloads
        // differ.
        let reqs: Vec<ServeRequest> = (0..4)
            .map(|i| ServeRequest {
                tenant: 0,
                seq: i,
                kind: *kind,
                n: 4,
                seed: (k * 10 + i * 5) as u64,
            })
            .collect();
        let misses_before = cache.misses();
        let cold = execute_batch(&cache, &reqs);
        assert_eq!(
            cache.misses(),
            misses_before + 1,
            "{kind:?}: cold run must compile/place once"
        );
        let warm = execute_batch(&cache, &reqs);
        assert!(warm.cache_hit, "{kind:?}: second run must be warm");
        assert_eq!(
            cache.misses(),
            misses_before + 1,
            "{kind:?}: warm run must skip compile/place"
        );
        assert_eq!(cold.engine, warm.engine, "{kind:?}");
        assert_eq!(cold.outcomes.len(), warm.outcomes.len());
        for (i, (c, w)) in cold.outcomes.iter().zip(&warm.outcomes).enumerate() {
            assert_eq!(c.outputs, w.outputs, "{kind:?} item {i}: warm != cold");
        }
        assert!(
            cold.verified.iter().all(|&v| v),
            "{kind:?} failed verification on {}",
            cold.engine
        );
    }
    assert!(cache.hits() >= kinds.len() as u64);
}

/// A benchmark mix on an undersized fabric serves through the resident
/// sharded rack (and the single-instance pool through the reconfig
/// scheduler) — and still verifies everything.
#[test]
fn undersized_fabrics_serve_sharded_and_reconfig() {
    // Sized against the *optimized* graph — what the session cache
    // actually routes — so the placed path stays unreachable.
    let g = dataflow_accel::optimize(
        &dataflow_accel::bench_defs::build(BenchId::DotProd),
        dataflow_accel::OptLevel::Default,
    )
    .0;
    let topo = FabricTopology::sized_for_shards(&g, 2);
    let mut tenant = bench_tenant("t", 1, 4, 12);
    tenant.mix = vec![WorkKind::Bench(BenchId::DotProd)];
    let profile = LoadProfile {
        tenants: vec![tenant],
        arrival: Arrival::Closed,
        n: 4,
        seed: 5,
    };
    for (pool_size, engine) in [(4usize, "sharded"), (1usize, "reconfig")] {
        let opts = ServeOptions {
            topo: topo.clone(),
            pool_size,
            ..ServeOptions::default()
        };
        let r = run_profile(&profile, &opts).report;
        assert_eq!(r.global.lost(), 0);
        assert_eq!(r.global.verified, r.global.completed, "pool {pool_size}");
        assert_eq!(
            r.global.engine_requests.get(engine).copied().unwrap_or(0),
            r.global.completed,
            "pool {pool_size} must serve via {engine}: {:?}",
            r.global.engine_requests
        );
    }
}

/// Same seed ⇒ same request trace, and — because scheduling is driven
/// by virtual ticks, not wall time — the same dispatch schedule.
#[test]
fn loadgen_and_schedule_are_deterministic() {
    let profile = standard_profile(6, 4, 99);
    for t in 0..profile.tenants.len() {
        assert_eq!(tenant_trace(&profile, t), tenant_trace(&profile, t));
    }
    let a = run_profile(&profile, &ServeOptions::default());
    let b = run_profile(&profile, &ServeOptions::default());
    assert_eq!(a.dispatches, b.dispatches, "dispatch schedule diverged");
    assert_eq!(a.report.global.submitted, b.report.global.submitted);
    assert_eq!(a.report.global.completed, b.report.global.completed);
    assert_eq!(a.report.global.shed(), b.report.global.shed());
    assert_eq!(a.report.cache_misses, b.report.cache_misses);

    let other = standard_profile(6, 4, 100);
    assert_ne!(
        tenant_trace(&profile, 0),
        tenant_trace(&other, 0),
        "different seeds must offer different traces"
    );
}

/// The standard three-tenant profile (the CLI/CI mix) drains cleanly:
/// zero lost requests, everything verified, warm sessions reused, and
/// every tenant's percentiles populated.
#[test]
fn standard_profile_serves_mixed_tenants_end_to_end() {
    let profile = standard_profile(8, 4, 7);
    let r = run_profile(&profile, &ServeOptions::default()).report;
    assert_eq!(r.global.submitted, 8 * 4 + 8 * 2 + 8);
    assert_eq!(r.global.lost(), 0);
    assert_eq!(r.global.verified, r.global.completed);
    assert!(r.cache_hits > 0, "repeat tenants must hit warm sessions");
    // Distinct graphs: 6 benchmarks + saxpy + ≤ 10 random-DFG family
    // members — misses stay far below the batch count.
    assert!(r.cache_misses <= 17, "misses {}", r.cache_misses);
    for t in &r.tenants {
        assert!(t.completed > 0, "{}", t.name);
        assert!(t.latency.p50_ns() > 0, "{}", t.name);
        assert!(t.latency.p99_ns() >= t.latency.p50_ns(), "{}", t.name);
    }
    assert!(
        r.global.engine_requests.contains_key("lanes"),
        "loop benchmarks take the lane engine: {:?}",
        r.global.engine_requests
    );
    let engine_total: u64 = r.global.engine_requests.values().sum();
    assert_eq!(engine_total, r.global.completed);
}

/// A tenant offering only the pipelineable SAXPY workload is served by
/// the pipelined resident session (the Fig. 1c case) whenever a batch
/// has anything to overlap.
#[test]
fn pipelineable_tenant_takes_the_resident_streamed_session() {
    let profile = LoadProfile {
        tenants: vec![TenantSpec {
            name: "pipeline".to_string(),
            weight: 1,
            quota: 32,
            window: 4,
            mix: vec![WorkKind::Saxpy],
            requests: 12,
        }],
        arrival: Arrival::Closed,
        n: 4,
        seed: 13,
    };
    let r = run_profile(&profile, &ServeOptions::default()).report;
    assert_eq!(r.global.lost(), 0);
    assert_eq!(r.global.verified, r.global.completed);
    let streamed = r
        .global
        .engine_requests
        .get("streamed")
        .copied()
        .unwrap_or(0);
    // Every multi-wave batch overlaps; at most a size-1 straggler may
    // run-to-completion on the lane engine instead.
    assert!(
        streamed >= r.global.completed - 1,
        "streamed {streamed} of {}: {:?}",
        r.global.completed,
        r.global.engine_requests
    );
}

/// Optimizer integration with the warm-state cache (the serve tier
/// optimizes by default): the key is (pre-optimization fingerprint,
/// OptLevel) — the same raw submission hits across repeats even though
/// the cached graph is the optimized one, a pre-optimized submission
/// is different content (its own entry), and changing the level is a
/// miss, never a silent mismatch.
#[test]
fn opt_level_and_pre_opt_fingerprint_form_the_cache_key() {
    use dataflow_accel::{frontend, optimize, OptLevel};
    let cache = SessionCache::new(FabricTopology::serving(), 2, 32);
    let raw = frontend::compile_with(
        "fibonacci",
        dataflow_accel::bench_defs::c_source(BenchId::Fibonacci),
        OptLevel::None,
    )
    .unwrap();

    let (cold, hit) = cache.warm(&raw);
    assert!(!hit);
    assert_eq!(cold.fingerprint, raw.fingerprint());
    assert!(
        cold.graph.n_nodes() < raw.n_nodes(),
        "the cache must store the optimized graph"
    );
    let (warm, hit) = cache.warm(&raw);
    assert!(hit, "same raw submission, same pre-opt fingerprint: hit");
    assert_eq!(warm.fingerprint, cold.fingerprint);

    // Submitting the already-optimized content is a different key.
    let og = optimize(&raw, OptLevel::Default).0;
    let (opt_state, hit) = cache.warm(&og);
    assert!(!hit, "optimized content has its own fingerprint");
    assert_eq!(opt_state.fingerprint, og.fingerprint());

    // Same graph, different level: a miss with its own entry.
    let (agg, hit) = cache.warm_at(&raw, OptLevel::Aggressive);
    assert!(!hit, "changing OptLevel must be a cache miss");
    assert_eq!(agg.fingerprint, raw.fingerprint());
    assert_eq!(agg.opt_level, OptLevel::Aggressive);
    let (_, hit) = cache.warm_at(&raw, OptLevel::Aggressive);
    assert!(hit);

    // Warm == cold byte-identity with optimization on, through the
    // public batch executor (fibonacci requests resolve to the same
    // benchmark graph the cache already warmed raw — a distinct hint,
    // so this exercises a separate entry end to end).
    let reqs: Vec<ServeRequest> = (0..3)
        .map(|i| ServeRequest {
            tenant: 0,
            seq: i,
            kind: WorkKind::Bench(BenchId::Fibonacci),
            n: 5,
            seed: i as u64,
        })
        .collect();
    let cold = execute_batch(&cache, &reqs);
    let warm = execute_batch(&cache, &reqs);
    assert!(warm.cache_hit);
    for (c, w) in cold.outcomes.iter().zip(&warm.outcomes) {
        assert_eq!(c.outputs, w.outputs, "warm != cold under optimization");
    }
    assert!(cold.verified.iter().all(|&v| v));
}

/// Parallel dispatch reproduces the serial service tier exactly: the
/// same dispatch schedule, the same per-request result digests, and
/// the same counters at every worker count — the invariant the
/// `serve --scale-workers` sweep enforces before writing SERVE_6.json.
#[test]
fn parallel_dispatch_is_byte_identical_across_worker_counts() {
    let profile = standard_profile(6, 4, 77);
    let base = run_profile(&profile, &ServeOptions::default());
    assert_eq!(base.report.workers, 1);
    assert!(!base.digests.is_empty());
    assert_eq!(
        base.digests.len() as u64,
        base.report.global.completed,
        "one digest per completed request"
    );
    for workers in [2usize, 4] {
        let opts = ServeOptions {
            workers,
            ..ServeOptions::default()
        };
        let par = run_profile(&profile, &opts);
        assert_eq!(par.report.workers, workers);
        assert_eq!(
            par.dispatches, base.dispatches,
            "{workers} workers: dispatch schedule diverged"
        );
        assert_eq!(
            par.digests, base.digests,
            "{workers} workers: results diverged from serial"
        );
        assert_eq!(par.report.global.submitted, base.report.global.submitted);
        assert_eq!(par.report.global.completed, base.report.global.completed);
        assert_eq!(par.report.global.shed(), base.report.global.shed());
        assert_eq!(par.report.global.verified, base.report.global.verified);
        assert_eq!(par.report.tokens_out, base.report.tokens_out);
        assert_eq!(par.report.global.lost(), 0);
    }
}

/// The open-loop burst-series ramp is deterministic end to end: same
/// seed ⇒ same trace, schedule, and result digests, serial and
/// parallel — and the invariants (nothing lost, everything verified)
/// hold under the ramped offered load.
#[test]
fn burst_series_profile_is_deterministic_serial_and_parallel() {
    let mut profile = standard_profile(6, 4, 55);
    profile.arrival = burst_series(4);
    let a = run_profile(&profile, &ServeOptions::default());
    let b = run_profile(&profile, &ServeOptions::default());
    assert_eq!(a.dispatches, b.dispatches, "same-seed schedule diverged");
    assert_eq!(a.digests, b.digests, "same-seed results diverged");
    assert_eq!(a.report.global.lost(), 0);
    assert_eq!(a.report.global.verified, a.report.global.completed);
    let par = run_profile(
        &profile,
        &ServeOptions {
            workers: 4,
            ..ServeOptions::default()
        },
    );
    assert_eq!(par.dispatches, a.dispatches, "parallel schedule diverged");
    assert_eq!(par.digests, a.digests, "parallel results diverged");
}
