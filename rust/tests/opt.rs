//! Optimizer unit suite: each pass's targeted before/after graph
//! shapes, report bookkeeping, and the rewrites the pipeline must
//! *refuse* (div-by-power-of-two, identity elision — both rate or
//! rounding changes in this word semantics). The cross-engine
//! differential obligations live in `rust/tests/conformance.rs`.

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::dfg::{Graph, GraphBuilder, Op};
use dataflow_accel::frontend;
use dataflow_accel::opt::{optimize, run_pass, OptLevel};
use dataflow_accel::sim::{run_token, SimConfig};

fn census(g: &Graph, op: &str) -> usize {
    g.op_census().get(op).copied().unwrap_or(0)
}

#[test]
fn fold_consts_collapses_a_constant_subgraph() {
    // (3 + 4) * x over a const chain: the add folds to const 7; the
    // chain is exact (one token per reset, before and after).
    let mut b = GraphBuilder::new("t");
    let c3 = b.constant(3);
    let c4 = b.constant(4);
    let s = b.op2(Op::Add, c3, c4);
    let x = b.input_port("x");
    let z = b.output_port("z");
    b.node(Op::Mul, &[s, x], &[z]);
    let g = b.finish().unwrap();

    let (opt, stats) = run_pass(&g, "fold-consts");
    assert_eq!(census(&opt, "add"), 0);
    assert_eq!(census(&opt, "const"), 1);
    assert_eq!(opt.n_nodes(), g.n_nodes() - 2);
    assert_eq!(stats.nodes_delta, -2);
    assert_eq!(stats.arcs_delta, -2);
    let cfg = SimConfig::new().inject("x", vec![6]);
    assert_eq!(run_token(&opt, &cfg).stream("z"), &[42]);
}

#[test]
fn fold_consts_cascades_through_chains() {
    // not(3 > 4) folds in two rounds of the pass's own fixpoint.
    let mut b = GraphBuilder::new("t");
    let c3 = b.constant(3);
    let c4 = b.constant(4);
    let d = b.op2(Op::IfGt, c3, c4);
    let n = b.node(Op::Not, &[d], &[]);
    let nd = b.out_arc(n, 0);
    let x = b.input_port("x");
    let z = b.output_port("z");
    b.node(Op::And, &[nd, x], &[z]);
    let g = b.finish().unwrap();

    let (opt, stats) = run_pass(&g, "fold-consts");
    assert_eq!(opt.n_nodes(), 2, "const + and survive");
    assert_eq!(stats.applications, 2, "decider fold then not fold");
    let cfg = SimConfig::new().inject("x", vec![-1]);
    // !(3>4) = !0 = -1 (bitwise not of 0x0000); -1 & -1 = -1.
    assert_eq!(run_token(&opt, &cfg).stream("z"), &[-1]);
}

#[test]
fn copy_chain_of_length_k_collapses_to_zero() {
    let mut b = GraphBuilder::new("t");
    let a = b.input_port("a");
    let mut cur = a;
    for _ in 0..4 {
        let (next, _spill) = b.copy(cur); // spill dangles anonymously
        cur = next;
    }
    let z = b.output_port("z");
    b.node(Op::Not, &[cur], &[z]);
    let g = b.finish().unwrap();
    assert_eq!(census(&g, "copy"), 4);

    let (opt, stats) = run_pass(&g, "elide-copies");
    assert_eq!(census(&opt, "copy"), 0);
    assert_eq!(opt.n_nodes(), 1);
    assert_eq!(stats.applications, 4);
    assert_eq!(stats.nodes_delta, -4);
    assert_eq!(stats.arcs_delta, -8);
    assert!(opt.arc_by_name("a").is_some());
    assert!(opt.arc_by_name("z").is_some());
    let cfg = SimConfig::new().inject("a", vec![0]);
    assert_eq!(run_token(&opt, &cfg).stream("z"), &[-1]);
}

#[test]
fn port_to_port_repeater_copy_is_not_elided() {
    // in -> copy -> out: the copy is the only node; eliding it would
    // leave a disconnected pin pair. The pipeline must keep it.
    let mut b = GraphBuilder::new("t");
    let a = b.input_port("a");
    let n = b.node(Op::Copy, &[a], &[]);
    let out = b.out_arc(n, 0);
    b.rename_arc(out, "z");
    let g = b.finish().unwrap();
    let (opt, report) = optimize(&g, OptLevel::Aggressive);
    assert_eq!(census(&opt, "copy"), 1);
    assert!(!report.changed());
    let cfg = SimConfig::new().inject("a", vec![5, 6]);
    assert_eq!(run_token(&opt, &cfg).stream("z"), &[5, 6]);
}

#[test]
fn cse_merges_duplicate_pure_nodes() {
    // x fanned to two `x + 5` computations (distinct const nodes, as
    // the frontend would emit them): aggressive CSE keeps one add and
    // fans its result; cleanup collects the orphaned operand tree.
    let mut b = GraphBuilder::new("t");
    let x = b.input_port("x");
    let (x1, x2) = b.copy(x);
    let c1 = b.constant(5);
    let c2 = b.constant(5);
    let z0 = b.output_port("z0");
    let z1 = b.output_port("z1");
    b.node(Op::Add, &[x1, c1], &[z0]);
    b.node(Op::Add, &[c2, x2], &[z1]); // operands swapped on purpose
    let g = b.finish().unwrap();

    let (opt, report) = optimize(&g, OptLevel::Aggressive);
    assert_eq!(census(&opt, "add"), 1, "duplicate add must merge");
    assert_eq!(census(&opt, "const"), 1, "orphaned const collected");
    assert_eq!(census(&opt, "copy"), 1, "one fan-out copy remains");
    assert_eq!(opt.n_nodes(), 3);
    assert!(report.passes.iter().any(|p| p.name == "cse" && p.applications > 0));
    let cfg = SimConfig::new().inject("x", vec![37]);
    let out = run_token(&opt, &cfg);
    assert_eq!(out.stream("z0"), &[42]);
    assert_eq!(out.stream("z1"), &[42]);

    // Default level never runs CSE.
    let (def, report) = optimize(&g, OptLevel::Default);
    assert_eq!(census(&def, "add"), 2);
    assert!(report.passes.iter().all(|p| p.name != "cse"));
}

#[test]
fn dce_removes_a_dead_branch_arm() {
    // branch TRUE arm reaches the named output; the FALSE arm feeds a
    // `not` whose result dangles anonymously — dead, removable.
    let mut b = GraphBuilder::new("t");
    let ctl = b.input_port("ctl");
    let data = b.input_port("data");
    let br = b.node(Op::Branch, &[ctl, data], &[]);
    let t_arm = b.out_arc(br, 0);
    let f_arm = b.out_arc(br, 1);
    let z = b.output_port("z");
    b.node(Op::Not, &[t_arm], &[z]);
    b.node(Op::Not, &[f_arm], &[]); // dead arm; output dangles
    let g = b.finish().unwrap();

    let (opt, stats) = run_pass(&g, "dce");
    assert_eq!(census(&opt, "not"), 1);
    assert_eq!(stats.nodes_delta, -1);
    assert_eq!(opt.n_nodes(), g.n_nodes() - 1);
    // The branch itself stays (it still routes), its false output
    // dangling as an anonymous drain.
    assert_eq!(census(&opt, "branch"), 1);
    let cfg = SimConfig::new()
        .inject("ctl", vec![1, 0, 1])
        .inject("data", vec![1, 2, 3]);
    assert_eq!(run_token(&opt, &cfg).stream("z"), &[-2, -4]);
}

#[test]
fn dce_keeps_port_fed_sinks() {
    // A port-fed drain chain must survive: deleting it would leave the
    // input port as a disconnected pin that *echoes* injections.
    let mut b = GraphBuilder::new("t");
    let a = b.input_port("a");
    b.node(Op::Not, &[a], &[]); // drains `a`, result dangles
    let x = b.input_port("x");
    let z = b.output_port("z");
    b.node(Op::Not, &[x], &[z]);
    let g = b.finish().unwrap();
    let (opt, _) = optimize(&g, OptLevel::Aggressive);
    assert_eq!(census(&opt, "not"), 2, "port-fed sink survives");
    let cfg = SimConfig::new().inject("a", vec![1]).inject("x", vec![2]);
    let out = run_token(&opt, &cfg);
    assert_eq!(out.stream("z"), &[-3]);
    assert!(out.stream("a").is_empty(), "no echo of `a` injections");
}

#[test]
fn strength_reduces_mul_by_power_of_two_only() {
    let build = |k: i16, op: Op| {
        let mut b = GraphBuilder::new("t");
        let x = b.input_port("x");
        let c = b.constant(k);
        let z = b.output_port("z");
        b.node(op, &[x, c], &[z]);
        b.finish().unwrap()
    };
    // mul by 8 → shl by 3, value-exact including negatives and wrap.
    let g = build(8, Op::Mul);
    let (opt, stats) = run_pass(&g, "strength");
    assert_eq!(census(&opt, "mul"), 0);
    assert_eq!(census(&opt, "shl"), 1);
    assert_eq!(stats.rewrites, 1);
    assert_eq!(stats.nodes_delta, 0);
    for x in [0i16, 1, -1, 5, -4097, i16::MAX, i16::MIN] {
        let cfg = SimConfig::new().inject("x", vec![x]);
        assert_eq!(
            run_token(&opt, &cfg).stream("z"),
            &[x.wrapping_mul(8)],
            "x={x}"
        );
    }
    // mul by 3 is untouched.
    let g = build(3, Op::Mul);
    assert_eq!(census(&run_pass(&g, "strength").0, "mul"), 1);
    // div by 2 must NOT become shr: wrapping_div truncates toward
    // zero, shr floors — they disagree on negative odd dividends.
    let g = build(2, Op::Div);
    let (opt, _) = optimize(&g, OptLevel::Aggressive);
    assert_eq!(census(&opt, "div"), 1);
    assert_eq!(census(&opt, "shr"), 0);
    let cfg = SimConfig::new().inject("x", vec![-3]);
    assert_eq!(run_token(&opt, &cfg).stream("z"), &[-1], "-3/2 truncates");
}

#[test]
fn strength_handles_const_in_either_operand_slot() {
    // 2 * x (const first) swaps operands before rewriting to shl.
    let mut b = GraphBuilder::new("t");
    let c = b.constant(2);
    let x = b.input_port("x");
    let z = b.output_port("z");
    b.node(Op::Mul, &[c, x], &[z]);
    let g = b.finish().unwrap();
    let (opt, _) = run_pass(&g, "strength");
    assert_eq!(census(&opt, "shl"), 1);
    let cfg = SimConfig::new().inject("x", vec![-7]);
    assert_eq!(run_token(&opt, &cfg).stream("z"), &[-14]);
}

#[test]
fn identity_ops_are_not_elided() {
    // `x + 0` pairs ONE const token with ONE x token — it is a
    // one-shot gate, not a wire. Rewriting it away would change how
    // many tokens flow. The pipeline must keep the add.
    let mut b = GraphBuilder::new("t");
    let x = b.input_port("x");
    let c = b.constant(0);
    let z = b.output_port("z");
    b.node(Op::Add, &[x, c], &[z]);
    let g = b.finish().unwrap();
    let (opt, _) = optimize(&g, OptLevel::Aggressive);
    assert_eq!(census(&opt, "add"), 1);
    let cfg = SimConfig::new().inject("x", vec![7, 8, 9]);
    let out = run_token(&opt, &cfg);
    assert_eq!(out.stream("z"), &[7], "one const token = one pairing");
    assert!(!out.quiescent, "later x tokens strand, as in the raw graph");
}

#[test]
fn canonicalize_masks_shift_counts() {
    let mut b = GraphBuilder::new("t");
    let x = b.input_port("x");
    let c = b.constant(17); // & 0xf == 1
    let z = b.output_port("z");
    b.node(Op::Shl, &[x, c], &[z]);
    let g = b.finish().unwrap();
    let (opt, stats) = run_pass(&g, "canonicalize");
    assert_eq!(stats.rewrites, 1);
    let konst = opt
        .nodes
        .iter()
        .find_map(|n| match n.op {
            Op::Const(v) => Some(v),
            _ => None,
        })
        .unwrap();
    assert_eq!(konst, 1);
    let cfg = SimConfig::new().inject("x", vec![3]);
    assert_eq!(run_token(&opt, &cfg).stream("z"), &[6]);
}

#[test]
fn report_counts_match_the_structural_diff() {
    for level in [OptLevel::Default, OptLevel::Aggressive] {
        for bench in BenchId::ALL {
            let g = frontend::compile_with(
                bench.slug(),
                bench_defs::c_source(bench),
                OptLevel::None,
            )
            .unwrap();
            let (opt, report) = optimize(&g, level);
            let pass_nodes: i64 = report.passes.iter().map(|p| p.nodes_delta).sum();
            let pass_arcs: i64 = report.passes.iter().map(|p| p.arcs_delta).sum();
            assert_eq!(
                -pass_nodes,
                report.nodes_removed(),
                "{} @ {level}: node bookkeeping",
                bench.slug()
            );
            assert_eq!(
                -pass_arcs,
                report.arcs_removed(),
                "{} @ {level}: arc bookkeeping",
                bench.slug()
            );
            assert_eq!(report.nodes_after, opt.n_nodes());
            assert_eq!(report.arcs_after, opt.n_arcs());
        }
    }
}

#[test]
fn optimize_none_is_the_identity_and_unknown_pass_panics() {
    let g = bench_defs::build(BenchId::Max);
    let (o, report) = optimize(&g, OptLevel::None);
    assert_eq!(dataflow_accel::asm::print(&o), dataflow_accel::asm::print(&g));
    assert!(!report.changed());
    let err = std::panic::catch_unwind(|| run_pass(&g, "no-such-pass"));
    assert!(err.is_err());
}
