//! Cross-module integration tests: the full C → graph → {asm, VHDL,
//! simulation, estimation, offload} pipeline, plus property tests over
//! randomly generated programs and graphs.

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::frontend::{self, interpret, lex, parse_program};
use dataflow_accel::sim::{run_dynamic, run_fsm, run_token, SimConfig, TokenSim};
use dataflow_accel::util::proptest::{check, PropCfg};
use dataflow_accel::util::Rng;
use dataflow_accel::{asm, estimate, vhdl};

/// Every benchmark, full chain: C → graph → asm → graph → sim, compared
/// against the interpreter and the hand-built graph on three engines.
#[test]
fn full_chain_every_benchmark() {
    for b in BenchId::ALL {
        let src = bench_defs::c_source(b);
        let g = frontend::compile(b.slug(), src).unwrap();

        // asm round trip preserves structure and semantics
        let text = asm::print(&g);
        let g2 = asm::parse(b.slug(), &text).unwrap();
        assert_eq!(g.n_nodes(), g2.n_nodes());

        // VHDL generates deterministically
        let d1 = vhdl::generate(&g).render();
        let d2 = vhdl::generate(&g).render();
        assert_eq!(d1, d2);

        // workload agreement: interpreter == token == fsm == dynamic
        let wl = bench_defs::workload(b, 7, 99);
        let prog = parse_program(&lex(src).unwrap()).unwrap();
        let interp = interpret(&prog, &wl.inject, 10_000_000).unwrap();
        let mut cfg = wl.sim_config();
        cfg.max_cycles *= 8;
        let tok = run_token(&g2, &cfg);
        let fsm = run_fsm(&g2, &cfg);
        let dy = run_dynamic(&g2, &cfg, 2);
        for (port, want) in &wl.expect {
            assert_eq!(interp.outputs.get(port), Some(want), "{} interp", b.slug());
            assert_eq!(tok.stream(port), want.as_slice(), "{} token", b.slug());
            assert_eq!(fsm.stream(port), want.as_slice(), "{} fsm", b.slug());
            assert_eq!(dy.stream(port), want.as_slice(), "{} dynamic", b.slug());
        }
    }
}

/// Property: random straight-line expression programs — interpreter and
/// dataflow lowering agree bit-for-bit.
#[test]
fn prop_random_expression_programs() {
    fn gen_expr(r: &mut Rng, depth: usize, vars: &[&str]) -> String {
        if depth == 0 || r.below(4) == 0 {
            match r.below(3) {
                0 => format!("{}", r.word(-100, 100)),
                _ => vars[r.below(vars.len())].to_string(),
            }
        } else {
            let ops = ["+", "-", "*", "/", "&", "|", "^", "<<", ">>", "<", ">", "=="];
            let op = ops[r.below(ops.len())];
            format!(
                "({} {} {})",
                gen_expr(r, depth - 1, vars),
                op,
                gen_expr(r, depth - 1, vars)
            )
        }
    }

    check(
        "random expression programs: interp == dataflow",
        PropCfg {
            cases: 40,
            base_seed: 0xC0FFEE,
        },
        |r| {
            let e1 = gen_expr(r, 3, &["a", "b"]);
            let e2 = gen_expr(r, 2, &["a", "b", "t"]);
            let src = format!(
                "in int a;\nin int b;\nout int r;\nint t = {e1};\nr = {e2};\n"
            );
            let a = r.word(-500, 500);
            let b = r.word(-500, 500);
            (src, a, b)
        },
        |(src, a, b)| {
            let g = frontend::compile("prop", src).map_err(|e| e.to_string())?;
            let prog = parse_program(&lex(src).unwrap()).unwrap();
            let mut inject = std::collections::BTreeMap::new();
            inject.insert("a".to_string(), vec![*a]);
            inject.insert("b".to_string(), vec![*b]);
            let want = interpret(&prog, &inject, 100_000)
                .map_err(|e| e.to_string())?
                .outputs["r"]
                .clone();
            let cfg = SimConfig::new().inject("a", vec![*a]).inject("b", vec![*b]);
            let got = run_token(&g, &cfg);
            if got.stream("r") != want.as_slice() {
                return Err(format!("dataflow {:?} != interp {:?}", got.stream("r"), want));
            }
            Ok(())
        },
    );
}

/// Property: random counted-loop programs with an accumulator and an
/// if/else in the body.
#[test]
fn prop_random_loop_programs() {
    check(
        "random loop programs: interp == dataflow",
        PropCfg {
            cases: 20,
            base_seed: 0xBEEF,
        },
        |r| {
            let add = r.word(1, 20);
            let mul = r.word(2, 5);
            let thr = r.word(-50, 50);
            let n = r.word(0, 12);
            let src = format!(
                "in int n;\nout int r;\nint acc = 0;\nint i = 0;\n\
                 while (i < n) {{\n\
                   if (acc > {thr}) {{ acc = acc - {add}; }} else {{ acc = acc * {mul} + {add}; }}\n\
                   i = i + 1;\n\
                 }}\nr = acc;\n"
            );
            (src, n)
        },
        |(src, n)| {
            let g = frontend::compile("prop_loop", src).map_err(|e| e.to_string())?;
            let prog = parse_program(&lex(src).unwrap()).unwrap();
            let mut inject = std::collections::BTreeMap::new();
            inject.insert("n".to_string(), vec![*n]);
            let want = interpret(&prog, &inject, 1_000_000)
                .map_err(|e| e.to_string())?
                .outputs["r"]
                .clone();
            let cfg = SimConfig::new()
                .inject("n", vec![*n])
                .max_cycles(2_000_000);
            let got = run_token(&g, &cfg);
            if got.stream("r") != want.as_slice() {
                return Err(format!(
                    "n={n}: dataflow {:?} != interp {:?}",
                    got.stream("r"),
                    want
                ));
            }
            Ok(())
        },
    );
}

/// Property: token conservation in the fast engine — the number of
/// tokens in flight never exceeds arcs, and outputs are produced only
/// while tokens exist.
#[test]
fn prop_token_occupancy_bounded() {
    check(
        "token occupancy ≤ arcs",
        PropCfg {
            cases: 12,
            base_seed: 0xA11CE,
        },
        |r| {
            let b = BenchId::ALL[r.below(6)];
            (b, 2 + r.below(8), r.next_u64())
        },
        |&(b, n, seed)| {
            let g = bench_defs::build(b);
            let wl = bench_defs::workload(b, n, seed);
            let cfg = wl.sim_config();
            let mut sim = TokenSim::new(&g, &cfg);
            for _ in 0..20_000 {
                sim.step();
                if sim.occupancy() > g.n_arcs() {
                    return Err(format!(
                        "{}: occupancy {} > arcs {}",
                        b.slug(),
                        sim.occupancy(),
                        g.n_arcs()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Property: the dynamic engine with any bound reproduces static results
/// on every benchmark (the paper's future-work extension is semantics-
/// preserving).
#[test]
fn prop_dynamic_bound_semantics_preserving() {
    check(
        "dynamic(k) == static for all k",
        PropCfg {
            cases: 12,
            base_seed: 0xD1CE,
        },
        |r| {
            let b = BenchId::ALL[r.below(6)];
            (b, 2 + r.below(6), r.next_u64(), 1 + r.below(8))
        },
        |&(b, n, seed, bound)| {
            let g = bench_defs::build(b);
            let wl = bench_defs::workload(b, n, seed);
            let cfg = wl.sim_config();
            let stat = run_token(&g, &cfg);
            let dy = run_dynamic(&g, &cfg, bound);
            if stat.outputs != dy.outputs {
                return Err(format!("{} bound {bound} diverged", b.slug()));
            }
            Ok(())
        },
    );
}

/// Resource model sanity across every benchmark + the paper's headline
/// cross-system orderings (Fig. 8 narrative).
#[test]
fn estimates_reproduce_fig8_narrative() {
    use dataflow_accel::baselines::{ctv, kernel_spec, lalp};
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let ours = estimate::estimate(&g);
        let spec = kernel_spec(b);
        let c = ctv::estimate(&spec);
        // (1) max frequency: ours beats both baselines on every benchmark
        assert!(ours.fmax_mhz > c.fmax_mhz, "{}", b.slug());
        if let Some(l) = lalp::estimate(&spec) {
            assert!(ours.fmax_mhz > l.fmax_mhz, "{}", b.slug());
            // (2) LALP smallest
            assert!(l.ff < c.ff && l.lut < c.lut, "{}", b.slug());
        }
        // (3) ours ≈ 613 MHz, flat across benchmarks (paper's signature)
        assert!((560.0..660.0).contains(&ours.fmax_mhz), "{}", b.slug());
    }
}

/// Offloaded batch execution equals per-instance execution for every
/// benchmark (native ALU; the XLA path has its own tests in-module).
#[test]
fn batch_engine_matches_singletons() {
    use dataflow_accel::coordinator::run_batch_native;
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let cfgs: Vec<_> = (0..4)
            .map(|s| bench_defs::workload(b, 3 + s, s as u64).sim_config())
            .collect();
        let batch = run_batch_native(&g, &cfgs);
        for (i, cfg) in cfgs.iter().enumerate() {
            assert_eq!(
                batch[i].outputs,
                run_token(&g, cfg).outputs,
                "{} #{i}",
                b.slug()
            );
        }
    }
}
