//! Serve-tier robustness conformance.
//!
//! Two contracts, checked end to end from outside the crate:
//!
//! * **Accounting is exact everywhere**: `completed + shed ==
//!   submitted` and `lost == 0`, per tenant and globally, across every
//!   arrival mode (closed loop, open-loop burst, ramping burst series)
//!   and at every dispatch worker count — a request is either served or
//!   explicitly shed, never silently dropped.
//! * **The chaos gate holds under injected faults**: a seeded
//!   [`FaultPlan`](dataflow_accel::fabric::FaultPlan) with slot, bus
//!   and whole-fabric outage events recovers every in-flight request
//!   (migration, retry or lattice demotion) with output digests
//!   byte-identical to the fault-free baseline.

use dataflow_accel::dfg::OpClass;
use dataflow_accel::fabric::{FaultEvent, FaultKind, FaultPlan};
use dataflow_accel::report::ChaosGate;
use dataflow_accel::serve::{
    burst_series, fairness_profile, run_profile, run_profile_chaos, tenant_trace, Arrival,
    ServeCfg, ServeOptions, ServeReport,
};

fn assert_exact(label: &str, report: &ServeReport) {
    for t in &report.tenants {
        assert_eq!(t.lost(), 0, "{label}: tenant `{}` lost requests", t.name);
        assert_eq!(
            t.completed + t.shed(),
            t.submitted,
            "{label}: tenant `{}` accounting",
            t.name
        );
    }
    let g = &report.global;
    assert_eq!(g.lost(), 0, "{label}: global lost");
    assert_eq!(g.completed + g.shed(), g.submitted, "{label}: global accounting");
}

/// Satellite conformance matrix: `completed + shed == submitted` and
/// `lost == 0` under Closed, Open-burst and BurstSeries arrivals, at
/// worker counts 1 and 2 — and the per-request digest map is identical
/// across worker counts (the dispatch schedule never reads execution
/// results, so parallelism cannot change what was served).
#[test]
fn accounting_is_exact_across_arrival_modes_and_worker_counts() {
    let arrivals: [(&str, Arrival); 3] = [
        ("closed", Arrival::Closed),
        ("open-burst", Arrival::Open { burst: 4 }),
        ("burst-series", burst_series(2)),
    ];
    for (mode, arrival) in arrivals {
        let mut serial_digests = None;
        for workers in [1usize, 2] {
            let label = format!("{mode} @ {workers} worker(s)");
            let mut profile = fairness_profile(2, 5, 0xACC7);
            profile.arrival = arrival;
            let offered: u64 = (0..profile.tenants.len())
                .map(|t| tenant_trace(&profile, t).len() as u64)
                .sum();
            let opts = ServeOptions {
                workers,
                ..ServeOptions::default()
            };
            let out = run_profile(&profile, &opts);
            assert_exact(&label, &out.report);
            assert_eq!(
                out.report.global.submitted, offered,
                "{label}: submitted != offered trace"
            );
            assert!(out.report.global.completed > 0, "{label}: nothing completed");
            match &serial_digests {
                None => serial_digests = Some(out.digests),
                Some(serial) => assert_eq!(
                    &out.digests, serial,
                    "{label}: digest map diverged from the serial run"
                ),
            }
        }
    }
}

/// The accounting contract survives injected faults, in every arrival
/// mode: a seeded plan (≥1 slot fail, ≥1 bus fail, ≥1 outage) loses
/// nothing and serves byte-identical outputs to the fault-free
/// baseline of the *same* arrival mode.
#[test]
fn chaos_accounting_and_digests_hold_across_arrival_modes() {
    let arrivals: [(&str, Arrival); 3] = [
        ("closed", Arrival::Closed),
        ("open-burst", Arrival::Open { burst: 4 }),
        ("burst-series", burst_series(2)),
    ];
    for (mode, arrival) in arrivals {
        let mut profile = fairness_profile(2, 5, 0xFA_0175);
        profile.arrival = arrival;
        let opts = ServeOptions::default();
        let plan = FaultPlan::seeded(29, opts.pool_size);
        let baseline = run_profile_chaos(&profile, &opts, &FaultPlan::empty());
        let faulted = run_profile_chaos(&profile, &opts, &plan);
        assert_exact(&format!("chaos {mode}"), &faulted.report);
        assert!(
            faulted.chaos.faults_injected() >= 3,
            "chaos {mode}: plan under-injected"
        );
        assert_eq!(
            faulted.output_digests, baseline.output_digests,
            "chaos {mode}: outputs diverged from the fault-free baseline"
        );
    }
}

/// Hand-built plan (PR 10 regression): slot and bus quarantines whose
/// **repair overlaps a whole-instance outage window**, on a pool of
/// ONE instance — there is nowhere to migrate, so every batch due
/// inside the window must park on the retry schedule and drain after
/// the repair. The window closes with a same-tick fault + wholesale
/// `Repair` pair: the chronological replay fixed in this PR folds the
/// co-scheduled faults first and the technician's repair last, so the
/// tick-5 view is fully healthy. The pre-fix fold (push-order ties,
/// outage-only probe) left the probe blind to the overlapping slot and
/// bus state and re-dispatched into a degraded instance.
#[test]
fn repairs_overlapping_an_outage_window_on_one_instance_lose_nothing() {
    let profile = fairness_profile(2, 5, 0x0B5E);
    // Small batches spread the heavy tenant's dispatches across enough
    // ticks that the outage window (3..5) actually catches traffic.
    let opts = ServeOptions {
        pool_size: 1,
        cfg: ServeCfg { max_batch: 4, ..ServeCfg::default() },
        ..ServeOptions::default()
    };
    let plan = FaultPlan::new(vec![
        // Degrade in layers: slots, then buses, then the instance dark.
        FaultEvent {
            tick: 1,
            instance: 0,
            kind: FaultKind::SlotFail { class: OpClass::Alu2, count: 64 },
        },
        FaultEvent {
            tick: 2,
            instance: 0,
            kind: FaultKind::BusFail { channels: 64 },
        },
        FaultEvent { tick: 3, instance: 0, kind: FaultKind::Outage },
        // The window closes on a same-tick pile-up: two more faults and
        // the wholesale repair, all at tick 5. Canonical order replays
        // the faults first, the repair last.
        FaultEvent {
            tick: 5,
            instance: 0,
            kind: FaultKind::SlotFail { class: OpClass::Alu1, count: 64 },
        },
        FaultEvent {
            tick: 5,
            instance: 0,
            kind: FaultKind::BusFail { channels: 64 },
        },
        FaultEvent { tick: 5, instance: 0, kind: FaultKind::Repair },
    ]);
    // The pure replay agrees with the schedule: degraded-but-up before
    // the outage, dark inside it, fully healthy once the repair lands.
    assert!(plan.healthy_at(2, 0));
    assert!(plan.health_at(2, 0).is_degraded());
    assert!(!plan.healthy_at(3, 0));
    assert!(!plan.healthy_at(4, 0));
    assert!(
        plan.healthy_at(5, 0) && !plan.health_at(5, 0).is_degraded(),
        "same-tick Repair must fold after the co-scheduled faults"
    );
    let c = plan.counts();
    assert!(c.slot == 2 && c.bus == 2 && c.outage == 1 && c.repair == 1, "census: {c:?}");

    let baseline = run_profile_chaos(&profile, &opts, &FaultPlan::empty());
    let faulted = run_profile_chaos(&profile, &opts, &plan);
    assert_exact("overlap-repair", &faulted.report);
    assert_eq!(faulted.chaos.faults_injected(), 5, "every scheduled fault applied");
    assert_eq!(faulted.chaos.repairs, 1);
    assert_eq!(
        faulted.output_digests, baseline.output_digests,
        "outputs diverged from the fault-free baseline"
    );
}

/// End-to-end chaos gate, exactly as `serve --chaos` evaluates it:
/// fault census complete, zero lost, accounting exact, digests match.
#[test]
fn chaos_gate_passes_end_to_end_on_the_fairness_profile() {
    let profile = fairness_profile(2, 6, 11);
    let opts = ServeOptions::default();
    let plan = FaultPlan::seeded(11, opts.pool_size);
    let baseline = run_profile_chaos(&profile, &opts, &FaultPlan::empty());
    let faulted = run_profile_chaos(&profile, &opts, &plan);
    let gate = ChaosGate::check(&plan, &faulted, &baseline);
    assert!(gate.passed(), "gate failures: {:?}", gate.failures());
    let c = plan.counts();
    assert!(c.slot >= 1 && c.bus >= 1 && c.outage >= 1, "census: {c:?}");
    assert_eq!(faulted.report.global.lost(), 0);
}
