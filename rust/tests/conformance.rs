//! Differential conformance harness.
//!
//! One semantics, many executors: `TokenSim`, `FsmSim`, `DynamicSim`,
//! the streaming tier (`StreamSession`, pipelined and serialized), the
//! sharded executor and the time-multiplexed executor must all produce
//! identical output streams. This harness checks them against each
//! other on:
//!
//! * seeded **random DFGs** from the generator in `util::proptest`
//!   (covering `const`, `fifo #k`, `dmerge`/`branch` routing and
//!   `build_loop` branch/merge loops), and
//! * the six paper benchmarks under multi-wave streamed injection, and
//! * the **lane engine** (`Program` + `LaneSim`): per-lane output
//!   streams byte-identical to `TokenSim` on all seven benchmarks (the
//!   six loop schemas plus SAXPY) and on random DFGs, including ragged
//!   multi-word chunks (up to `MAX_LANES` = 256 lanes per chunk),
//!   per-lane deadlock containment, the batch router's lanes→placed
//!   fallback, and superinstruction **fusion**: programs compiled with
//!   fused chains produce outcomes byte-identical to unfused programs
//!   on every suite graph and on random pipeline DFGs.
//!
//! Every property is replayable from the seed in its failure message.
//! CI runs the same properties as a fixed-seed smoke subset by setting
//! `PROPTEST_CASES` (see `.github/workflows/ci.yml`).

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::coordinator::{
    run_batch_lanes_par, run_batch_lanes_prog, run_batch_sharded, run_batch_sharded_par,
    run_batch_sstream_par,
};
use dataflow_accel::dfg::is_anon_label;
use dataflow_accel::fabric::{self, FabricTopology};
use dataflow_accel::frontend;
use dataflow_accel::opt::{self, optimize, OptLevel};
use dataflow_accel::par::Executor;
use dataflow_accel::sim::{
    run_dynamic, run_fsm, run_lanes, run_stream, run_stream_lanes, run_token, Program, SimConfig,
    StreamCheckpoint, StreamSession, WaveInput, WaveMode, MAX_LANES,
};
use dataflow_accel::util::proptest::{
    check, random_dfg, random_dfg_with, random_workload, GenCfg, GenGraph, PropCfg,
};
use dataflow_accel::util::Rng;
use dataflow_accel::Graph;
use std::collections::BTreeMap;

fn config_for(wl: &BTreeMap<String, Vec<i16>>, max_cycles: u64) -> SimConfig {
    let mut cfg = SimConfig::new().max_cycles(max_cycles);
    for (p, s) in wl {
        cfg = cfg.inject(p, s.clone());
    }
    cfg
}

/// TokenSim == FsmSim == DynamicSim(k) == streamed (single serialized
/// wave) on random DFGs with `const`s, `fifo #k`s and branch/merge
/// loops, under single-token streams.
///
/// Why single-token streams and no free `dmerge`/`branch`: `FsmSim`'s
/// latched input registers and `DynamicSim`'s deeper queues are extra
/// arc capacity. On workloads that strand tokens behind a `copy`, that
/// slack legally admits extra firings, so only *quiescing* cases define
/// a cross-engine contract (unit-rate ops + the balanced loop schema
/// quiesce by construction; the capacity-identical comparisons below
/// cover arbitrary stranding).
#[test]
fn prop_engines_agree_on_random_dfgs() {
    check(
        "TokenSim == FsmSim == DynamicSim == streamed",
        PropCfg::from_env(48, 0xD1FF_C0DE),
        |r: &mut Rng| {
            let gg = random_dfg_with(
                r,
                GenCfg {
                    routing: false,
                    loops: true,
                    consts: true,
                },
            );
            let wl = random_workload(r, &gg, 1);
            let bound = 1 + r.below(4);
            (gg, wl, bound)
        },
        |(gg, wl, bound): &(GenGraph, BTreeMap<String, Vec<i16>>, usize)| {
            let g = &gg.graph;
            let cfg = config_for(wl, 200_000);
            let tok = run_token(g, &cfg);

            let mut fsm_cfg = cfg.clone();
            fsm_cfg.max_cycles *= 4;
            let fsm = run_fsm(g, &fsm_cfg);
            if fsm.outputs != tok.outputs {
                return Err(format!(
                    "FsmSim diverged: {:?} != {:?}",
                    fsm.outputs, tok.outputs
                ));
            }

            let dy = run_dynamic(g, &cfg, *bound);
            if dy.outputs != tok.outputs {
                return Err(format!(
                    "DynamicSim(bound={bound}) diverged: {:?} != {:?}",
                    dy.outputs, tok.outputs
                ));
            }

            let (outs, metrics) = run_stream(g, std::slice::from_ref(wl), cfg.max_cycles);
            if outs[0].outputs != tok.outputs {
                return Err(format!(
                    "streamed diverged: {:?} != {:?}",
                    outs[0].outputs, tok.outputs
                ));
            }
            if metrics.tag_stalls != 0 {
                return Err(format!("tag stalls on a single wave: {}", metrics.tag_stalls));
            }
            Ok(())
        },
    );
}

/// Serialized multi-wave streaming == running each wave alone, on
/// random branchy DFGs (waves may strand tokens; the session's
/// wave-boundary reset must still isolate them).
#[test]
fn prop_serialized_waves_match_isolated_runs_on_random_dfgs() {
    check(
        "serialized waves == isolated TokenSim runs",
        PropCfg::from_env(32, 0x5E71A1),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let n_waves = 2 + r.below(3);
            let waves: Vec<BTreeMap<String, Vec<i16>>> = (0..n_waves)
                .map(|_| random_workload(r, &gg, 1 + r.below(3)))
                .collect();
            (gg, waves)
        },
        |(gg, waves): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>)| {
            let g = &gg.graph;
            let mut session = StreamSession::with_mode(g, WaveMode::Serialized);
            for w in waves {
                session.admit(w).map_err(|e| e.to_string())?;
            }
            session.run(200_000 * waves.len() as u64);
            for (i, w) in waves.iter().enumerate() {
                let alone = run_token(g, &config_for(w, 200_000));
                if session.wave_outputs(i as u32) != &alone.outputs {
                    return Err(format!(
                        "wave {i}: streamed {:?} != isolated {:?}",
                        session.wave_outputs(i as u32),
                        alone.outputs
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Pipelined (overlapping) streaming == running each wave alone, on
/// random unit-rate pipeline DFGs — and the overlap must not be slower
/// than run-to-completion.
#[test]
fn prop_pipelined_waves_match_isolated_runs_and_win_throughput() {
    check(
        "pipelined waves == isolated runs, streamed rounds <= r2c rounds",
        PropCfg::from_env(32, 0xF10_11E),
        |r: &mut Rng| {
            let gg = random_dfg(r, false);
            let len = 1 + r.below(3);
            let n_waves = 3 + r.below(4);
            let waves: Vec<BTreeMap<String, Vec<i16>>> = (0..n_waves)
                .map(|_| random_workload(r, &gg, len))
                .collect();
            (gg, waves)
        },
        |(gg, waves): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>)| {
            let g = &gg.graph;
            if !dataflow_accel::sim::overlap_safe(g) {
                return Err("pipeline generator produced a non-overlap-safe graph".into());
            }
            let mut r2c_cycles = 0u64;
            let mut isolated = Vec::new();
            for w in waves {
                let out = run_token(g, &config_for(w, 200_000));
                r2c_cycles += out.cycles;
                isolated.push(out);
            }
            let (outs, metrics) = run_stream(g, waves, 200_000 * waves.len() as u64);
            if metrics.waves_completed as usize != waves.len() {
                return Err(format!(
                    "only {}/{} waves completed",
                    metrics.waves_completed,
                    waves.len()
                ));
            }
            for (i, alone) in isolated.iter().enumerate() {
                if outs[i].outputs != alone.outputs {
                    return Err(format!(
                        "wave {i}: streamed {:?} != isolated {:?}",
                        outs[i].outputs, alone.outputs
                    ));
                }
            }
            if metrics.tag_stalls != 0 {
                return Err(format!("tag stalls: {}", metrics.tag_stalls));
            }
            if waves.len() >= 3 && metrics.rounds > r2c_cycles {
                return Err(format!(
                    "streamed makespan {} rounds > run-to-completion {}",
                    metrics.rounds, r2c_cycles
                ));
            }
            Ok(())
        },
    );
}

/// All six paper benchmarks, multi-wave streamed injection through one
/// resident session: per-wave output streams byte-identical to running
/// each wave alone through whole-graph TokenSim.
#[test]
fn streamed_waves_match_isolated_runs_on_all_benchmarks() {
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let wls = bench_defs::wave_workloads(b, 4, 4, 0xBEE5);
        let waves: Vec<WaveInput> = wls.iter().map(|w| w.inject.clone()).collect();
        let budget: u64 = wls.iter().map(|w| w.max_cycles).sum();
        let (outs, metrics) = run_stream(&g, &waves, budget);
        assert_eq!(
            metrics.waves_completed as usize,
            waves.len(),
            "{}: waves incomplete",
            b.slug()
        );
        for (i, wl) in wls.iter().enumerate() {
            let alone = run_token(&g, &wl.sim_config());
            assert_eq!(
                outs[i].outputs,
                alone.outputs,
                "{} wave {i}: streamed != isolated",
                b.slug()
            );
            for (port, want) in &wl.expect {
                assert_eq!(
                    outs[i].stream(port),
                    want.as_slice(),
                    "{} wave {i} port `{port}`",
                    b.slug()
                );
            }
        }
    }
}

/// Streamed injection through the sharded and reconfig executors agrees
/// with whole-graph TokenSim per wave on every benchmark.
#[test]
fn streamed_fabric_executors_match_whole_graph() {
    let mut rng = Rng::new(0xFAB_57B);
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = fabric::partition(&g, &topo).unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
        let wls: Vec<_> = (0..3)
            .map(|_| bench_defs::workload(b, 1 + rng.below(5), rng.next_u64()))
            .collect();
        let waves: Vec<WaveInput> = wls.iter().map(|w| w.inject.clone()).collect();
        let budget = wls.iter().map(|w| w.max_cycles).max().unwrap();

        let sharded = fabric::run_sharded_waves(&plan, &waves, budget);
        let (reconf, _stats) = fabric::run_reconfig_waves(&plan, &topo, &waves, budget);
        for (i, wl) in wls.iter().enumerate() {
            let whole = run_token(&g, &wl.sim_config());
            assert_eq!(
                sharded[i].outputs,
                whole.outputs,
                "{} wave {i}: sharded-streamed != whole",
                b.slug()
            );
            assert_eq!(
                reconf[i].outputs,
                whole.outputs,
                "{} wave {i}: reconfig-streamed != whole",
                b.slug()
            );
        }
    }
}

/// The streamed coordinator batch path equals the run-to-completion
/// batch path per request.
#[test]
fn streamed_batch_path_matches_run_to_completion() {
    use dataflow_accel::coordinator::{run_batch_native, run_batch_streamed};
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let cfgs: Vec<_> = (0..3)
            .map(|s| bench_defs::workload(b, 2 + s, 40 + s as u64).sim_config())
            .collect();
        let native = run_batch_native(&g, &cfgs);
        let streamed = run_batch_streamed(&g, &cfgs);
        for i in 0..cfgs.len() {
            assert_eq!(streamed[i].outputs, native[i].outputs, "{} #{i}", b.slug());
        }
    }
}

/// Print → parse round-trip on every random-generated graph (not just
/// the six benchmarks): the printed assembler re-parses to a graph with
/// identical structure and identical behaviour, and print∘parse is a
/// fixpoint.
#[test]
fn prop_asm_roundtrip_on_random_dfgs() {
    check(
        "asm print -> parse round-trip on random DFGs",
        PropCfg::from_env(48, 0xA5B_C0DE),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let wl = random_workload(r, &gg, 1 + r.below(3));
            (gg, wl)
        },
        |(gg, wl): &(GenGraph, BTreeMap<String, Vec<i16>>)| {
            let g = &gg.graph;
            let text = dataflow_accel::asm::print(g);
            let g2 = dataflow_accel::asm::parse(&g.name, &text)
                .map_err(|e| format!("re-parse failed: {e}\n{text}"))?;
            if g2.n_nodes() != g.n_nodes() || g2.n_arcs() != g.n_arcs() {
                return Err(format!(
                    "shape changed: {}x{} -> {}x{}",
                    g.n_nodes(),
                    g.n_arcs(),
                    g2.n_nodes(),
                    g2.n_arcs()
                ));
            }
            let text2 = dataflow_accel::asm::print(&g2);
            if text2 != text {
                return Err("print∘parse is not a fixpoint".into());
            }
            let cfg = config_for(wl, 200_000);
            let a = run_token(g, &cfg);
            let b = run_token(&g2, &cfg);
            if a.outputs != b.outputs {
                return Err(format!(
                    "round-tripped graph diverged: {:?} != {:?}",
                    b.outputs, a.outputs
                ));
            }
            Ok(())
        },
    );
}

/// The lane engine against the scalar engine, item by item, on all
/// seven benchmarks — the six loop schemas exercise the snapshot-round
/// path (branch/dmerge/ndmerge control divergence resolved per lane),
/// SAXPY exercises the topo ripple fast path.
#[test]
fn lane_engine_matches_token_on_all_seven_benchmarks() {
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let prog = Program::compile(&g);
        let wls: Vec<_> = (0..6)
            .map(|i| bench_defs::workload(b, 2 + i, 90 + i as u64))
            .collect();
        let cfgs: Vec<SimConfig> = wls.iter().map(|w| w.sim_config()).collect();
        let outs = run_lanes(&prog, &cfgs);
        for (i, wl) in wls.iter().enumerate() {
            let alone = run_token(&g, &cfgs[i]);
            assert_eq!(
                outs[i].outputs,
                alone.outputs,
                "{} item {i}: lanes != scalar",
                b.slug()
            );
            for (port, want) in &wl.expect {
                assert_eq!(
                    outs[i].stream(port),
                    want.as_slice(),
                    "{} item {i} port `{port}`",
                    b.slug()
                );
            }
        }
    }
    // The seventh: SAXPY through the topo fast path.
    let g = bench_defs::saxpy::build();
    let prog = Program::compile(&g);
    assert!(prog.topo.is_some(), "saxpy must take the topo fast path");
    let pairs = bench_defs::saxpy::waves(6, 5, 0x5A);
    let cfgs: Vec<SimConfig> = pairs
        .iter()
        .map(|(w, _)| {
            let mut c = SimConfig::new();
            for (p, s) in w {
                c = c.inject(p, s.clone());
            }
            c
        })
        .collect();
    let outs = run_lanes(&prog, &cfgs);
    for (i, (_, expect)) in pairs.iter().enumerate() {
        assert_eq!(outs[i].stream("z"), expect.as_slice(), "saxpy item {i}");
        assert_eq!(
            outs[i].outputs,
            run_token(&g, &cfgs[i]).outputs,
            "saxpy item {i} vs scalar"
        );
    }
}

/// Lane == scalar on random DFGs (branch/dmerge routing, consts, fifos,
/// loop schemas) under multi-item batches.
#[test]
fn prop_lane_engine_matches_token_on_random_dfgs() {
    check(
        "LaneSim == TokenSim per item on random DFGs",
        PropCfg::from_env(48, 0x1A9E_C0DE),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let n_items = 1 + r.below(7);
            let wls: Vec<BTreeMap<String, Vec<i16>>> = (0..n_items)
                .map(|_| random_workload(r, &gg, 1 + r.below(3)))
                .collect();
            (gg, wls)
        },
        |(gg, wls): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>)| {
            let g = &gg.graph;
            let prog = Program::compile(g);
            let cfgs: Vec<SimConfig> = wls.iter().map(|w| config_for(w, 200_000)).collect();
            let outs = run_lanes(&prog, &cfgs);
            for (i, cfg) in cfgs.iter().enumerate() {
                let alone = run_token(g, cfg);
                if outs[i].outputs != alone.outputs {
                    return Err(format!(
                        "item {i}: lanes {:?} != scalar {:?}",
                        outs[i].outputs, alone.outputs
                    ));
                }
            }
            // The lane-backed serialized stream path must agree too.
            let streamed = run_stream_lanes(g, wls, 200_000);
            for (i, cfg) in cfgs.iter().enumerate() {
                let alone = run_token(g, cfg);
                if streamed[i].outputs != alone.outputs {
                    return Err(format!(
                        "wave {i}: lane stream {:?} != scalar {:?}",
                        streamed[i].outputs, alone.outputs
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Ragged chunking: batches at every occupancy-mask word boundary — a
/// singleton, exactly one 64-bit mask word, one word plus a ragged
/// second, a full 256-lane multi-word chunk, and a chunk-and-a-bit —
/// stay item-exact.
#[test]
fn lane_batches_survive_ragged_final_chunks() {
    use dataflow_accel::coordinator::run_batch_lanes_with_stats;
    let b = BenchId::VectorSum;
    let g = bench_defs::build(b);
    for items in [1usize, 64, 70, 129, MAX_LANES, MAX_LANES + 6] {
        let wls: Vec<_> = (0..items)
            .map(|i| bench_defs::workload(b, 1 + i % 3, i as u64))
            .collect();
        let cfgs: Vec<SimConfig> = wls.iter().map(|w| w.sim_config()).collect();
        let (outs, stats) = run_batch_lanes_with_stats(&g, &cfgs);
        assert_eq!(outs.len(), items);
        assert_eq!(stats.chunks, items.div_ceil(MAX_LANES), "items={items}");
        for (i, wl) in wls.iter().enumerate() {
            let alone = run_token(&g, &cfgs[i]);
            assert_eq!(outs[i].outputs, alone.outputs, "items={items} #{i}");
            for (port, want) in &wl.expect {
                assert_eq!(outs[i].stream(port), want.as_slice(), "items={items} #{i}");
            }
        }
    }
}

/// One deadlocked lane must not stall its siblings, and the batch-level
/// lanes→scalar fallback must hand the stuck item back byte-identical
/// to a scalar run under its own budget.
#[test]
fn lane_deadlock_is_contained_and_falls_back_to_scalar() {
    use dataflow_accel::coordinator::run_batch_lanes_with_stats;
    use dataflow_accel::dfg::{GraphBuilder, Op};
    let mut b = GraphBuilder::new("adder");
    let a = b.input_port("a");
    let x = b.input_port("b");
    let z = b.output_port("z");
    b.node(Op::Add, &[a, x], &[z]);
    let g = b.finish().unwrap();
    let prog = Program::compile(&g);

    let mut cfgs: Vec<SimConfig> = (0..10)
        .map(|i| {
            SimConfig::new()
                .inject("a", vec![i as i16])
                .inject("b", vec![100])
        })
        .collect();
    // Lane 4 deadlocks: `b` never arrives.
    cfgs[4] = SimConfig::new().inject("a", vec![7]).max_cycles(50);

    let outs = run_lanes(&prog, &cfgs);
    for (i, out) in outs.iter().enumerate() {
        if i == 4 {
            assert_eq!(out.stream("z"), &[] as &[i16]);
            assert!(!out.quiescent, "stuck lane must not report quiescence");
        } else {
            assert_eq!(out.stream("z"), &[100 + i as i16], "sibling lane {i}");
            assert!(out.quiescent, "sibling lane {i} stalled by the stuck lane");
        }
    }

    let (fb, stats) = run_batch_lanes_with_stats(&g, &cfgs);
    assert_eq!(stats.scalar_reruns, 1);
    for (i, cfg) in cfgs.iter().enumerate() {
        assert_eq!(fb[i].outputs, run_token(&g, cfg).outputs, "item {i}");
    }
}

/// The lane-backed serialized stream path equals both the resident
/// serialized session and isolated runs, per wave, on every benchmark.
#[test]
fn lane_stream_path_matches_serialized_session_on_all_benchmarks() {
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let wls = bench_defs::wave_workloads(b, 4, 3, 0x1A9E);
        let waves: Vec<WaveInput> = wls.iter().map(|w| w.inject.clone()).collect();
        let budget = wls.iter().map(|w| w.max_cycles).max().unwrap();
        let lanes = run_stream_lanes(&g, &waves, budget);
        let mut session = StreamSession::with_mode(&g, WaveMode::Serialized);
        for w in &waves {
            session.admit(w).unwrap();
        }
        session.run(budget.saturating_mul(waves.len() as u64));
        for (i, wl) in wls.iter().enumerate() {
            let alone = run_token(&g, &wl.sim_config());
            assert_eq!(
                lanes[i].outputs,
                alone.outputs,
                "{} wave {i}: lane stream != isolated",
                b.slug()
            );
            assert_eq!(
                &lanes[i].outputs,
                session.wave_outputs(i as u32),
                "{} wave {i}: lane stream != serialized session",
                b.slug()
            );
        }
    }
}

/// Superinstruction fusion is invisible to outcomes: programs compiled
/// with fused chains reproduce the unfused programs' outcomes — output
/// streams, firings, quiescence — item by item on all 13 suite graphs
/// (the cyclic schemas compile to zero chains, so the comparison there
/// pins down that fusion never misfires on the snapshot path; SAXPY
/// and the other acyclic graphs exercise real chains).
#[test]
fn fused_programs_match_unfused_on_suite_graphs() {
    let mut chained = 0usize;
    for (name, g, cfgs) in par_suite(12) {
        let fused = Program::compile(&g);
        let unfused = Program::compile_unfused(&g);
        chained += usize::from(fused.n_chains() > 0);
        let (f_outs, f_stats) = run_batch_lanes_prog(&g, &fused, &cfgs);
        let (u_outs, u_stats) = run_batch_lanes_prog(&g, &unfused, &cfgs);
        assert_eq!(
            f_stats.scalar_reruns, u_stats.scalar_reruns,
            "{name}: fallback accounting diverged"
        );
        // Outputs, firings and quiescence must match exactly; pass
        // counts may not (a fused chain buffers less internally than
        // its members did, which is allowed to shift in-flight timing).
        for (i, cfg) in cfgs.iter().enumerate() {
            assert_eq!(f_outs[i].outputs, u_outs[i].outputs, "{name} #{i}: outputs");
            assert_eq!(f_outs[i].firings, u_outs[i].firings, "{name} #{i}: firings");
            assert_eq!(
                f_outs[i].quiescent, u_outs[i].quiescent,
                "{name} #{i}: quiescence"
            );
            let alone = run_token(&g, cfg);
            assert_eq!(f_outs[i].outputs, alone.outputs, "{name} #{i}: vs scalar");
        }
    }
    assert!(chained >= 1, "no suite graph produced a fused chain");
}

/// Fused == unfused == scalar on seeded random *pipeline* DFGs (the
/// acyclic unit-rate family where fusion actually forms chains), under
/// multi-item batches.
#[test]
fn prop_fused_matches_unfused_on_random_pipelines() {
    check(
        "fused program == unfused program on random pipeline DFGs",
        PropCfg::from_env(32, 0xF05E_D0DE),
        |r: &mut Rng| {
            let gg = random_dfg(r, false);
            let n_items = 1 + r.below(7);
            let wls: Vec<BTreeMap<String, Vec<i16>>> = (0..n_items)
                .map(|_| random_workload(r, &gg, 1 + r.below(3)))
                .collect();
            (gg, wls)
        },
        |(gg, wls): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>)| {
            let g = &gg.graph;
            let fused = Program::compile(g);
            let unfused = Program::compile_unfused(g);
            let cfgs: Vec<SimConfig> = wls.iter().map(|w| config_for(w, 200_000)).collect();
            let f_outs = run_lanes(&fused, &cfgs);
            let u_outs = run_lanes(&unfused, &cfgs);
            for i in 0..cfgs.len() {
                if f_outs[i].outputs != u_outs[i].outputs
                    || f_outs[i].quiescent != u_outs[i].quiescent
                {
                    return Err(format!(
                        "item {i}: fused {:?} != unfused {:?}",
                        f_outs[i], u_outs[i]
                    ));
                }
                if f_outs[i].quiescent && f_outs[i].firings != u_outs[i].firings {
                    return Err(format!(
                        "item {i}: firings {} != {} at quiescence",
                        f_outs[i].firings, u_outs[i].firings
                    ));
                }
                let alone = run_token(g, &cfgs[i]);
                if f_outs[i].outputs != alone.outputs {
                    return Err(format!(
                        "item {i}: fused {:?} != scalar {:?}",
                        f_outs[i].outputs, alone.outputs
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Multi-word lane widths: one LaneSim chunk at every occupancy-mask
/// word boundary (1, 63, 64, 65, 128, 129, 256 lanes) reproduces the
/// scalar engine item by item — fused program, SAXPY's topo path plus
/// a cyclic schema's snapshot path.
#[test]
fn lane_widths_across_mask_word_boundaries_match_scalar() {
    // SAXPY: acyclic, fused, topo ripple.
    let g = bench_defs::saxpy::build();
    let prog = Program::compile(&g);
    for width in [1usize, 63, 64, 65, 128, 129, MAX_LANES] {
        let pairs = bench_defs::saxpy::waves(width, 3, 0x77AD + width as u64);
        let cfgs: Vec<SimConfig> = pairs
            .iter()
            .map(|(w, _)| {
                let mut c = SimConfig::new();
                for (p, s) in w {
                    c = c.inject(p, s.clone());
                }
                c
            })
            .collect();
        let outs = run_lanes(&prog, &cfgs);
        assert_eq!(outs.len(), width);
        for (i, (_, expect)) in pairs.iter().enumerate() {
            assert_eq!(outs[i].stream("z"), expect.as_slice(), "width={width} #{i}");
            let alone = run_token(&g, &cfgs[i]);
            assert_eq!(outs[i].outputs, alone.outputs, "width={width} #{i}");
        }
    }
    // Fibonacci: cyclic, snapshot rounds, per-lane loop trip counts.
    let b = BenchId::Fibonacci;
    let g = bench_defs::build(b);
    let prog = Program::compile(&g);
    for width in [63usize, 65, 129] {
        let wls: Vec<_> = (0..width)
            .map(|i| bench_defs::workload(b, 1 + i % 5, i as u64))
            .collect();
        let cfgs: Vec<SimConfig> = wls.iter().map(|w| w.sim_config()).collect();
        let outs = run_lanes(&prog, &cfgs);
        for (i, wl) in wls.iter().enumerate() {
            let alone = run_token(&g, &cfgs[i]);
            assert_eq!(
                outs[i].outputs,
                alone.outputs,
                "{} width={width} #{i}",
                b.slug()
            );
            for (port, want) in &wl.expect {
                assert_eq!(outs[i].stream(port), want.as_slice(), "width={width} #{i}");
            }
        }
    }
}

/// The dynamic engine agrees with the static engine on random DFGs for
/// every queue bound (extends the per-benchmark seed property to
/// generated graphs; quiescing cases, see `prop_engines_agree_*`).
#[test]
fn prop_dynamic_bounds_agree_on_random_dfgs() {
    check(
        "DynamicSim(k) == TokenSim on random DFGs",
        PropCfg::from_env(24, 0xD1_CE2),
        |r: &mut Rng| {
            let gg = random_dfg_with(
                r,
                GenCfg {
                    routing: false,
                    loops: true,
                    consts: true,
                },
            );
            let wl = random_workload(r, &gg, 1);
            (gg, wl)
        },
        |(gg, wl): &(GenGraph, BTreeMap<String, Vec<i16>>)| {
            let g = &gg.graph;
            let cfg = config_for(wl, 200_000);
            let tok = run_token(g, &cfg);
            for bound in [1usize, 2, 8] {
                let dy = run_dynamic(g, &cfg, bound);
                if dy.outputs != tok.outputs {
                    return Err(format!("bound {bound} diverged"));
                }
            }
            Ok(())
        },
    );
}

// ---- optimizer pass-level differential harness -------------------------
//
// The optimizer's contract (DESIGN.md §9): for every pass individually
// *and* the full pipeline, on every execution that quiesces on the raw
// graph, the streams collected at **named** output ports are
// byte-identical between the raw and the optimized graph under every
// engine, and the named external port set is preserved exactly.
// Anonymous `sN` dangles are drain wires the optimizer may remove, so
// they are excluded from the comparison; non-quiescing executions are
// excluded because buffer-capacity changes (a copy is a one-place
// buffer) are only unobservable at quiescence — the same boundary the
// cross-engine contract above draws (`prop_engines_agree_*`).
//
// Everything here is named `opt_*` so CI's `opt-smoke` job can run
// exactly this subset (`cargo test --test conformance opt_`).

/// Every standalone pass plus the two pipelines.
const OPT_TRANSFORMS: [&str; 8] = [
    "canonicalize",
    "fold-consts",
    "strength",
    "elide-copies",
    "cse",
    "dce",
    "pipeline:default",
    "pipeline:aggressive",
];

fn apply_transform(g: &Graph, t: &str) -> Graph {
    match t {
        "pipeline:default" => optimize(g, OptLevel::Default).0,
        "pipeline:aggressive" => optimize(g, OptLevel::Aggressive).0,
        pass => opt::run_pass(g, pass).0,
    }
}

fn named_streams(outputs: &BTreeMap<String, Vec<i16>>) -> BTreeMap<&str, &Vec<i16>> {
    outputs
        .iter()
        .filter(|(k, _)| !is_anon_label(k))
        .map(|(k, v)| (k.as_str(), v))
        .collect()
}

/// The 13-graph suite: the seven hand-built benchmark graphs (six
/// paper loop schemas + SAXPY) and the six frontend-lowered raw forms,
/// each with one deterministic workload.
fn opt_suite() -> Vec<(String, Graph, SimConfig)> {
    let mut suite = Vec::new();
    for b in BenchId::ALL {
        let wl = bench_defs::workload(b, 4, 9);
        suite.push((
            format!("built:{}", b.slug()),
            bench_defs::build(b),
            wl.sim_config(),
        ));
        let raw = frontend::compile_with(b.slug(), bench_defs::c_source(b), OptLevel::None)
            .unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
        let mut cfg = wl.sim_config();
        cfg.max_cycles *= 4;
        suite.push((format!("lowered:{}", b.slug()), raw, cfg));
    }
    let (inject, _z) = bench_defs::saxpy::wave(5, 9);
    let mut cfg = SimConfig::new().max_cycles(200_000);
    for (p, s) in &inject {
        cfg = cfg.inject(p, s.clone());
    }
    suite.push(("built:saxpy".to_string(), bench_defs::saxpy::build(), cfg));
    suite
}

/// Each pass individually: token and lane engines on the transformed
/// graph reproduce the raw graph's named-output streams on all 13
/// suite graphs.
#[test]
fn opt_each_pass_preserves_benchmark_outputs() {
    let mut covered = 0usize;
    for (name, g, cfg) in opt_suite() {
        let base = run_token(&g, &cfg);
        if !base.quiescent {
            // Outside the equivalence contract (see module comment);
            // benchmark workloads quiesce in practice, so this is a
            // safety valve, not an expected path.
            eprintln!("opt harness: {name} raw run did not quiesce; skipped");
            continue;
        }
        covered += 1;
        for t in OPT_TRANSFORMS {
            let tg = apply_transform(&g, t);
            let tok = run_token(&tg, &cfg);
            assert_eq!(
                named_streams(&tok.outputs),
                named_streams(&base.outputs),
                "{name} / {t}: token engine diverged"
            );
            let prog = Program::compile(&tg);
            let lanes = run_lanes(&prog, std::slice::from_ref(&cfg));
            assert_eq!(
                named_streams(&lanes[0].outputs),
                named_streams(&base.outputs),
                "{name} / {t}: lane engine diverged"
            );
        }
    }
    assert!(covered >= 8, "only {covered}/13 suite graphs quiesced");
}

/// The full pipelines across the remaining engine matrix: streamed
/// (resident session), sharded, and time-multiplexed execution of the
/// optimized graph reproduce the raw graph's named-output streams.
#[test]
fn opt_pipeline_preserves_outputs_across_stream_shard_reconfig() {
    let mut fabric_covered = 0usize;
    for (name, g, cfg) in opt_suite() {
        let base = run_token(&g, &cfg);
        if !base.quiescent {
            eprintln!("opt harness: {name} raw run did not quiesce; skipped");
            continue;
        }
        for t in ["pipeline:default", "pipeline:aggressive"] {
            let tg = apply_transform(&g, t);
            // Streamed: two successive waves of the same workload
            // through one resident session, each byte-identical to the
            // raw isolated run.
            let waves: Vec<WaveInput> = vec![cfg.inject.clone(), cfg.inject.clone()];
            let (outs, _m) = run_stream(&tg, &waves, cfg.max_cycles * 2);
            for (i, out) in outs.iter().enumerate() {
                assert_eq!(
                    named_streams(&out.outputs),
                    named_streams(&base.outputs),
                    "{name} / {t}: streamed wave {i} diverged"
                );
            }
            // Sharded + reconfig on a fabric sized for the optimized
            // graph (graphs the KL partitioner cannot split at k=2 are
            // skipped; the coverage floor below keeps the benchmark
            // graphs honest).
            let topo = FabricTopology::sized_for_shards(&tg, 2);
            let plan = match fabric::partition(&tg, &topo) {
                Ok(plan) => plan,
                Err(e) => {
                    eprintln!("opt harness: {name} / {t}: unpartitionable ({e}); skipped");
                    continue;
                }
            };
            fabric_covered += 1;
            let waves: Vec<WaveInput> = vec![cfg.inject.clone()];
            let sharded = fabric::run_sharded_waves(&plan, &waves, cfg.max_cycles);
            assert_eq!(
                named_streams(&sharded[0].outputs),
                named_streams(&base.outputs),
                "{name} / {t}: sharded diverged"
            );
            let (reconf, _stats) = fabric::run_reconfig_waves(&plan, &topo, &waves, cfg.max_cycles);
            assert_eq!(
                named_streams(&reconf[0].outputs),
                named_streams(&base.outputs),
                "{name} / {t}: reconfig diverged"
            );
        }
    }
    assert!(
        fabric_covered >= 10,
        "only {fabric_covered} sharded/reconfig comparisons ran"
    );
}

/// Acceptance: the pipeline strictly reduces every frontend-lowered
/// benchmark graph (nodes *and* arcs), never grows a hand-built one,
/// and the report's per-pass deltas reconcile with the structural
/// diff.
#[test]
fn opt_pipeline_strictly_reduces_all_lowered_benchmarks() {
    let mut lowered_reduced = 0usize;
    for (name, g, _cfg) in opt_suite() {
        let (og, report) = optimize(&g, OptLevel::Default);
        assert!(
            og.n_nodes() <= g.n_nodes() && og.n_arcs() <= g.n_arcs(),
            "{name}: pipeline grew the graph"
        );
        let pass_nodes: i64 = report.passes.iter().map(|p| p.nodes_delta).sum();
        assert_eq!(-pass_nodes, report.nodes_removed(), "{name}: bookkeeping");
        if name.starts_with("lowered:") {
            assert!(
                og.n_nodes() < g.n_nodes() && og.n_arcs() < g.n_arcs(),
                "{name}: lowered graph did not strictly shrink ({} -> {} nodes)",
                g.n_nodes(),
                og.n_nodes()
            );
            lowered_reduced += 1;
        }
    }
    assert_eq!(lowered_reduced, 6, "all six lowered benchmarks reduce");
}

/// Pass-level differential property on seeded random DFGs: for every
/// pass and both pipelines, quiescing workloads see byte-identical
/// named-output streams on the token and lane engines, and the
/// serialized lane-stream path agrees per wave.
#[test]
fn opt_prop_passes_preserve_random_dfg_outputs() {
    check(
        "optimized == raw (named ports) on quiescing random DFGs",
        PropCfg::from_env(32, 0x0C0D_E5E5),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let wl = random_workload(r, &gg, 1 + r.below(3));
            (gg, wl)
        },
        |(gg, wl): &(GenGraph, BTreeMap<String, Vec<i16>>)| {
            let g = &gg.graph;
            let cfg = config_for(wl, 200_000);
            let base = run_token(g, &cfg);
            if !base.quiescent {
                // Stranding workloads are outside the optimizer's
                // equivalence contract (capacity differences become
                // observable) — same boundary as the cross-engine
                // comparisons.
                return Ok(());
            }
            for t in OPT_TRANSFORMS {
                let tg = apply_transform(g, t);
                let tok = run_token(&tg, &cfg);
                if named_streams(&tok.outputs) != named_streams(&base.outputs) {
                    return Err(format!(
                        "{t}: token diverged: {:?} != {:?}",
                        tok.outputs, base.outputs
                    ));
                }
                let prog = Program::compile(&tg);
                let lanes = run_lanes(&prog, std::slice::from_ref(&cfg));
                if named_streams(&lanes[0].outputs) != named_streams(&base.outputs) {
                    return Err(format!("{t}: lanes diverged"));
                }
            }
            // The serialized lane-stream path over the aggressive
            // pipeline's output, two waves, each equal to the raw
            // isolated run.
            let tg = apply_transform(g, "pipeline:aggressive");
            let waves = vec![wl.clone(), wl.clone()];
            let streamed = run_stream_lanes(&tg, &waves, 200_000);
            for (i, out) in streamed.iter().enumerate() {
                if named_streams(&out.outputs) != named_streams(&base.outputs) {
                    return Err(format!("aggressive lane-stream wave {i} diverged"));
                }
            }
            Ok(())
        },
    );
}

/// Metamorphic properties: `OptLevel::None` is the identity; both
/// pipelines are idempotent to the byte at their fixpoint; the named
/// external port set (input ports and named output ports) is
/// preserved exactly at every level.
#[test]
fn opt_metamorphic_identity_idempotence_and_port_preservation() {
    fn port_sets(g: &Graph) -> (Vec<String>, Vec<String>) {
        let mut ins: Vec<String> = g
            .input_ports()
            .iter()
            .map(|&a| g.arc(a).name.clone())
            .filter(|n| !is_anon_label(n))
            .collect();
        let mut outs: Vec<String> = g
            .output_ports()
            .iter()
            .map(|&a| g.arc(a).name.clone())
            .filter(|n| !is_anon_label(n))
            .collect();
        ins.sort();
        outs.sort();
        (ins, outs)
    }
    let mut graphs: Vec<(String, Graph)> = opt_suite()
        .into_iter()
        .map(|(n, g, _)| (n, g))
        .collect();
    let mut rng = Rng::new(0x1DE_A7E5);
    for i in 0..4 {
        graphs.push((format!("random:{i}"), random_dfg(&mut rng, i % 2 == 0).graph));
    }
    for (name, g) in &graphs {
        let (none, none_report) = optimize(g, OptLevel::None);
        assert_eq!(
            dataflow_accel::asm::print(&none),
            dataflow_accel::asm::print(g),
            "{name}: OptLevel::None must be the identity"
        );
        assert!(!none_report.changed());
        for level in [OptLevel::Default, OptLevel::Aggressive] {
            let (o1, _) = optimize(g, level);
            let (o2, r2) = optimize(&o1, level);
            assert!(!r2.changed(), "{name} @ {level}: not idempotent");
            assert_eq!(
                dataflow_accel::asm::print(&o1),
                dataflow_accel::asm::print(&o2),
                "{name} @ {level}: fixpoint not byte-stable"
            );
            assert_eq!(
                port_sets(g),
                port_sets(&o1),
                "{name} @ {level}: external port set changed"
            );
        }
    }
}

/// Optimized graphs survive the assembler round trip and re-optimizing
/// the re-parsed graph is a fixed point (print → parse → re-optimize
/// changes nothing, to the byte).
#[test]
fn opt_asm_roundtrip_reoptimize_is_a_fixed_point() {
    for b in BenchId::ALL {
        for level in [OptLevel::Default, OptLevel::Aggressive] {
            let raw = frontend::compile_with(b.slug(), bench_defs::c_source(b), OptLevel::None)
                .unwrap();
            let (og, _) = optimize(&raw, level);
            let text = dataflow_accel::asm::print(&og);
            let g2 = dataflow_accel::asm::parse(b.slug(), &text)
                .unwrap_or_else(|e| panic!("{} @ {level}: re-parse failed: {e}", b.slug()));
            assert_eq!(g2.n_nodes(), og.n_nodes(), "{} @ {level}", b.slug());
            let (g3, r3) = optimize(&g2, level);
            assert!(
                !r3.changed(),
                "{} @ {level}: re-optimize after round trip rewrote the graph",
                b.slug()
            );
            assert_eq!(
                dataflow_accel::asm::print(&g3),
                text,
                "{} @ {level}: print∘parse∘optimize not a fixed point",
                b.slug()
            );
        }
    }
}

// ---- work-stealing executor determinism harness ------------------------
//
// PR 6's non-negotiable invariant (DESIGN.md §10): the parallel batch
// paths built on `par::Executor` return results byte-identical to the
// serial paths at every worker count. Schedules (who executed what,
// steal counts, timing) may vary run to run; results and the
// seed-determinism of traces may not. Everything here is named
// `par_determinism_*` so CI's `par-smoke` job can run exactly this
// subset (`cargo test --test conformance par_determinism`).

/// The 13-graph suite with a multi-item batch per graph: the seven
/// hand-built benchmark graphs + the six frontend-lowered raw forms,
/// each with `items` seed-varied workloads (mirrors [`opt_suite`]).
fn par_suite(items: usize) -> Vec<(String, Graph, Vec<SimConfig>)> {
    let mut suite = Vec::new();
    for b in BenchId::ALL {
        let wls = bench_defs::wave_workloads(b, items, 3, 0x9A7);
        let cfgs: Vec<SimConfig> = wls.iter().map(|w| w.sim_config()).collect();
        suite.push((
            format!("built:{}", b.slug()),
            bench_defs::build(b),
            cfgs.clone(),
        ));
        let raw = frontend::compile_with(b.slug(), bench_defs::c_source(b), OptLevel::None)
            .unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
        let cfgs4: Vec<SimConfig> = cfgs
            .into_iter()
            .map(|mut c| {
                c.max_cycles *= 4;
                c
            })
            .collect();
        suite.push((format!("lowered:{}", b.slug()), raw, cfgs4));
    }
    let pairs = bench_defs::saxpy::waves(items, 4, 0x9A7);
    let cfgs: Vec<SimConfig> = pairs
        .iter()
        .map(|(w, _)| {
            let mut c = SimConfig::new().max_cycles(200_000);
            for (p, s) in w {
                c = c.inject(p, s.clone());
            }
            c
        })
        .collect();
    suite.push(("built:saxpy".to_string(), bench_defs::saxpy::build(), cfgs));
    suite
}

/// Lane batches through the work-stealing pool: byte-identical
/// outcomes and identical fallback accounting at workers {1, 2, 4} on
/// all 13 suite graphs.
#[test]
fn par_determinism_lanes_on_suite_graphs() {
    for (name, g, cfgs) in par_suite(12) {
        let prog = Program::compile(&g);
        let (base, base_stats) = run_batch_lanes_prog(&g, &prog, &cfgs);
        for workers in [1usize, 2, 4] {
            let exec = Executor::new(workers);
            let (outs, stats) = run_batch_lanes_par(&g, &prog, &cfgs, &exec);
            assert_eq!(outs, base, "{name}: lanes diverged at {workers} workers");
            assert_eq!(
                stats.scalar_reruns, base_stats.scalar_reruns,
                "{name}: fallback accounting diverged at {workers} workers"
            );
        }
    }
}

/// Sharded batches (isolated and resident-wave modes) through the
/// pool: byte-identical to the serial sharded path at workers
/// {1, 2, 4} on every suite graph the k=2 partitioner can split.
#[test]
fn par_determinism_sharded_on_suite_graphs() {
    let mut covered = 0usize;
    for (name, g, cfgs) in par_suite(12) {
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = match fabric::partition(&g, &topo) {
            Ok(plan) => plan,
            Err(e) => {
                eprintln!("par harness: {name}: unpartitionable ({e}); skipped");
                continue;
            }
        };
        covered += 1;
        for resident in [false, true] {
            let base = run_batch_sharded(&plan, &cfgs, resident);
            for workers in [1usize, 2, 4] {
                let exec = Executor::new(workers);
                let outs = run_batch_sharded_par(&plan, &cfgs, resident, &exec);
                assert_eq!(
                    outs, base,
                    "{name}: sharded (resident={resident}) diverged at {workers} workers"
                );
            }
        }
    }
    assert!(covered >= 8, "only {covered}/13 suite graphs partitioned");
}

/// Serialized-stream batches split into contiguous wave spans across
/// the pool: byte-identical to the single-session serial path at
/// workers {1, 2, 4} on all 13 suite graphs.
#[test]
fn par_determinism_sstream_on_suite_graphs() {
    for (name, g, cfgs) in par_suite(12) {
        let base = run_batch_sstream_par(&g, &cfgs, &Executor::single());
        for workers in [2usize, 4] {
            let exec = Executor::new(workers);
            let outs = run_batch_sstream_par(&g, &cfgs, &exec);
            assert_eq!(
                outs, base,
                "{name}: serialized stream diverged at {workers} workers"
            );
        }
    }
}

/// Multi-chunk lane batches: with more items than 2×MAX_LANES the
/// parallel path actually distributes whole 256-lane multi-word chunks
/// across workers (the single-chunk fallback can't mask a bug here).
#[test]
fn par_determinism_lanes_multi_chunk_batches() {
    for b in [BenchId::DotProd, BenchId::VectorSum, BenchId::Fibonacci] {
        let g = bench_defs::build(b);
        let prog = Program::compile(&g);
        let items = 2 * MAX_LANES + 3;
        let cfgs: Vec<SimConfig> = (0..items)
            .map(|i| bench_defs::workload(b, 1 + i % 4, i as u64).sim_config())
            .collect();
        let (base, _) = run_batch_lanes_prog(&g, &prog, &cfgs);
        assert_eq!(base.len(), items);
        for workers in [2usize, 4] {
            let exec = Executor::new(workers);
            let (outs, _) = run_batch_lanes_par(&g, &prog, &cfgs, &exec);
            assert_eq!(outs, base, "{}: {workers} workers", b.slug());
        }
    }
}

/// Parallel batch paths on seeded random DFGs: the serialized-stream
/// and lane paths reproduce their serial results at workers {2, 4} on
/// arbitrary generated graphs (branch/dmerge routing, consts, fifos,
/// loop schemas).
#[test]
fn prop_par_determinism_random_dfgs() {
    check(
        "parallel batches == serial batches on random DFGs",
        PropCfg::from_env(24, 0x9A7_C0DE),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let n_items = 3 + r.below(6);
            let wls: Vec<BTreeMap<String, Vec<i16>>> = (0..n_items)
                .map(|_| random_workload(r, &gg, 1 + r.below(3)))
                .collect();
            (gg, wls)
        },
        |(gg, wls): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>)| {
            let g = &gg.graph;
            let cfgs: Vec<SimConfig> = wls.iter().map(|w| config_for(w, 200_000)).collect();
            let prog = Program::compile(g);
            let (lanes_base, _) = run_batch_lanes_prog(g, &prog, &cfgs);
            let sstream_base = run_batch_sstream_par(g, &cfgs, &Executor::single());
            for workers in [2usize, 4] {
                let exec = Executor::new(workers);
                let (lanes, _) = run_batch_lanes_par(g, &prog, &cfgs, &exec);
                if lanes != lanes_base {
                    return Err(format!("lanes diverged at {workers} workers"));
                }
                let sstream = run_batch_sstream_par(g, &cfgs, &exec);
                if sstream != sstream_base {
                    return Err(format!(
                        "serialized stream diverged at {workers} workers"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Warm == cold byte-identity holds through the lock-striped session
/// cache under the parallel batch executor: a cold parallel run, a
/// warm parallel run, and the serial executor all agree item by item
/// at workers {1, 2, 4}, on benchmarks and a random-DFG family.
#[test]
fn par_determinism_warm_equals_cold_through_striped_cache() {
    use dataflow_accel::serve::{
        execute_batch, execute_batch_par, ServeRequest, SessionCache, WorkKind,
    };
    let kinds = [
        WorkKind::Bench(BenchId::DotProd),
        WorkKind::Bench(BenchId::Fibonacci),
        WorkKind::Saxpy,
        WorkKind::Random { branchy: true },
    ];
    for (k, kind) in kinds.iter().enumerate() {
        let reqs: Vec<ServeRequest> = (0..6)
            .map(|i| ServeRequest {
                tenant: 0,
                seq: i,
                kind: *kind,
                n: 4,
                seed: (k * 10 + i * 5) as u64,
            })
            .collect();
        // Serial reference through its own (default-striped) cache.
        let serial_cache = SessionCache::new(FabricTopology::serving(), 2, 32);
        let serial = execute_batch(&serial_cache, &reqs);
        for workers in [1usize, 2, 4] {
            let exec = Executor::new(workers);
            let cache = SessionCache::new(FabricTopology::serving(), 2, 32);
            assert!(cache.stripes() > 1, "default cache must be striped");
            let cold = execute_batch_par(&cache, &reqs, &exec);
            let warm = execute_batch_par(&cache, &reqs, &exec);
            assert!(warm.cache_hit, "{kind:?} @ {workers}: second run must be warm");
            assert_eq!(cold.engine, serial.engine, "{kind:?} @ {workers}");
            assert_eq!(warm.engine, serial.engine, "{kind:?} @ {workers}");
            for (i, s) in serial.outcomes.iter().enumerate() {
                assert_eq!(
                    cold.outcomes[i].outputs, s.outputs,
                    "{kind:?} item {i} @ {workers}: cold parallel != serial"
                );
                assert_eq!(
                    warm.outcomes[i].outputs, s.outputs,
                    "{kind:?} item {i} @ {workers}: warm parallel != serial"
                );
            }
            assert!(cold.verified.iter().all(|&v| v), "{kind:?} @ {workers}");
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint conformance (the `ckpt_` subset; CI runs it standalone as
// `cargo test --release --test conformance ckpt_`). The contract behind
// the serve tier's fault-recovery migration: a `StreamCheckpoint` is a
// complete capture — byte-identical through encode/decode/restore — and
// resuming one finishes with wave outcomes identical to a run that was
// never interrupted, counters included.
// ---------------------------------------------------------------------------

/// Snapshot → bytes → decode → restore is byte-identical at every hop
/// on all 13 suite graphs, at several cut depths, and the resumed run
/// reproduces the uninterrupted run's full per-wave `SimOutcome`
/// (outputs, cycles, firings, quiescence).
#[test]
fn ckpt_roundtrip_and_resume_are_byte_identical_on_all_suite_graphs() {
    for (name, g, cfg) in opt_suite() {
        let waves: Vec<WaveInput> = vec![cfg.inject.clone(), cfg.inject.clone()];
        let budget = cfg.max_cycles * 2;

        let mut whole = StreamSession::with_mode(&g, WaveMode::Serialized);
        for w in &waves {
            whole.admit(w).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        whole.run(budget);

        // `run` budgets *cumulative* rounds, so `run(cut)` then
        // `run(budget)` walks the same round sequence as one call.
        for cut in [0u64, 1, 7, 63] {
            let mut first = StreamSession::with_mode(&g, WaveMode::Serialized);
            for w in &waves {
                first.admit(w).unwrap_or_else(|e| panic!("{name}: {e}"));
            }
            first.run(cut);
            let ck = first.snapshot();
            let bytes = ck.to_bytes();
            let decoded = StreamCheckpoint::from_bytes(&bytes)
                .unwrap_or_else(|e| panic!("{name} cut {cut}: decode failed: {e:?}"));
            assert_eq!(decoded, ck, "{name} cut {cut}: decoded image != snapshot");
            assert_eq!(
                decoded.to_bytes(),
                bytes,
                "{name} cut {cut}: re-encoded image differs"
            );
            let mut resumed = StreamSession::restore(&g, &decoded)
                .unwrap_or_else(|e| panic!("{name} cut {cut}: restore failed: {e:?}"));
            assert_eq!(
                resumed.snapshot().to_bytes(),
                bytes,
                "{name} cut {cut}: restored session re-captures differently"
            );
            resumed.run(budget);
            for w in 0..whole.n_waves() {
                assert_eq!(
                    resumed.wave_outcome(w),
                    whole.wave_outcome(w),
                    "{name} cut {cut} wave {w}: resumed != uninterrupted"
                );
            }
        }
    }
}

/// Property: the same round-trip + interrupted-resume contract on
/// seeded random branchy DFGs (stranding tokens, serialized flushes)
/// with a seeded cut point — including cuts that land mid-stall-streak,
/// which is why the streak itself is part of the checkpoint image.
#[test]
fn ckpt_prop_interrupted_resume_matches_uninterrupted_on_random_dfgs() {
    check(
        "checkpoint/restore == uninterrupted",
        PropCfg::from_env(32, 0xC4EC_4901),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let n_waves = 2 + r.below(3);
            let waves: Vec<BTreeMap<String, Vec<i16>>> = (0..n_waves)
                .map(|_| random_workload(r, &gg, 1 + r.below(3)))
                .collect();
            let cut = r.below(32) as u64;
            (gg, waves, cut)
        },
        |(gg, waves, cut): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>, u64)| {
            let g = &gg.graph;
            let budget = 200_000 * waves.len() as u64;
            let mut whole = StreamSession::with_mode(g, WaveMode::Serialized);
            for w in waves {
                whole.admit(w).map_err(|e| e.to_string())?;
            }
            whole.run(budget);

            let mut first = StreamSession::with_mode(g, WaveMode::Serialized);
            for w in waves {
                first.admit(w).map_err(|e| e.to_string())?;
            }
            first.run(*cut);
            let bytes = first.snapshot().to_bytes();
            let ck = StreamCheckpoint::from_bytes(&bytes).map_err(|e| format!("{e:?}"))?;
            if ck.to_bytes() != bytes {
                return Err(format!("cut {cut}: re-encoded image differs"));
            }
            let mut resumed = StreamSession::restore(g, &ck).map_err(|e| format!("{e:?}"))?;
            if resumed.snapshot().to_bytes() != bytes {
                return Err(format!("cut {cut}: restored session re-captures differently"));
            }
            resumed.run(budget);
            for w in 0..whole.n_waves() {
                if resumed.wave_outcome(w) != whole.wave_outcome(w) {
                    return Err(format!(
                        "wave {w} at cut {cut}: resumed {:?} != uninterrupted {:?}",
                        resumed.wave_outcome(w),
                        whole.wave_outcome(w)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Restore legality: a checkpoint only restores onto the graph that
/// produced it. Every cross-graph restore across the suite is refused
/// with a typed error — never a panic, never a silently wrong session.
#[test]
fn ckpt_restore_refuses_every_other_suite_graph() {
    let suite = opt_suite();
    let images: Vec<(String, Graph, StreamCheckpoint)> = suite
        .into_iter()
        .map(|(name, g, cfg)| {
            let mut s = StreamSession::with_mode(&g, WaveMode::Serialized);
            s.admit(&cfg.inject).unwrap_or_else(|e| panic!("{name}: {e}"));
            s.run(4);
            let ck = s.snapshot();
            (name, g, ck)
        })
        .collect();
    let mut refused = 0usize;
    for (name_i, _, ck) in &images {
        for (name_j, g_j, _) in &images {
            if g_j.fingerprint() == ck.fingerprint {
                // The same graph (or a structural twin) is a legal
                // restore target; legality is by fingerprint, not name.
                assert!(
                    StreamSession::restore(g_j, ck).is_ok(),
                    "{name_i} -> {name_j}: same-fingerprint restore refused"
                );
            } else {
                assert!(
                    StreamSession::restore(g_j, ck).is_err(),
                    "{name_i} -> {name_j}: cross-graph restore accepted"
                );
                refused += 1;
            }
        }
    }
    assert!(refused >= 100, "only {refused} cross-graph refusals exercised");
}

// ---------------------------------------------------------------------------
// Observability conformance (the `obs_determinism_` subset; CI runs it
// standalone as `cargo test --release --test conformance obs_determinism`).
// Two contracts (DESIGN.md §12): profiling is a read-only observer —
// every engine run with `ProfileLevel::Full` reproduces its unprofiled
// run exactly (outputs, cycles, firings) and the profiler's own firing
// totals agree with the engine's; and the virtual-tick trace stream is
// a pure function of the workload — byte-identical `events_json` at
// every worker count, never containing wall-clock data.
// ---------------------------------------------------------------------------

/// Profiled == unprofiled on all 13 suite graphs for the token, lane,
/// and stream engines, and `ProfileLevel::Off` is a strict no-op.
#[test]
fn obs_determinism_profiled_equals_unprofiled_on_suite_graphs() {
    use dataflow_accel::obs::ProfileLevel;
    use dataflow_accel::sim::{run_lanes_profiled, TokenSim};
    for (name, g, cfg) in opt_suite() {
        // Token engine.
        let plain = run_token(&g, &cfg);
        let mut sim = TokenSim::new(&g, &cfg);
        sim.enable_profiling(ProfileLevel::Full);
        let (cycles, quiescent) = sim.run_in_place(&cfg);
        assert_eq!(cycles, plain.cycles, "{name}: token cycles perturbed");
        assert_eq!(quiescent, plain.quiescent, "{name}: token quiescence");
        assert_eq!(sim.firings(), plain.firings, "{name}: token firings");
        let prof = sim.take_profile().expect("token profile");
        assert_eq!(
            prof.total_firings, plain.firings,
            "{name}: token profiler miscounted"
        );

        // Lane engine: Full must not perturb, Off must be the identity.
        let prog = Program::compile(&g);
        let base = run_lanes(&prog, std::slice::from_ref(&cfg));
        let (full, lp) = run_lanes_profiled(&prog, std::slice::from_ref(&cfg), ProfileLevel::Full);
        assert_eq!(full, base, "{name}: lanes perturbed by Full profiling");
        assert_eq!(
            lp.total_firings, base[0].firings,
            "{name}: lane profiler miscounted"
        );
        let (off, op) = run_lanes_profiled(&prog, std::slice::from_ref(&cfg), ProfileLevel::Off);
        assert_eq!(off, base, "{name}: lanes perturbed by Off profiling");
        assert_eq!(op.total_firings, 0, "{name}: Off profile must stay empty");

        // Stream engine: a profiled serialized session reproduces the
        // unprofiled session's wave outcomes.
        let waves: Vec<WaveInput> = vec![cfg.inject.clone(), cfg.inject.clone()];
        let budget = cfg.max_cycles * 2;
        let mut unprofiled = StreamSession::with_mode(&g, WaveMode::Serialized);
        let mut profiled = StreamSession::with_mode(&g, WaveMode::Serialized);
        profiled.enable_profiling(ProfileLevel::Full);
        for w in &waves {
            unprofiled.admit(w).unwrap_or_else(|e| panic!("{name}: {e}"));
            profiled.admit(w).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        unprofiled.run(budget);
        profiled.run(budget);
        for w in 0..unprofiled.n_waves() {
            assert_eq!(
                profiled.wave_outcome(w),
                unprofiled.wave_outcome(w),
                "{name} wave {w}: stream perturbed by profiling"
            );
        }
        let sp = profiled.take_profile().expect("stream profile");
        assert_eq!(
            sp.total_firings,
            unprofiled.metrics().firings,
            "{name}: stream profiler miscounted"
        );
    }
}

/// Profiled == unprofiled through the sharded and time-multiplexed
/// fabric executors on every suite graph the k=2 partitioner can
/// split, with shard profile totals reconciling to the merged outcome.
#[test]
fn obs_determinism_fabric_profiles_match_unprofiled() {
    use dataflow_accel::obs::ProfileLevel;
    let mut covered = 0usize;
    for (name, g, cfg) in opt_suite() {
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = match fabric::partition(&g, &topo) {
            Ok(plan) => plan,
            Err(_) => continue,
        };
        covered += 1;
        let plain = fabric::run_sharded(&plan, &cfg);
        let (profiled, profiles) = fabric::run_sharded_profiled(&plan, &cfg, ProfileLevel::Full);
        assert_eq!(profiled, plain, "{name}: sharded perturbed by profiling");
        let shard_total: u64 = profiles
            .iter()
            .filter(|(l, _)| l.starts_with("shard"))
            .map(|(_, p)| p.total_firings)
            .sum();
        assert_eq!(shard_total, plain.firings, "{name}: shard totals");

        let (r_plain, s_plain) = fabric::run_reconfig(&plan, &topo, &cfg);
        let (r_prof, s_prof, _) =
            fabric::run_reconfig_profiled(&plan, &topo, &cfg, ProfileLevel::Full);
        assert_eq!(r_prof, r_plain, "{name}: reconfig perturbed by profiling");
        assert_eq!(s_prof.swaps, s_plain.swaps, "{name}: reconfig swap count");
    }
    assert!(covered >= 8, "only {covered}/13 suite graphs partitioned");
}

/// The serve tier's virtual-tick trace stream is byte-identical across
/// worker counts {1, 2, 4}, and attaching the trace changes no result
/// digests (recording is observation, not participation).
#[test]
fn obs_determinism_serve_trace_identical_across_worker_counts() {
    use dataflow_accel::obs::{events_json, SpanKind, TraceBuf};
    use dataflow_accel::serve::{run_profile, standard_profile, ServeOptions};
    use std::sync::Arc;
    for seed in [7u64, 23] {
        let profile = standard_profile(2, 4, seed);
        let untraced = run_profile(&profile, &ServeOptions::default());
        let mut streams: Vec<String> = Vec::new();
        for workers in [1usize, 2, 4] {
            let buf = Arc::new(TraceBuf::new(TraceBuf::DEFAULT_CAPACITY));
            let opts = ServeOptions {
                workers,
                trace: Some(buf.clone()),
                ..ServeOptions::default()
            };
            let outcome = run_profile(&profile, &opts);
            assert_eq!(
                outcome.digests, untraced.digests,
                "seed {seed}: tracing changed digests at {workers} workers"
            );
            let events = buf.drain_sorted();
            assert_eq!(buf.dropped(), 0, "seed {seed}: ring overflowed");
            let executes = events
                .iter()
                .filter(|e| matches!(e.kind, SpanKind::Execute))
                .count() as u64;
            assert_eq!(
                executes, outcome.report.global.completed,
                "seed {seed}: one Execute span per completed request"
            );
            streams.push(events_json(&events));
        }
        assert_eq!(
            streams[0], streams[1],
            "seed {seed}: trace differs between 1 and 2 workers"
        );
        assert_eq!(
            streams[0], streams[2],
            "seed {seed}: trace differs between 1 and 4 workers"
        );
        assert!(
            !streams[0].contains("wall"),
            "deterministic view must not carry wall-clock data"
        );
    }
}

/// Property: profiling is a read-only observer on seeded random DFGs —
/// the lane and stream engines under `ProfileLevel::Full` reproduce
/// their unprofiled runs, and the sharded executor agrees whenever the
/// generated graph partitions.
#[test]
fn obs_determinism_prop_profiled_random_dfgs() {
    use dataflow_accel::obs::ProfileLevel;
    use dataflow_accel::sim::run_lanes_profiled;
    check(
        "profiled engines == unprofiled engines on random DFGs",
        PropCfg::from_env(24, 0x0B5_C0DE),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let n_items = 1 + r.below(5);
            let wls: Vec<BTreeMap<String, Vec<i16>>> = (0..n_items)
                .map(|_| random_workload(r, &gg, 1 + r.below(3)))
                .collect();
            (gg, wls)
        },
        |(gg, wls): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>)| {
            let g = &gg.graph;
            let cfgs: Vec<SimConfig> = wls.iter().map(|w| config_for(w, 200_000)).collect();
            let prog = Program::compile(g);
            let base = run_lanes(&prog, &cfgs);
            let (full, prof) = run_lanes_profiled(&prog, &cfgs, ProfileLevel::Full);
            if full != base {
                return Err("lanes perturbed by Full profiling".into());
            }
            let firings: u64 = base.iter().map(|o| o.firings).sum();
            if prof.total_firings != firings {
                return Err(format!(
                    "lane profiler counted {} firings, engine reports {firings}",
                    prof.total_firings
                ));
            }

            let mut unprofiled = StreamSession::with_mode(g, WaveMode::Serialized);
            let mut profiled = StreamSession::with_mode(g, WaveMode::Serialized);
            profiled.enable_profiling(ProfileLevel::Full);
            for w in wls {
                unprofiled.admit(w).map_err(|e| e.to_string())?;
                profiled.admit(w).map_err(|e| e.to_string())?;
            }
            let budget = 200_000 * wls.len() as u64;
            unprofiled.run(budget);
            profiled.run(budget);
            for w in 0..unprofiled.n_waves() {
                if profiled.wave_outcome(w) != unprofiled.wave_outcome(w) {
                    return Err(format!("stream wave {w} perturbed by profiling"));
                }
            }

            let topo = FabricTopology::sized_for_shards(g, 2);
            if let Ok(plan) = fabric::partition(g, &topo) {
                let plain = fabric::run_sharded(&plan, &cfgs[0]);
                let (prof_out, _) =
                    fabric::run_sharded_profiled(&plan, &cfgs[0], ProfileLevel::Full);
                if prof_out != plain {
                    return Err("sharded perturbed by profiling".into());
                }
            }
            Ok(())
        },
    );
}

/// Property (PR 10): elastic repartitioning is *invisible* in results —
/// on seeded fairness profiles, the scarce-start elastic run serves the
/// same dispatch schedule and byte-identical per-request outputs as its
/// static-allocation twin, loses nothing, and accounts exactly. This is
/// the `serve --elastic` gate as a seed-swept property (CI runs the
/// `elastic_` prefix as a fixed-seed smoke subset).
#[test]
fn elastic_prop_digests_match_static_baseline_on_seeded_profiles() {
    use dataflow_accel::serve::{
        fairness_profile, run_profile_elastic, ElasticPolicy, ServeCfg, ServeOptions,
    };
    check(
        "elastic(scarce) == elastic(static) on fairness profiles",
        PropCfg::from_env(12, 0xE1A5_71C0),
        |r: &mut Rng| {
            let scale = 1 + r.below(3);
            let n = 4 + r.below(4);
            let seed = r.next_u64();
            (scale, n, seed)
        },
        |&(scale, n, seed): &(usize, usize, u64)| {
            let profile = fairness_profile(scale, n, seed);
            // Small batches spread dispatches across epoch boundaries;
            // default max_batch drains small profiles in one tick.
            let opts = ServeOptions {
                cfg: ServeCfg {
                    max_batch: 4,
                    ..ServeCfg::default()
                },
                ..ServeOptions::default()
            };
            let policy = ElasticPolicy::scarce();
            let baseline = run_profile_elastic(&profile, &opts, &policy.static_allocation());
            let elastic = run_profile_elastic(&profile, &opts, &policy);
            if elastic.dispatches != baseline.dispatches {
                return Err(format!(
                    "seed {seed:#x}: dispatch schedule diverged under repartitioning"
                ));
            }
            if elastic.output_digests != baseline.output_digests {
                return Err(format!(
                    "seed {seed:#x}: outputs diverged from the static baseline"
                ));
            }
            let g = &elastic.report.global;
            if g.lost() != 0 {
                return Err(format!("seed {seed:#x}: lost {} request(s)", g.lost()));
            }
            if g.completed + g.shed() != g.submitted {
                return Err(format!(
                    "seed {seed:#x}: accounting {} + {} != {}",
                    g.completed,
                    g.shed(),
                    g.submitted
                ));
            }
            if baseline.elastic != Default::default() {
                return Err(format!(
                    "seed {seed:#x}: static twin ran the epoch loop: {:?}",
                    baseline.elastic
                ));
            }
            Ok(())
        },
    );
}

/// Property (PR 10): with `epoch_ticks == 0` the elastic runner *is*
/// the plain serial runner — same dispatches, same full per-request
/// digests, zero elastic counters — and an unreserved overlay never
/// delays a wave. Dispatch schedules never read execution results, so
/// overlay bookkeeping cannot leak into what was served.
#[test]
fn elastic_unreserved_static_policy_is_the_identity_on_seeded_profiles() {
    use dataflow_accel::serve::{
        fairness_profile, run_profile, run_profile_elastic, ElasticPolicy, ServeOptions,
    };
    for seed in [3u64, 0xE1A5, 0xDEC0_DE10] {
        let profile = fairness_profile(2, 5, seed);
        let opts = ServeOptions::default();
        let plain = run_profile(&profile, &opts);
        let elastic = run_profile_elastic(&profile, &opts, &ElasticPolicy::unreserved());
        assert_eq!(
            elastic.dispatches, plain.dispatches,
            "seed {seed:#x}: dispatch schedule diverged"
        );
        assert_eq!(
            elastic.digests, plain.digests,
            "seed {seed:#x}: outcome digests diverged from the plain runner"
        );
        assert_eq!(
            elastic.elastic,
            Default::default(),
            "seed {seed:#x}: identity policy moved the fabric"
        );
        assert!(
            elastic.promoted_tenants.is_empty(),
            "seed {seed:#x}: identity policy promoted a tenant"
        );
    }
}
