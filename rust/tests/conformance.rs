//! Differential conformance harness.
//!
//! One semantics, many executors: `TokenSim`, `FsmSim`, `DynamicSim`,
//! the streaming tier (`StreamSession`, pipelined and serialized), the
//! sharded executor and the time-multiplexed executor must all produce
//! identical output streams. This harness checks them against each
//! other on:
//!
//! * seeded **random DFGs** from the generator in `util::proptest`
//!   (covering `const`, `fifo #k`, `dmerge`/`branch` routing and
//!   `build_loop` branch/merge loops), and
//! * the six paper benchmarks under multi-wave streamed injection.
//!
//! Every property is replayable from the seed in its failure message.
//! CI runs the same properties as a fixed-seed smoke subset by setting
//! `PROPTEST_CASES` (see `.github/workflows/ci.yml`).

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::fabric::{self, FabricTopology};
use dataflow_accel::sim::{
    run_dynamic, run_fsm, run_stream, run_token, SimConfig, StreamSession, WaveInput, WaveMode,
};
use dataflow_accel::util::proptest::{
    check, random_dfg, random_dfg_with, random_workload, GenCfg, GenGraph, PropCfg,
};
use dataflow_accel::util::Rng;
use std::collections::BTreeMap;

fn config_for(wl: &BTreeMap<String, Vec<i16>>, max_cycles: u64) -> SimConfig {
    let mut cfg = SimConfig::new().max_cycles(max_cycles);
    for (p, s) in wl {
        cfg = cfg.inject(p, s.clone());
    }
    cfg
}

/// TokenSim == FsmSim == DynamicSim(k) == streamed (single serialized
/// wave) on random DFGs with `const`s, `fifo #k`s and branch/merge
/// loops, under single-token streams.
///
/// Why single-token streams and no free `dmerge`/`branch`: `FsmSim`'s
/// latched input registers and `DynamicSim`'s deeper queues are extra
/// arc capacity. On workloads that strand tokens behind a `copy`, that
/// slack legally admits extra firings, so only *quiescing* cases define
/// a cross-engine contract (unit-rate ops + the balanced loop schema
/// quiesce by construction; the capacity-identical comparisons below
/// cover arbitrary stranding).
#[test]
fn prop_engines_agree_on_random_dfgs() {
    check(
        "TokenSim == FsmSim == DynamicSim == streamed",
        PropCfg::from_env(48, 0xD1FF_C0DE),
        |r: &mut Rng| {
            let gg = random_dfg_with(
                r,
                GenCfg {
                    routing: false,
                    loops: true,
                    consts: true,
                },
            );
            let wl = random_workload(r, &gg, 1);
            let bound = 1 + r.below(4);
            (gg, wl, bound)
        },
        |(gg, wl, bound): &(GenGraph, BTreeMap<String, Vec<i16>>, usize)| {
            let g = &gg.graph;
            let cfg = config_for(wl, 200_000);
            let tok = run_token(g, &cfg);

            let mut fsm_cfg = cfg.clone();
            fsm_cfg.max_cycles *= 4;
            let fsm = run_fsm(g, &fsm_cfg);
            if fsm.outputs != tok.outputs {
                return Err(format!(
                    "FsmSim diverged: {:?} != {:?}",
                    fsm.outputs, tok.outputs
                ));
            }

            let dy = run_dynamic(g, &cfg, *bound);
            if dy.outputs != tok.outputs {
                return Err(format!(
                    "DynamicSim(bound={bound}) diverged: {:?} != {:?}",
                    dy.outputs, tok.outputs
                ));
            }

            let (outs, metrics) = run_stream(g, std::slice::from_ref(wl), cfg.max_cycles);
            if outs[0].outputs != tok.outputs {
                return Err(format!(
                    "streamed diverged: {:?} != {:?}",
                    outs[0].outputs, tok.outputs
                ));
            }
            if metrics.tag_stalls != 0 {
                return Err(format!("tag stalls on a single wave: {}", metrics.tag_stalls));
            }
            Ok(())
        },
    );
}

/// Serialized multi-wave streaming == running each wave alone, on
/// random branchy DFGs (waves may strand tokens; the session's
/// wave-boundary reset must still isolate them).
#[test]
fn prop_serialized_waves_match_isolated_runs_on_random_dfgs() {
    check(
        "serialized waves == isolated TokenSim runs",
        PropCfg::from_env(32, 0x5E71A1),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let n_waves = 2 + r.below(3);
            let waves: Vec<BTreeMap<String, Vec<i16>>> = (0..n_waves)
                .map(|_| random_workload(r, &gg, 1 + r.below(3)))
                .collect();
            (gg, waves)
        },
        |(gg, waves): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>)| {
            let g = &gg.graph;
            let mut session = StreamSession::with_mode(g, WaveMode::Serialized);
            for w in waves {
                session.admit(w).map_err(|e| e.to_string())?;
            }
            session.run(200_000 * waves.len() as u64);
            for (i, w) in waves.iter().enumerate() {
                let alone = run_token(g, &config_for(w, 200_000));
                if session.wave_outputs(i as u32) != &alone.outputs {
                    return Err(format!(
                        "wave {i}: streamed {:?} != isolated {:?}",
                        session.wave_outputs(i as u32),
                        alone.outputs
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Pipelined (overlapping) streaming == running each wave alone, on
/// random unit-rate pipeline DFGs — and the overlap must not be slower
/// than run-to-completion.
#[test]
fn prop_pipelined_waves_match_isolated_runs_and_win_throughput() {
    check(
        "pipelined waves == isolated runs, streamed rounds <= r2c rounds",
        PropCfg::from_env(32, 0xF10_11E),
        |r: &mut Rng| {
            let gg = random_dfg(r, false);
            let len = 1 + r.below(3);
            let n_waves = 3 + r.below(4);
            let waves: Vec<BTreeMap<String, Vec<i16>>> = (0..n_waves)
                .map(|_| random_workload(r, &gg, len))
                .collect();
            (gg, waves)
        },
        |(gg, waves): &(GenGraph, Vec<BTreeMap<String, Vec<i16>>>)| {
            let g = &gg.graph;
            if !dataflow_accel::sim::overlap_safe(g) {
                return Err("pipeline generator produced a non-overlap-safe graph".into());
            }
            let mut r2c_cycles = 0u64;
            let mut isolated = Vec::new();
            for w in waves {
                let out = run_token(g, &config_for(w, 200_000));
                r2c_cycles += out.cycles;
                isolated.push(out);
            }
            let (outs, metrics) = run_stream(g, waves, 200_000 * waves.len() as u64);
            if metrics.waves_completed as usize != waves.len() {
                return Err(format!(
                    "only {}/{} waves completed",
                    metrics.waves_completed,
                    waves.len()
                ));
            }
            for (i, alone) in isolated.iter().enumerate() {
                if outs[i].outputs != alone.outputs {
                    return Err(format!(
                        "wave {i}: streamed {:?} != isolated {:?}",
                        outs[i].outputs, alone.outputs
                    ));
                }
            }
            if metrics.tag_stalls != 0 {
                return Err(format!("tag stalls: {}", metrics.tag_stalls));
            }
            if waves.len() >= 3 && metrics.rounds > r2c_cycles {
                return Err(format!(
                    "streamed makespan {} rounds > run-to-completion {}",
                    metrics.rounds, r2c_cycles
                ));
            }
            Ok(())
        },
    );
}

/// All six paper benchmarks, multi-wave streamed injection through one
/// resident session: per-wave output streams byte-identical to running
/// each wave alone through whole-graph TokenSim.
#[test]
fn streamed_waves_match_isolated_runs_on_all_benchmarks() {
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let wls = bench_defs::wave_workloads(b, 4, 4, 0xBEE5);
        let waves: Vec<WaveInput> = wls.iter().map(|w| w.inject.clone()).collect();
        let budget: u64 = wls.iter().map(|w| w.max_cycles).sum();
        let (outs, metrics) = run_stream(&g, &waves, budget);
        assert_eq!(
            metrics.waves_completed as usize,
            waves.len(),
            "{}: waves incomplete",
            b.slug()
        );
        for (i, wl) in wls.iter().enumerate() {
            let alone = run_token(&g, &wl.sim_config());
            assert_eq!(
                outs[i].outputs,
                alone.outputs,
                "{} wave {i}: streamed != isolated",
                b.slug()
            );
            for (port, want) in &wl.expect {
                assert_eq!(
                    outs[i].stream(port),
                    want.as_slice(),
                    "{} wave {i} port `{port}`",
                    b.slug()
                );
            }
        }
    }
}

/// Streamed injection through the sharded and reconfig executors agrees
/// with whole-graph TokenSim per wave on every benchmark.
#[test]
fn streamed_fabric_executors_match_whole_graph() {
    let mut rng = Rng::new(0xFAB_57B);
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = fabric::partition(&g, &topo).unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
        let wls: Vec<_> = (0..3)
            .map(|_| bench_defs::workload(b, 1 + rng.below(5), rng.next_u64()))
            .collect();
        let waves: Vec<WaveInput> = wls.iter().map(|w| w.inject.clone()).collect();
        let budget = wls.iter().map(|w| w.max_cycles).max().unwrap();

        let sharded = fabric::run_sharded_waves(&plan, &waves, budget);
        let (reconf, _stats) = fabric::run_reconfig_waves(&plan, &topo, &waves, budget);
        for (i, wl) in wls.iter().enumerate() {
            let whole = run_token(&g, &wl.sim_config());
            assert_eq!(
                sharded[i].outputs,
                whole.outputs,
                "{} wave {i}: sharded-streamed != whole",
                b.slug()
            );
            assert_eq!(
                reconf[i].outputs,
                whole.outputs,
                "{} wave {i}: reconfig-streamed != whole",
                b.slug()
            );
        }
    }
}

/// The streamed coordinator batch path equals the run-to-completion
/// batch path per request.
#[test]
fn streamed_batch_path_matches_run_to_completion() {
    use dataflow_accel::coordinator::{run_batch_native, run_batch_streamed};
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let cfgs: Vec<_> = (0..3)
            .map(|s| bench_defs::workload(b, 2 + s, 40 + s as u64).sim_config())
            .collect();
        let native = run_batch_native(&g, &cfgs);
        let streamed = run_batch_streamed(&g, &cfgs);
        for i in 0..cfgs.len() {
            assert_eq!(streamed[i].outputs, native[i].outputs, "{} #{i}", b.slug());
        }
    }
}

/// Print → parse round-trip on every random-generated graph (not just
/// the six benchmarks): the printed assembler re-parses to a graph with
/// identical structure and identical behaviour, and print∘parse is a
/// fixpoint.
#[test]
fn prop_asm_roundtrip_on_random_dfgs() {
    check(
        "asm print -> parse round-trip on random DFGs",
        PropCfg::from_env(48, 0xA5B_C0DE),
        |r: &mut Rng| {
            let gg = random_dfg(r, true);
            let wl = random_workload(r, &gg, 1 + r.below(3));
            (gg, wl)
        },
        |(gg, wl): &(GenGraph, BTreeMap<String, Vec<i16>>)| {
            let g = &gg.graph;
            let text = dataflow_accel::asm::print(g);
            let g2 = dataflow_accel::asm::parse(&g.name, &text)
                .map_err(|e| format!("re-parse failed: {e}\n{text}"))?;
            if g2.n_nodes() != g.n_nodes() || g2.n_arcs() != g.n_arcs() {
                return Err(format!(
                    "shape changed: {}x{} -> {}x{}",
                    g.n_nodes(),
                    g.n_arcs(),
                    g2.n_nodes(),
                    g2.n_arcs()
                ));
            }
            let text2 = dataflow_accel::asm::print(&g2);
            if text2 != text {
                return Err("print∘parse is not a fixpoint".into());
            }
            let cfg = config_for(wl, 200_000);
            let a = run_token(g, &cfg);
            let b = run_token(&g2, &cfg);
            if a.outputs != b.outputs {
                return Err(format!(
                    "round-tripped graph diverged: {:?} != {:?}",
                    b.outputs, a.outputs
                ));
            }
            Ok(())
        },
    );
}

/// The dynamic engine agrees with the static engine on random DFGs for
/// every queue bound (extends the per-benchmark seed property to
/// generated graphs; quiescing cases, see `prop_engines_agree_*`).
#[test]
fn prop_dynamic_bounds_agree_on_random_dfgs() {
    check(
        "DynamicSim(k) == TokenSim on random DFGs",
        PropCfg::from_env(24, 0xD1_CE2),
        |r: &mut Rng| {
            let gg = random_dfg_with(
                r,
                GenCfg {
                    routing: false,
                    loops: true,
                    consts: true,
                },
            );
            let wl = random_workload(r, &gg, 1);
            (gg, wl)
        },
        |(gg, wl): &(GenGraph, BTreeMap<String, Vec<i16>>)| {
            let g = &gg.graph;
            let cfg = config_for(wl, 200_000);
            let tok = run_token(g, &cfg);
            for bound in [1usize, 2, 8] {
                let dy = run_dynamic(g, &cfg, bound);
                if dy.outputs != tok.outputs {
                    return Err(format!("bound {bound} diverged"));
                }
            }
            Ok(())
        },
    );
}
