//! Property tests for the physical fabric layer (`fabric` module):
//!
//! 1. **Partition soundness** — for every benchmark and shard pressure,
//!    the union of all shards equals the original graph: every node in
//!    exactly one shard, every arc in exactly one shard except cut arcs,
//!    which appear in exactly their two home shards; every shard is a
//!    structurally valid graph that places on the topology it was split
//!    for.
//! 2. **Sharded-execution equivalence** — on all six paper benchmarks
//!    under random workloads, running the partition on multiple fabric
//!    instances (and time-multiplexed on one instance) produces output
//!    streams byte-identical to whole-graph `TokenSim`.
//! 3. **Capacity rejection** — the placer rejects any graph whose
//!    operator-class demand or arc count exceeds the topology with a
//!    descriptive error naming the class and the shortfall.

use dataflow_accel::bench_defs::{self, BenchId};
use dataflow_accel::dfg::validate;
use dataflow_accel::fabric::{self, FabricTopology, PlaceError};
use dataflow_accel::sim::run_token;
use dataflow_accel::util::proptest::{check, PropCfg};
use dataflow_accel::util::Rng;
use std::collections::BTreeMap;

/// Shard pressures exercised everywhere below: `sized_for_shards(g, 2)`
/// never fits a whole benchmark graph (forcing a real split), 3 forces a
/// finer one.
const PRESSURES: [usize; 2] = [2, 3];

#[test]
fn partition_union_equals_original_graph() {
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        for k in PRESSURES {
            let topo = FabricTopology::sized_for_shards(&g, k);
            let plan = fabric::partition(&g, &topo)
                .unwrap_or_else(|e| panic!("{} k={k}: {e}", b.slug()));

            // Nodes: every original node in exactly one shard, same op.
            let mut node_seen = vec![0usize; g.n_nodes()];
            for sh in &plan.shards {
                assert_eq!(
                    sh.orig_nodes.len(),
                    sh.graph.n_nodes(),
                    "{} k={k} shard {}: node map length",
                    b.slug(),
                    sh.index
                );
                for (si, &orig) in sh.orig_nodes.iter().enumerate() {
                    node_seen[orig.0 as usize] += 1;
                    assert_eq!(
                        sh.graph.nodes[si].op,
                        g.node(orig).op,
                        "{} k={k} shard {}: op preserved",
                        b.slug(),
                        sh.index
                    );
                }
            }
            assert!(
                node_seen.iter().all(|&c| c == 1),
                "{} k={k}: every node in exactly one shard ({node_seen:?})",
                b.slug()
            );

            // Arcs: cut arcs live in exactly their two home shards, all
            // others in exactly one; nothing missing, nothing duplicated.
            let mut arc_seen: BTreeMap<u32, usize> = BTreeMap::new();
            for sh in &plan.shards {
                assert_eq!(
                    sh.orig_arcs.len(),
                    sh.graph.n_arcs(),
                    "{} k={k} shard {}: arc map length",
                    b.slug(),
                    sh.index
                );
                for &orig in &sh.orig_arcs {
                    *arc_seen.entry(orig.0).or_insert(0) += 1;
                }
            }
            let cut_ids: Vec<u32> = plan.cuts.iter().map(|c| c.arc.0).collect();
            for a in &g.arcs {
                let want = if cut_ids.contains(&a.id.0) { 2 } else { 1 };
                assert_eq!(
                    arc_seen.get(&a.id.0).copied().unwrap_or(0),
                    want,
                    "{} k={k}: arc `{}` copies",
                    b.slug(),
                    a.name
                );
            }

            // Every shard is a valid graph and places on the topology.
            for sh in &plan.shards {
                validate(&sh.graph)
                    .unwrap_or_else(|e| panic!("{} k={k} shard {}: {e:?}", b.slug(), sh.index));
                fabric::place(&sh.graph, &topo)
                    .unwrap_or_else(|e| panic!("{} k={k} shard {}: {e}", b.slug(), sh.index));
            }

            // Cut bookkeeping is internally consistent.
            for cut in &plan.cuts {
                assert_ne!(cut.from, cut.to, "{} k={k}: self-cut", b.slug());
                assert!(cut.from < plan.n_shards() && cut.to < plan.n_shards());
                assert_eq!(g.arc(cut.arc).name, cut.name, "{} k={k}", b.slug());
            }
        }
    }
}

#[test]
fn sharded_execution_matches_whole_graph_on_all_benchmarks() {
    let mut rng = Rng::new(0xFAB51C);
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        for k in PRESSURES {
            let topo = FabricTopology::sized_for_shards(&g, k);
            let plan = fabric::partition(&g, &topo)
                .unwrap_or_else(|e| panic!("{} k={k}: {e}", b.slug()));
            if k == 2 {
                assert!(
                    plan.n_shards() >= 2,
                    "{}: half-size fabric must force a split",
                    b.slug()
                );
            }
            for _ in 0..3 {
                let n = 1 + rng.below(8);
                let seed = rng.next_u64();
                let wl = bench_defs::workload(b, n, seed);
                let cfg = wl.sim_config();
                let whole = run_token(&g, &cfg);
                let sharded = fabric::run_sharded(&plan, &cfg);
                assert_eq!(
                    sharded.outputs,
                    whole.outputs,
                    "{} k={k} n={n} seed={seed}: sharded != whole-graph",
                    b.slug()
                );
                // The workload's software reference agrees too.
                for (port, want) in &wl.expect {
                    assert_eq!(
                        sharded.stream(port),
                        want.as_slice(),
                        "{} k={k} n={n} seed={seed}: port `{port}`",
                        b.slug()
                    );
                }
            }
        }
    }
}

#[test]
fn reconfig_execution_matches_whole_graph_on_all_benchmarks() {
    let mut rng = Rng::new(0x5EC0F16);
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan =
            fabric::partition(&g, &topo).unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
        let n = 1 + rng.below(6);
        let seed = rng.next_u64();
        let wl = bench_defs::workload(b, n, seed);
        let cfg = wl.sim_config();
        let whole = run_token(&g, &cfg);
        let (out, stats) = fabric::run_reconfig(&plan, &topo, &cfg);
        assert_eq!(
            out.outputs,
            whole.outputs,
            "{} n={n} seed={seed}: reconfig != whole-graph",
            b.slug()
        );
        assert!(stats.swaps >= 1, "{}", b.slug());
        assert_eq!(
            stats.reconfig_cycles,
            stats.swaps * topo.reconfig_cycles,
            "{}",
            b.slug()
        );
    }
}

/// The same equivalence as a seeded property: a random benchmark, shard
/// pressure and workload every case, replayable from the reported seed.
#[test]
fn prop_sharded_equivalence_random() {
    check(
        "sharded execution == whole-graph TokenSim",
        PropCfg {
            cases: 24,
            base_seed: 0xD0FAB,
        },
        |r: &mut Rng| {
            let b = BenchId::ALL[r.below(6)];
            let k = 2 + r.below(3);
            let n = 1 + r.below(8);
            let seed = r.next_u64();
            (b, k, n, seed)
        },
        |&(b, k, n, seed)| {
            let g = bench_defs::build(b);
            let topo = FabricTopology::sized_for_shards(&g, k);
            let plan = fabric::partition(&g, &topo)
                .map_err(|e| format!("{}: unpartitionable: {e}", b.slug()))?;
            let wl = bench_defs::workload(b, n, seed);
            let cfg = wl.sim_config();
            let whole = run_token(&g, &cfg);
            let sharded = fabric::run_sharded(&plan, &cfg);
            if sharded.outputs != whole.outputs {
                return Err(format!(
                    "{} k={k}: {:?} != {:?}",
                    b.slug(),
                    sharded.outputs,
                    whole.outputs
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn placer_rejects_over_capacity_demand_with_descriptive_error() {
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let full = FabricTopology::paper();
        // Starve each used class in turn: the placer must name the class
        // and both counts in its error.
        for &class in FabricTopology::demand(&g).keys() {
            let mut topo = full.clone();
            topo.slots.remove(&class);
            let err = match fabric::place(&g, &topo) {
                Err(e) => e,
                Ok(_) => panic!("{}: missing {} slots must reject", b.slug(), class.name()),
            };
            match &err {
                PlaceError::InsufficientSlots {
                    class: c,
                    need,
                    have,
                } => {
                    assert_eq!(*c, class, "{}", b.slug());
                    assert!(*need > 0 && *have == 0, "{}", b.slug());
                }
                other => panic!("{}: wrong error {other:?}", b.slug()),
            }
            let msg = err.to_string();
            assert!(
                msg.contains(class.name()) && msg.contains("operator slots"),
                "{}: undescriptive error `{msg}`",
                b.slug()
            );
        }
        // Starve the channel pool.
        let mut topo = full.clone();
        topo.channels = 0;
        let err = match fabric::place(&g, &topo) {
            Err(e) => e,
            Ok(_) => panic!("{}: no channels must reject", b.slug()),
        };
        assert!(
            matches!(err, PlaceError::InsufficientChannels { have: 0, .. }),
            "{}: {err:?}",
            b.slug()
        );
        assert!(err.to_string().contains("bus channels"), "{}", b.slug());
    }
}

#[test]
fn paper_topology_places_every_benchmark_with_headroom() {
    let topo = FabricTopology::paper();
    for b in BenchId::ALL {
        let g = bench_defs::build(b);
        let p = fabric::place(&g, &topo).unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
        // Placement covers the whole graph.
        assert_eq!(p.slots.len(), g.n_nodes(), "{}", b.slug());
        assert_eq!(p.channels.len(), g.n_arcs(), "{}", b.slug());
        // Utilization never exceeds provisioning.
        for (class, used, total) in p.utilization(&topo) {
            assert!(
                used <= total,
                "{}: class {} over-subscribed ({used}/{total})",
                b.slug(),
                class.name()
            );
        }
        let (cu, ct) = p.channel_utilization(&topo);
        assert!(cu <= ct, "{}", b.slug());
    }
    // Slot entries come straight from benchmark demand plus headroom, so
    // none may be zero.
    for (class, &slots) in &topo.slots {
        assert!(slots > 0, "empty slot entry for {}", class.name());
    }
}
