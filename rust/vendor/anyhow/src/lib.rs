//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network and no vendored crates.io
//! registry, so this is the minimal API-compatible subset `dataflow-accel`
//! uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros, and
//! the [`Context`] extension trait. Like the real crate, [`Error`] does
//! *not* implement `std::error::Error` itself — that is what makes the
//! blanket `From<E: std::error::Error>` conversion (and therefore the `?`
//! operator on arbitrary error types) possible on stable Rust.

use std::fmt;

/// A type-erased error: a display message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message (what `anyhow!` expands
    /// to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap a concrete error value, keeping it as the source.
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Prepend a context line, `anyhow`-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root cause, when the error wraps a concrete source.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error side of a `Result` (or turn an `Option`'s
/// `None` into an error).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (inline captures work
/// because the literal token reaches `format!` unchanged).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // From<ParseIntError> via the blanket impl
        Ok(v)
    }

    #[test]
    fn question_mark_converts() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_prepends() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.map_err(|e| e.context("outer"));
        assert_eq!(e.unwrap_err().to_string(), "outer: inner 7");
    }

    #[test]
    fn with_context_on_result() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let msg = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert!(msg.to_string().starts_with("reading x: "));
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<i32> {
            if flag {
                bail!("flagged {flag}");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged true");
    }

    #[test]
    fn source_is_kept() {
        let e = Error::new(std::io::Error::new(std::io::ErrorKind::Other, "root"));
        assert!(e.source().is_some());
        assert!(Error::msg("plain").source().is_none());
    }
}
