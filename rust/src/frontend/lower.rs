//! AST → static dataflow graph lowering.
//!
//! Invariants the lowering maintains (the module doc of
//! [`super`] explains why each exists):
//!
//! * **lazy copy** — every variable *use* consumes a fresh copy of the
//!   variable's current arc; the remainder arc stays in the environment.
//!   Superseded remainders dangle as anonymous output ports, which the
//!   simulation environment (and, in hardware, a sink) drains.
//! * **literal hoisting** — entering a loop, every literal that appears
//!   inside it becomes a circulating loop variable `#lit_<v>` (constants
//!   fire once; loop bodies need them every iteration).
//! * **if-diamond** — the condition token is fanned out; every variable
//!   (and hoisted literal) an arm touches is routed by a `branch`, each
//!   arm is lowered against its side, and `ndmerge` rejoins.
//! * **while-schema** — loops lower through [`crate::dfg::build_loop`];
//!   loop variables are exactly the environment variables the loop
//!   touches plus its hoisted literals.

use super::ast::{literals_of, vars_of, Expr, Program, Stmt, UnOp};
use super::CError;
use crate::dfg::{build_loop, ArcId, Graph, GraphBuilder, Op};
use std::cell::RefCell;
use std::collections::{HashMap, HashSet};

type Env = HashMap<String, ArcId>;
/// Shared lowering context: a `RefCell` because the while-schema's cond
/// and body closures both need access while `build_loop` holds them.
type Cx<'p> = RefCell<Ctx<'p>>;

/// Lowering context: everything but the builder (so closures can borrow
/// the context and the builder disjointly).
struct Ctx<'p> {
    prog: &'p Program,
    /// Stream input port arcs (consumed at their unique `next` site).
    streams: HashMap<String, ArcId>,
    /// Pre-created FIFO output wires (consumed at the unique `pop` site).
    fifo_out: HashMap<String, ArcId>,
    /// Arcs feeding each FIFO (one per `push` site).
    fifo_pushes: HashMap<String, Vec<ArcId>>,
    /// Output ports already bound.
    outs_bound: HashSet<String>,
}

fn lit_var(v: i16) -> String {
    format!("#lit_{v}")
}

/// One variable use: copy the current arc, keep the remainder.
fn use_var(b: &mut GraphBuilder, env: &mut Env, name: &str) -> ArcId {
    let arc = *env
        .get(name)
        .unwrap_or_else(|| panic!("internal: `{name}` not in env (semantic check missed it)"));
    let (u, rest) = b.copy(arc);
    env.insert(name.to_string(), rest);
    u
}

fn eval(b: &mut GraphBuilder, ctx: &Cx, env: &mut Env, e: &Expr) -> ArcId {
    match e {
        Expr::Lit(v) => {
            let lv = lit_var(*v);
            if env.contains_key(&lv) {
                use_var(b, env, &lv)
            } else {
                b.constant(*v)
            }
        }
        Expr::Var(n) => use_var(b, env, n),
        Expr::Bin(op, x, y) => {
            let ax = eval(b, ctx, env, x);
            let ay = eval(b, ctx, env, y);
            b.op2(op.to_op(), ax, ay)
        }
        Expr::Un(UnOp::Neg, x) => {
            let zero = eval(b, ctx, env, &Expr::Lit(0));
            let ax = eval(b, ctx, env, x);
            b.op2(Op::Sub, zero, ax)
        }
        Expr::Un(UnOp::Not, x) => {
            let ax = eval(b, ctx, env, x);
            let n = b.node(Op::Not, &[ax], &[]);
            b.out_arc(n, 0)
        }
        Expr::Next(s) => *ctx
            .borrow()
            .streams
            .get(s)
            .unwrap_or_else(|| panic!("internal: stream `{s}`")),
        Expr::Pop(f) => *ctx
            .borrow()
            .fifo_out
            .get(f)
            .unwrap_or_else(|| panic!("internal: fifo `{f}`")),
    }
}

/// Bind an evaluated arc to a named output port. Wraps in a copy when the
/// arc is not a fresh internal wire (e.g. `emit(z, next(x))`).
fn bind_output(b: &mut GraphBuilder, arc: ArcId, port: &str) {
    let needs_wrap = b.graph().arc(arc).is_input_port();
    if needs_wrap {
        let (out, _spill) = b.copy(arc);
        b.rename_arc(out, port);
    } else {
        b.rename_arc(arc, port);
    }
}

fn lower_stmts(b: &mut GraphBuilder, ctx: &Cx, env: &mut Env, stmts: &[Stmt]) {
    for s in stmts {
        match s {
            Stmt::Decl(n, e) | Stmt::Assign(n, e) => {
                let arc = eval(b, ctx, env, e);
                if ctx.borrow().prog.out_ints.contains(n) {
                    bind_output(b, arc, n);
                    ctx.borrow_mut().outs_bound.insert(n.clone());
                } else {
                    env.insert(n.clone(), arc);
                }
            }
            Stmt::Emit(p, e) => {
                let arc = eval(b, ctx, env, e);
                bind_output(b, arc, p);
                ctx.borrow_mut().outs_bound.insert(p.clone());
            }
            Stmt::Push(f, e) => {
                let arc = eval(b, ctx, env, e);
                ctx.borrow_mut().fifo_pushes.get_mut(f).unwrap().push(arc);
            }
            Stmt::If(c, t, e) => lower_if(b, ctx, env, c, t, e),
            Stmt::While(c, body) => lower_while(b, ctx, env, c, body),
        }
    }
}

/// The branch/route/ndmerge diamond.
fn lower_if(b: &mut GraphBuilder, ctx: &Cx, env: &mut Env, c: &Expr, t: &[Stmt], e: &[Stmt]) {
    let arms: Vec<Stmt> = t.iter().chain(e).cloned().collect();
    // Route every env-resident variable the arms touch, plus hoisted
    // literals the arms use (they are circulating tokens and must be
    // consumed on exactly one side per execution).
    let mut routed: Vec<String> = vars_of(&arms, None)
        .into_iter()
        .filter(|v| env.contains_key(v))
        .collect();
    for l in literals_of(&arms, None) {
        let lv = lit_var(l);
        if env.contains_key(&lv) && !routed.contains(&lv) {
            routed.push(lv);
        }
    }

    let ctl = eval(b, ctx, env, c);
    if routed.is_empty() {
        // Top-level conditional over constants only: evaluate arms
        // unconditionally is wrong, so this is rejected by the semantic
        // checker; reaching here is a bug.
        panic!("internal: if-statement with nothing to route");
    }
    let taps = b.copy_n(ctl, routed.len());
    let mut then_env = env.clone();
    let mut else_env = env.clone();
    for (i, v) in routed.iter().enumerate() {
        let cur = *env.get(v).unwrap();
        let bn = b.node(Op::Branch, &[taps[i], cur], &[]);
        then_env.insert(v.clone(), b.out_arc(bn, 0));
        else_env.insert(v.clone(), b.out_arc(bn, 1));
    }
    lower_stmts(b, ctx, &mut then_env, t);
    lower_stmts(b, ctx, &mut else_env, e);
    for v in &routed {
        let ta = *then_env.get(v).unwrap();
        let ea = *else_env.get(v).unwrap();
        let m = b.node(Op::NdMerge, &[ta, ea], &[]);
        env.insert(v.clone(), b.out_arc(m, 0));
    }
}

/// The while-schema (via [`build_loop`]), with literal hoisting.
fn lower_while(b: &mut GraphBuilder, ctx: &Cx, env: &mut Env, c: &Expr, body: &[Stmt]) {
    // Hoist literals not already circulating (top-level loops; nested
    // loops inherit their enclosing loop's hoists).
    for l in literals_of(body, Some(c)) {
        let lv = lit_var(l);
        if !env.contains_key(&lv) {
            let arc = b.constant(l);
            env.insert(lv, arc);
        }
    }
    // Loop variables: env-resident vars the loop touches + its literals.
    let mut loop_vars: Vec<String> = vars_of(body, Some(c))
        .into_iter()
        .filter(|v| env.contains_key(v))
        .collect();
    for l in literals_of(body, Some(c)) {
        let lv = lit_var(l);
        if !loop_vars.contains(&lv) {
            loop_vars.push(lv);
        }
    }
    assert!(!loop_vars.is_empty(), "internal: loop with no variables");

    // Which loop variables does the condition read (vars + literals)?
    let mut cond_vars: Vec<String> = Vec::new();
    c.walk(&mut |e| match e {
        Expr::Var(n) => {
            if loop_vars.contains(n) && !cond_vars.contains(n) {
                cond_vars.push(n.clone());
            }
        }
        Expr::Lit(v) => {
            let lv = lit_var(*v);
            if loop_vars.contains(&lv) && !cond_vars.contains(&lv) {
                cond_vars.push(lv);
            }
        }
        _ => {}
    });
    let cond_uses: Vec<usize> = cond_vars
        .iter()
        .map(|v| loop_vars.iter().position(|x| x == v).unwrap())
        .collect();

    let inits: Vec<ArcId> = loop_vars.iter().map(|v| env[v]).collect();

    let cond_vars_c = cond_vars.clone();
    let loop_vars_c = loop_vars.clone();
    let exits = build_loop(
        b,
        &inits,
        &cond_uses,
        |b, taps| {
            // Condition env: the tapped copies, under their names.
            let mut cenv: Env = cond_vars_c
                .iter()
                .cloned()
                .zip(taps.iter().copied())
                .collect();
            eval(b, ctx, &mut cenv, c)
            // Leftover remainders in cenv dangle; drained by the env.
        },
        |b, gated| {
            let mut benv: Env = loop_vars_c
                .iter()
                .cloned()
                .zip(gated.iter().copied())
                .collect();
            lower_stmts(b, ctx, &mut benv, body);
            loop_vars_c.iter().map(|v| benv[v]).collect()
        },
    );
    for (v, x) in loop_vars.iter().zip(exits) {
        env.insert(v.clone(), x);
    }
}

// ---- semantic checking ------------------------------------------------

fn count_sites(stmts: &[Stmt], f: &mut impl FnMut(&Expr), sf: &mut impl FnMut(&Stmt)) {
    for s in stmts {
        s.walk(sf, f);
    }
}

fn semantic_check(prog: &Program) -> Result<(), CError> {
    let err = |m: String| Err(CError::Semantic(m));

    // Unique next/pop/emit/out-assignment sites.
    let mut next_sites: HashMap<String, u32> = HashMap::new();
    let mut pop_sites: HashMap<String, u32> = HashMap::new();
    let mut emit_sites: HashMap<String, u32> = HashMap::new();
    let mut out_assigns: HashMap<String, u32> = HashMap::new();
    count_sites(
        &prog.body,
        &mut |e| match e {
            Expr::Next(s) => *next_sites.entry(s.clone()).or_insert(0) += 1,
            Expr::Pop(f) => *pop_sites.entry(f.clone()).or_insert(0) += 1,
            _ => {}
        },
        &mut |s| match s {
            Stmt::Emit(p, _) => *emit_sites.entry(p.clone()).or_insert(0) += 1,
            Stmt::Assign(n, _) | Stmt::Decl(n, _) if prog.out_ints.contains(n) => {
                *out_assigns.entry(n.clone()).or_insert(0) += 1
            }
            _ => {}
        },
    );
    for (s, n) in &next_sites {
        if !prog.in_streams.contains(s) {
            return err(format!("next() on undeclared stream `{s}`"));
        }
        if *n > 1 {
            return err(format!(
                "stream `{s}` is read at {n} sites; a dataflow channel has one \
                 consumer — bind it to a variable instead"
            ));
        }
    }
    for (f, n) in &pop_sites {
        if !prog.fifos.contains(f) {
            return err(format!("pop() on undeclared fifo `{f}`"));
        }
        if *n > 1 {
            return err(format!("fifo `{f}` is popped at {n} sites; only one allowed"));
        }
    }
    for (p, n) in &emit_sites {
        if !prog.out_streams.contains(p) {
            return err(format!("emit() to undeclared output stream `{p}`"));
        }
        if *n > 1 {
            return err(format!("output stream `{p}` has {n} emit sites; only one allowed"));
        }
    }
    for o in &prog.out_ints {
        match out_assigns.get(o) {
            Some(1) => {}
            Some(n) => return err(format!("output `{o}` assigned {n} times")),
            None => return err(format!("output `{o}` never assigned")),
        }
    }

    // Variables defined before use; no next/pop inside if-arms; if-arms
    // must reference a variable or literal (so routing can gate them).
    fn check_stmts(
        prog: &Program,
        stmts: &[Stmt],
        defined: &mut HashSet<String>,
        in_if_arm: bool,
    ) -> Result<(), CError> {
        let err = |m: String| Err(CError::Semantic(m));
        for s in stmts {
            // expression-level checks
            let mut bad: Option<String> = None;
            let check_expr = |e: &Expr, defined: &HashSet<String>, bad: &mut Option<String>| {
                e.walk(&mut |e| match e {
                    Expr::Var(n) => {
                        if !defined.contains(n) && bad.is_none() {
                            *bad = Some(format!("variable `{n}` used before definition"));
                        }
                    }
                    Expr::Next(_) | Expr::Pop(_) if in_if_arm => {
                        if bad.is_none() {
                            *bad = Some(
                                "next()/pop() inside a conditional arm is not \
                                 gateable; read into a variable first"
                                    .to_string(),
                            );
                        }
                    }
                    _ => {}
                });
            };
            match s {
                Stmt::Decl(n, e) => {
                    check_expr(e, defined, &mut bad);
                    defined.insert(n.clone());
                }
                Stmt::Assign(n, e) => {
                    check_expr(e, defined, &mut bad);
                    if !defined.contains(n) && !prog.out_ints.contains(n) {
                        return err(format!("assignment to undeclared variable `{n}`"));
                    }
                }
                Stmt::Emit(_, e) | Stmt::Push(_, e) => check_expr(e, defined, &mut bad),
                Stmt::While(c, body) => {
                    check_expr(c, defined, &mut bad);
                    let mut inner = defined.clone();
                    check_stmts(prog, body, &mut inner, in_if_arm)?;
                }
                Stmt::If(c, t, el) => {
                    check_expr(c, defined, &mut bad);
                    for arm in [t, el] {
                        if !arm.is_empty() {
                            let arm_vars = vars_of(arm, None);
                            let has_ref = arm_vars.iter().any(|v| defined.contains(v))
                                || !literals_of(arm, None).is_empty();
                            if !has_ref {
                                return err(
                                    "conditional arm references no variable or literal; \
                                     it cannot be gated"
                                        .to_string(),
                                );
                            }
                        }
                        let mut inner = defined.clone();
                        check_stmts(prog, arm, &mut inner, true)?;
                    }
                }
            }
            if let Some(m) = bad {
                return err(m);
            }
        }
        Ok(())
    }

    let mut defined: HashSet<String> = prog.in_ints.iter().cloned().collect();
    check_stmts(prog, &prog.body, &mut defined, false)
}

/// Lower a checked program to a dataflow graph and run the optimizer's
/// default pipeline over it (the lazy-copy discipline leaves copy
/// chains and constant subgraphs the paper's hand-drawn graphs don't
/// have; see [`crate::opt`]).
pub fn lower(name: &str, prog: &Program) -> Result<Graph, CError> {
    lower_with(name, prog, crate::opt::OptLevel::Default)
}

/// [`lower`] with an explicit [`OptLevel`](crate::opt::OptLevel) —
/// `None` yields the raw lowering (what the optimizer's differential
/// harness compares against).
pub fn lower_with(
    name: &str,
    prog: &Program,
    level: crate::opt::OptLevel,
) -> Result<Graph, CError> {
    let g = lower_raw(name, prog)?;
    Ok(crate::opt::optimize(&g, level).0)
}

fn lower_raw(name: &str, prog: &Program) -> Result<Graph, CError> {
    semantic_check(prog)?;

    let mut b = GraphBuilder::new(name);
    let mut env: Env = Env::new();
    let ctx = RefCell::new(Ctx {
        prog,
        streams: HashMap::new(),
        fifo_out: HashMap::new(),
        fifo_pushes: prog.fifos.iter().map(|f| (f.clone(), Vec::new())).collect(),
        outs_bound: HashSet::new(),
    });

    for n in &prog.in_ints {
        let arc = b.input_port(n);
        env.insert(n.clone(), arc);
    }
    for s in &prog.in_streams {
        let arc = b.input_port(s);
        ctx.borrow_mut().streams.insert(s.clone(), arc);
    }
    for f in &prog.fifos {
        let w = b.wire();
        ctx.borrow_mut().fifo_out.insert(f.clone(), w);
    }

    lower_stmts(&mut b, &ctx, &mut env, &prog.body);

    // Close the FIFOs: merge push sites, instantiate the node.
    let mut ctx = ctx.into_inner();
    for f in &prog.fifos {
        let pushes = ctx.fifo_pushes.remove(f).unwrap();
        let out = ctx.fifo_out[f];
        if pushes.is_empty() {
            return Err(CError::Semantic(format!("fifo `{f}` is never pushed")));
        }
        let mut merged = pushes[0];
        for &p in &pushes[1..] {
            let m = b.node(Op::NdMerge, &[merged, p], &[]);
            merged = b.out_arc(m, 0);
        }
        b.node(Op::Fifo(crate::bench_defs::bubble::FIFO_DEPTH), &[merged], &[out]);
    }

    Ok(b.finish()?)
}
