//! Abstract syntax of the mini-C subset.

/// Binary operators, mapped 1:1 onto dataflow ALU/decider opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl BinOp {
    pub fn to_op(self) -> crate::dfg::Op {
        use crate::dfg::Op;
        match self {
            BinOp::Add => Op::Add,
            BinOp::Sub => Op::Sub,
            BinOp::Mul => Op::Mul,
            BinOp::Div => Op::Div,
            BinOp::And => Op::And,
            BinOp::Or => Op::Or,
            BinOp::Xor => Op::Xor,
            BinOp::Shl => Op::Shl,
            BinOp::Shr => Op::Shr,
            BinOp::Lt => Op::IfLt,
            BinOp::Le => Op::IfLe,
            BinOp::Gt => Op::IfGt,
            BinOp::Ge => Op::IfGe,
            BinOp::Eq => Op::IfEq,
            BinOp::Ne => Op::IfDf,
        }
    }

    pub fn eval(self, a: i16, b: i16) -> i16 {
        self.to_op().eval2(a, b)
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    /// Arithmetic negation (lowered as `0 - e`).
    Neg,
    /// Bitwise complement (the dataflow `not`).
    Not,
}

/// Expressions.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Lit(i16),
    Var(String),
    Bin(BinOp, Box<Expr>, Box<Expr>),
    Un(UnOp, Box<Expr>),
    /// `next(stream)` — consume one token from a stream input port.
    Next(String),
    /// `pop(fifo)` — consume one token from an on-fabric FIFO.
    Pop(String),
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `int x = e;`
    Decl(String, Expr),
    /// `x = e;`
    Assign(String, Expr),
    /// `while (e) { ... }`
    While(Expr, Vec<Stmt>),
    /// `if (e) { ... } else { ... }`
    If(Expr, Vec<Stmt>, Vec<Stmt>),
    /// `emit(port, e);`
    Emit(String, Expr),
    /// `push(fifo, e);`
    Push(String, Expr),
}

/// A whole program: port/fifo declarations plus top-level statements.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Program {
    pub in_ints: Vec<String>,
    pub in_streams: Vec<String>,
    pub out_ints: Vec<String>,
    pub out_streams: Vec<String>,
    pub fifos: Vec<String>,
    pub body: Vec<Stmt>,
}

impl Expr {
    /// Visit every sub-expression.
    pub fn walk(&self, f: &mut impl FnMut(&Expr)) {
        f(self);
        match self {
            Expr::Bin(_, a, b) => {
                a.walk(f);
                b.walk(f);
            }
            Expr::Un(_, a) => a.walk(f),
            _ => {}
        }
    }
}

impl Stmt {
    /// Visit every statement (depth-first) and every expression in it.
    pub fn walk(&self, sf: &mut impl FnMut(&Stmt), ef: &mut impl FnMut(&Expr)) {
        sf(self);
        match self {
            Stmt::Decl(_, e) | Stmt::Assign(_, e) | Stmt::Emit(_, e) | Stmt::Push(_, e) => {
                e.walk(ef)
            }
            Stmt::While(c, body) => {
                c.walk(ef);
                for s in body {
                    s.walk(sf, ef);
                }
            }
            Stmt::If(c, t, e) => {
                c.walk(ef);
                for s in t.iter().chain(e) {
                    s.walk(sf, ef);
                }
            }
        }
    }
}

/// All variable names read or written in the statements (not literals,
/// not stream/fifo names).
pub fn vars_of(stmts: &[Stmt], cond: Option<&Expr>) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    let mut push = |n: &str| {
        if !out.iter().any(|v| v == n) {
            out.push(n.to_string());
        }
    };
    let mut ef = |e: &Expr| {
        if let Expr::Var(n) = e {
            push(n);
        }
    };
    if let Some(c) = cond {
        c.walk(&mut ef);
    }
    let mut out2: Vec<String> = Vec::new();
    for s in stmts {
        s.walk(
            &mut |s| match s {
                Stmt::Decl(n, _) | Stmt::Assign(n, _) => {
                    if !out2.iter().any(|v| v == n) {
                        out2.push(n.clone());
                    }
                }
                _ => {}
            },
            &mut ef,
        );
    }
    for n in out2 {
        if !out.iter().any(|v| *v == n) {
            out.push(n);
        }
    }
    out
}

/// Variables *assigned* in the statements (excluding fresh `Decl`s, which
/// are scoped to the block).
pub fn mutated_of(stmts: &[Stmt]) -> Vec<String> {
    let mut out = Vec::new();
    let mut declared = Vec::new();
    for s in stmts {
        s.walk(
            &mut |s| match s {
                Stmt::Decl(n, _) => declared.push(n.clone()),
                Stmt::Assign(n, _) => {
                    if !out.contains(n) {
                        out.push(n.clone());
                    }
                }
                _ => {}
            },
            &mut |_| {},
        );
    }
    out.retain(|n| !declared.contains(n));
    out
}

/// All integer literals appearing in the statements + condition.
pub fn literals_of(stmts: &[Stmt], cond: Option<&Expr>) -> Vec<i16> {
    let mut out = Vec::new();
    let mut ef = |e: &Expr| {
        if let Expr::Lit(v) = e {
            if !out.contains(v) {
                out.push(*v);
            }
        }
    };
    if let Some(c) = cond {
        c.walk(&mut ef);
    }
    for s in stmts {
        s.walk(&mut |_| {}, &mut ef);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(n: &str) -> Expr {
        Expr::Var(n.into())
    }

    #[test]
    fn vars_of_collects_reads_and_writes() {
        let body = vec![
            Stmt::Assign("x".into(), Expr::Bin(BinOp::Add, Box::new(v("y")), Box::new(Expr::Lit(1)))),
            Stmt::While(v("z"), vec![Stmt::Assign("w".into(), Expr::Lit(0))]),
        ];
        let vs = vars_of(&body, Some(&v("c")));
        for n in ["c", "x", "y", "z", "w"] {
            assert!(vs.iter().any(|s| s == n), "missing {n}");
        }
    }

    #[test]
    fn mutated_excludes_block_locals() {
        let body = vec![
            Stmt::Decl("t".into(), Expr::Lit(0)),
            Stmt::Assign("t".into(), Expr::Lit(1)),
            Stmt::Assign("x".into(), Expr::Lit(2)),
        ];
        let m = mutated_of(&body);
        assert_eq!(m, vec!["x".to_string()]);
    }

    #[test]
    fn literals_dedup() {
        let body = vec![
            Stmt::Assign("x".into(), Expr::Bin(BinOp::Add, Box::new(Expr::Lit(1)), Box::new(Expr::Lit(1)))),
            Stmt::Assign("y".into(), Expr::Lit(2)),
        ];
        let mut l = literals_of(&body, None);
        l.sort();
        assert_eq!(l, vec![1, 2]);
    }
}
