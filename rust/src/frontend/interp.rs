//! Reference interpreter for the mini-C subset — the oracle for
//! differential tests against the dataflow lowering.

use super::ast::{Expr, Program, Stmt, UnOp};
use crate::dfg::Word;
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Interpreter outcome: tokens per output port (scalars are single-token
/// streams) in emission order.
#[derive(Debug, Clone, Default)]
pub struct InterpResult {
    pub outputs: BTreeMap<String, Vec<Word>>,
}

struct I<'p> {
    prog: &'p Program,
    env: HashMap<String, Word>,
    streams: HashMap<String, VecDeque<Word>>,
    fifos: HashMap<String, VecDeque<Word>>,
    out: InterpResult,
    fuel: u64,
}

impl<'p> I<'p> {
    fn eval(&mut self, e: &Expr) -> Result<Word, String> {
        Ok(match e {
            Expr::Lit(v) => *v,
            Expr::Var(n) => *self
                .env
                .get(n)
                .ok_or_else(|| format!("undefined variable `{n}`"))?,
            Expr::Bin(op, a, b) => {
                let va = self.eval(a)?;
                let vb = self.eval(b)?;
                op.eval(va, vb)
            }
            Expr::Un(UnOp::Neg, a) => 0i16.wrapping_sub(self.eval(a)?),
            Expr::Un(UnOp::Not, a) => !self.eval(a)?,
            Expr::Next(s) => self
                .streams
                .get_mut(s)
                .ok_or_else(|| format!("unknown stream `{s}`"))?
                .pop_front()
                .ok_or_else(|| format!("stream `{s}` exhausted"))?,
            Expr::Pop(f) => self
                .fifos
                .get_mut(f)
                .ok_or_else(|| format!("unknown fifo `{f}`"))?
                .pop_front()
                .ok_or_else(|| format!("fifo `{f}` empty"))?,
        })
    }

    fn exec(&mut self, stmts: &[Stmt]) -> Result<(), String> {
        for s in stmts {
            self.fuel = self
                .fuel
                .checked_sub(1)
                .ok_or_else(|| "interpreter fuel exhausted".to_string())?;
            match s {
                Stmt::Decl(n, e) | Stmt::Assign(n, e) => {
                    let v = self.eval(e)?;
                    if self.prog.out_ints.contains(n) {
                        self.out.outputs.entry(n.clone()).or_default().push(v);
                    } else {
                        self.env.insert(n.clone(), v);
                    }
                }
                Stmt::While(c, body) => {
                    while self.eval(c)? != 0 {
                        self.fuel = self
                            .fuel
                            .checked_sub(1)
                            .ok_or_else(|| "interpreter fuel exhausted".to_string())?;
                        self.exec(body)?;
                    }
                }
                Stmt::If(c, t, e) => {
                    if self.eval(c)? != 0 {
                        self.exec(t)?;
                    } else {
                        self.exec(e)?;
                    }
                }
                Stmt::Emit(p, e) => {
                    let v = self.eval(e)?;
                    self.out.outputs.entry(p.clone()).or_default().push(v);
                }
                Stmt::Push(f, e) => {
                    let v = self.eval(e)?;
                    self.fifos
                        .get_mut(f)
                        .ok_or_else(|| format!("unknown fifo `{f}`"))?
                        .push_back(v);
                }
            }
        }
        Ok(())
    }
}

/// Run a program on the given input streams (scalar inputs are
/// single-token streams, matching [`crate::sim::SimConfig::inject`]).
pub fn interpret(
    prog: &Program,
    inject: &BTreeMap<String, Vec<Word>>,
    fuel: u64,
) -> Result<InterpResult, String> {
    let mut i = I {
        prog,
        env: HashMap::new(),
        streams: HashMap::new(),
        fifos: prog
            .fifos
            .iter()
            .map(|f| (f.clone(), VecDeque::new()))
            .collect(),
        out: InterpResult::default(),
        fuel,
    };
    for n in &prog.in_ints {
        let v = inject
            .get(n)
            .and_then(|s| s.first())
            .copied()
            .ok_or_else(|| format!("no input for scalar port `{n}`"))?;
        i.env.insert(n.clone(), v);
    }
    for s in &prog.in_streams {
        let stream = inject.get(s).cloned().unwrap_or_default();
        i.streams.insert(s.clone(), stream.into());
    }
    for p in prog.out_ints.iter().chain(&prog.out_streams) {
        i.out.outputs.entry(p.clone()).or_default();
    }
    i.exec(&prog.body)?;
    Ok(i.out)
}

#[cfg(test)]
mod tests {
    use super::super::{lex, parse_program};
    use super::*;

    fn run(src: &str, inject: &[(&str, Vec<Word>)]) -> InterpResult {
        let prog = parse_program(&lex(src).unwrap()).unwrap();
        let inj: BTreeMap<String, Vec<Word>> = inject
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect();
        interpret(&prog, &inj, 1_000_000).unwrap()
    }

    #[test]
    fn interprets_fibonacci() {
        let r = run(
            crate::bench_defs::c_source(crate::bench_defs::BenchId::Fibonacci),
            &[("n", vec![10])],
        );
        assert_eq!(r.outputs["fibo"], vec![55]);
    }

    #[test]
    fn interprets_streams_and_fifos() {
        let src = "
            in stream x;
            out stream y;
            fifo q;
            int i = 0;
            while (i < 3) {
                push(q, next(x) * 2);
                i = i + 1;
            }
            int j = 0;
            while (j < 3) {
                emit(y, pop(q));
                j = j + 1;
            }
        ";
        let r = run(src, &[("x", vec![1, 2, 3])]);
        assert_eq!(r.outputs["y"], vec![2, 4, 6]);
    }

    #[test]
    fn fuel_bounds_infinite_loops() {
        let prog = parse_program(&lex("out int r; int i = 1; while (i > 0) { i = 1; } r = i;").unwrap()).unwrap();
        assert!(interpret(&prog, &BTreeMap::new(), 10_000).is_err());
    }

    #[test]
    fn stream_exhaustion_is_an_error() {
        let prog =
            parse_program(&lex("in stream x; out int r; r = next(x);").unwrap()).unwrap();
        let mut inj = BTreeMap::new();
        inj.insert("x".to_string(), vec![]);
        assert!(interpret(&prog, &inj, 1000).is_err());
    }
}
