//! The mini-C frontend — the paper's named future work ("develop a
//! module to convert C directly into a VHDL", §6), completing the
//! C → dataflow graph → VHDL chain.
//!
//! The language is the C subset the paper's benchmarks need:
//!
//! ```c
//! in int n;            // scalar input port
//! in stream x;         // stream input port (read with next(x))
//! out int max;         // scalar output port
//! out stream z;        // stream output port (written with emit(z, e))
//! fifo buf;            // on-fabric FIFO (push(buf, e) / pop(buf))
//! int m = -32768;      // 16-bit locals
//! while (e) { ... }    // loops (arbitrarily nested)
//! if (e) { ... } else { ... }
//! m = e;  emit(z, e);  push(buf, e);
//! // expressions: + - * / & | ^ << >> < <= > >= == != unary - ~
//! ```
//!
//! Lowering rules (see `lower.rs`):
//!
//! * every loop becomes the canonical while-schema
//!   ([`crate::dfg::build_loop`]);
//! * literals used inside a loop are hoisted into circulating loop
//!   variables (a dataflow constant fires only once);
//! * `if` becomes the branch/route/ndmerge diamond — every routed token
//!   is consumed on exactly one side;
//! * values threading through an inner loop sequence the enclosing
//!   loop's iterations (this is what makes FIFO recirculation safe);
//! * each `next`/`pop`/`emit` site must be unique per port — a dataflow
//!   channel has one consumer and one producer (§3).
//!
//! The frontend also ships a reference interpreter (`interp.rs`) used by
//! differential tests: interpreter results == dataflow-simulation results
//! for every program and input.

mod ast;
mod interp;
mod lexer;
mod lower;
mod parser;

pub use ast::{literals_of, mutated_of, vars_of, BinOp, Expr, Program, Stmt, UnOp};
pub use interp::{interpret, InterpResult};
pub use lexer::{lex, Token};
pub use lower::{lower, lower_with};
pub use parser::parse_program;

use crate::dfg::Graph;

#[derive(Debug)]
pub enum CError {
    Lex(usize, String),
    Parse(usize, String),
    Semantic(String),
    Graph(crate::dfg::ValidateError),
}

impl std::fmt::Display for CError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CError::Lex(line, msg) => write!(f, "lex error at line {line}: {msg}"),
            CError::Parse(line, msg) => write!(f, "parse error at line {line}: {msg}"),
            CError::Semantic(msg) => write!(f, "semantic error: {msg}"),
            CError::Graph(e) => write!(f, "graph construction failed: {e}"),
        }
    }
}

impl std::error::Error for CError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CError::Graph(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::dfg::ValidateError> for CError {
    fn from(e: crate::dfg::ValidateError) -> Self {
        CError::Graph(e)
    }
}

/// Compile mini-C source into a static dataflow graph. The result is
/// optimized at [`OptLevel::Default`](crate::opt::OptLevel) — use
/// [`compile_with`] to control (or disable) the pipeline.
pub fn compile(name: &str, src: &str) -> Result<Graph, CError> {
    compile_with(name, src, crate::opt::OptLevel::Default)
}

/// [`compile`] with an explicit optimizer level.
pub fn compile_with(
    name: &str,
    src: &str,
    level: crate::opt::OptLevel,
) -> Result<Graph, CError> {
    let tokens = lex(src)?;
    let prog = parse_program(&tokens)?;
    lower_with(name, &prog, level)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};
    use crate::sim::{run_token, SimConfig};
    use crate::util::proptest::{check, PropCfg};
    use crate::util::Rng;

    #[test]
    fn compiles_all_paper_benchmarks() {
        for b in BenchId::ALL {
            let src = bench_defs::c_source(b);
            compile(b.slug(), src).unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
        }
    }

    /// The compiled graphs compute the same results as the hand-built
    /// graphs on the standard workloads — the full C→graph→simulation
    /// chain, checked per benchmark.
    #[test]
    fn compiled_benchmarks_match_workloads() {
        for b in BenchId::ALL {
            let g = compile(b.slug(), bench_defs::c_source(b)).unwrap();
            let wl = bench_defs::workload(b, 6, 21);
            let mut cfg = wl.sim_config();
            cfg.max_cycles *= 4;
            let out = run_token(&g, &cfg);
            for (port, want) in &wl.expect {
                assert_eq!(
                    out.stream(port),
                    want.as_slice(),
                    "{} (compiled from C)",
                    b.slug()
                );
            }
        }
    }

    #[test]
    fn interpreter_matches_dataflow_on_benchmarks() {
        check(
            "interp == dataflow over benchmark suite",
            PropCfg {
                cases: 24,
                base_seed: 77,
            },
            |r: &mut Rng| {
                let b = BenchId::ALL[r.below(6)];
                let n = 1 + r.below(8);
                let seed = r.next_u64();
                (b, n, seed)
            },
            |&(b, n, seed)| {
                let wl = bench_defs::workload(b, n, seed);
                let prog = parse_program(&lex(bench_defs::c_source(b)).unwrap()).unwrap();
                let interp = interpret(&prog, &wl.inject, 2_000_000)
                    .map_err(|e| format!("{}: interp: {e}", b.slug()))?;
                let g = compile(b.slug(), bench_defs::c_source(b)).unwrap();
                let mut cfg = wl.sim_config();
                cfg.max_cycles *= 4;
                let sim = run_token(&g, &cfg);
                for (port, want) in &wl.expect {
                    let got_i = interp.outputs.get(port).cloned().unwrap_or_default();
                    if &got_i != want {
                        return Err(format!(
                            "{}: interpreter {got_i:?} != expected {want:?}",
                            b.slug()
                        ));
                    }
                    if sim.stream(port) != want.as_slice() {
                        return Err(format!(
                            "{}: dataflow {:?} != expected {want:?}",
                            b.slug(),
                            sim.stream(port)
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nested_control_flow_compiles_and_runs() {
        // Collatz-ish nested if inside while: exercises if/else diamonds
        // with mutation in both arms inside a data-dependent loop.
        let src = "
            in int x;
            out int steps;
            int w = x;
            int s = 0;
            while (w > 1) {
                if ((w & 1) == 1) {
                    w = w * 3 + 1;
                } else {
                    w = w / 2;
                }
                s = s + 1;
            }
            steps = s;
        ";
        let g = compile("collatz", src).unwrap();
        for x in [1i16, 2, 3, 6, 7, 27] {
            let cfg = SimConfig::new().inject("x", vec![x]).max_cycles(2_000_000);
            let out = run_token(&g, &cfg);
            // reference
            let (mut w, mut s) = (x, 0i16);
            while w > 1 {
                w = if w & 1 == 1 { w.wrapping_mul(3).wrapping_add(1) } else { w / 2 };
                s += 1;
            }
            assert_eq!(out.last("steps"), Some(s), "collatz({x})");
        }
    }

    #[test]
    fn if_without_else() {
        let src = "
            in int a;
            in int b;
            out int r;
            int m = a;
            if (b > m) { m = b; }
            r = m;
        ";
        let g = compile("max2", src).unwrap();
        for (a, b) in [(3, 7), (7, 3), (5, 5), (-1, -2)] {
            let cfg = SimConfig::new().inject("a", vec![a]).inject("b", vec![b]);
            assert_eq!(run_token(&g, &cfg).last("r"), Some(a.max(b)), "({a},{b})");
        }
    }

    #[test]
    fn rejects_double_stream_read_sites() {
        let src = "
            in stream x;
            out int r;
            r = next(x) + next(x);
        ";
        assert!(matches!(compile("bad", src), Err(CError::Semantic(_))));
    }

    #[test]
    fn rejects_unknown_variable() {
        let src = "out int r; r = q + 1;";
        assert!(matches!(compile("bad", src), Err(CError::Semantic(_))));
    }

    #[test]
    fn compiled_vhdl_roundtrip() {
        // C → graph → VHDL and C → graph → asm → graph all hold together.
        let g = compile("fibonacci", bench_defs::c_source(BenchId::Fibonacci)).unwrap();
        let vhdl = crate::vhdl::generate(&g);
        assert!(vhdl.top.contains("entity fibonacci is"));
        let asm = crate::asm::print(&g);
        let g2 = crate::asm::parse("fibonacci", &asm).unwrap();
        assert_eq!(g.n_nodes(), g2.n_nodes());
    }

    #[test]
    fn expression_precedence() {
        let src = "
            in int a;
            out int r;
            r = 2 + 3 * a - (a >> 1 & 3);
        ";
        let g = compile("prec", src).unwrap();
        for a in [0i16, 1, 5, 9, 100] {
            let cfg = SimConfig::new().inject("a", vec![a]);
            let want = 2i16
                .wrapping_add(3i16.wrapping_mul(a))
                .wrapping_sub((a >> 1) & 3);
            assert_eq!(run_token(&g, &cfg).last("r"), Some(want), "a={a}");
        }
    }
}
