//! Tokenizer for the mini-C subset.

use super::CError;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Token {
    // keywords
    In,
    Out,
    Int,
    Stream,
    Fifo,
    While,
    If,
    Else,
    Next,
    Pop,
    Push,
    Emit,
    // punctuation
    LParen,
    RParen,
    LBrace,
    RBrace,
    Semi,
    Comma,
    Assign,
    // operators
    Plus,
    Minus,
    Star,
    Slash,
    Amp,
    Pipe,
    Caret,
    Tilde,
    Shl,
    Shr,
    Lt,
    Le,
    Gt,
    Ge,
    EqEq,
    Ne,
    // atoms
    Ident(String),
    Num(i32),
    /// line number marker (internal; lets the parser report lines)
    Line(usize),
}

/// Tokenize; interleaves `Token::Line` markers at line starts.
pub fn lex(src: &str) -> Result<Vec<Token>, CError> {
    let mut out = Vec::new();
    for (ln, line) in src.lines().enumerate() {
        let line_no = ln + 1;
        out.push(Token::Line(line_no));
        let line = match line.find("//") {
            Some(i) => &line[..i],
            None => line,
        };
        let mut it = line.chars().peekable();
        while let Some(&c) = it.peek() {
            match c {
                ' ' | '\t' | '\r' => {
                    it.next();
                }
                '(' => { it.next(); out.push(Token::LParen); }
                ')' => { it.next(); out.push(Token::RParen); }
                '{' => { it.next(); out.push(Token::LBrace); }
                '}' => { it.next(); out.push(Token::RBrace); }
                ';' => { it.next(); out.push(Token::Semi); }
                ',' => { it.next(); out.push(Token::Comma); }
                '+' => { it.next(); out.push(Token::Plus); }
                '-' => { it.next(); out.push(Token::Minus); }
                '*' => { it.next(); out.push(Token::Star); }
                '/' => { it.next(); out.push(Token::Slash); }
                '&' => { it.next(); out.push(Token::Amp); }
                '|' => { it.next(); out.push(Token::Pipe); }
                '^' => { it.next(); out.push(Token::Caret); }
                '~' => { it.next(); out.push(Token::Tilde); }
                '<' => {
                    it.next();
                    match it.peek() {
                        Some('=') => { it.next(); out.push(Token::Le); }
                        Some('<') => { it.next(); out.push(Token::Shl); }
                        _ => out.push(Token::Lt),
                    }
                }
                '>' => {
                    it.next();
                    match it.peek() {
                        Some('=') => { it.next(); out.push(Token::Ge); }
                        Some('>') => { it.next(); out.push(Token::Shr); }
                        _ => out.push(Token::Gt),
                    }
                }
                '=' => {
                    it.next();
                    if it.peek() == Some(&'=') {
                        it.next();
                        out.push(Token::EqEq);
                    } else {
                        out.push(Token::Assign);
                    }
                }
                '!' => {
                    it.next();
                    if it.peek() == Some(&'=') {
                        it.next();
                        out.push(Token::Ne);
                    } else {
                        return Err(CError::Lex(line_no, "`!` without `=`".into()));
                    }
                }
                '0'..='9' => {
                    let mut n = 0i64;
                    while let Some(&d) = it.peek() {
                        if let Some(v) = d.to_digit(10) {
                            n = n * 10 + v as i64;
                            it.next();
                            if n > i32::MAX as i64 {
                                return Err(CError::Lex(line_no, "number too large".into()));
                            }
                        } else {
                            break;
                        }
                    }
                    out.push(Token::Num(n as i32));
                }
                c if c.is_ascii_alphabetic() || c == '_' => {
                    let mut s = String::new();
                    while let Some(&d) = it.peek() {
                        if d.is_ascii_alphanumeric() || d == '_' {
                            s.push(d);
                            it.next();
                        } else {
                            break;
                        }
                    }
                    out.push(match s.as_str() {
                        "in" => Token::In,
                        "out" => Token::Out,
                        "int" => Token::Int,
                        "stream" => Token::Stream,
                        "fifo" => Token::Fifo,
                        "while" => Token::While,
                        "if" => Token::If,
                        "else" => Token::Else,
                        "next" => Token::Next,
                        "pop" => Token::Pop,
                        "push" => Token::Push,
                        "emit" => Token::Emit,
                        _ => Token::Ident(s),
                    });
                }
                other => {
                    return Err(CError::Lex(line_no, format!("unexpected `{other}`")));
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Token> {
        lex(src)
            .unwrap()
            .into_iter()
            .filter(|t| !matches!(t, Token::Line(_)))
            .collect()
    }

    #[test]
    fn lexes_declaration() {
        assert_eq!(
            toks("in int n;"),
            vec![Token::In, Token::Int, Token::Ident("n".into()), Token::Semi]
        );
    }

    #[test]
    fn lexes_operators() {
        assert_eq!(
            toks("a <= b >> 2 != c"),
            vec![
                Token::Ident("a".into()),
                Token::Le,
                Token::Ident("b".into()),
                Token::Shr,
                Token::Num(2),
                Token::Ne,
                Token::Ident("c".into()),
            ]
        );
    }

    #[test]
    fn comments_are_stripped() {
        assert_eq!(toks("x // the whole rest ; = 5"), vec![Token::Ident("x".into())]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(lex("a $ b").is_err());
        assert!(lex("a ! b").is_err());
    }
}
