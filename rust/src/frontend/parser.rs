//! Recursive-descent parser.

use super::ast::{BinOp, Expr, Program, Stmt, UnOp};
use super::lexer::Token;
use super::CError;

struct P<'t> {
    toks: &'t [Token],
    pos: usize,
    line: usize,
}

impl<'t> P<'t> {
    fn peek(&mut self) -> Option<&'t Token> {
        while let Some(Token::Line(l)) = self.toks.get(self.pos) {
            self.line = *l;
            self.pos += 1;
        }
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<&'t Token> {
        let t = self.peek()?;
        self.pos += 1;
        Some(t)
    }

    fn err(&self, msg: impl Into<String>) -> CError {
        CError::Parse(self.line, msg.into())
    }

    fn expect(&mut self, t: &Token, what: &str) -> Result<(), CError> {
        match self.next() {
            Some(x) if x == t => Ok(()),
            other => Err(self.err(format!("expected {what}, found {other:?}"))),
        }
    }

    fn ident(&mut self) -> Result<String, CError> {
        match self.next() {
            Some(Token::Ident(s)) => Ok(s.clone()),
            other => Err(self.err(format!("expected identifier, found {other:?}"))),
        }
    }

    // ---- expressions, precedence climbing --------------------------
    // lowest: | ^ &  then == !=  then < <= > >=  then << >>  then + -
    // then * /  then unary.
    fn expr(&mut self) -> Result<Expr, CError> {
        self.bin_or()
    }

    fn bin_level(
        &mut self,
        next: fn(&mut Self) -> Result<Expr, CError>,
        table: &[(Token, BinOp)],
    ) -> Result<Expr, CError> {
        let mut lhs = next(self)?;
        loop {
            let Some(tok) = self.peek() else { break };
            let Some((_, op)) = table.iter().find(|(t, _)| t == tok) else {
                break;
            };
            let op = *op;
            self.next();
            let rhs = next(self)?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn bin_or(&mut self) -> Result<Expr, CError> {
        self.bin_level(Self::bin_xor, &[(Token::Pipe, BinOp::Or)])
    }
    fn bin_xor(&mut self) -> Result<Expr, CError> {
        self.bin_level(Self::bin_and, &[(Token::Caret, BinOp::Xor)])
    }
    fn bin_and(&mut self) -> Result<Expr, CError> {
        self.bin_level(Self::bin_eq, &[(Token::Amp, BinOp::And)])
    }
    fn bin_eq(&mut self) -> Result<Expr, CError> {
        self.bin_level(
            Self::bin_rel,
            &[(Token::EqEq, BinOp::Eq), (Token::Ne, BinOp::Ne)],
        )
    }
    fn bin_rel(&mut self) -> Result<Expr, CError> {
        self.bin_level(
            Self::bin_shift,
            &[
                (Token::Lt, BinOp::Lt),
                (Token::Le, BinOp::Le),
                (Token::Gt, BinOp::Gt),
                (Token::Ge, BinOp::Ge),
            ],
        )
    }
    fn bin_shift(&mut self) -> Result<Expr, CError> {
        self.bin_level(
            Self::bin_add,
            &[(Token::Shl, BinOp::Shl), (Token::Shr, BinOp::Shr)],
        )
    }
    fn bin_add(&mut self) -> Result<Expr, CError> {
        self.bin_level(
            Self::bin_mul,
            &[(Token::Plus, BinOp::Add), (Token::Minus, BinOp::Sub)],
        )
    }
    fn bin_mul(&mut self) -> Result<Expr, CError> {
        self.bin_level(
            Self::unary,
            &[(Token::Star, BinOp::Mul), (Token::Slash, BinOp::Div)],
        )
    }

    fn unary(&mut self) -> Result<Expr, CError> {
        match self.peek() {
            Some(Token::Minus) => {
                self.next();
                // constant-fold negative literals so `-32768` lexes fine
                let e = self.unary()?;
                Ok(match e {
                    Expr::Lit(v) => Expr::Lit(v.wrapping_neg()),
                    e => Expr::Un(UnOp::Neg, Box::new(e)),
                })
            }
            Some(Token::Tilde) => {
                self.next();
                Ok(Expr::Un(UnOp::Not, Box::new(self.unary()?)))
            }
            _ => self.primary(),
        }
    }

    fn primary(&mut self) -> Result<Expr, CError> {
        match self.next() {
            Some(Token::Num(n)) => Ok(Expr::Lit(*n as i16)),
            Some(Token::Ident(s)) => Ok(Expr::Var(s.clone())),
            Some(Token::Next) => {
                self.expect(&Token::LParen, "`(`")?;
                let s = self.ident()?;
                self.expect(&Token::RParen, "`)`")?;
                Ok(Expr::Next(s))
            }
            Some(Token::Pop) => {
                self.expect(&Token::LParen, "`(`")?;
                let s = self.ident()?;
                self.expect(&Token::RParen, "`)`")?;
                Ok(Expr::Pop(s))
            }
            Some(Token::LParen) => {
                let e = self.expr()?;
                self.expect(&Token::RParen, "`)`")?;
                Ok(e)
            }
            other => Err(self.err(format!("expected expression, found {other:?}"))),
        }
    }

    // ---- statements -------------------------------------------------
    fn block(&mut self) -> Result<Vec<Stmt>, CError> {
        self.expect(&Token::LBrace, "`{`")?;
        let mut out = Vec::new();
        loop {
            if self.peek() == Some(&Token::RBrace) {
                self.next();
                return Ok(out);
            }
            out.push(self.stmt()?);
        }
    }

    fn stmt(&mut self) -> Result<Stmt, CError> {
        match self.peek() {
            Some(Token::Int) => {
                self.next();
                let name = self.ident()?;
                self.expect(&Token::Assign, "`=`")?;
                let e = self.expr()?;
                self.expect(&Token::Semi, "`;`")?;
                Ok(Stmt::Decl(name, e))
            }
            Some(Token::While) => {
                self.next();
                self.expect(&Token::LParen, "`(`")?;
                let c = self.expr()?;
                self.expect(&Token::RParen, "`)`")?;
                let body = self.block()?;
                Ok(Stmt::While(c, body))
            }
            Some(Token::If) => {
                self.next();
                self.expect(&Token::LParen, "`(`")?;
                let c = self.expr()?;
                self.expect(&Token::RParen, "`)`")?;
                let t = self.block()?;
                let e = if self.peek() == Some(&Token::Else) {
                    self.next();
                    self.block()?
                } else {
                    Vec::new()
                };
                Ok(Stmt::If(c, t, e))
            }
            Some(Token::Emit) => {
                self.next();
                self.expect(&Token::LParen, "`(`")?;
                let p = self.ident()?;
                self.expect(&Token::Comma, "`,`")?;
                let e = self.expr()?;
                self.expect(&Token::RParen, "`)`")?;
                self.expect(&Token::Semi, "`;`")?;
                Ok(Stmt::Emit(p, e))
            }
            Some(Token::Push) => {
                self.next();
                self.expect(&Token::LParen, "`(`")?;
                let p = self.ident()?;
                self.expect(&Token::Comma, "`,`")?;
                let e = self.expr()?;
                self.expect(&Token::RParen, "`)`")?;
                self.expect(&Token::Semi, "`;`")?;
                Ok(Stmt::Push(p, e))
            }
            Some(Token::Ident(_)) => {
                let name = self.ident()?;
                self.expect(&Token::Assign, "`=`")?;
                let e = self.expr()?;
                self.expect(&Token::Semi, "`;`")?;
                Ok(Stmt::Assign(name, e))
            }
            other => Err(self.err(format!("expected statement, found {other:?}"))),
        }
    }
}

/// Parse a whole program.
pub fn parse_program(toks: &[Token]) -> Result<Program, CError> {
    let mut p = P {
        toks,
        pos: 0,
        line: 1,
    };
    let mut prog = Program::default();
    loop {
        match p.peek() {
            None => break,
            Some(Token::In) => {
                p.next();
                match p.next() {
                    Some(Token::Int) => {
                        let n = p.ident()?;
                        prog.in_ints.push(n);
                    }
                    Some(Token::Stream) => {
                        let n = p.ident()?;
                        prog.in_streams.push(n);
                    }
                    other => return Err(p.err(format!("expected int/stream, found {other:?}"))),
                }
                p.expect(&Token::Semi, "`;`")?;
            }
            Some(Token::Out) => {
                p.next();
                match p.next() {
                    Some(Token::Int) => {
                        let n = p.ident()?;
                        prog.out_ints.push(n);
                    }
                    Some(Token::Stream) => {
                        let n = p.ident()?;
                        prog.out_streams.push(n);
                    }
                    other => return Err(p.err(format!("expected int/stream, found {other:?}"))),
                }
                p.expect(&Token::Semi, "`;`")?;
            }
            Some(Token::Fifo) => {
                p.next();
                let n = p.ident()?;
                prog.fifos.push(n);
                p.expect(&Token::Semi, "`;`")?;
            }
            _ => prog.body.push(p.stmt()?),
        }
    }
    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn parse(src: &str) -> Program {
        parse_program(&lex(src).unwrap()).unwrap()
    }

    #[test]
    fn parses_ports_and_body() {
        let p = parse("in int n; out int r; int x = 1; r = x + n;");
        assert_eq!(p.in_ints, vec!["n"]);
        assert_eq!(p.out_ints, vec!["r"]);
        assert_eq!(p.body.len(), 2);
    }

    #[test]
    fn precedence_mul_over_add() {
        let p = parse("out int r; r = 1 + 2 * 3;");
        match &p.body[0] {
            Stmt::Assign(_, Expr::Bin(BinOp::Add, a, b)) => {
                assert_eq!(**a, Expr::Lit(1));
                assert!(matches!(**b, Expr::Bin(BinOp::Mul, _, _)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn negative_literal_folds() {
        let p = parse("out int r; r = -32768;");
        assert!(matches!(&p.body[0], Stmt::Assign(_, Expr::Lit(v)) if *v == i16::MIN));
    }

    #[test]
    fn nested_blocks() {
        let p = parse(
            "in int n; out int r;
             int i = 0;
             while (i < n) { if (i > 2) { i = i + 2; } else { i = i + 1; } }
             r = i;",
        );
        assert!(matches!(&p.body[1], Stmt::While(_, body) if body.len() == 1));
    }

    #[test]
    fn error_reports_line() {
        let toks = lex("in int n;\nout int r;\nr = ;\n").unwrap();
        match parse_program(&toks) {
            Err(CError::Parse(line, _)) => assert_eq!(line, 3),
            other => panic!("{other:?}"),
        }
    }
}
