//! A seeded property-test driver (the vendored environment has no
//! proptest). Runs a property over `cases` random inputs derived from a
//! base seed; on failure it reports the failing seed so the case can be
//! replayed exactly, and — when the input type supports it — retries a
//! sequence of caller-provided shrink candidates.

use super::Rng;

/// Configuration for [`check`].
#[derive(Debug, Clone, Copy)]
pub struct PropCfg {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropCfg {
    fn default() -> Self {
        PropCfg {
            cases: 64,
            base_seed: 0xDA7AF10B,
        }
    }
}

/// Run `prop` on `cfg.cases` inputs produced by `gen`. Panics with the
/// failing seed and the input's `Debug` rendering on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: PropCfg,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed (case {case}, seed {seed:#x}):\n  \
                 input: {input:?}\n  error: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "add commutes",
            PropCfg::default(),
            |r| (r.word(-100, 100), r.word(-100, 100)),
            |&(a, b)| {
                if a.wrapping_add(b) == b.wrapping_add(a) {
                    Ok(())
                } else {
                    Err("not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always fails` failed")]
    fn failing_property_reports_seed() {
        check(
            "always fails",
            PropCfg {
                cases: 3,
                base_seed: 1,
            },
            |r| r.word(0, 10),
            |_| Err("nope".into()),
        );
    }
}
