//! A seeded property-test driver (the vendored environment has no
//! proptest). Runs a property over `cases` random inputs derived from a
//! base seed; on failure it reports the failing seed so the case can be
//! replayed exactly, and — when the input type supports it — retries a
//! sequence of caller-provided shrink candidates.
//!
//! Also home to the **random-DFG generator** the differential
//! conformance harness (`rust/tests/conformance.rs`) feeds to every
//! engine: seeded, replayable graphs covering `const`, `fifo #k`,
//! `copy`/ALU/decider pipelines, `dmerge`/`branch` routing and
//! `build_loop` branch/merge loops.

use super::Rng;
use crate::dfg::{build_loop, ArcId, Graph, GraphBuilder, Op, Word};
use std::collections::BTreeMap;

/// Configuration for [`check`].
#[derive(Debug, Clone, Copy)]
pub struct PropCfg {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropCfg {
    fn default() -> Self {
        PropCfg {
            cases: 64,
            base_seed: 0xDA7AF10B,
        }
    }
}

impl PropCfg {
    /// Like a literal `PropCfg`, but the case count can be overridden
    /// through the `PROPTEST_CASES` environment variable — CI runs a
    /// fixed-seed smoke subset (small count) of the same properties the
    /// full suite runs at depth.
    pub fn from_env(cases: usize, base_seed: u64) -> PropCfg {
        let cases = std::env::var("PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(cases);
        PropCfg { cases, base_seed }
    }
}

/// Run `prop` on `cfg.cases` inputs produced by `gen`. Panics with the
/// failing seed and the input's `Debug` rendering on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: PropCfg,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed (case {case}, seed {seed:#x}):\n  \
                 input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// What kind of injection stream an input port of a generated graph
/// expects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PortKind {
    /// A loop trip-count: inject exactly one small non-negative token.
    LoopCount,
    /// A data stream: inject `len` tokens.
    Stream,
}

/// A generated graph plus the port contract its workloads must follow.
#[derive(Debug, Clone)]
pub struct GenGraph {
    pub graph: Graph,
    /// `(port label, kind)` for every input port.
    pub ports: Vec<(String, PortKind)>,
}

fn pop_random(r: &mut Rng, open: &mut Vec<ArcId>) -> ArcId {
    let i = r.below(open.len());
    open.swap_remove(i)
}

const ALU2: [Op; 9] = [
    Op::Add,
    Op::Sub,
    Op::Mul,
    Op::Div,
    Op::And,
    Op::Or,
    Op::Xor,
    Op::Shl,
    Op::Shr,
];
const DECIDERS: [Op; 6] = [Op::IfGt, Op::IfGe, Op::IfLt, Op::IfLe, Op::IfEq, Op::IfDf];

/// Knobs for [`random_dfg_with`]. Every flag off yields a pure
/// unit-rate pipeline (`copy`/`not`/`fifo`/ALU/decider over stream
/// ports, no cycles) — exactly the class the streaming tier may
/// overlap ([`crate::sim::overlap_safe`]).
#[derive(Debug, Clone, Copy)]
pub struct GenCfg {
    /// Emit free-form `dmerge`/`branch` routing. These strand tokens on
    /// data-dependent paths, so only engines with *identical* arc
    /// capacity (TokenSim, StreamSession) agree on arbitrary such
    /// graphs; `FsmSim`'s latched input registers and `DynamicSim`'s
    /// deeper queues add slack that legally admits extra firings behind
    /// a stalled consumer.
    pub routing: bool,
    /// Emit a counted accumulator loop (the full branch/merge
    /// while-schema via [`build_loop`]).
    pub loops: bool,
    /// Emit `const` sources as operands.
    pub consts: bool,
}

/// Generate a random well-formed DFG. `branchy` is shorthand for all
/// [`GenCfg`] knobs on; `!branchy` for all off.
pub fn random_dfg(r: &mut Rng, branchy: bool) -> GenGraph {
    random_dfg_with(
        r,
        GenCfg {
            routing: branchy,
            loops: branchy,
            consts: branchy,
        },
    )
}

/// Generate a random well-formed DFG under explicit knobs.
pub fn random_dfg_with(r: &mut Rng, cfg: GenCfg) -> GenGraph {
    let mut b = GraphBuilder::new("gen");
    let mut ports: Vec<(String, PortKind)> = Vec::new();
    let mut open: Vec<ArcId> = Vec::new();

    let n_ports = 1 + r.below(3);
    for i in 0..n_ports {
        let name = format!("p{i}");
        open.push(b.input_port(&name));
        ports.push((name, PortKind::Stream));
    }

    let ops = 3 + r.below(9);
    for _ in 0..ops {
        // Replenish operands: extra ports, or consts when allowed (a
        // const is not unit-rate across waves).
        while open.len() < 3 {
            if cfg.consts && r.bool() {
                open.push(b.constant(r.word(-50, 50)));
            } else {
                let name = format!("p{}", ports.len());
                open.push(b.input_port(&name));
                ports.push((name, PortKind::Stream));
            }
        }
        match r.below(if cfg.routing { 12 } else { 10 }) {
            0 => {
                let a = pop_random(r, &mut open);
                let (x, y) = b.copy(a);
                open.push(x);
                open.push(y);
            }
            1 => {
                let a = pop_random(r, &mut open);
                let n = b.node(Op::Fifo(1 + r.below(8) as u16), &[a], &[]);
                open.push(b.out_arc(n, 0));
            }
            2 => {
                let a = pop_random(r, &mut open);
                let n = b.node(Op::Not, &[a], &[]);
                open.push(b.out_arc(n, 0));
            }
            3 | 4 => {
                let op = DECIDERS[r.below(DECIDERS.len())];
                let a = pop_random(r, &mut open);
                let c = pop_random(r, &mut open);
                open.push(b.op2(op, a, c));
            }
            10 => {
                // dmerge: decider-driven select between two operands.
                let a = pop_random(r, &mut open);
                let c = pop_random(r, &mut open);
                let ctl = b.op2(DECIDERS[r.below(DECIDERS.len())], a, c);
                while open.len() < 2 {
                    open.push(b.constant(r.word(-50, 50)));
                }
                let d0 = pop_random(r, &mut open);
                let d1 = pop_random(r, &mut open);
                let n = b.node(Op::DMerge, &[ctl, d0, d1], &[]);
                open.push(b.out_arc(n, 0));
            }
            11 => {
                // branch: decider-routed token; both sides stay open.
                let a = pop_random(r, &mut open);
                let c = pop_random(r, &mut open);
                let ctl = b.op2(DECIDERS[r.below(DECIDERS.len())], a, c);
                while open.is_empty() {
                    open.push(b.constant(r.word(-50, 50)));
                }
                let d = pop_random(r, &mut open);
                let n = b.node(Op::Branch, &[ctl, d], &[]);
                open.push(b.out_arc(n, 0));
                open.push(b.out_arc(n, 1));
            }
            _ => {
                let op = ALU2[r.below(ALU2.len())];
                let a = pop_random(r, &mut open);
                let c = pop_random(r, &mut open);
                open.push(b.op2(op, a, c));
            }
        }
    }

    if cfg.loops && r.bool() {
        // A counted accumulator loop: the full branch/merge while-schema
        // (ndmerge back-edges, branch exits, copy fan-out, decider).
        let nname = format!("n{}", ports.len());
        let n_port = b.input_port(&nname);
        ports.push((nname, PortKind::LoopCount));
        let i0 = b.constant(0);
        let one0 = b.constant(1);
        let acc0 = b.constant(r.word(-20, 20));
        let body_op = [Op::Add, Op::Sub, Op::Xor, Op::Or, Op::And][r.below(5)];
        let exits = build_loop(
            &mut b,
            &[i0, n_port, one0, acc0],
            &[0, 1],
            |b, c| b.op2(Op::IfLt, c[0], c[1]),
            |b, g| {
                let (i_use, i_tap) = b.copy(g[0]);
                let (one_use, one_back) = b.copy(g[2]);
                let i_next = b.op2(Op::Add, i_use, one_use);
                let acc_next = b.op2(body_op, g[3], i_tap);
                vec![i_next, g[1], one_back, acc_next]
            },
        );
        // The accumulator exit feeds back into the open pool half the
        // time (loop output consumed downstream), else dangles as an
        // output port.
        if r.bool() {
            open.push(exits[3]);
        }
    }

    // Terminate floating input ports: an arc that appears in no
    // statement would not survive the assembler round-trip, so each
    // unconsumed port runs through a `not` whose result dangles as an
    // anonymous output pin.
    let floating: Vec<ArcId> = open
        .iter()
        .copied()
        .filter(|&a| b.graph().arc(a).src.is_none())
        .collect();
    open.retain(|&a| b.graph().arc(a).src.is_some());
    for a in floating {
        b.node(Op::Not, &[a], &[]);
    }

    // A couple of named result taps (driven arcs only — renaming an
    // unconsumed *input* port would break the port contract); every
    // other open arc dangles as an anonymous output port (legal
    // hardware: unused result pins).
    let driven: Vec<ArcId> = open
        .iter()
        .copied()
        .filter(|&a| b.graph().arc(a).src.is_some())
        .collect();
    for (i, &a) in driven.iter().take(2).enumerate() {
        b.rename_arc(a, &format!("z{i}"));
    }

    GenGraph {
        graph: b.finish().expect("generated graph is structurally valid"),
        ports,
    }
}

/// A random injection map honouring `gg`'s port contract: loop counts
/// get one small token, streams get `len` tokens each.
pub fn random_workload(r: &mut Rng, gg: &GenGraph, len: usize) -> BTreeMap<String, Vec<Word>> {
    let mut m = BTreeMap::new();
    for (name, kind) in &gg.ports {
        let stream = match kind {
            PortKind::LoopCount => vec![r.word(0, 6)],
            PortKind::Stream => r.words(len.max(1), -100, 100),
        };
        m.insert(name.clone(), stream);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "add commutes",
            PropCfg::default(),
            |r| (r.word(-100, 100), r.word(-100, 100)),
            |&(a, b)| {
                if a.wrapping_add(b) == b.wrapping_add(a) {
                    Ok(())
                } else {
                    Err("not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always fails` failed")]
    fn failing_property_reports_seed() {
        check(
            "always fails",
            PropCfg {
                cases: 3,
                base_seed: 1,
            },
            |r| r.word(0, 10),
            |_| Err("nope".into()),
        );
    }
}
