//! Offline-environment stand-ins for the usual crates.
//!
//! This build environment has no network and no vendored copies of
//! `rand`, `criterion`, `proptest` or `clap`, so this module provides the
//! minimal, well-tested subset the rest of the crate needs:
//!
//! * [`Rng`] — SplitMix64, a tiny, high-quality deterministic PRNG.
//! * [`bench`] — a criterion-style measurement loop (warmup, N samples,
//!   median/mean/stddev) used by all `rust/benches/*` harnesses.
//! * [`proptest`] — a seeded random-input property-test driver with
//!   failure reporting (seed + shrunken case where applicable).
//! * [`args`] — a `--flag value` parser for the CLI and examples.

pub mod args;
pub mod bench;
pub mod proptest;
mod rng;

pub use rng::Rng;
