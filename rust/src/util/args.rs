//! A minimal `--flag value` / `--switch` argument parser for the CLI and
//! the example binaries (no clap in the vendored environment).

use std::collections::BTreeMap;

/// Parsed command line: positional arguments plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator (normally `std::env::args().skip(1)`).
    /// `switch_names` lists flags that take no value.
    pub fn parse(raw: impl Iterator<Item = String>, switch_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = raw.peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some(v) = iter.peek() {
                    if v.starts_with("--") {
                        out.switches.push(name.to_string());
                    } else {
                        let v = iter.next().unwrap();
                        out.options.insert(name.to_string(), v);
                    }
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, switches: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), switches)
    }

    #[test]
    fn parses_options_and_positionals() {
        let a = parse("run --n 64 --seed=7 fibonacci --verbose", &["verbose"]);
        assert_eq!(a.positional, vec!["run", "fibonacci"]);
        assert_eq!(a.get_usize("n", 0), 64);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn trailing_switch_without_value() {
        let a = parse("--fig8", &[]);
        assert!(a.has("fig8"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("", &[]);
        assert_eq!(a.get_usize("n", 16), 16);
        assert_eq!(a.get_or("mode", "token"), "token");
    }
}
