//! A small criterion-style benchmark harness.
//!
//! Used by every `[[bench]]` target (the vendored environment has no
//! criterion). Methodology: warm up for `warmup_iters`, then take
//! `samples` timed samples of `iters_per_sample` iterations each and
//! report min / median / mean / p95 wall time per iteration plus derived
//! throughput.

use std::hint::black_box;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Nanoseconds per iteration: (min, median, mean, p95).
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub samples: usize,
}

impl Measurement {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

/// Configuration for [`run`].
#[derive(Debug, Clone, Copy)]
pub struct BenchCfg {
    pub warmup_iters: usize,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg {
            warmup_iters: 3,
            samples: 20,
            iters_per_sample: 1,
        }
    }
}

/// Time `f`, returning per-iteration statistics.
pub fn run<T>(name: &str, cfg: BenchCfg, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..cfg.iters_per_sample {
            black_box(f());
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / cfg.iters_per_sample as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = per_iter.len();
    let mean = per_iter.iter().sum::<f64>() / n as f64;
    Measurement {
        name: name.to_string(),
        min_ns: per_iter[0],
        median_ns: per_iter[n / 2],
        mean_ns: mean,
        p95_ns: per_iter[((n as f64 * 0.95) as usize).min(n - 1)],
        samples: n,
    }
}

/// Pretty time.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print a measurement row in a stable, greppable format.
pub fn report(m: &Measurement) {
    println!(
        "bench {:<42} median {:>12}  mean {:>12}  min {:>12}  p95 {:>12}  ({} samples)",
        m.name,
        fmt_ns(m.median_ns),
        fmt_ns(m.mean_ns),
        fmt_ns(m.min_ns),
        fmt_ns(m.p95_ns),
        m.samples
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let m = run(
            "spin",
            BenchCfg {
                warmup_iters: 1,
                samples: 5,
                iters_per_sample: 10,
            },
            || {
                let mut s = 0u64;
                for i in 0..1000u64 {
                    s = s.wrapping_add(i * i);
                }
                s
            },
        );
        assert!(m.min_ns > 0.0);
        assert!(m.median_ns >= m.min_ns);
        assert!(m.p95_ns >= m.median_ns);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
