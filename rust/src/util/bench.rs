//! A small criterion-style benchmark harness.
//!
//! Used by every `[[bench]]` target (the vendored environment has no
//! criterion). Methodology: warm up for `warmup_iters`, then take
//! `samples` timed samples of `iters_per_sample` iterations each and
//! report min / median / mean / p95 wall time per iteration plus derived
//! throughput.

use std::hint::black_box;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Nanoseconds per iteration: (min, median, mean, p95). Always
    /// *wall* time of the measuring thread — on a multi-worker
    /// workload this is what latency/throughput derive from, and it is
    /// NOT the CPU cost.
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub samples: usize,
    /// Median per-iteration *busy* nanoseconds summed across every
    /// worker that executed part of the iteration. For single-threaded
    /// work ([`run`]) this equals the median wall time; for pooled
    /// work ([`run_timed`]) it can exceed wall by up to `workers`×.
    pub busy_ns: f64,
    /// Workers that contributed to `busy_ns` (1 for [`run`]).
    pub workers: usize,
}

impl Measurement {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }

    /// Mean pool utilization: `busy / (wall × workers)`. 1.0 means the
    /// pool never idled during the iteration; a single-threaded
    /// measurement reports ≈1.0 by construction.
    pub fn cpu_util(&self) -> f64 {
        if self.median_ns <= 0.0 || self.workers == 0 {
            0.0
        } else {
            self.busy_ns / (self.median_ns * self.workers as f64)
        }
    }
}

/// Per-iteration cost report from a [`run_timed`] closure: how much
/// worker busy-time the iteration consumed and across how many
/// workers. The caller reads these off a [`crate::par::ParStats`]
/// delta (`Executor::stats` before/after).
#[derive(Debug, Clone, Copy)]
pub struct IterCost {
    pub busy_ns: u64,
    pub workers: usize,
}

/// Configuration for [`run`].
#[derive(Debug, Clone, Copy)]
pub struct BenchCfg {
    pub warmup_iters: usize,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg {
            warmup_iters: 3,
            samples: 20,
            iters_per_sample: 1,
        }
    }
}

/// Time `f`, returning per-iteration statistics. Single-threaded:
/// busy time is wall time and `workers` is 1.
pub fn run<T>(name: &str, cfg: BenchCfg, mut f: impl FnMut() -> T) -> Measurement {
    run_timed(name, cfg, || {
        let t0 = Instant::now();
        let out = f();
        let busy = t0.elapsed().as_nanos() as u64;
        (
            out,
            IterCost {
                busy_ns: busy,
                workers: 1,
            },
        )
    })
}

/// Time a closure that reports its own per-iteration worker cost —
/// the multi-threaded measurement path. Wall statistics come from the
/// measuring thread's clock exactly as in [`run`]; busy time is
/// whatever the closure reports (typically an
/// [`Executor::stats`](crate::par::Executor::stats) delta around the
/// call), aggregated per iteration and summarized by its own median —
/// never by assuming wall == CPU, which a pool breaks in both
/// directions (idle workers, or N× wall when saturated).
pub fn run_timed<T>(
    name: &str,
    cfg: BenchCfg,
    mut f: impl FnMut() -> (T, IterCost),
) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        black_box(f().0);
    }
    // A zeroed config (hand-built quick/smoke configs) must still
    // produce one sample — an empty sample vector would panic on
    // indexing below, and 0 iters per sample would divide to NaN.
    let samples = cfg.samples.max(1);
    let iters_per_sample = cfg.iters_per_sample.max(1);
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    let mut busy_iter: Vec<f64> = Vec::with_capacity(samples);
    let mut workers = 1usize;
    for _ in 0..samples {
        let mut busy = 0u64;
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            let (out, cost) = f();
            black_box(out);
            busy += cost.busy_ns;
            workers = workers.max(cost.workers);
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        busy_iter.push(busy as f64 / iters_per_sample as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    busy_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = per_iter.len();
    let mean = per_iter.iter().sum::<f64>() / n as f64;
    Measurement {
        name: name.to_string(),
        min_ns: per_iter[0],
        median_ns: per_iter[n / 2],
        mean_ns: mean,
        p95_ns: per_iter[((n as f64 * 0.95) as usize).min(n - 1)],
        samples: n,
        busy_ns: busy_iter[n / 2],
        workers,
    }
}

/// Pretty time.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print a measurement row in a stable, greppable format.
pub fn report(m: &Measurement) {
    println!(
        "bench {:<42} median {:>12}  mean {:>12}  min {:>12}  p95 {:>12}  ({} samples)",
        m.name,
        fmt_ns(m.median_ns),
        fmt_ns(m.mean_ns),
        fmt_ns(m.min_ns),
        fmt_ns(m.p95_ns),
        m.samples
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let m = run(
            "spin",
            BenchCfg {
                warmup_iters: 1,
                samples: 5,
                iters_per_sample: 10,
            },
            || {
                let mut s = 0u64;
                for i in 0..1000u64 {
                    s = s.wrapping_add(i * i);
                }
                s
            },
        );
        assert!(m.min_ns > 0.0);
        assert!(m.median_ns >= m.min_ns);
        assert!(m.p95_ns >= m.median_ns);
    }

    #[test]
    fn single_threaded_busy_tracks_wall() {
        let m = run(
            "spin1",
            BenchCfg {
                warmup_iters: 1,
                samples: 7,
                iters_per_sample: 5,
            },
            || {
                let mut s = 1u64;
                for i in 1..5000u64 {
                    s = s.wrapping_mul(i | 1);
                }
                s
            },
        );
        assert_eq!(m.workers, 1);
        assert!(m.busy_ns > 0.0);
        // Busy is measured inside the iteration, wall outside: busy
        // can never exceed wall, and for CPU-bound work it dominates.
        assert!(m.busy_ns <= m.median_ns * 1.05);
        assert!(m.cpu_util() > 0.5, "util {}", m.cpu_util());
        assert!(m.cpu_util() <= 1.05);
    }

    #[test]
    fn run_timed_aggregates_reported_worker_cost() {
        // A synthetic 4-worker workload reporting 2× wall as busy:
        // utilization must come out near 0.5, not near 2.0 (the bug a
        // wall==CPU assumption would produce) and not 1.0.
        let m = run_timed(
            "pooled",
            BenchCfg {
                warmup_iters: 0,
                samples: 5,
                iters_per_sample: 2,
            },
            || {
                let t0 = Instant::now();
                let mut s = 0u64;
                for i in 0..20_000u64 {
                    s = s.wrapping_add(i * i);
                }
                let wall = t0.elapsed().as_nanos() as u64;
                (
                    s,
                    IterCost {
                        busy_ns: wall * 2,
                        workers: 4,
                    },
                )
            },
        );
        assert_eq!(m.workers, 4);
        assert!(m.busy_ns > m.median_ns, "busy exceeds wall on a pool");
        let util = m.cpu_util();
        assert!(util > 0.2 && util < 0.75, "util {util}");
    }

    #[test]
    fn zeroed_config_still_yields_one_sample() {
        // Pre-guard this panicked indexing an empty sample vector.
        let m = run(
            "zeroed",
            BenchCfg {
                warmup_iters: 0,
                samples: 0,
                iters_per_sample: 0,
            },
            || black_box(42u64),
        );
        assert_eq!(m.samples, 1);
        assert!(m.median_ns.is_finite());
        assert!(m.busy_ns.is_finite());
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
