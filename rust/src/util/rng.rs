//! SplitMix64 — deterministic, seedable, passes BigCrush for our sizes.

/// A tiny deterministic PRNG (SplitMix64, Steele et al. 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo < hi);
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i32
    }

    /// Uniform i16 in `[lo, hi)`.
    pub fn word(&mut self, lo: i32, hi: i32) -> i16 {
        self.range_i32(lo, hi) as i16
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// A vector of `n` words in `[lo, hi)`.
    pub fn words(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i16> {
        (0..n).map(|_| self.word(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let v = r.range_i32(-50, 50);
            assert!((-50..50).contains(&v));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }
}
