//! First-class checkpoints of resident simulator state.
//!
//! A fabric instance can fail mid-wave (see [`crate::fabric::fault`]);
//! the serve tier's recovery path snapshots the resident session on
//! the dead instance and restores it on a healthy one. This module
//! defines the portable state captures for both resident engines:
//!
//! * [`StreamCheckpoint`] — a [`StreamSession`](super::StreamSession)
//!   between rounds: tokens in flight per arc (with wave tags), fifo
//!   queues, const-arm wave queues, pending injections, the serialized
//!   admission gate, and per-wave bookkeeping.
//! * [`TokenCheckpoint`] — a [`TokenSim`](super::TokenSim) between
//!   steps: arc tokens, fifo queues, const arms fired, pending
//!   injections, and collected output streams.
//!
//! Both serialize to a versioned little-endian byte image
//! ([`to_bytes`](StreamCheckpoint::to_bytes) /
//! [`from_bytes`](StreamCheckpoint::from_bytes)) so a checkpoint can
//! cross a process boundary. The contract, enforced by the `ckpt_*`
//! conformance properties, is **round-trip byte-identity**:
//! `snapshot → restore → snapshot` produces the same bytes, and a
//! restored session finishes with the same outputs the uninterrupted
//! run produces.
//!
//! **Restore legality.** A checkpoint binds to the graph it was taken
//! from via [`Graph::fingerprint`](crate::dfg::Graph::fingerprint);
//! restoring against any other graph is a
//! [`CheckpointError::FingerprintMismatch`]. Shape checks (arc/node/
//! port counts) back the fingerprint up so a corrupted image cannot
//! index out of bounds. Checkpoints are only taken *between* rounds —
//! never with staged writes outstanding — which is what makes the
//! captured arc state complete (DESIGN.md §11).

use super::stream::WaveMode;
use crate::dfg::Word;
use std::collections::BTreeMap;
use std::error::Error;
use std::fmt;

/// Why a checkpoint could not be decoded or restored.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointError {
    /// The byte image ended before the decoder was done.
    Truncated,
    /// The image does not start with the checkpoint magic.
    BadMagic,
    /// The image's format version is not one this build reads.
    BadVersion(u16),
    /// The image's kind byte names neither engine.
    BadKind(u8),
    /// An option/bool tag held a value other than 0 or 1.
    BadTag(u8),
    /// The checkpoint was taken from a different graph.
    FingerprintMismatch { want: u64, got: u64 },
    /// A captured collection disagrees with the graph's shape.
    ShapeMismatch(String),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Truncated => write!(f, "checkpoint image truncated"),
            CheckpointError::BadMagic => write!(f, "not a checkpoint image (bad magic)"),
            CheckpointError::BadVersion(v) => {
                write!(f, "unsupported checkpoint version {v} (this build reads 1)")
            }
            CheckpointError::BadKind(k) => {
                write!(f, "unknown checkpoint kind {k} (0 = token, 1 = stream)")
            }
            CheckpointError::BadTag(t) => write!(f, "corrupt checkpoint: tag byte {t}"),
            CheckpointError::FingerprintMismatch { want, got } => write!(
                f,
                "checkpoint is for graph {want:#018x}, not {got:#018x} — \
                 restore requires the identical graph"
            ),
            CheckpointError::ShapeMismatch(what) => {
                write!(f, "checkpoint shape mismatch: {what}")
            }
        }
    }
}

impl Error for CheckpointError {}

const MAGIC: &[u8; 4] = b"DACK";
const VERSION: u16 = 1;
const KIND_TOKEN: u8 = 0;
const KIND_STREAM: u8 = 1;

/// One wave's bookkeeping inside a [`StreamCheckpoint`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WaveCkpt {
    pub alive: u64,
    pub started: Option<u64>,
    pub done: Option<u64>,
    pub quiescent: bool,
    pub firings: u64,
    pub outputs: BTreeMap<String, Vec<Word>>,
}

/// A [`StreamSession`](super::StreamSession) captured between rounds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamCheckpoint {
    /// [`Graph::fingerprint`](crate::dfg::Graph::fingerprint) of the
    /// session's graph — the restore-legality witness.
    pub fingerprint: u64,
    pub mode: WaveMode,
    /// Per arc: the in-flight token `(value, wave tag)`, if any.
    pub tokens: Vec<Option<(Word, u32)>>,
    /// Per node: fifo contents, front first.
    pub fifos: Vec<Vec<(Word, u32)>>,
    /// Per node: wave ids whose const arm has not fired yet.
    pub const_pending: Vec<Vec<u32>>,
    /// Per input port (graph port order): not-yet-injected tokens.
    pub pending: Vec<Vec<(Word, u32)>>,
    /// Serialized-mode admission gate: waves not yet released.
    pub gate: Vec<(u32, BTreeMap<String, Vec<Word>>)>,
    pub waves: Vec<WaveCkpt>,
    pub rounds: u64,
    pub firings: u64,
    pub tokens_out: u64,
    pub tag_stalls: u64,
    pub next_done: u64,
    /// Consecutive zero-progress rounds at capture time. Persisted so
    /// a restored serialized session flushes a stalled wave on the
    /// same round an uninterrupted run would have.
    pub stall: u32,
}

impl StreamCheckpoint {
    /// Waves captured mid-flight — admitted but not yet done. This is
    /// what a migration (chaos) or a rolling drain (elastic) actually
    /// moves: finished waves ride along as recorded outputs, in-flight
    /// waves resume token-for-token on the restored session.
    pub fn waves_in_flight(&self) -> usize {
        self.waves.iter().filter(|w| w.done.is_none()).count()
    }
}

/// A [`TokenSim`](super::TokenSim) captured between steps.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TokenCheckpoint {
    /// [`Graph::fingerprint`](crate::dfg::Graph::fingerprint) of the
    /// sim's graph — the restore-legality witness.
    pub fingerprint: u64,
    /// Per arc: the in-flight token, if any.
    pub tokens: Vec<Option<Word>>,
    /// Per node: fifo contents, front first.
    pub fifos: Vec<Vec<Word>>,
    /// Per node: whether its const arm already fired.
    pub const_done: Vec<bool>,
    /// Per input port (graph port order): not-yet-injected tokens.
    pub pending: Vec<Vec<Word>>,
    /// Output streams collected so far.
    pub collected: BTreeMap<String, Vec<Word>>,
    pub firings: u64,
}

// ---------------------------------------------------------------------------
// Little-endian byte codec. Every integer is fixed-width LE; strings
// and collections are u32-length-prefixed; options and bools are a
// single 0/1 tag byte. No self-describing framing beyond the header —
// both ends share this file.

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(kind: u8) -> Self {
        let mut w = Writer { buf: Vec::new() };
        w.buf.extend_from_slice(MAGIC);
        w.u16(VERSION);
        w.u8(kind);
        w
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn word(&mut self, v: Word) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn boolean(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    fn len(&mut self, n: usize) {
        self.u32(u32::try_from(n).expect("checkpoint collection exceeds u32 length"));
    }

    fn string(&mut self, s: &str) {
        self.len(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn streams(&mut self, m: &BTreeMap<String, Vec<Word>>) {
        self.len(m.len());
        for (k, v) in m {
            self.string(k);
            self.len(v.len());
            for &w in v {
                self.word(w);
            }
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8], kind: u8) -> Result<Self, CheckpointError> {
        let mut r = Reader { buf, pos: 0 };
        if r.bytes(4)? != MAGIC {
            return Err(CheckpointError::BadMagic);
        }
        let version = r.u16()?;
        if version != VERSION {
            return Err(CheckpointError::BadVersion(version));
        }
        let k = r.u8()?;
        if k != kind {
            return Err(CheckpointError::BadKind(k));
        }
        Ok(r)
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        let end = self.pos.checked_add(n).ok_or(CheckpointError::Truncated)?;
        if end > self.buf.len() {
            return Err(CheckpointError::Truncated);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CheckpointError> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, CheckpointError> {
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, CheckpointError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CheckpointError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn word(&mut self) -> Result<Word, CheckpointError> {
        Ok(Word::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
    }

    fn boolean(&mut self) -> Result<bool, CheckpointError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            t => Err(CheckpointError::BadTag(t)),
        }
    }

    fn len(&mut self) -> Result<usize, CheckpointError> {
        Ok(self.u32()? as usize)
    }

    fn string(&mut self) -> Result<String, CheckpointError> {
        let n = self.len()?;
        let raw = self.bytes(n)?.to_vec();
        String::from_utf8(raw).map_err(|_| CheckpointError::BadMagic)
    }

    fn streams(&mut self) -> Result<BTreeMap<String, Vec<Word>>, CheckpointError> {
        let n = self.len()?;
        let mut m = BTreeMap::new();
        for _ in 0..n {
            let k = self.string()?;
            let len = self.len()?;
            let mut v = Vec::with_capacity(len.min(1 << 16));
            for _ in 0..len {
                v.push(self.word()?);
            }
            m.insert(k, v);
        }
        Ok(m)
    }

    fn finish(self) -> Result<(), CheckpointError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(CheckpointError::BadMagic)
        }
    }
}

impl StreamCheckpoint {
    /// Serialize to the portable byte image.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new(KIND_STREAM);
        w.u64(self.fingerprint);
        w.u8(match self.mode {
            WaveMode::Pipelined => 0,
            WaveMode::Serialized => 1,
        });
        w.len(self.tokens.len());
        for t in &self.tokens {
            match t {
                None => w.u8(0),
                Some((v, wave)) => {
                    w.u8(1);
                    w.word(*v);
                    w.u32(*wave);
                }
            }
        }
        w.len(self.fifos.len());
        for q in &self.fifos {
            w.len(q.len());
            for (v, wave) in q {
                w.word(*v);
                w.u32(*wave);
            }
        }
        w.len(self.const_pending.len());
        for q in &self.const_pending {
            w.len(q.len());
            for &wave in q {
                w.u32(wave);
            }
        }
        w.len(self.pending.len());
        for q in &self.pending {
            w.len(q.len());
            for (v, wave) in q {
                w.word(*v);
                w.u32(*wave);
            }
        }
        w.len(self.gate.len());
        for (wave, input) in &self.gate {
            w.u32(*wave);
            w.streams(input);
        }
        w.len(self.waves.len());
        for wv in &self.waves {
            w.u64(wv.alive);
            match wv.started {
                None => w.u8(0),
                Some(r) => {
                    w.u8(1);
                    w.u64(r);
                }
            }
            match wv.done {
                None => w.u8(0),
                Some(r) => {
                    w.u8(1);
                    w.u64(r);
                }
            }
            w.boolean(wv.quiescent);
            w.u64(wv.firings);
            w.streams(&wv.outputs);
        }
        w.u64(self.rounds);
        w.u64(self.firings);
        w.u64(self.tokens_out);
        w.u64(self.tag_stalls);
        w.u64(self.next_done);
        w.u32(self.stall);
        w.buf
    }

    /// Decode a byte image produced by [`to_bytes`](Self::to_bytes).
    pub fn from_bytes(buf: &[u8]) -> Result<Self, CheckpointError> {
        let mut r = Reader::new(buf, KIND_STREAM)?;
        let fingerprint = r.u64()?;
        let mode = match r.u8()? {
            0 => WaveMode::Pipelined,
            1 => WaveMode::Serialized,
            t => return Err(CheckpointError::BadTag(t)),
        };
        let n_tokens = r.len()?;
        let mut tokens = Vec::with_capacity(n_tokens.min(1 << 16));
        for _ in 0..n_tokens {
            tokens.push(match r.u8()? {
                0 => None,
                1 => Some((r.word()?, r.u32()?)),
                t => return Err(CheckpointError::BadTag(t)),
            });
        }
        let n_fifos = r.len()?;
        let mut fifos = Vec::with_capacity(n_fifos.min(1 << 16));
        for _ in 0..n_fifos {
            let len = r.len()?;
            let mut q = Vec::with_capacity(len.min(1 << 16));
            for _ in 0..len {
                q.push((r.word()?, r.u32()?));
            }
            fifos.push(q);
        }
        let n_cp = r.len()?;
        let mut const_pending = Vec::with_capacity(n_cp.min(1 << 16));
        for _ in 0..n_cp {
            let len = r.len()?;
            let mut q = Vec::with_capacity(len.min(1 << 16));
            for _ in 0..len {
                q.push(r.u32()?);
            }
            const_pending.push(q);
        }
        let n_pending = r.len()?;
        let mut pending = Vec::with_capacity(n_pending.min(1 << 16));
        for _ in 0..n_pending {
            let len = r.len()?;
            let mut q = Vec::with_capacity(len.min(1 << 16));
            for _ in 0..len {
                q.push((r.word()?, r.u32()?));
            }
            pending.push(q);
        }
        let n_gate = r.len()?;
        let mut gate = Vec::with_capacity(n_gate.min(1 << 16));
        for _ in 0..n_gate {
            let wave = r.u32()?;
            gate.push((wave, r.streams()?));
        }
        let n_waves = r.len()?;
        let mut waves = Vec::with_capacity(n_waves.min(1 << 16));
        for _ in 0..n_waves {
            let alive = r.u64()?;
            let started = match r.u8()? {
                0 => None,
                1 => Some(r.u64()?),
                t => return Err(CheckpointError::BadTag(t)),
            };
            let done = match r.u8()? {
                0 => None,
                1 => Some(r.u64()?),
                t => return Err(CheckpointError::BadTag(t)),
            };
            let quiescent = r.boolean()?;
            let firings = r.u64()?;
            let outputs = r.streams()?;
            waves.push(WaveCkpt {
                alive,
                started,
                done,
                quiescent,
                firings,
                outputs,
            });
        }
        let ck = StreamCheckpoint {
            fingerprint,
            mode,
            tokens,
            fifos,
            const_pending,
            pending,
            gate,
            waves,
            rounds: r.u64()?,
            firings: r.u64()?,
            tokens_out: r.u64()?,
            tag_stalls: r.u64()?,
            next_done: r.u64()?,
            stall: r.u32()?,
        };
        r.finish()?;
        Ok(ck)
    }
}

impl TokenCheckpoint {
    /// Serialize to the portable byte image.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new(KIND_TOKEN);
        w.u64(self.fingerprint);
        w.len(self.tokens.len());
        for t in &self.tokens {
            match t {
                None => w.u8(0),
                Some(v) => {
                    w.u8(1);
                    w.word(*v);
                }
            }
        }
        w.len(self.fifos.len());
        for q in &self.fifos {
            w.len(q.len());
            for &v in q {
                w.word(v);
            }
        }
        w.len(self.const_done.len());
        for &b in &self.const_done {
            w.boolean(b);
        }
        w.len(self.pending.len());
        for q in &self.pending {
            w.len(q.len());
            for &v in q {
                w.word(v);
            }
        }
        w.streams(&self.collected);
        w.u64(self.firings);
        w.buf
    }

    /// Decode a byte image produced by [`to_bytes`](Self::to_bytes).
    pub fn from_bytes(buf: &[u8]) -> Result<Self, CheckpointError> {
        let mut r = Reader::new(buf, KIND_TOKEN)?;
        let fingerprint = r.u64()?;
        let n_tokens = r.len()?;
        let mut tokens = Vec::with_capacity(n_tokens.min(1 << 16));
        for _ in 0..n_tokens {
            tokens.push(match r.u8()? {
                0 => None,
                1 => Some(r.word()?),
                t => return Err(CheckpointError::BadTag(t)),
            });
        }
        let n_fifos = r.len()?;
        let mut fifos = Vec::with_capacity(n_fifos.min(1 << 16));
        for _ in 0..n_fifos {
            let len = r.len()?;
            let mut q = Vec::with_capacity(len.min(1 << 16));
            for _ in 0..len {
                q.push(r.word()?);
            }
            fifos.push(q);
        }
        let n_const = r.len()?;
        let mut const_done = Vec::with_capacity(n_const.min(1 << 16));
        for _ in 0..n_const {
            const_done.push(r.boolean()?);
        }
        let n_pending = r.len()?;
        let mut pending = Vec::with_capacity(n_pending.min(1 << 16));
        for _ in 0..n_pending {
            let len = r.len()?;
            let mut q = Vec::with_capacity(len.min(1 << 16));
            for _ in 0..len {
                q.push(r.word()?);
            }
            pending.push(q);
        }
        let collected = r.streams()?;
        let firings = r.u64()?;
        let ck = TokenCheckpoint {
            fingerprint,
            tokens,
            fifos,
            const_done,
            pending,
            collected,
            firings,
        };
        r.finish()?;
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stream() -> StreamCheckpoint {
        StreamCheckpoint {
            fingerprint: 0xDEAD_BEEF_0123_4567,
            mode: WaveMode::Serialized,
            tokens: vec![None, Some((-3, 1)), Some((7, 0))],
            fifos: vec![vec![], vec![(9, 2), (-1, 2)]],
            const_pending: vec![vec![1, 2], vec![]],
            pending: vec![vec![(5, 0)]],
            gate: vec![(2, BTreeMap::from([("x".to_string(), vec![1, 2, 3])]))],
            waves: vec![WaveCkpt {
                alive: 4,
                started: Some(2),
                done: None,
                quiescent: false,
                firings: 11,
                outputs: BTreeMap::from([("z".to_string(), vec![-7])]),
            }],
            rounds: 12,
            firings: 34,
            tokens_out: 5,
            tag_stalls: 1,
            next_done: 0,
            stall: 1,
        }
    }

    fn sample_token() -> TokenCheckpoint {
        TokenCheckpoint {
            fingerprint: 42,
            tokens: vec![Some(1), None],
            fifos: vec![vec![2, 3]],
            const_done: vec![true, false],
            pending: vec![vec![], vec![-5, 5]],
            collected: BTreeMap::from([("out".to_string(), vec![0, 1])]),
            firings: 9,
        }
    }

    #[test]
    fn stream_codec_round_trips_byte_identically() {
        let ck = sample_stream();
        let bytes = ck.to_bytes();
        let back = StreamCheckpoint::from_bytes(&bytes).expect("decode");
        assert_eq!(back, ck);
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn token_codec_round_trips_byte_identically() {
        let ck = sample_token();
        let bytes = ck.to_bytes();
        let back = TokenCheckpoint::from_bytes(&bytes).expect("decode");
        assert_eq!(back, ck);
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn decoder_rejects_corrupt_images() {
        let bytes = sample_stream().to_bytes();
        assert_eq!(
            StreamCheckpoint::from_bytes(&bytes[..bytes.len() - 1]),
            Err(CheckpointError::Truncated)
        );
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] = b'X';
        assert_eq!(
            StreamCheckpoint::from_bytes(&wrong_magic),
            Err(CheckpointError::BadMagic)
        );
        // A stream image is not a token image.
        assert_eq!(
            TokenCheckpoint::from_bytes(&bytes),
            Err(CheckpointError::BadKind(1))
        );
        let mut bad_version = bytes;
        bad_version[4] = 9;
        assert_eq!(
            StreamCheckpoint::from_bytes(&bad_version),
            Err(CheckpointError::BadVersion(9))
        );
    }
}
