//! The cycle-accurate FSM engine.
//!
//! Every operator runs the paper's ASM chart (Fig. 6):
//!
//! * `S0` — reset: clear registers (done once at construction),
//! * `S1` — receive: latch arriving items into `dadoa`/`dadob`, set
//!   `bita`/`bitb`, pulse `ack`,
//! * `S2` — execute: compute `dadoz`, set `bitz`,
//! * `S3` — send: assert `strz` until the consumer's `ack` arrives, then
//!   clear status bits and return to `S1`.
//!
//! Arcs carry explicit per-cycle `str` (data strobe) and `ack` wires
//! (Fig. 3). One firing therefore costs ≥3 clock edges — exactly the
//! latency the paper's VHDL pays — and communication is "asynchronous"
//! in the paper's sense: nobody knows in advance when a neighbour fires.

use super::{SimConfig, SimOutcome};
use crate::dfg::{Graph, Op, Word};
use std::collections::{BTreeMap, VecDeque};

/// FSM state per the ASM chart. `S0` happens at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Receive, // S1
    Execute, // S2
    Send,    // S3
}

/// What happened on an arc this cycle (recorded when tracing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandshakeKind {
    /// Sender drove `str` with data.
    Str(Word),
    /// Receiver pulsed `ack`.
    Ack,
}

/// A traced handshake event: (cycle, arc index, what).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HandshakeEvent {
    pub cycle: u64,
    pub arc: u32,
    pub kind: HandshakeKind,
}

/// Cycle-accurate simulator.
pub struct FsmSim<'g> {
    g: &'g Graph,
    state: Vec<State>,
    in_regs: Vec<Vec<Option<Word>>>,  // dadoa/dadob + bita/bitb
    out_regs: Vec<Vec<Option<Word>>>, // dadoz + bitz, per output port
    fifo_q: Vec<VecDeque<Word>>,
    const_spent: Vec<bool>,
    pending: Vec<VecDeque<Word>>, // per arc: env injection stream (input ports)
    collected: BTreeMap<String, Vec<Word>>,
    cycle: u64,
    firings: u64,
    /// When `Some`, records every `str`/`ack` for protocol property tests.
    pub trace: Option<Vec<HandshakeEvent>>,
    // scratch wires, one slot per arc, rebuilt every cycle
    str_wire: Vec<Option<Word>>,
    ack_wire: Vec<bool>,
    // §Perf: precomputed port arc lists (the environment's side of the
    // handshake) — avoids two full-arc classification scans per edge.
    in_port_arcs: Vec<usize>,
    out_port_arcs: Vec<usize>,
}

impl<'g> FsmSim<'g> {
    pub fn new(g: &'g Graph, cfg: &SimConfig) -> Self {
        let mut pending = vec![VecDeque::new(); g.n_arcs()];
        for a in g.input_ports() {
            if let Some(stream) = cfg.inject.get(&g.arc(a).name) {
                pending[a.0 as usize] = stream.iter().copied().collect();
            }
        }
        let mut collected = BTreeMap::new();
        for p in g.output_ports() {
            collected.insert(g.arc(p).name.clone(), Vec::new());
        }
        let mut state = Vec::with_capacity(g.n_nodes());
        let mut out_regs = Vec::with_capacity(g.n_nodes());
        for n in &g.nodes {
            // S0: Const nodes come out of reset with their token already in
            // dadoz (bitz set) and go straight to S3; everyone else clears
            // registers and enters S1.
            match n.op {
                Op::Const(v) => {
                    state.push(State::Send);
                    out_regs.push(vec![Some(v)]);
                }
                _ => {
                    state.push(State::Receive);
                    out_regs.push(vec![None; n.op.n_out()]);
                }
            }
        }
        FsmSim {
            g,
            state,
            in_regs: g.nodes.iter().map(|n| vec![None; n.op.n_in()]).collect(),
            out_regs,
            fifo_q: g.nodes.iter().map(|_| VecDeque::new()).collect(),
            const_spent: vec![false; g.n_nodes()],
            pending,
            collected,
            cycle: 0,
            firings: 0,
            trace: None,
            str_wire: vec![None; g.n_arcs()],
            ack_wire: vec![false; g.n_arcs()],
            in_port_arcs: g.input_ports().iter().map(|a| a.0 as usize).collect(),
            out_port_arcs: g.output_ports().iter().map(|a| a.0 as usize).collect(),
        }
    }

    fn trace_str(&mut self, arc: u32, v: Word) {
        let c = self.cycle;
        if let Some(t) = &mut self.trace {
            t.push(HandshakeEvent {
                cycle: c,
                arc,
                kind: HandshakeKind::Str(v),
            });
        }
    }

    fn trace_ack(&mut self, arc: u32) {
        let c = self.cycle;
        if let Some(t) = &mut self.trace {
            t.push(HandshakeEvent {
                cycle: c,
                arc,
                kind: HandshakeKind::Ack,
            });
        }
    }

    /// Is node `ni`'s fire rule satisfied by its latched registers?
    fn fire_ready(&self, ni: usize) -> bool {
        let n = &self.g.nodes[ni];
        let regs = &self.in_regs[ni];
        match n.op {
            Op::Const(_) => false, // fires only from reset
            Op::Fifo(_) => false,  // handled outside the FSM
            Op::NdMerge => regs[0].is_some() || regs[1].is_some(),
            Op::DMerge => match regs[0] {
                Some(c) => {
                    if c != 0 {
                        regs[1].is_some()
                    } else {
                        regs[2].is_some()
                    }
                }
                None => false,
            },
            _ => regs.iter().all(|r| r.is_some()),
        }
    }

    /// Execute node `ni` (state S2): consume registers, fill `dadoz`.
    fn execute(&mut self, ni: usize) {
        let op = self.g.nodes[ni].op;
        self.firings += 1;
        match op {
            Op::Copy => {
                let v = self.in_regs[ni][0].take().unwrap();
                self.out_regs[ni][0] = Some(v);
                self.out_regs[ni][1] = Some(v);
            }
            Op::Not => {
                let v = self.in_regs[ni][0].take().unwrap();
                self.out_regs[ni][0] = Some(op.eval1(v));
            }
            Op::NdMerge => {
                let v = if self.in_regs[ni][0].is_some() {
                    self.in_regs[ni][0].take().unwrap()
                } else {
                    self.in_regs[ni][1].take().unwrap()
                };
                self.out_regs[ni][0] = Some(v);
            }
            Op::DMerge => {
                let c = self.in_regs[ni][0].take().unwrap();
                let sel = if c != 0 { 1 } else { 2 };
                let v = self.in_regs[ni][sel].take().unwrap();
                self.out_regs[ni][0] = Some(v);
            }
            Op::Branch => {
                let c = self.in_regs[ni][0].take().unwrap();
                let v = self.in_regs[ni][1].take().unwrap();
                let port = if c != 0 { 0 } else { 1 };
                self.out_regs[ni][port] = Some(v);
            }
            Op::Const(_) | Op::Fifo(_) => unreachable!("not FSM-executed"),
            _ => {
                let a = self.in_regs[ni][0].take().unwrap();
                let b = self.in_regs[ni][1].take().unwrap();
                self.out_regs[ni][0] = Some(op.eval2(a, b));
            }
        }
    }

    /// Advance one clock edge. Returns the number of `ack` pulses plus
    /// operator executions this cycle — the liveness measure `run` uses:
    /// any sustained progress implies acks (see `run`).
    pub fn step(&mut self) -> u64 {
        let n_arcs = self.g.n_arcs();
        self.str_wire[..n_arcs].fill(None);
        self.ack_wire[..n_arcs].fill(false);
        let mut acks = 0u64;

        // ---- Phase A: drive `str` wires -----------------------------
        // Environment drives input ports that still have tokens queued.
        for pi in 0..self.in_port_arcs.len() {
            let a = self.in_port_arcs[pi];
            if let Some(&v) = self.pending[a].front() {
                self.str_wire[a] = Some(v);
                self.trace_str(a as u32, v);
            }
        }
        // Nodes in S3 drive every pending output register.
        for ni in 0..self.g.nodes.len() {
            match self.g.nodes[ni].op {
                Op::Fifo(_) => {
                    if let Some(&v) = self.fifo_q[ni].front() {
                        let a = self.g.nodes[ni].outs[0].0 as usize;
                        self.str_wire[a] = Some(v);
                        self.trace_str(a as u32, v);
                    }
                }
                _ => {
                    if self.state[ni] == State::Send {
                        for p in 0..self.out_regs[ni].len() {
                            if let Some(v) = self.out_regs[ni][p] {
                                let a = self.g.nodes[ni].outs[p].0 as usize;
                                self.str_wire[a] = Some(v);
                                self.trace_str(a as u32, v);
                            }
                        }
                    }
                }
            }
        }

        // ---- Phase B: receivers latch + pulse `ack` ------------------
        // Environment always acks output ports (the testbench is ready).
        for pi in 0..self.out_port_arcs.len() {
            let a = self.out_port_arcs[pi];
            if let Some(v) = self.str_wire[a] {
                let name = self.g.arcs[a].name.clone();
                self.collected.get_mut(&name).unwrap().push(v);
                self.ack_wire[a] = true;
                acks += 1;
                self.trace_ack(a as u32);
            }
        }
        for ni in 0..self.g.nodes.len() {
            let op = self.g.nodes[ni].op;
            match op {
                Op::Fifo(k) => {
                    let a = self.g.nodes[ni].ins[0].0 as usize;
                    if self.fifo_q[ni].len() < k as usize {
                        if let Some(v) = self.str_wire[a] {
                            self.fifo_q[ni].push_back(v);
                            self.ack_wire[a] = true;
                            acks += 1;
                            self.trace_ack(a as u32);
                        }
                    }
                }
                _ => {
                    if self.state[ni] == State::Receive {
                        for p in 0..self.g.nodes[ni].ins.len() {
                            let a = self.g.nodes[ni].ins[p].0 as usize;
                            if self.in_regs[ni][p].is_none() {
                                if let Some(v) = self.str_wire[a] {
                                    self.in_regs[ni][p] = Some(v);
                                    self.ack_wire[a] = true;
                                    acks += 1;
                                    self.trace_ack(a as u32);
                                }
                            }
                        }
                    }
                }
            }
        }

        // ---- Phase C: retire acks, advance FSMs ----------------------
        let mut progress = acks;
        // Environment pops an injected token when its port got acked.
        for pi in 0..self.in_port_arcs.len() {
            let a = self.in_port_arcs[pi];
            if self.ack_wire[a] {
                self.pending[a].pop_front();
            }
        }
        for ni in 0..self.g.nodes.len() {
            let op = self.g.nodes[ni].op;
            if let Op::Fifo(_) = op {
                let a = self.g.nodes[ni].outs[0].0 as usize;
                if self.ack_wire[a] {
                    self.fifo_q[ni].pop_front();
                }
                continue;
            }
            match self.state[ni] {
                State::Send => {
                    let mut all_clear = true;
                    for p in 0..self.out_regs[ni].len() {
                        let a = self.g.nodes[ni].outs[p].0 as usize;
                        if self.out_regs[ni][p].is_some() {
                            if self.ack_wire[a] {
                                self.out_regs[ni][p] = None;
                            } else {
                                all_clear = false;
                            }
                        }
                    }
                    if all_clear {
                        if let Op::Const(_) = op {
                            self.const_spent[ni] = true;
                            // Spent const idles in S1 forever (no inputs).
                        }
                        self.state[ni] = State::Receive;
                    }
                }
                State::Receive => {
                    if self.fire_ready(ni) {
                        self.state[ni] = State::Execute;
                    }
                }
                State::Execute => {
                    self.execute(ni);
                    progress += 1;
                    self.state[ni] = State::Send;
                }
            }
        }
        self.cycle += 1;
        progress
    }

    fn busy(&self) -> bool {
        // Anything queued, latched, pending, or mid-FSM?
        self.pending.iter().any(|q| !q.is_empty())
            || self.fifo_q.iter().any(|q| !q.is_empty())
            || (0..self.g.nodes.len()).any(|ni| {
                match self.g.nodes[ni].op {
                    // A spent const parked in S1/S3-done is not busy.
                    Op::Const(_) => !self.const_spent[ni],
                    _ => {
                        self.state[ni] != State::Receive
                            || self.in_regs[ni].iter().any(|r| r.is_some())
                    }
                }
            })
    }

    /// Run until quiescent or `max_cycles`.
    ///
    /// Liveness argument: any sustained activity in the fabric produces an
    /// `ack` or an execution within a bounded window (an FSM can spend at
    /// most one cycle in S2 and needs an ack to leave S3; a FIFO hop is an
    /// ack), so eight consecutive zero-progress cycles means the fabric is
    /// either finished or deadlocked — `busy()` distinguishes the two.
    pub fn run(mut self, cfg: &SimConfig) -> SimOutcome {
        let mut idle = 0u32;
        while self.cycle < cfg.max_cycles {
            let progress = self.step();
            if progress == 0 {
                idle += 1;
                if idle >= 2 && !self.busy() {
                    break;
                }
                if idle >= 8 {
                    break; // deadlock / starvation
                }
            } else {
                idle = 0;
            }
        }
        let quiescent = !self.busy();
        SimOutcome {
            outputs: self.collected,
            cycles: self.cycle,
            firings: self.firings,
            quiescent,
        }
    }

    /// Clock count so far.
    pub fn cycles(&self) -> u64 {
        self.cycle
    }
}

/// Convenience: build + run in one call.
pub fn run_fsm(g: &Graph, cfg: &SimConfig) -> SimOutcome {
    FsmSim::new(g, cfg).run(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::GraphBuilder;
    use crate::sim::token::run_token;

    fn adder() -> Graph {
        let mut b = GraphBuilder::new("adder");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        b.finish().unwrap()
    }

    #[test]
    fn add_matches_token_engine() {
        let g = adder();
        let cfg = SimConfig::new()
            .inject("a", vec![1, 2, 3])
            .inject("b", vec![10, 20, 30]);
        let fsm = run_fsm(&g, &cfg);
        let tok = run_token(&g, &cfg);
        assert_eq!(fsm.outputs, tok.outputs);
        assert!(fsm.quiescent);
        // The FSM engine pays handshake cycles: strictly more cycles than
        // the token engine's rounds.
        assert!(fsm.cycles > tok.cycles / 2);
    }

    #[test]
    fn firing_costs_at_least_three_cycles() {
        let g = adder();
        let cfg = SimConfig::new().inject("a", vec![7]).inject("b", vec![8]);
        let out = run_fsm(&g, &cfg);
        assert_eq!(out.stream("z"), &[15]);
        // S1 latch → S2 execute → S3 send: ≥3 edges.
        assert!(out.cycles >= 3, "cycles = {}", out.cycles);
    }

    #[test]
    fn handshake_trace_is_well_formed() {
        let g = adder();
        let cfg = SimConfig::new()
            .inject("a", vec![1, 2])
            .inject("b", vec![3, 4]);
        let mut sim = FsmSim::new(&g, &cfg);
        sim.trace = Some(Vec::new());
        for _ in 0..200 {
            sim.step();
        }
        let trace = sim.trace.take().unwrap();
        // Every ack on an arc must be preceded (same cycle) by a str.
        for e in trace.iter().filter(|e| e.kind == HandshakeKind::Ack) {
            assert!(
                trace.iter().any(|s| s.arc == e.arc
                    && s.cycle == e.cycle
                    && matches!(s.kind, HandshakeKind::Str(_))),
                "ack without str on arc {} at cycle {}",
                e.arc,
                e.cycle
            );
        }
    }

    #[test]
    fn dmerge_parks_unselected_token() {
        let mut b = GraphBuilder::new("t");
        let ctl = b.input_port("ctl");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::DMerge, &[ctl, a, c], &[z]);
        let g = b.finish().unwrap();
        let cfg = SimConfig::new()
            .inject("ctl", vec![0, 1])
            .inject("a", vec![7])
            .inject("b", vec![9]);
        let out = run_fsm(&g, &cfg);
        assert_eq!(out.stream("z"), &[9, 7]);
    }

    #[test]
    fn const_fires_exactly_once() {
        let mut b = GraphBuilder::new("t");
        let k = b.constant(5);
        let a = b.input_port("a");
        let z = b.output_port("z");
        b.node(Op::Mul, &[k, a], &[z]);
        let g = b.finish().unwrap();
        let cfg = SimConfig::new().inject("a", vec![8, 9]);
        let out = run_fsm(&g, &cfg);
        assert_eq!(out.stream("z"), &[40]);
        assert!(!out.quiescent); // second `a` token is latched, starved
    }
}
