//! The lane-vectorized batch engine: up to [`MAX_LANES`] independent
//! input sets ("lanes") executed in lockstep through one compiled
//! [`Program`].
//!
//! The scalar engines walk `Option<Word>` arcs one token at a time; the
//! coordinator's batch path therefore re-runs the whole interpreter per
//! batch item. This engine replicates only the *state*, not the
//! control: token storage is structure-of-arrays — per arc a row of
//! 64-bit `occupied` mask words (bit ℓ of word w = lane `w·64+ℓ`'s
//! token present) plus a `[Word; LANES]` value row per mask word — so
//! one pass over the node table advances every lane at once. Fire
//! decisions for ALU/decider/`copy`/`const`/`ndmerge` ops are pure
//! bitmask algebra; only value-dependent routing (`branch`/`dmerge`
//! control) needs a lane scan to build its truth mask, and only `fifo`
//! keeps a per-lane queue.
//!
//! Lanes never interact: lane ℓ executes a legal schedule of exactly
//! the firings a scalar [`TokenSim`](super::TokenSim) run of lane ℓ's
//! config would perform, and every firing rule is deterministic, so
//! per-port output streams at fixpoint are byte-identical — with the
//! same scoping the sharded executor's confluence argument carries: a
//! *contended* `ndmerge` (both inputs holding tokens whose arrival
//! order differs between schedules) is arrival-order dependent in
//! every engine of this crate, and only the loop schema's guarantee
//! that its merge nodes never hold two competing tokens
//! (`dfg::schema`) makes cross-engine comparison exact. All seven
//! benchmarks and the `util::proptest` generator stay inside that
//! class, and the conformance harness enforces byte-identity there. A
//! lane that deadlocks simply stops contributing fire-mask bits; its
//! siblings keep advancing.
//!
//! Two firing schedules, selected by [`Program::compile`]:
//!
//! * **snapshot rounds** (general graphs): table-order scan, input
//!   consumption immediate, output occupancy staged to the end of the
//!   pass — the scalar engines' round semantics, vectorized.
//! * **topo ripple** (acyclic unit-rate graphs): producer-before-
//!   consumer scan with immediate occupancy updates, so a token crosses
//!   the whole pipeline in one pass. On this path the schedule is the
//!   program's fused [`ExecUnit`] list: linear operator runs execute as
//!   one [`FusedChain`] superinstruction — external inputs consumed,
//!   steps evaluated through a register row, one output emitted — with
//!   link arcs never touching token storage. Legal exactly on this
//!   class — the per-arc token sequence is schedule-independent there
//!   (see `sim::compiled` and DESIGN.md §6).
//!
//! The inner row kernels (`eval2`/`blend`) are written straight-line
//! over whole `[Word; LANES]` rows so the autovectorizer can keep them
//! branch-free; `--features simd` (nightly) swaps in explicit
//! `std::simd` kernels that are required — and tested — to stay
//! byte-identical to the scalar arms.

use super::compiled::{CNode, ExecUnit, FusedSrc, Program};
use super::{SimConfig, SimOutcome};
use crate::dfg::{Op, OpClass, Word};
use crate::obs::{EngineProfile, ProfileLevel};
use std::collections::{BTreeMap, VecDeque};

/// Lanes per occupancy-mask word: one `u64` worth.
pub const LANES: usize = 64;

/// Maximum lanes per [`LaneSim`] — [`MAX_WORDS`] mask words in
/// lockstep. Chunking helpers ([`run_lanes`], the coordinator batch
/// path) split larger batches at this width.
pub const MAX_LANES: usize = MAX_WORDS * LANES;

/// Occupancy-mask words per arc at full width.
const MAX_WORDS: usize = 4;

/// One input port's pending injections: per-lane streams + cursors.
struct Inject {
    arc: u32,
    streams: Vec<Vec<Word>>,
    pos: Vec<usize>,
}

/// Per-lane collected output streams for one port.
type LaneStreams = Vec<Vec<Word>>;

/// Up to [`MAX_LANES`] batch items in lockstep through one compiled
/// program.
pub struct LaneSim<'p> {
    p: &'p Program,
    n_lanes: usize,
    /// Mask words actually in play: `ceil(n_lanes / 64)`.
    words: usize,
    /// Per-word mask of lanes in use (all bits except the ragged tail).
    active: Vec<u64>,
    /// Topo ripple (immediate occupancy) vs snapshot rounds (staged).
    immediate: bool,
    /// Per-arc lane occupancy, flat: slot `a·words + w`.
    occ: Vec<u64>,
    /// Per-slot value rows, flat at `slot·LANES`; `vals[slot·LANES+ℓ]`
    /// is live iff `occ[slot]` bit ℓ.
    vals: Vec<Word>,
    /// Per node × word: lanes whose `Const` reset token was emitted.
    const_done: Vec<u64>,
    /// Per-node per-lane FIFO queues (empty vec for non-`Fifo` nodes),
    /// indexed by global lane.
    fifos: Vec<Vec<VecDeque<Word>>>,
    inject: Vec<Inject>,
    /// Collected tokens per output port per lane.
    collected: Vec<LaneStreams>,
    /// Staged occupancy writes (slot, mask) for the current snapshot
    /// round.
    staged: Vec<(u32, u64)>,
    lane_firings: Vec<u64>,
    firings: u64,
    passes: u64,
    max_cycles: u64,
    /// `None` unless profiling was enabled — the hot path pays one
    /// pointer-null branch per fired node when off, nothing more.
    prof: Option<Box<EngineProfile>>,
}

impl<'p> LaneSim<'p> {
    /// One lane per config; `cfgs.len()` must be at most [`MAX_LANES`].
    /// An empty slice yields a valid sim that is already at fixpoint
    /// and produces no outcomes.
    pub fn new(p: &'p Program, cfgs: &[SimConfig]) -> Self {
        let n = cfgs.len();
        assert!(
            n <= MAX_LANES,
            "LaneSim takes at most {MAX_LANES} lane configs, got {n}"
        );
        let words = n.div_ceil(LANES);
        let mut active = vec![u64::MAX; words];
        if let Some(last) = active.last_mut() {
            if n % LANES != 0 {
                *last = (1u64 << (n % LANES)) - 1;
            }
        }
        LaneSim {
            p,
            n_lanes: n,
            words,
            active,
            immediate: p.topo.is_some(),
            occ: vec![0; p.n_arcs * words],
            vals: vec![0; p.n_arcs * words * LANES],
            const_done: vec![0; p.n_nodes() * words],
            fifos: p
                .nodes
                .iter()
                .map(|cn| match cn.op {
                    Op::Fifo(_) => vec![VecDeque::new(); n],
                    _ => Vec::new(),
                })
                .collect(),
            inject: p
                .input_ports
                .iter()
                .map(|(arc, name)| Inject {
                    arc: *arc,
                    streams: cfgs
                        .iter()
                        .map(|c| c.inject.get(name).cloned().unwrap_or_default())
                        .collect(),
                    pos: vec![0; n],
                })
                .collect(),
            collected: vec![vec![Vec::new(); n]; p.output_ports.len()],
            staged: Vec::new(),
            lane_firings: vec![0; words * LANES],
            firings: 0,
            passes: 0,
            // No lanes → no budget: `run` exits immediately. (This used
            // to be `.max().unwrap()`, panicking on empty batches.)
            max_cycles: cfgs.iter().map(|c| c.max_cycles).max().unwrap_or(0),
            prof: None,
        }
    }

    /// Allocate profiling state at `level`. [`ProfileLevel::Off`]
    /// deallocates instead, restoring the zero-cost path.
    pub fn enable_profiling(&mut self, level: ProfileLevel) {
        if level == ProfileLevel::Off {
            self.prof = None;
        } else {
            self.prof = Some(Box::new(EngineProfile::new(
                "lanes",
                level,
                self.p.n_nodes(),
                self.p.n_arcs,
            )));
        }
    }

    /// Harvest the profile (if any), leaving the sim unprofiled.
    pub fn take_profile(&mut self) -> Option<EngineProfile> {
        self.prof.take().map(|p| *p)
    }

    /// One synchronous pass over all lanes. Returns total progress
    /// events (injections + collections + firings across lanes); zero
    /// means a global fixpoint.
    pub fn step(&mut self) -> u64 {
        let mut progress = 0u64;
        let words = self.words;

        // Phase 1a: environment injection — one token per free port
        // arc per lane (the always-ready sender, per lane).
        for inj in &mut self.inject {
            let a = inj.arc as usize;
            for w in 0..words {
                let slot = a * words + w;
                let mut free = !self.occ[slot] & self.active[w];
                while free != 0 {
                    let ll = free.trailing_zeros() as usize;
                    free &= free - 1;
                    let l = w * LANES + ll;
                    if inj.pos[l] < inj.streams[l].len() {
                        self.vals[slot * LANES + ll] = inj.streams[l][inj.pos[l]];
                        inj.pos[l] += 1;
                        self.occ[slot] |= 1 << ll;
                        progress += 1;
                    }
                }
            }
        }
        // Phase 1b: environment collection at output ports.
        for pi in 0..self.p.output_ports.len() {
            let a = self.p.output_ports[pi].0 as usize;
            for w in 0..words {
                let slot = a * words + w;
                let mut m = self.occ[slot] & self.active[w];
                self.occ[slot] &= !m;
                progress += m.count_ones() as u64;
                while m != 0 {
                    let ll = m.trailing_zeros() as usize;
                    m &= m - 1;
                    self.collected[pi][w * LANES + ll].push(self.vals[slot * LANES + ll]);
                }
            }
        }

        // Phase 2: fire every schedule entry once, over all lanes at
        // once — the fused exec list on the topo path, the plain table
        // under snapshot rounds.
        let p = self.p;
        let mut fired = 0u64;
        if self.immediate {
            for unit in &p.exec {
                fired += match *unit {
                    ExecUnit::Node(ni) => self.fire_node(ni as usize),
                    ExecUnit::Chain(ci) => self.fire_chain(ci as usize),
                };
            }
        } else {
            for ni in 0..p.n_nodes() {
                fired += self.fire_node(ni);
            }
            let staged = std::mem::take(&mut self.staged);
            for &(slot, m) in &staged {
                debug_assert_eq!(self.occ[slot as usize] & m, 0, "lane token overwrite");
                self.occ[slot as usize] |= m;
            }
            let mut staged = staged;
            staged.clear();
            self.staged = staged;
        }

        self.firings += fired;
        self.passes += 1;
        if let Some(prof) = self.prof.as_deref_mut() {
            prof.cycles += 1;
            if prof.level >= ProfileLevel::Full {
                // Occupancy integral: tokens parked on each arc at the
                // end of this pass, summed over active lanes.
                for a in 0..self.p.n_arcs {
                    let mut tokens = 0u64;
                    for w in 0..words {
                        tokens += (self.occ[a * words + w] & self.active[w]).count_ones() as u64;
                    }
                    if tokens > 0 {
                        prof.occupy(a, tokens);
                    }
                }
            }
        }
        progress + fired
    }

    /// Run until every lane reaches a fixpoint (two consecutive
    /// zero-progress passes, mirroring the scalar drain round) or the
    /// shared cycle budget (the max over the lane configs) is spent.
    pub fn run(&mut self) {
        let mut idle = 0u32;
        while self.passes < self.max_cycles {
            if self.step() == 0 {
                idle += 1;
                if idle >= 2 {
                    break;
                }
            } else {
                idle = 0;
            }
        }
    }

    /// Storage slot for (arc, mask word).
    #[inline]
    fn slot(&self, arc: usize, w: usize) -> usize {
        arc * self.words + w
    }

    /// Copy of one value row.
    #[inline]
    fn row(&self, slot: usize) -> [Word; LANES] {
        self.vals[slot * LANES..(slot + 1) * LANES]
            .try_into()
            .expect("row is LANES wide")
    }

    #[inline]
    fn row_mut(&mut self, slot: usize) -> &mut [Word; LANES] {
        (&mut self.vals[slot * LANES..(slot + 1) * LANES])
            .try_into()
            .expect("row is LANES wide")
    }

    /// Mark `mask` lanes of storage `slot` occupied — staged under
    /// snapshot rounds, immediate on the topo ripple path.
    #[inline]
    fn emit(&mut self, slot: usize, mask: u64) {
        if mask == 0 {
            return;
        }
        if self.immediate {
            debug_assert_eq!(self.occ[slot] & mask, 0, "lane token overwrite");
            self.occ[slot] |= mask;
        } else {
            self.staged.push((slot as u32, mask));
        }
    }

    /// Credit `times` firings to every mask lane of word `w` — a
    /// straight-line sweep over the word (no per-set-bit loop) so the
    /// accounting vectorizes with the rest of the row work. Returns the
    /// lane-firing total.
    #[inline]
    fn count_times(&mut self, w: usize, mask: u64, times: u64) -> u64 {
        let lf = &mut self.lane_firings[w * LANES..(w + 1) * LANES];
        for (l, f) in lf.iter_mut().enumerate() {
            *f += ((mask >> l) & 1) * times;
        }
        mask.count_ones() as u64 * times
    }

    #[inline]
    fn count(&mut self, w: usize, mask: u64) -> u64 {
        self.count_times(w, mask, 1)
    }

    /// Truth mask over lanes with a non-zero value on storage `slot`
    /// (garbage on unoccupied lanes — callers mask with occupancy).
    #[inline]
    fn truthy(&self, slot: usize) -> u64 {
        let mut t = 0u64;
        for (l, v) in self.vals[slot * LANES..(slot + 1) * LANES].iter().enumerate() {
            t |= ((*v != 0) as u64) << l;
        }
        t
    }

    /// Fire node `ni` on every lane whose fire rule holds; returns the
    /// number of lane-firings. Each opcode class hoists its fire-rule
    /// mask out of the row work, so the per-element bodies stay
    /// branch-free.
    fn fire_node(&mut self, ni: usize) -> u64 {
        let cn: CNode = self.p.nodes[ni];
        let words = self.words;
        let mut fired = 0u64;
        match cn.op.class() {
            OpClass::Alu2 | OpClass::Decider => {
                let (a, b, o) = (cn.ins[0] as usize, cn.ins[1] as usize, cn.outs[0] as usize);
                for w in 0..words {
                    let (sa, sb, so) = (self.slot(a, w), self.slot(b, w), self.slot(o, w));
                    let m = self.occ[sa] & self.occ[sb] & !self.occ[so];
                    if m == 0 {
                        continue;
                    }
                    self.occ[sa] &= !m;
                    self.occ[sb] &= !m;
                    let (va, vb) = (self.row(sa), self.row(sb));
                    let mut tmp = [0; LANES];
                    eval2_lanes(cn.op, &va, &vb, &mut tmp);
                    blend(self.row_mut(so), &tmp, m);
                    self.emit(so, m);
                    fired += self.count(w, m);
                }
            }
            OpClass::Alu1 => {
                let (a, o) = (cn.ins[0] as usize, cn.outs[0] as usize);
                for w in 0..words {
                    let (sa, so) = (self.slot(a, w), self.slot(o, w));
                    let m = self.occ[sa] & !self.occ[so];
                    if m == 0 {
                        continue;
                    }
                    self.occ[sa] &= !m;
                    let va = self.row(sa);
                    let mut tmp = [0; LANES];
                    eval1_lanes(cn.op, &va, &mut tmp);
                    blend(self.row_mut(so), &tmp, m);
                    self.emit(so, m);
                    fired += self.count(w, m);
                }
            }
            OpClass::Copy => {
                let (a, o0, o1) = (cn.ins[0] as usize, cn.outs[0] as usize, cn.outs[1] as usize);
                for w in 0..words {
                    let (sa, s0, s1) = (self.slot(a, w), self.slot(o0, w), self.slot(o1, w));
                    let m = self.occ[sa] & !self.occ[s0] & !self.occ[s1];
                    if m == 0 {
                        continue;
                    }
                    self.occ[sa] &= !m;
                    let va = self.row(sa);
                    blend(self.row_mut(s0), &va, m);
                    blend(self.row_mut(s1), &va, m);
                    self.emit(s0, m);
                    self.emit(s1, m);
                    fired += self.count(w, m);
                }
            }
            OpClass::Const => {
                let o = cn.outs[0] as usize;
                let Op::Const(v) = cn.op else { unreachable!() };
                let kv = [v; LANES];
                for w in 0..words {
                    let so = self.slot(o, w);
                    let cd = ni * words + w;
                    let m = self.active[w] & !self.const_done[cd] & !self.occ[so];
                    if m == 0 {
                        continue;
                    }
                    self.const_done[cd] |= m;
                    blend(self.row_mut(so), &kv, m);
                    self.emit(so, m);
                    fired += self.count(w, m);
                }
            }
            OpClass::NdMerge => {
                // First-come-first-served; on a tie, port 0 wins (the
                // scalar engines' fixed arbiter priority, per lane).
                let (i0, i1, o) = (cn.ins[0] as usize, cn.ins[1] as usize, cn.outs[0] as usize);
                for w in 0..words {
                    let (s0, s1, so) = (self.slot(i0, w), self.slot(i1, w), self.slot(o, w));
                    let f = !self.occ[so] & self.active[w];
                    let take0 = self.occ[s0] & f;
                    let take1 = self.occ[s1] & f & !self.occ[s0];
                    if (take0 | take1) == 0 {
                        continue;
                    }
                    self.occ[s0] &= !take0;
                    self.occ[s1] &= !take1;
                    let (v0, v1) = (self.row(s0), self.row(s1));
                    blend(self.row_mut(so), &v0, take0);
                    blend(self.row_mut(so), &v1, take1);
                    self.emit(so, take0 | take1);
                    fired += self.count(w, take0 | take1);
                }
            }
            OpClass::DMerge => {
                // Port 0 is the control; TRUE selects port 1, FALSE
                // port 2. The unselected token, if any, stays put.
                let (c, d1, d2, o) = (
                    cn.ins[0] as usize,
                    cn.ins[1] as usize,
                    cn.ins[2] as usize,
                    cn.outs[0] as usize,
                );
                for w in 0..words {
                    let (sc, sd1, sd2, so) = (
                        self.slot(c, w),
                        self.slot(d1, w),
                        self.slot(d2, w),
                        self.slot(o, w),
                    );
                    let t = self.truthy(sc);
                    let ready = self.occ[sc] & !self.occ[so];
                    let m_t = ready & t & self.occ[sd1];
                    let m_f = ready & !t & self.occ[sd2];
                    if (m_t | m_f) == 0 {
                        continue;
                    }
                    self.occ[sc] &= !(m_t | m_f);
                    self.occ[sd1] &= !m_t;
                    self.occ[sd2] &= !m_f;
                    let (vd1, vd2) = (self.row(sd1), self.row(sd2));
                    blend(self.row_mut(so), &vd1, m_t);
                    blend(self.row_mut(so), &vd2, m_f);
                    self.emit(so, m_t | m_f);
                    fired += self.count(w, m_t | m_f);
                }
            }
            OpClass::Branch => {
                // Port 0 is control, port 1 data; output 0 is the TRUE
                // side. Only the selected output must be free.
                let (c, d, o0, o1) = (
                    cn.ins[0] as usize,
                    cn.ins[1] as usize,
                    cn.outs[0] as usize,
                    cn.outs[1] as usize,
                );
                for w in 0..words {
                    let (sc, sd, s0, s1) = (
                        self.slot(c, w),
                        self.slot(d, w),
                        self.slot(o0, w),
                        self.slot(o1, w),
                    );
                    let t = self.truthy(sc);
                    let ready = self.occ[sc] & self.occ[sd];
                    let m_t = ready & t & !self.occ[s0];
                    let m_f = ready & !t & !self.occ[s1];
                    if (m_t | m_f) == 0 {
                        continue;
                    }
                    self.occ[sc] &= !(m_t | m_f);
                    self.occ[sd] &= !(m_t | m_f);
                    let vd = self.row(sd);
                    blend(self.row_mut(s0), &vd, m_t);
                    blend(self.row_mut(s1), &vd, m_f);
                    self.emit(s0, m_t);
                    self.emit(s1, m_f);
                    fired += self.count(w, m_t | m_f);
                }
            }
            OpClass::Fifo => {
                // Control diverges per lane (queue depths differ), so
                // this is the one per-lane fallback: accept and emit in
                // the same pass, exactly like the scalar engine.
                let Op::Fifo(k) = cn.op else { unreachable!() };
                let cap = k as usize;
                let (i, o) = (cn.ins[0] as usize, cn.outs[0] as usize);
                for w in 0..words {
                    let (si, so) = (self.slot(i, w), self.slot(o, w));
                    let mut acted_mask = 0u64;
                    let mut emit_mask = 0u64;
                    let mut act = self.active[w];
                    while act != 0 {
                        let ll = act.trailing_zeros() as usize;
                        act &= act - 1;
                        let bit = 1u64 << ll;
                        let l = w * LANES + ll;
                        if self.occ[si] & bit != 0 && self.fifos[ni][l].len() < cap {
                            self.occ[si] &= !bit;
                            let v = self.vals[si * LANES + ll];
                            self.fifos[ni][l].push_back(v);
                            acted_mask |= bit;
                        }
                        if self.occ[so] & bit == 0 && emit_mask & bit == 0 {
                            if let Some(v) = self.fifos[ni][l].pop_front() {
                                self.vals[so * LANES + ll] = v;
                                emit_mask |= bit;
                                acted_mask |= bit;
                            }
                        }
                    }
                    self.emit(so, emit_mask);
                    fired += self.count(w, acted_mask);
                }
            }
        }
        if fired > 0 {
            if let Some(prof) = self.prof.as_deref_mut() {
                prof.fire_n(ni, fired);
                prof.opcode(cn.op.mnemonic(), fired);
            }
        }
        fired
    }

    /// Fire a fused superinstruction chain: on every lane where *all*
    /// external inputs hold a token and the output is free, consume the
    /// inputs, evaluate the member steps through a register row (link
    /// arcs never touch token storage), and emit the single output.
    /// Each member is credited one firing per token, so firing totals
    /// match the unfused schedule at quiescence.
    fn fire_chain(&mut self, ci: usize) -> u64 {
        let p = self.p;
        let c = &p.chains[ci];
        let words = self.words;
        let o = c.out as usize;
        let chain_len = c.nodes.len() as u64;
        let mut fired = 0u64;
        let mut tokens = 0u64;
        for w in 0..words {
            let so = o * words + w;
            let mut m = self.active[w] & !self.occ[so];
            for &a in &c.ext_ins {
                m &= self.occ[a as usize * words + w];
            }
            if m == 0 {
                continue;
            }
            for &a in &c.ext_ins {
                self.occ[a as usize * words + w] &= !m;
            }
            // `cur` carries the elided link value; only `m` lanes are
            // meaningful, the rest are garbage the final blend drops.
            let mut cur = [0; LANES];
            let mut tmp = [0; LANES];
            for step in &c.steps {
                let xa = match step.a {
                    FusedSrc::Arc(a) => self.row(a as usize * words + w),
                    FusedSrc::Prev | FusedSrc::None => cur,
                };
                match step.op.class() {
                    OpClass::Alu2 | OpClass::Decider => {
                        let xb = match step.b {
                            FusedSrc::Arc(a) => self.row(a as usize * words + w),
                            FusedSrc::Prev | FusedSrc::None => cur,
                        };
                        eval2_lanes(step.op, &xa, &xb, &mut tmp);
                        cur = tmp;
                    }
                    OpClass::Alu1 => {
                        eval1_lanes(step.op, &xa, &mut tmp);
                        cur = tmp;
                    }
                    // `fifo` / single-output `copy`: pure transport.
                    _ => cur = xa,
                }
            }
            blend(self.row_mut(so), &cur, m);
            self.emit(so, m);
            fired += self.count_times(w, m, chain_len);
            tokens += m.count_ones() as u64;
        }
        if tokens > 0 {
            if let Some(prof) = self.prof.as_deref_mut() {
                // Credit each member node with the token count, under its
                // own mnemonic, so fused and unfused runs profile alike.
                for &nid in &c.nodes {
                    let mi = nid as usize;
                    prof.fire_n(mi, tokens);
                    prof.opcode(p.nodes[mi].op.mnemonic(), tokens);
                }
            }
        }
        fired
    }

    /// True when lane `l` can make no progress ever again: injections
    /// drained, no tokens on arcs, no tokens queued in FIFOs (the
    /// scalar engine's `idle` test, per lane).
    fn lane_idle(&self, l: usize) -> bool {
        let (w, bit) = (l / LANES, 1u64 << (l % LANES));
        let words = self.words;
        self.inject
            .iter()
            .all(|inj| inj.pos[l] >= inj.streams[l].len())
            && (0..self.p.n_arcs).all(|a| self.occ[a * words + w] & bit == 0)
            && self
                .fifos
                .iter()
                .all(|q| q.is_empty() || q[l].is_empty())
    }

    /// Finalize into one [`SimOutcome`] per lane. As in the lockstep
    /// batch engine, `cycles` is the chunk's shared pass count;
    /// `firings` and `quiescent` are per lane.
    pub fn into_outcomes(mut self) -> Vec<SimOutcome> {
        let mut outs = Vec::with_capacity(self.n_lanes);
        for l in 0..self.n_lanes {
            let quiescent = self.lane_idle(l);
            let mut outputs = BTreeMap::new();
            for (pi, (_, name)) in self.p.output_ports.iter().enumerate() {
                outputs.insert(name.clone(), std::mem::take(&mut self.collected[pi][l]));
            }
            outs.push(SimOutcome {
                outputs,
                cycles: self.passes,
                firings: self.lane_firings[l],
                quiescent,
            });
        }
        outs
    }

    /// Total lane-firings across the chunk so far.
    pub fn firings(&self) -> u64 {
        self.firings
    }

    /// Passes executed so far.
    pub fn passes(&self) -> u64 {
        self.passes
    }
}

/// `dst[ℓ] = src[ℓ]` where `mask` bit ℓ is set. Full and empty masks —
/// the common cases on saturated chunks — short-circuit to a plain row
/// copy / no-op before any per-element work.
#[inline]
fn blend(dst: &mut [Word; LANES], src: &[Word; LANES], mask: u64) {
    if mask == u64::MAX {
        *dst = *src;
    } else if mask != 0 {
        blend_partial(dst, src, mask);
    }
}

/// Partial-mask blend, branch-free (bitwise select against a
/// sign-extended lane mask) so the element loop vectorizes.
fn blend_partial(dst: &mut [Word; LANES], src: &[Word; LANES], mask: u64) {
    for (l, (d, &s)) in dst.iter_mut().zip(src).enumerate() {
        let sel = 0i16.wrapping_sub(((mask >> l) & 1) as i16);
        *d = (s & sel) | (*d & !sel);
    }
}

/// Unary opcode over a whole row — one tight loop, no lane branches.
fn eval1_lanes(op: Op, a: &[Word; LANES], out: &mut [Word; LANES]) {
    for (o, &x) in out.iter_mut().zip(a) {
        *o = op.eval1(x);
    }
}

/// The vector opcode table: evaluate a 2-input opcode over all lanes.
#[inline]
fn eval2_lanes(op: Op, a: &[Word; LANES], b: &[Word; LANES], out: &mut [Word; LANES]) {
    #[cfg(feature = "simd")]
    vector::eval2(op, a, b, out);
    #[cfg(not(feature = "simd"))]
    eval2_lanes_scalar(op, a, b, out);
}

/// Scalar reference kernels: one tight loop per opcode so the
/// autovectorizer can keep each arm branch-free. Always compiled —
/// the `simd` path falls back here for branchy opcodes (`Div`) and the
/// equivalence test uses it as the byte-identity oracle.
fn eval2_lanes_scalar(op: Op, a: &[Word; LANES], b: &[Word; LANES], out: &mut [Word; LANES]) {
    macro_rules! arm {
        ($f:expr) => {{
            let f = $f;
            for (o, (&x, &y)) in out.iter_mut().zip(a.iter().zip(b)) {
                *o = f(x, y);
            }
        }};
    }
    match op {
        Op::Add => arm!(|x: Word, y: Word| x.wrapping_add(y)),
        Op::Sub => arm!(|x: Word, y: Word| x.wrapping_sub(y)),
        Op::Mul => arm!(|x: Word, y: Word| x.wrapping_mul(y)),
        Op::And => arm!(|x: Word, y: Word| x & y),
        Op::Or => arm!(|x: Word, y: Word| x | y),
        Op::Xor => arm!(|x: Word, y: Word| x ^ y),
        Op::Shl => arm!(|x: Word, y: Word| x.wrapping_shl((y & 0xf) as u32)),
        Op::Shr => arm!(|x: Word, y: Word| x.wrapping_shr((y & 0xf) as u32)),
        Op::IfGt => arm!(|x: Word, y: Word| (x > y) as Word),
        Op::IfGe => arm!(|x: Word, y: Word| (x >= y) as Word),
        Op::IfLt => arm!(|x: Word, y: Word| (x < y) as Word),
        Op::IfLe => arm!(|x: Word, y: Word| (x <= y) as Word),
        Op::IfEq => arm!(|x: Word, y: Word| (x == y) as Word),
        Op::IfDf => arm!(|x: Word, y: Word| (x != y) as Word),
        // Div (branchy divide-by-zero guard) and anything future: the
        // scalar rule per lane.
        _ => arm!(|x: Word, y: Word| op.eval2(x, y)),
    }
}

/// Explicit `std::simd` row kernels (nightly-only, `--features simd`).
/// Equivalence obligation (DESIGN.md §6): every arm must be
/// byte-identical to [`eval2_lanes_scalar`] — the in-module test pins
/// this per opcode, and the bench verification gate re-checks the
/// end-to-end outputs on every run.
#[cfg(feature = "simd")]
mod vector {
    use super::{eval2_lanes_scalar, Word, LANES};
    use crate::dfg::Op;
    use std::simd::prelude::*;

    /// 16 × i16 per register: 256-bit rows, four registers per word.
    const W: usize = 16;
    type V = Simd<Word, W>;

    pub fn eval2(op: Op, a: &[Word; LANES], b: &[Word; LANES], out: &mut [Word; LANES]) {
        macro_rules! arm {
            (|$x:ident, $y:ident| $e:expr) => {{
                for i in (0..LANES).step_by(W) {
                    let $x = V::from_slice(&a[i..i + W]);
                    let $y = V::from_slice(&b[i..i + W]);
                    let r: V = $e;
                    r.copy_to_slice(&mut out[i..i + W]);
                }
            }};
        }
        match op {
            // `std::simd` integer arithmetic wraps, matching the
            // scalar `wrapping_*` semantics exactly.
            Op::Add => arm!(|x, y| x + y),
            Op::Sub => arm!(|x, y| x - y),
            Op::Mul => arm!(|x, y| x * y),
            Op::And => arm!(|x, y| x & y),
            Op::Or => arm!(|x, y| x | y),
            Op::Xor => arm!(|x, y| x ^ y),
            // Amounts are masked to 0..=15 first, so every lane shift
            // is in range; `>>` on i16 lanes is arithmetic, matching
            // `wrapping_shr` on the masked amount.
            Op::Shl => arm!(|x, y| x << (y & V::splat(0xf))),
            Op::Shr => arm!(|x, y| x >> (y & V::splat(0xf))),
            Op::IfGt => arm!(|x, y| x.simd_gt(y).select(V::splat(1), V::splat(0))),
            Op::IfGe => arm!(|x, y| x.simd_ge(y).select(V::splat(1), V::splat(0))),
            Op::IfLt => arm!(|x, y| x.simd_lt(y).select(V::splat(1), V::splat(0))),
            Op::IfLe => arm!(|x, y| x.simd_le(y).select(V::splat(1), V::splat(0))),
            Op::IfEq => arm!(|x, y| x.simd_eq(y).select(V::splat(1), V::splat(0))),
            Op::IfDf => arm!(|x, y| x.simd_ne(y).select(V::splat(1), V::splat(0))),
            // Div's divide-by-zero guard is branchy — scalar per lane.
            _ => eval2_lanes_scalar(op, a, b, out),
        }
    }
}

/// Run any number of configs through `p`, in lane chunks of
/// [`MAX_LANES`]; one outcome per config, in order.
pub fn run_lanes(p: &Program, cfgs: &[SimConfig]) -> Vec<SimOutcome> {
    let mut outs = Vec::with_capacity(cfgs.len());
    for chunk in cfgs.chunks(MAX_LANES) {
        let mut sim = LaneSim::new(p, chunk);
        sim.run();
        outs.extend(sim.into_outcomes());
    }
    outs
}

/// [`run_lanes`] with profiling at `level`: per-chunk profiles fold into
/// one via [`EngineProfile::merge`] (cycles = max over chunks, counters
/// summed), so `total_firings` equals the batch's lane-firing total.
pub fn run_lanes_profiled(
    p: &Program,
    cfgs: &[SimConfig],
    level: ProfileLevel,
) -> (Vec<SimOutcome>, EngineProfile) {
    let mut merged = EngineProfile::new("lanes", level, p.n_nodes(), p.n_arcs);
    let mut outs = Vec::with_capacity(cfgs.len());
    for chunk in cfgs.chunks(MAX_LANES) {
        let mut sim = LaneSim::new(p, chunk);
        sim.enable_profiling(level);
        sim.run();
        if let Some(prof) = sim.take_profile() {
            merged.merge(&prof);
        }
        outs.extend(sim.into_outcomes());
    }
    (outs, merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{Graph, GraphBuilder};
    use crate::sim::run_token;

    fn adder() -> Graph {
        let mut b = GraphBuilder::new("adder");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        b.finish().unwrap()
    }

    #[test]
    fn lanes_match_scalar_on_an_adder_batch() {
        let g = adder();
        let p = Program::compile(&g);
        assert!(p.topo.is_some());
        let cfgs: Vec<SimConfig> = (0..10)
            .map(|i| {
                SimConfig::new()
                    .inject("a", vec![i as Word, 2 * i as Word])
                    .inject("b", vec![100, 200])
            })
            .collect();
        let outs = run_lanes(&p, &cfgs);
        for (cfg, out) in cfgs.iter().zip(&outs) {
            let alone = run_token(&g, cfg);
            assert_eq!(out.outputs, alone.outputs);
            assert_eq!(out.firings, alone.firings);
            assert!(out.quiescent);
        }
    }

    #[test]
    fn empty_batches_are_valid_and_produce_nothing() {
        // Regression: `LaneSim::new` used to panic on an empty config
        // slice (`.max().unwrap()` over the cycle budgets).
        let g = adder();
        let p = Program::compile(&g);
        let mut sim = LaneSim::new(&p, &[]);
        sim.run();
        assert_eq!(sim.firings(), 0);
        assert_eq!(sim.passes(), 0);
        assert!(sim.into_outcomes().is_empty());
        assert!(run_lanes(&p, &[]).is_empty());
    }

    #[test]
    fn branch_routes_per_lane() {
        let mut b = GraphBuilder::new("t");
        let ctl = b.input_port("ctl");
        let data = b.input_port("data");
        let t = b.output_port("t");
        let f = b.output_port("f");
        b.node(Op::Branch, &[ctl, data], &[t, f]);
        let g = b.finish().unwrap();
        let p = Program::compile(&g);
        assert!(p.topo.is_none(), "branch graphs take snapshot rounds");
        let cfgs = vec![
            SimConfig::new()
                .inject("ctl", vec![1, 0, 1])
                .inject("data", vec![10, 20, 30]),
            SimConfig::new()
                .inject("ctl", vec![0, 0])
                .inject("data", vec![7, 8]),
        ];
        let outs = run_lanes(&p, &cfgs);
        assert_eq!(outs[0].stream("t"), &[10, 30]);
        assert_eq!(outs[0].stream("f"), &[20]);
        assert_eq!(outs[1].stream("t"), &[] as &[Word]);
        assert_eq!(outs[1].stream("f"), &[7, 8]);
    }

    #[test]
    fn const_fires_once_per_lane() {
        let mut b = GraphBuilder::new("t");
        let k = b.constant(42);
        let a = b.input_port("a");
        let z = b.output_port("z");
        b.node(Op::Add, &[k, a], &[z]);
        let g = b.finish().unwrap();
        let p = Program::compile(&g);
        let cfgs = vec![
            SimConfig::new().inject("a", vec![1, 2]),
            SimConfig::new().inject("a", vec![8]),
        ];
        let outs = run_lanes(&p, &cfgs);
        // One const token per lane: the second `a` token never pairs.
        assert_eq!(outs[0].stream("z"), &[43]);
        assert!(!outs[0].quiescent);
        assert_eq!(outs[1].stream("z"), &[50]);
        assert!(outs[1].quiescent);
    }

    #[test]
    fn stuck_lane_does_not_stall_siblings() {
        let g = adder();
        let p = Program::compile(&g);
        let cfgs = vec![
            SimConfig::new().inject("a", vec![1]).inject("b", vec![2]),
            SimConfig::new().inject("a", vec![5]), // deadlocked: no `b`
            SimConfig::new().inject("a", vec![3]).inject("b", vec![4]),
        ];
        let outs = run_lanes(&p, &cfgs);
        assert_eq!(outs[0].stream("z"), &[3]);
        assert!(outs[0].quiescent);
        assert_eq!(outs[1].stream("z"), &[] as &[Word]);
        assert!(!outs[1].quiescent);
        assert_eq!(outs[2].stream("z"), &[7]);
        assert!(outs[2].quiescent);
    }

    #[test]
    fn full_and_ragged_chunks_agree_with_scalar() {
        let g = adder();
        let p = Program::compile(&g);
        // 256 + 6: one full multi-word chunk plus a ragged tail chunk.
        let cfgs: Vec<SimConfig> = (0..MAX_LANES + 6)
            .map(|i| {
                SimConfig::new()
                    .inject("a", vec![i as Word])
                    .inject("b", vec![1000 - i as Word])
            })
            .collect();
        let outs = run_lanes(&p, &cfgs);
        assert_eq!(outs.len(), MAX_LANES + 6);
        for (cfg, out) in cfgs.iter().zip(&outs) {
            assert_eq!(out.outputs, run_token(&g, cfg).outputs);
        }
    }

    #[test]
    fn every_mask_word_boundary_width_agrees_with_scalar() {
        // Widths straddling each occupancy-word boundary run in ONE
        // LaneSim (no chunk split below MAX_LANES) and must match the
        // scalar engine lane for lane.
        let g = adder();
        let p = Program::compile(&g);
        for n in [1usize, 63, 64, 65, 128, 129, MAX_LANES] {
            let cfgs: Vec<SimConfig> = (0..n)
                .map(|i| {
                    SimConfig::new()
                        .inject("a", vec![i as Word, -(i as Word)])
                        .inject("b", vec![7, 1 + i as Word])
                })
                .collect();
            let mut sim = LaneSim::new(&p, &cfgs);
            sim.run();
            let outs = sim.into_outcomes();
            assert_eq!(outs.len(), n);
            for (i, (cfg, out)) in cfgs.iter().zip(&outs).enumerate() {
                let alone = run_token(&g, cfg);
                assert_eq!(out.outputs, alone.outputs, "width {n}, lane {i}");
                assert_eq!(out.firings, alone.firings, "width {n}, lane {i}");
                assert!(out.quiescent, "width {n}, lane {i}");
            }
        }
    }

    #[test]
    fn fifo_pipeline_ripples_on_the_topo_path() {
        let g = crate::bench_defs::saxpy::build();
        let p = Program::compile(&g);
        assert!(p.topo.is_some());
        let (w, expect) = crate::bench_defs::saxpy::wave(8, 3);
        let mut cfg = SimConfig::new();
        for (port, s) in &w {
            cfg = cfg.inject(port, s.clone());
        }
        let outs = run_lanes(&p, std::slice::from_ref(&cfg));
        assert_eq!(outs[0].stream("z"), expect.as_slice());
        assert!(outs[0].quiescent);
        // The ripple pass moves a token through the whole pipeline per
        // pass, so the lane run cannot be slower than the scalar rounds.
        let scalar = run_token(&g, &cfg);
        assert!(outs[0].cycles <= scalar.cycles);
    }

    #[test]
    fn fused_chains_match_the_unfused_schedule() {
        // saxpy compiles to one mul→fifo→add superinstruction; fused
        // and unfused programs must agree on outputs, firings and
        // quiescence, and fusing may only shorten the run.
        let g = crate::bench_defs::saxpy::build();
        let pf = Program::compile(&g);
        let pu = Program::compile_unfused(&g);
        assert_eq!(pf.n_chains(), 1);
        assert_eq!(pu.n_chains(), 0);
        let cfgs: Vec<SimConfig> = (0..70)
            .map(|i| {
                let (w, _) = crate::bench_defs::saxpy::wave(6, i as u64);
                let mut cfg = SimConfig::new();
                for (port, s) in &w {
                    cfg = cfg.inject(port, s.clone());
                }
                cfg
            })
            .collect();
        let fused = run_lanes(&pf, &cfgs);
        let unfused = run_lanes(&pu, &cfgs);
        for (i, (f, u)) in fused.iter().zip(&unfused).enumerate() {
            assert_eq!(f.outputs, u.outputs, "lane {i}");
            assert_eq!(f.firings, u.firings, "lane {i}");
            assert_eq!(f.quiescent, u.quiescent, "lane {i}");
            assert!(f.cycles <= u.cycles, "lane {i}");
        }
    }

    #[cfg(feature = "simd")]
    #[test]
    fn simd_kernels_match_scalar_kernels_bytewise() {
        // The simd-feature equivalence obligation, pinned per opcode on
        // adversarial rows (full-range values, zeros for Div/shifts).
        use crate::util::Rng;
        let ops = [
            Op::Add,
            Op::Sub,
            Op::Mul,
            Op::Div,
            Op::And,
            Op::Or,
            Op::Xor,
            Op::Shl,
            Op::Shr,
            Op::IfGt,
            Op::IfGe,
            Op::IfLt,
            Op::IfLe,
            Op::IfEq,
            Op::IfDf,
        ];
        let mut rng = Rng::new(0xD1CE);
        for round in 0..32 {
            let mut a: Vec<Word> = rng.words(LANES, i16::MIN as i32, i16::MAX as i32);
            let mut b: Vec<Word> = rng.words(LANES, i16::MIN as i32, i16::MAX as i32);
            // Pin the edge cases on a few lanes every round.
            a[0] = i16::MIN;
            b[0] = -1;
            a[1] = i16::MAX;
            b[1] = i16::MAX;
            b[2] = 0; // div-by-zero, shift-by-zero
            let a: [Word; LANES] = a.as_slice().try_into().unwrap();
            let b: [Word; LANES] = b.as_slice().try_into().unwrap();
            for op in ops {
                let mut simd = [0; LANES];
                let mut scalar = [0; LANES];
                super::vector::eval2(op, &a, &b, &mut simd);
                eval2_lanes_scalar(op, &a, &b, &mut scalar);
                assert_eq!(simd, scalar, "op {op:?}, round {round}");
            }
        }
    }

    #[test]
    fn profiling_observes_lanes_without_perturbing() {
        // Profiled and plain runs must agree on every outcome, the
        // profile's firing total must match the engine's own count, and
        // opcode density must be identical fused vs unfused (members are
        // credited under their own mnemonics).
        let g = crate::bench_defs::saxpy::build();
        let pf = Program::compile(&g);
        let pu = Program::compile_unfused(&g);
        let cfgs: Vec<SimConfig> = (0..70)
            .map(|i| {
                let (w, _) = crate::bench_defs::saxpy::wave(6, i as u64);
                let mut cfg = SimConfig::new();
                for (port, s) in &w {
                    cfg = cfg.inject(port, s.clone());
                }
                cfg
            })
            .collect();
        let plain = run_lanes(&pf, &cfgs);
        let (profiled, prof) = run_lanes_profiled(&pf, &cfgs, ProfileLevel::Full);
        for (i, (a, b)) in plain.iter().zip(&profiled).enumerate() {
            assert_eq!(a.outputs, b.outputs, "lane {i}");
            assert_eq!(a.firings, b.firings, "lane {i}");
            assert_eq!(a.cycles, b.cycles, "lane {i}");
            assert_eq!(a.quiescent, b.quiescent, "lane {i}");
        }
        let total: u64 = plain.iter().map(|o| o.firings).sum();
        assert_eq!(prof.total_firings, total);
        assert_eq!(prof.engine, "lanes");
        assert_eq!(prof.opcode_density.values().sum::<u64>(), total);
        assert!(prof.arc_occupancy.iter().any(|&o| o > 0));
        let (_, prof_u) = run_lanes_profiled(&pu, &cfgs, ProfileLevel::Full);
        assert_eq!(prof.opcode_density, prof_u.opcode_density);
        assert_eq!(prof.total_firings, prof_u.total_firings);
    }

    #[test]
    fn profiling_off_allocates_nothing_on_lanes() {
        // The satellite-3 structural guarantee: `Off` leaves `prof` as
        // `None`, so the hot path's only cost is the null branch.
        let g = adder();
        let p = Program::compile(&g);
        let cfgs = vec![SimConfig::new().inject("a", vec![1]).inject("b", vec![2])];
        let mut sim = LaneSim::new(&p, &cfgs);
        sim.enable_profiling(ProfileLevel::Off);
        sim.run();
        assert!(sim.take_profile().is_none());
    }

    #[test]
    #[should_panic(expected = "LaneSim takes at most 256")]
    fn rejects_oversized_chunks() {
        let g = adder();
        let p = Program::compile(&g);
        let cfgs = vec![SimConfig::new(); MAX_LANES + 1];
        let _ = LaneSim::new(&p, &cfgs);
    }
}
