//! The lane-vectorized batch engine: up to [`LANES`] independent input
//! sets ("lanes") executed in lockstep through one compiled
//! [`Program`].
//!
//! The scalar engines walk `Option<Word>` arcs one token at a time; the
//! coordinator's batch path therefore re-runs the whole interpreter per
//! batch item. This engine replicates only the *state*, not the
//! control: token storage is structure-of-arrays — per arc a 64-bit
//! `occupied` bitmask (bit ℓ = lane ℓ's token present) plus a
//! `[Word; LANES]` value row — so one pass over the node table advances
//! every lane at once. Fire decisions for ALU/decider/`copy`/`const`/
//! `ndmerge` ops are pure bitmask algebra; only value-dependent routing
//! (`branch`/`dmerge` control) needs a lane scan to build its truth
//! mask, and only `fifo` keeps a per-lane queue.
//!
//! Lanes never interact: lane ℓ executes a legal schedule of exactly
//! the firings a scalar [`TokenSim`](super::TokenSim) run of lane ℓ's
//! config would perform, and every firing rule is deterministic, so
//! per-port output streams at fixpoint are byte-identical — with the
//! same scoping the sharded executor's confluence argument carries: a
//! *contended* `ndmerge` (both inputs holding tokens whose arrival
//! order differs between schedules) is arrival-order dependent in
//! every engine of this crate, and only the loop schema's guarantee
//! that its merge nodes never hold two competing tokens
//! (`dfg::schema`) makes cross-engine comparison exact. All seven
//! benchmarks and the `util::proptest` generator stay inside that
//! class, and the conformance harness enforces byte-identity there. A
//! lane that deadlocks simply stops contributing fire-mask bits; its
//! siblings keep advancing.
//!
//! Two firing schedules, selected by [`Program::compile`]:
//!
//! * **snapshot rounds** (general graphs): table-order scan, input
//!   consumption immediate, output occupancy staged to the end of the
//!   pass — the scalar engines' round semantics, vectorized.
//! * **topo ripple** (acyclic unit-rate graphs): producer-before-
//!   consumer scan with immediate occupancy updates, so a token crosses
//!   the whole pipeline in one pass. Legal exactly on this class — the
//!   per-arc token sequence is schedule-independent there (see
//!   `sim::compiled` and DESIGN.md §6).

use super::compiled::{CNode, Program};
use super::{SimConfig, SimOutcome};
use crate::dfg::{Op, OpClass, Word};
use std::collections::{BTreeMap, VecDeque};

/// Lanes per [`LaneSim`]: one `u64` occupancy mask worth.
pub const LANES: usize = 64;

/// One input port's pending injections: per-lane streams + cursors.
struct Inject {
    arc: u32,
    streams: Vec<Vec<Word>>,
    pos: Vec<usize>,
}

/// Per-lane collected output streams for one port.
type LaneStreams = Vec<Vec<Word>>;

/// Up to 64 batch items in lockstep through one compiled program.
pub struct LaneSim<'p> {
    p: &'p Program,
    n_lanes: usize,
    /// Bitmask of lanes in use (low `n_lanes` bits).
    active: u64,
    /// Firing schedule: `p.topo` when present, else table order.
    schedule: Vec<u32>,
    /// Topo ripple (immediate occupancy) vs snapshot rounds (staged).
    immediate: bool,
    /// Per-arc lane occupancy.
    occ: Vec<u64>,
    /// Per-arc lane values; `vals[a][ℓ]` is live iff `occ[a]` bit ℓ.
    vals: Vec<[Word; LANES]>,
    /// Per-node: lanes whose `Const` reset token has been emitted.
    const_done: Vec<u64>,
    /// Per-node per-lane FIFO queues (empty vec for non-`Fifo` nodes).
    fifos: Vec<Vec<VecDeque<Word>>>,
    inject: Vec<Inject>,
    /// Collected tokens per output port per lane.
    collected: Vec<LaneStreams>,
    /// Staged occupancy writes for the current snapshot round.
    staged: Vec<(u32, u64)>,
    lane_firings: [u64; LANES],
    firings: u64,
    passes: u64,
    max_cycles: u64,
}

impl<'p> LaneSim<'p> {
    /// One lane per config; `cfgs.len()` must be in `1..=LANES`.
    pub fn new(p: &'p Program, cfgs: &[SimConfig]) -> Self {
        let n = cfgs.len();
        assert!(
            (1..=LANES).contains(&n),
            "LaneSim takes 1..={LANES} lane configs, got {n}"
        );
        let active = if n == LANES { u64::MAX } else { (1u64 << n) - 1 };
        let (schedule, immediate) = match &p.topo {
            Some(order) => (order.clone(), true),
            None => ((0..p.n_nodes() as u32).collect(), false),
        };
        LaneSim {
            p,
            n_lanes: n,
            active,
            schedule,
            immediate,
            occ: vec![0; p.n_arcs],
            vals: vec![[0; LANES]; p.n_arcs],
            const_done: vec![0; p.n_nodes()],
            fifos: p
                .nodes
                .iter()
                .map(|cn| match cn.op {
                    Op::Fifo(_) => vec![VecDeque::new(); n],
                    _ => Vec::new(),
                })
                .collect(),
            inject: p
                .input_ports
                .iter()
                .map(|(arc, name)| Inject {
                    arc: *arc,
                    streams: cfgs
                        .iter()
                        .map(|c| c.inject.get(name).cloned().unwrap_or_default())
                        .collect(),
                    pos: vec![0; n],
                })
                .collect(),
            collected: vec![vec![Vec::new(); n]; p.output_ports.len()],
            staged: Vec::new(),
            lane_firings: [0; LANES],
            firings: 0,
            passes: 0,
            max_cycles: cfgs.iter().map(|c| c.max_cycles).max().unwrap(),
        }
    }

    /// One synchronous pass over all lanes. Returns total progress
    /// events (injections + collections + firings across lanes); zero
    /// means a global fixpoint.
    pub fn step(&mut self) -> u64 {
        let mut progress = 0u64;

        // Phase 1a: environment injection — one token per free port
        // arc per lane (the always-ready sender, per lane).
        for inj in &mut self.inject {
            let a = inj.arc as usize;
            let mut free = !self.occ[a] & self.active;
            while free != 0 {
                let l = free.trailing_zeros() as usize;
                free &= free - 1;
                if inj.pos[l] < inj.streams[l].len() {
                    self.vals[a][l] = inj.streams[l][inj.pos[l]];
                    inj.pos[l] += 1;
                    self.occ[a] |= 1 << l;
                    progress += 1;
                }
            }
        }
        // Phase 1b: environment collection at output ports.
        for pi in 0..self.p.output_ports.len() {
            let a = self.p.output_ports[pi].0 as usize;
            let mut m = self.occ[a] & self.active;
            self.occ[a] &= !m;
            progress += m.count_ones() as u64;
            while m != 0 {
                let l = m.trailing_zeros() as usize;
                m &= m - 1;
                self.collected[pi][l].push(self.vals[a][l]);
            }
        }

        // Phase 2: fire every node once, over all lanes at once.
        let mut fired = 0u64;
        let schedule = std::mem::take(&mut self.schedule);
        for &ni in &schedule {
            fired += self.fire_node(ni as usize);
        }
        self.schedule = schedule;
        if !self.immediate {
            let staged = std::mem::take(&mut self.staged);
            for &(a, m) in &staged {
                debug_assert_eq!(self.occ[a as usize] & m, 0, "lane token overwrite");
                self.occ[a as usize] |= m;
            }
            let mut staged = staged;
            staged.clear();
            self.staged = staged;
        }

        self.firings += fired;
        self.passes += 1;
        progress + fired
    }

    /// Run until every lane reaches a fixpoint (two consecutive
    /// zero-progress passes, mirroring the scalar drain round) or the
    /// shared cycle budget (the max over the lane configs) is spent.
    pub fn run(&mut self) {
        let mut idle = 0u32;
        while self.passes < self.max_cycles {
            if self.step() == 0 {
                idle += 1;
                if idle >= 2 {
                    break;
                }
            } else {
                idle = 0;
            }
        }
    }

    /// Mark `mask` lanes of `arc` occupied — staged under snapshot
    /// rounds, immediate on the topo ripple path.
    #[inline]
    fn emit(&mut self, arc: u32, mask: u64) {
        if mask == 0 {
            return;
        }
        if self.immediate {
            debug_assert_eq!(self.occ[arc as usize] & mask, 0, "lane token overwrite");
            self.occ[arc as usize] |= mask;
        } else {
            self.staged.push((arc, mask));
        }
    }

    #[inline]
    fn count(&mut self, mut mask: u64) -> u64 {
        let n = mask.count_ones() as u64;
        while mask != 0 {
            let l = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            self.lane_firings[l] += 1;
        }
        n
    }

    /// Truth mask over lanes with a non-zero value on `arc` (garbage on
    /// unoccupied lanes — callers mask with the arc's occupancy).
    #[inline]
    fn truthy(&self, arc: usize) -> u64 {
        let mut t = 0u64;
        for (l, &v) in self.vals[arc].iter().enumerate() {
            t |= ((v != 0) as u64) << l;
        }
        t
    }

    /// Fire node `ni` on every lane whose fire rule holds; returns the
    /// number of lane-firings.
    fn fire_node(&mut self, ni: usize) -> u64 {
        let cn: CNode = self.p.nodes[ni];
        match cn.op.class() {
            OpClass::Alu2 | OpClass::Decider => {
                let (a, b, o) = (cn.ins[0] as usize, cn.ins[1] as usize, cn.outs[0] as usize);
                let m = self.occ[a] & self.occ[b] & !self.occ[o];
                if m == 0 {
                    return 0;
                }
                self.occ[a] &= !m;
                self.occ[b] &= !m;
                let (va, vb) = (self.vals[a], self.vals[b]);
                let mut tmp = [0; LANES];
                eval2_lanes(cn.op, &va, &vb, &mut tmp);
                blend(&mut self.vals[o], &tmp, m);
                self.emit(o as u32, m);
                self.count(m)
            }
            OpClass::Alu1 => {
                let (a, o) = (cn.ins[0] as usize, cn.outs[0] as usize);
                let m = self.occ[a] & !self.occ[o];
                if m == 0 {
                    return 0;
                }
                self.occ[a] &= !m;
                let va = self.vals[a];
                let mut tmp = [0; LANES];
                for (x, v) in tmp.iter_mut().zip(&va) {
                    *x = cn.op.eval1(*v);
                }
                blend(&mut self.vals[o], &tmp, m);
                self.emit(o as u32, m);
                self.count(m)
            }
            OpClass::Copy => {
                let (a, o0, o1) = (cn.ins[0] as usize, cn.outs[0] as usize, cn.outs[1] as usize);
                let m = self.occ[a] & !self.occ[o0] & !self.occ[o1];
                if m == 0 {
                    return 0;
                }
                self.occ[a] &= !m;
                let va = self.vals[a];
                blend(&mut self.vals[o0], &va, m);
                blend(&mut self.vals[o1], &va, m);
                self.emit(o0 as u32, m);
                self.emit(o1 as u32, m);
                self.count(m)
            }
            OpClass::Const => {
                let o = cn.outs[0] as usize;
                let m = self.active & !self.const_done[ni] & !self.occ[o];
                if m == 0 {
                    return 0;
                }
                let Op::Const(v) = cn.op else { unreachable!() };
                self.const_done[ni] |= m;
                blend(&mut self.vals[o], &[v; LANES], m);
                self.emit(o as u32, m);
                self.count(m)
            }
            OpClass::NdMerge => {
                // First-come-first-served; on a tie, port 0 wins (the
                // scalar engines' fixed arbiter priority, per lane).
                let (i0, i1, o) = (cn.ins[0] as usize, cn.ins[1] as usize, cn.outs[0] as usize);
                let f = !self.occ[o] & self.active;
                let take0 = self.occ[i0] & f;
                let take1 = self.occ[i1] & f & !self.occ[i0];
                if (take0 | take1) == 0 {
                    return 0;
                }
                self.occ[i0] &= !take0;
                self.occ[i1] &= !take1;
                let (v0, v1) = (self.vals[i0], self.vals[i1]);
                blend(&mut self.vals[o], &v0, take0);
                blend(&mut self.vals[o], &v1, take1);
                self.emit(o as u32, take0 | take1);
                self.count(take0 | take1)
            }
            OpClass::DMerge => {
                // Port 0 is the control; TRUE selects port 1, FALSE
                // port 2. The unselected token, if any, stays put.
                let (c, d1, d2, o) = (
                    cn.ins[0] as usize,
                    cn.ins[1] as usize,
                    cn.ins[2] as usize,
                    cn.outs[0] as usize,
                );
                let t = self.truthy(c);
                let ready = self.occ[c] & !self.occ[o];
                let m_t = ready & t & self.occ[d1];
                let m_f = ready & !t & self.occ[d2];
                if (m_t | m_f) == 0 {
                    return 0;
                }
                self.occ[c] &= !(m_t | m_f);
                self.occ[d1] &= !m_t;
                self.occ[d2] &= !m_f;
                let (vd1, vd2) = (self.vals[d1], self.vals[d2]);
                blend(&mut self.vals[o], &vd1, m_t);
                blend(&mut self.vals[o], &vd2, m_f);
                self.emit(o as u32, m_t | m_f);
                self.count(m_t | m_f)
            }
            OpClass::Branch => {
                // Port 0 is control, port 1 data; output 0 is the TRUE
                // side. Only the selected output must be free.
                let (c, d, o0, o1) = (
                    cn.ins[0] as usize,
                    cn.ins[1] as usize,
                    cn.outs[0] as usize,
                    cn.outs[1] as usize,
                );
                let t = self.truthy(c);
                let ready = self.occ[c] & self.occ[d];
                let m_t = ready & t & !self.occ[o0];
                let m_f = ready & !t & !self.occ[o1];
                if (m_t | m_f) == 0 {
                    return 0;
                }
                self.occ[c] &= !(m_t | m_f);
                self.occ[d] &= !(m_t | m_f);
                let vd = self.vals[d];
                blend(&mut self.vals[o0], &vd, m_t);
                blend(&mut self.vals[o1], &vd, m_f);
                self.emit(o0 as u32, m_t);
                self.emit(o1 as u32, m_f);
                self.count(m_t | m_f)
            }
            OpClass::Fifo => {
                // Control diverges per lane (queue depths differ), so
                // this is the one per-lane fallback: accept and emit in
                // the same pass, exactly like the scalar engine.
                let Op::Fifo(k) = cn.op else { unreachable!() };
                let cap = k as usize;
                let (i, o) = (cn.ins[0] as usize, cn.outs[0] as usize);
                let mut acted_mask = 0u64;
                let mut emit_mask = 0u64;
                let mut act = self.active;
                while act != 0 {
                    let l = act.trailing_zeros() as usize;
                    act &= act - 1;
                    let bit = 1u64 << l;
                    if self.occ[i] & bit != 0 && self.fifos[ni][l].len() < cap {
                        self.occ[i] &= !bit;
                        let v = self.vals[i][l];
                        self.fifos[ni][l].push_back(v);
                        acted_mask |= bit;
                    }
                    if self.occ[o] & bit == 0 && emit_mask & bit == 0 {
                        if let Some(v) = self.fifos[ni][l].pop_front() {
                            self.vals[o][l] = v;
                            emit_mask |= bit;
                            acted_mask |= bit;
                        }
                    }
                }
                self.emit(o as u32, emit_mask);
                self.count(acted_mask)
            }
        }
    }

    /// True when lane `l` can make no progress ever again: injections
    /// drained, no tokens on arcs, no tokens queued in FIFOs (the
    /// scalar engine's `idle` test, per lane).
    fn lane_idle(&self, l: usize) -> bool {
        let bit = 1u64 << l;
        self.inject
            .iter()
            .all(|inj| inj.pos[l] >= inj.streams[l].len())
            && self.occ.iter().all(|&m| m & bit == 0)
            && self
                .fifos
                .iter()
                .all(|q| q.is_empty() || q[l].is_empty())
    }

    /// Finalize into one [`SimOutcome`] per lane. As in the lockstep
    /// batch engine, `cycles` is the chunk's shared pass count;
    /// `firings` and `quiescent` are per lane.
    pub fn into_outcomes(mut self) -> Vec<SimOutcome> {
        let mut outs = Vec::with_capacity(self.n_lanes);
        for l in 0..self.n_lanes {
            let quiescent = self.lane_idle(l);
            let mut outputs = BTreeMap::new();
            for (pi, (_, name)) in self.p.output_ports.iter().enumerate() {
                outputs.insert(name.clone(), std::mem::take(&mut self.collected[pi][l]));
            }
            outs.push(SimOutcome {
                outputs,
                cycles: self.passes,
                firings: self.lane_firings[l],
                quiescent,
            });
        }
        outs
    }

    /// Total lane-firings across the chunk so far.
    pub fn firings(&self) -> u64 {
        self.firings
    }

    /// Passes executed so far.
    pub fn passes(&self) -> u64 {
        self.passes
    }
}

/// `dst[ℓ] = src[ℓ]` where `mask` bit ℓ is set, branch-free (bitwise
/// select against a sign-extended lane mask).
#[inline]
fn blend(dst: &mut [Word; LANES], src: &[Word; LANES], mask: u64) {
    for (l, (d, &s)) in dst.iter_mut().zip(src).enumerate() {
        let sel = 0i16.wrapping_sub(((mask >> l) & 1) as i16);
        *d = (s & sel) | (*d & !sel);
    }
}

/// The vector opcode table: evaluate a 2-input opcode over all lanes.
/// One tight loop per opcode so the compiler can vectorize each arm.
fn eval2_lanes(op: Op, a: &[Word; LANES], b: &[Word; LANES], out: &mut [Word; LANES]) {
    macro_rules! arm {
        ($f:expr) => {{
            let f = $f;
            for (o, (&x, &y)) in out.iter_mut().zip(a.iter().zip(b)) {
                *o = f(x, y);
            }
        }};
    }
    match op {
        Op::Add => arm!(|x: Word, y: Word| x.wrapping_add(y)),
        Op::Sub => arm!(|x: Word, y: Word| x.wrapping_sub(y)),
        Op::Mul => arm!(|x: Word, y: Word| x.wrapping_mul(y)),
        Op::And => arm!(|x: Word, y: Word| x & y),
        Op::Or => arm!(|x: Word, y: Word| x | y),
        Op::Xor => arm!(|x: Word, y: Word| x ^ y),
        Op::Shl => arm!(|x: Word, y: Word| x.wrapping_shl((y & 0xf) as u32)),
        Op::Shr => arm!(|x: Word, y: Word| x.wrapping_shr((y & 0xf) as u32)),
        Op::IfGt => arm!(|x: Word, y: Word| (x > y) as Word),
        Op::IfGe => arm!(|x: Word, y: Word| (x >= y) as Word),
        Op::IfLt => arm!(|x: Word, y: Word| (x < y) as Word),
        Op::IfLe => arm!(|x: Word, y: Word| (x <= y) as Word),
        Op::IfEq => arm!(|x: Word, y: Word| (x == y) as Word),
        Op::IfDf => arm!(|x: Word, y: Word| (x != y) as Word),
        // Div (branchy divide-by-zero guard) and anything future: the
        // scalar rule per lane.
        _ => arm!(|x: Word, y: Word| op.eval2(x, y)),
    }
}

/// Run any number of configs through `p`, in lane chunks of [`LANES`];
/// one outcome per config, in order.
pub fn run_lanes(p: &Program, cfgs: &[SimConfig]) -> Vec<SimOutcome> {
    let mut outs = Vec::with_capacity(cfgs.len());
    for chunk in cfgs.chunks(LANES) {
        let mut sim = LaneSim::new(p, chunk);
        sim.run();
        outs.extend(sim.into_outcomes());
    }
    outs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{Graph, GraphBuilder};
    use crate::sim::run_token;

    fn adder() -> Graph {
        let mut b = GraphBuilder::new("adder");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        b.finish().unwrap()
    }

    #[test]
    fn lanes_match_scalar_on_an_adder_batch() {
        let g = adder();
        let p = Program::compile(&g);
        assert!(p.topo.is_some());
        let cfgs: Vec<SimConfig> = (0..10)
            .map(|i| {
                SimConfig::new()
                    .inject("a", vec![i as Word, 2 * i as Word])
                    .inject("b", vec![100, 200])
            })
            .collect();
        let outs = run_lanes(&p, &cfgs);
        for (cfg, out) in cfgs.iter().zip(&outs) {
            let alone = run_token(&g, cfg);
            assert_eq!(out.outputs, alone.outputs);
            assert_eq!(out.firings, alone.firings);
            assert!(out.quiescent);
        }
    }

    #[test]
    fn branch_routes_per_lane() {
        let mut b = GraphBuilder::new("t");
        let ctl = b.input_port("ctl");
        let data = b.input_port("data");
        let t = b.output_port("t");
        let f = b.output_port("f");
        b.node(Op::Branch, &[ctl, data], &[t, f]);
        let g = b.finish().unwrap();
        let p = Program::compile(&g);
        assert!(p.topo.is_none(), "branch graphs take snapshot rounds");
        let cfgs = vec![
            SimConfig::new()
                .inject("ctl", vec![1, 0, 1])
                .inject("data", vec![10, 20, 30]),
            SimConfig::new()
                .inject("ctl", vec![0, 0])
                .inject("data", vec![7, 8]),
        ];
        let outs = run_lanes(&p, &cfgs);
        assert_eq!(outs[0].stream("t"), &[10, 30]);
        assert_eq!(outs[0].stream("f"), &[20]);
        assert_eq!(outs[1].stream("t"), &[] as &[Word]);
        assert_eq!(outs[1].stream("f"), &[7, 8]);
    }

    #[test]
    fn const_fires_once_per_lane() {
        let mut b = GraphBuilder::new("t");
        let k = b.constant(42);
        let a = b.input_port("a");
        let z = b.output_port("z");
        b.node(Op::Add, &[k, a], &[z]);
        let g = b.finish().unwrap();
        let p = Program::compile(&g);
        let cfgs = vec![
            SimConfig::new().inject("a", vec![1, 2]),
            SimConfig::new().inject("a", vec![8]),
        ];
        let outs = run_lanes(&p, &cfgs);
        // One const token per lane: the second `a` token never pairs.
        assert_eq!(outs[0].stream("z"), &[43]);
        assert!(!outs[0].quiescent);
        assert_eq!(outs[1].stream("z"), &[50]);
        assert!(outs[1].quiescent);
    }

    #[test]
    fn stuck_lane_does_not_stall_siblings() {
        let g = adder();
        let p = Program::compile(&g);
        let cfgs = vec![
            SimConfig::new().inject("a", vec![1]).inject("b", vec![2]),
            SimConfig::new().inject("a", vec![5]), // deadlocked: no `b`
            SimConfig::new().inject("a", vec![3]).inject("b", vec![4]),
        ];
        let outs = run_lanes(&p, &cfgs);
        assert_eq!(outs[0].stream("z"), &[3]);
        assert!(outs[0].quiescent);
        assert_eq!(outs[1].stream("z"), &[] as &[Word]);
        assert!(!outs[1].quiescent);
        assert_eq!(outs[2].stream("z"), &[7]);
        assert!(outs[2].quiescent);
    }

    #[test]
    fn full_and_ragged_chunks_agree_with_scalar() {
        let g = adder();
        let p = Program::compile(&g);
        // 64 + 6: one full chunk plus a ragged tail.
        let cfgs: Vec<SimConfig> = (0..70)
            .map(|i| {
                SimConfig::new()
                    .inject("a", vec![i as Word])
                    .inject("b", vec![1000 - i as Word])
            })
            .collect();
        let outs = run_lanes(&p, &cfgs);
        assert_eq!(outs.len(), 70);
        for (cfg, out) in cfgs.iter().zip(&outs) {
            assert_eq!(out.outputs, run_token(&g, cfg).outputs);
        }
    }

    #[test]
    fn fifo_pipeline_ripples_on_the_topo_path() {
        let g = crate::bench_defs::saxpy::build();
        let p = Program::compile(&g);
        assert!(p.topo.is_some());
        let (w, expect) = crate::bench_defs::saxpy::wave(8, 3);
        let mut cfg = SimConfig::new();
        for (port, s) in &w {
            cfg = cfg.inject(port, s.clone());
        }
        let outs = run_lanes(&p, std::slice::from_ref(&cfg));
        assert_eq!(outs[0].stream("z"), expect.as_slice());
        assert!(outs[0].quiescent);
        // The ripple pass moves a token through the whole pipeline per
        // pass, so the lane run cannot be slower than the scalar rounds.
        let scalar = run_token(&g, &cfg);
        assert!(outs[0].cycles <= scalar.cycles);
    }

    #[test]
    #[should_panic(expected = "LaneSim takes 1..=64")]
    fn rejects_oversized_chunks() {
        let g = adder();
        let p = Program::compile(&g);
        let cfgs = vec![SimConfig::new(); 65];
        let _ = LaneSim::new(&p, &cfgs);
    }
}
