//! The streaming execution tier: wave-pipelined execution of successive
//! independent input sets ("waves") over one resident graph.
//!
//! Every other executor in this crate runs one input set to completion
//! before admitting the next, so the fabric idles between runs. The
//! paper's throughput claim (Fig. 1c) rests on the opposite behaviour:
//! independent tokens pipeline through the operators back-to-back. A
//! [`StreamSession`] keeps a graph resident and admits waves under one
//! of two admission policies:
//!
//! * [`WaveMode::Pipelined`] — waves overlap inside the fabric. The
//!   next wave's tokens enter an input arc the round after the previous
//!   wave's token left it (the one-token-per-arc rule is the only gate;
//!   the session never waits for the graph to drain). Sound only for
//!   *unit-rate* graphs — every operator consumes exactly one token per
//!   input and produces exactly one per output each firing, and the
//!   graph is acyclic — where the j-th token on every arc provably
//!   belongs to the j-th admitted input position, so waves can never
//!   mix ([`overlap_safe`] checks this structurally).
//! * [`WaveMode::Serialized`] — waves are admitted one at a time: the
//!   next wave is released when the previous one can make no further
//!   progress, and any residue (tokens stranded by a starved operator)
//!   is flushed first, exactly as a hardware reset between input sets
//!   would. The graph, FIFO storage and all allocations stay resident.
//!   This is the mode for the paper's loop-schema benchmarks, whose
//!   `ndmerge` back-edges would conflate overlapping waves.
//!
//! Internally every token carries its wave tag, which gives the engine
//! airtight per-wave output demultiplexing and lets multi-input
//! operators *refuse* to pair tokens from different waves (a structural
//! impossibility under the admission policies above; the refusal turns
//! a would-be correctness bug into a visible `tag_stalls` counter).
//!
//! Conformance contract (enforced by `rust/tests/conformance.rs`): the
//! per-wave output streams are byte-identical to running each wave
//! alone through whole-graph [`TokenSim`](super::TokenSim).

use super::ckpt::{CheckpointError, StreamCheckpoint, WaveCkpt};
use super::SimOutcome;
use crate::dfg::{ArcId, Graph, Op, Word};
use crate::obs::{EngineProfile, ProfileLevel, StallCause};
use std::collections::{BTreeMap, VecDeque};

/// One wave: injection streams per input-port label.
pub type WaveInput = BTreeMap<String, Vec<Word>>;

/// How the session admits successive waves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaveMode {
    /// Overlapping admission (unit-rate acyclic graphs only).
    Pipelined,
    /// One wave in flight at a time, reset between waves.
    Serialized,
}

/// Why a wave was rejected at admission.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamError {
    /// Pipelined waves must cover every input port with the same number
    /// of tokens (unit-rate admission); this one did not.
    RateMismatch(String),
    /// The wave names a port the graph does not have.
    UnknownPort(String),
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::RateMismatch(msg) => {
                write!(f, "pipelined wave admission requires equal-length streams on every input port: {msg}")
            }
            StreamError::UnknownPort(p) => write!(f, "wave names unknown input port `{p}`"),
        }
    }
}

impl std::error::Error for StreamError {}

/// True when waves may safely overlap inside `g`: every operator is
/// unit-rate (ALU, decider, `not`, `copy`, `fifo`) and the graph is
/// acyclic. `branch`/`dmerge` (conditional consumption or production),
/// `ndmerge` (arrival-order dependent) and `const` (fires once per
/// reset, not once per token) all break the j-th-token-is-wave-j
/// invariant, as does any cycle.
pub fn overlap_safe(g: &Graph) -> bool {
    for n in &g.nodes {
        match n.op {
            Op::NdMerge | Op::DMerge | Op::Branch | Op::Const(_) => return false,
            _ => {}
        }
    }
    // Kahn's algorithm over the node-to-node arc adjacency.
    let nn = g.n_nodes();
    let mut indeg = vec![0usize; nn];
    for a in &g.arcs {
        if let (Some((_, _)), Some((d, _))) = (a.src, a.dst) {
            indeg[d.0 as usize] += 1;
        }
    }
    let mut ready: Vec<usize> = (0..nn).filter(|&i| indeg[i] == 0).collect();
    let mut seen = 0usize;
    while let Some(ni) = ready.pop() {
        seen += 1;
        for &a in &g.nodes[ni].outs {
            if let Some((d, _)) = g.arc(a).dst {
                let d = d.0 as usize;
                indeg[d] -= 1;
                if indeg[d] == 0 {
                    ready.push(d);
                }
            }
        }
    }
    seen == nn
}

/// Sustained-throughput metrics for one session.
#[derive(Debug, Clone)]
pub struct StreamMetrics {
    /// The admission policy the session actually ran under (a
    /// pipelined-capable graph can still be served serialized when its
    /// waves fail unit-rate admission — see [`run_stream`]).
    pub mode: WaveMode,
    /// Synchronous rounds executed.
    pub rounds: u64,
    /// Total operator firings.
    pub firings: u64,
    /// Tokens collected at output ports.
    pub tokens_out: u64,
    pub waves_admitted: u32,
    pub waves_completed: u32,
    /// Rounds a multi-input operator held tokens of different waves and
    /// refused to fire. Always 0 under the documented admission
    /// policies; nonzero means a policy violation was contained.
    pub tag_stalls: u64,
    /// Per completed wave: rounds from its first token entering the
    /// fabric to its last output token leaving.
    pub latencies: Vec<u64>,
}

impl StreamMetrics {
    /// Output tokens per synchronous round — the Fig. 8 throughput axis.
    pub fn tokens_per_cycle(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.tokens_out as f64 / self.rounds as f64
        }
    }

    /// Mean fraction of operators firing per round (fireable-operator
    /// occupancy of the fabric).
    pub fn occupancy(&self, n_nodes: usize) -> f64 {
        if self.rounds == 0 || n_nodes == 0 {
            0.0
        } else {
            self.firings as f64 / (self.rounds as f64 * n_nodes as f64)
        }
    }

    /// Wave-latency histogram: `buckets` equal-width bins over the
    /// observed range, as `(lo, hi, count)` rows.
    pub fn latency_histogram(&self, buckets: usize) -> Vec<(u64, u64, usize)> {
        if self.latencies.is_empty() || buckets == 0 {
            return Vec::new();
        }
        let lo = *self.latencies.iter().min().unwrap();
        let hi = *self.latencies.iter().max().unwrap();
        let width = ((hi - lo) / buckets as u64 + 1).max(1);
        let mut rows: Vec<(u64, u64, usize)> = (0..buckets)
            .map(|i| (lo + i as u64 * width, lo + (i as u64 + 1) * width, 0))
            .collect();
        for &l in &self.latencies {
            let i = (((l - lo) / width) as usize).min(buckets - 1);
            rows[i].2 += 1;
        }
        rows.retain(|r| r.2 > 0);
        rows
    }
}

#[derive(Debug, Clone, Copy)]
struct Tok {
    v: Word,
    wave: u32,
}

/// Per-wave bookkeeping.
#[derive(Debug, Clone)]
struct WaveState {
    /// Tokens of this wave still in the system (gate + pending + arcs +
    /// FIFOs + unemitted const arms).
    alive: u64,
    /// Round the wave's first token entered the fabric.
    started: Option<u64>,
    /// Round the wave's last token left (or was flushed).
    done: Option<u64>,
    /// No residue was flushed and all injections were accepted.
    quiescent: bool,
    firings: u64,
    outputs: BTreeMap<String, Vec<Word>>,
}

/// A resident graph accepting successive input waves.
pub struct StreamSession<'g> {
    g: &'g Graph,
    mode: WaveMode,
    tokens: Vec<Option<Tok>>,
    fifos: Vec<VecDeque<Tok>>,
    /// Indices of `Const` nodes (armed once per wave, serialized mode).
    const_nodes: Vec<usize>,
    /// Waves each const still owes, oldest first.
    const_pending: Vec<VecDeque<u32>>,
    /// Per input port: (arc, queue of tagged tokens awaiting a free arc).
    pending: Vec<(ArcId, VecDeque<Tok>)>,
    /// Serialized mode: admitted waves not yet released into `pending`.
    gate: VecDeque<(u32, WaveInput)>,
    out_ports: Vec<ArcId>,
    waves: Vec<WaveState>,
    rounds: u64,
    firings: u64,
    tokens_out: u64,
    tag_stalls: u64,
    staged: Vec<(ArcId, Tok)>,
    /// First admitted wave not yet completed (completion is in wave
    /// order under both admission policies).
    next_done: usize,
    /// Consecutive zero-progress rounds in [`Self::run`]. Session
    /// state (not a run-loop local) so a checkpoint cut mid-streak
    /// resumes the countdown instead of restarting it — serialized
    /// flush timing stays byte-identical across migration.
    stall: u32,
    /// `None` unless profiling was enabled. Deliberately **excluded**
    /// from [`Self::snapshot`]/[`Self::restore`] so the checkpoint
    /// byte-identity contract (`ckpt_*` properties) is untouched.
    prof: Option<Box<EngineProfile>>,
}

impl<'g> StreamSession<'g> {
    /// Auto-select the widest sound admission policy for `g`.
    pub fn new(g: &'g Graph) -> Self {
        let mode = if overlap_safe(g) {
            WaveMode::Pipelined
        } else {
            WaveMode::Serialized
        };
        Self::with_mode_unchecked(g, mode)
    }

    /// Force a mode. Panics when `Pipelined` is requested for a graph
    /// where overlapping waves could mix (see [`overlap_safe`]).
    pub fn with_mode(g: &'g Graph, mode: WaveMode) -> Self {
        assert!(
            mode != WaveMode::Pipelined || overlap_safe(g),
            "graph `{}` is not overlap-safe; use WaveMode::Serialized",
            g.name
        );
        Self::with_mode_unchecked(g, mode)
    }

    /// [`Self::with_mode`] without the `overlap_safe` re-walk — for
    /// callers that just established (or cached) the answer.
    fn with_mode_unchecked(g: &'g Graph, mode: WaveMode) -> Self {
        let const_nodes: Vec<usize> = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.op, Op::Const(_)))
            .map(|(i, _)| i)
            .collect();
        StreamSession {
            g,
            mode,
            tokens: vec![None; g.n_arcs()],
            fifos: g.nodes.iter().map(|_| VecDeque::new()).collect(),
            const_pending: g.nodes.iter().map(|_| VecDeque::new()).collect(),
            const_nodes,
            pending: g
                .input_ports()
                .into_iter()
                .map(|a| (a, VecDeque::new()))
                .collect(),
            gate: VecDeque::new(),
            out_ports: g.output_ports(),
            waves: Vec::new(),
            rounds: 0,
            firings: 0,
            tokens_out: 0,
            tag_stalls: 0,
            staged: Vec::new(),
            next_done: 0,
            stall: 0,
            prof: None,
        }
    }

    pub fn mode(&self) -> WaveMode {
        self.mode
    }

    /// Allocate profiling state at `level`. [`ProfileLevel::Off`]
    /// deallocates instead, restoring the zero-cost path. The profile
    /// never rides along in checkpoints; a migrated session restarts
    /// unprofiled unless the new host re-enables it.
    pub fn enable_profiling(&mut self, level: ProfileLevel) {
        if level == ProfileLevel::Off {
            self.prof = None;
        } else {
            self.prof = Some(Box::new(EngineProfile::new(
                "stream",
                level,
                self.g.n_nodes(),
                self.g.n_arcs(),
            )));
        }
    }

    /// Harvest the profile (if any), leaving the session unprofiled.
    pub fn take_profile(&mut self) -> Option<EngineProfile> {
        self.prof.take().map(|p| *p)
    }

    /// Waves admitted so far.
    pub fn n_waves(&self) -> u32 {
        self.waves.len() as u32
    }

    fn fresh_wave_state(&self) -> WaveState {
        let mut outputs = BTreeMap::new();
        for &p in &self.out_ports {
            outputs.insert(self.g.arc(p).name.clone(), Vec::new());
        }
        WaveState {
            alive: 0,
            started: None,
            done: None,
            quiescent: true,
            firings: 0,
            outputs,
        }
    }

    /// Admit one wave; returns its id. In pipelined mode the wave's
    /// tokens become eligible for injection immediately (behind earlier
    /// waves' tokens, FIFO per port); in serialized mode the wave waits
    /// behind the gate until the previous wave finishes.
    /// The pipelined (unit-rate) admission rules: every input port
    /// present with the same stream length ≥ 1, no unknown ports.
    /// `None` means `wave` is admissible. Shared by [`Self::admit`] and
    /// [`run_stream`]'s fallback probe so the two can never disagree.
    fn pipelined_admit_error(&self, wave: &WaveInput) -> Option<StreamError> {
        for port in wave.keys() {
            if !self
                .pending
                .iter()
                .any(|(a, _)| &self.g.arc(*a).name == port)
            {
                return Some(StreamError::UnknownPort(port.clone()));
            }
        }
        let mut len: Option<usize> = None;
        for (a, _) in &self.pending {
            let name = &self.g.arc(*a).name;
            let l = wave.get(name).map(|s| s.len()).unwrap_or(0);
            if l == 0 {
                return Some(StreamError::RateMismatch(format!(
                    "port `{name}` got no tokens"
                )));
            }
            match len {
                None => len = Some(l),
                Some(p) if p != l => {
                    return Some(StreamError::RateMismatch(format!(
                        "port `{name}` got {l} tokens, expected {p}"
                    )))
                }
                _ => {}
            }
        }
        None
    }

    pub fn admit(&mut self, wave: &WaveInput) -> Result<u32, StreamError> {
        let w = self.waves.len() as u32;
        let mut st = self.fresh_wave_state();
        match self.mode {
            WaveMode::Pipelined => {
                if let Some(e) = self.pipelined_admit_error(wave) {
                    return Err(e);
                }
                for (a, q) in self.pending.iter_mut() {
                    let stream = &wave[&self.g.arc(*a).name];
                    st.alive += stream.len() as u64;
                    q.extend(stream.iter().map(|&v| Tok { v, wave: w }));
                }
                // No consts in overlap-safe graphs.
                self.waves.push(st);
            }
            WaveMode::Serialized => {
                // Streams for ports the graph does not have are ignored,
                // matching `SimConfig`/`TokenSim` semantics.
                let known: u64 = wave
                    .iter()
                    .filter(|(p, _)| {
                        self.pending
                            .iter()
                            .any(|(a, _)| self.g.arc(*a).name.as_str() == p.as_str())
                    })
                    .map(|(_, s)| s.len() as u64)
                    .sum();
                st.alive = known + self.const_nodes.len() as u64;
                self.waves.push(st);
                self.gate.push_back((w, wave.clone()));
                self.maybe_release();
            }
        }
        Ok(w)
    }

    /// Serialized mode: release the next gated wave when nothing is in
    /// flight.
    fn maybe_release(&mut self) {
        if self.mode != WaveMode::Serialized {
            return;
        }
        // Waves complete in admission order, so the oldest incomplete
        // wave is `next_done`; release it iff it is still gated (an
        // earlier released wave still in flight keeps it gated).
        match self.gate.front() {
            Some((w, _)) if *w as usize == self.next_done => {}
            _ => return,
        }
        let (w, wave) = self.gate.pop_front().unwrap();
        for (a, q) in self.pending.iter_mut() {
            if let Some(stream) = wave.get(&self.g.arc(*a).name) {
                q.extend(stream.iter().map(|&v| Tok { v, wave: w }));
            }
        }
        for &ni in &self.const_nodes {
            self.const_pending[ni].push_back(w);
        }
    }

    #[inline]
    fn full(&self, a: ArcId) -> bool {
        self.tokens[a.0 as usize].is_some()
    }

    #[inline]
    fn take(&mut self, a: ArcId) -> Tok {
        self.tokens[a.0 as usize].take().expect("token present")
    }

    fn note_start(&mut self, w: u32) {
        let st = &mut self.waves[w as usize];
        if st.started.is_none() {
            st.started = Some(self.rounds);
        }
    }

    /// One synchronous round. Returns total progress events (injections
    /// + collections + firings); zero means a global fixpoint.
    pub fn step(&mut self) -> u64 {
        let mut progress = 0u64;

        // Phase 1a: environment injection (one token per free port arc).
        for pi in 0..self.pending.len() {
            let (arc, _) = self.pending[pi];
            if self.tokens[arc.0 as usize].is_none() {
                if let Some(t) = self.pending[pi].1.pop_front() {
                    self.tokens[arc.0 as usize] = Some(t);
                    self.note_start(t.wave);
                    progress += 1;
                }
            }
        }
        // Phase 1b: environment collection at output ports.
        for pi in 0..self.out_ports.len() {
            let p = self.out_ports[pi];
            if let Some(t) = self.tokens[p.0 as usize].take() {
                let name = self.g.arc(p).name.clone();
                let st = &mut self.waves[t.wave as usize];
                st.outputs.get_mut(&name).expect("known port").push(t.v);
                st.alive -= 1;
                self.tokens_out += 1;
                progress += 1;
            }
        }

        // Phase 2: snapshot-fire every operator; writes are staged so
        // firing decisions see round-start state (identical semantics to
        // `TokenSim`; an arc has a unique consumer, so in-round takes
        // cannot perturb another node's decision).
        let mut staged = std::mem::take(&mut self.staged);
        debug_assert!(staged.is_empty());
        let mut fired = 0u64;
        for ni in 0..self.g.n_nodes() {
            if self.try_fire(ni, &mut staged) {
                fired += 1;
                if let Some(p) = self.prof.as_deref_mut() {
                    p.fire(ni);
                }
            } else if self.prof.is_some() {
                // Attribution reads the same pre-fire state `try_fire`
                // just rejected — nothing moved in between.
                let cause = self.classify_stall(ni);
                if let Some(p) = self.prof.as_deref_mut() {
                    p.stall(ni, cause);
                }
            }
        }
        for &(a, t) in &staged {
            debug_assert!(self.tokens[a.0 as usize].is_none(), "token overwrite");
            self.tokens[a.0 as usize] = Some(t);
        }
        staged.clear();
        self.staged = staged;

        self.firings += fired;
        progress += fired;
        self.rounds += 1;
        if let Some(p) = self.prof.as_deref_mut() {
            p.cycles += 1;
            if p.level >= ProfileLevel::Full {
                for (i, t) in self.tokens.iter().enumerate() {
                    if t.is_some() {
                        p.occupy(i, 1);
                    }
                }
            }
        }

        // Completion sweep: waves finish in admission order.
        while self.next_done < self.waves.len() {
            let w = self.next_done;
            let fully_admitted = match self.mode {
                WaveMode::Pipelined => true,
                WaveMode::Serialized => !self.gate.iter().any(|(gw, _)| *gw as usize == w),
            };
            if fully_admitted && self.waves[w].alive == 0 && self.waves[w].done.is_none() {
                if self.waves[w].started.is_none() {
                    self.waves[w].started = Some(self.rounds);
                }
                self.waves[w].done = Some(self.rounds);
                self.next_done += 1;
                if self.mode == WaveMode::Serialized {
                    self.maybe_release();
                }
            } else {
                break;
            }
        }
        progress
    }

    /// Fire node `ni` if enabled; consume inputs now, stage outputs.
    fn try_fire(&mut self, ni: usize, staged: &mut Vec<(ArcId, Tok)>) -> bool {
        let node = &self.g.nodes[ni];
        let op = node.op;
        match op {
            Op::Const(v) => {
                if self.const_pending[ni].is_empty() || self.full(node.outs[0]) {
                    return false;
                }
                let out = node.outs[0];
                let w = self.const_pending[ni].pop_front().unwrap();
                self.note_start(w);
                staged.push((out, Tok { v, wave: w }));
                self.waves[w as usize].firings += 1;
                true
            }
            Op::Copy => {
                if !self.full(node.ins[0]) || self.full(node.outs[0]) || self.full(node.outs[1]) {
                    return false;
                }
                let (o0, o1) = (node.outs[0], node.outs[1]);
                let t = self.take(node.ins[0]);
                self.waves[t.wave as usize].alive += 1; // 1 in, 2 out
                self.waves[t.wave as usize].firings += 1;
                staged.push((o0, t));
                staged.push((o1, t));
                true
            }
            Op::Not => {
                if !self.full(node.ins[0]) || self.full(node.outs[0]) {
                    return false;
                }
                let out = node.outs[0];
                let t = self.take(node.ins[0]);
                self.waves[t.wave as usize].firings += 1;
                staged.push((out, Tok { v: op.eval1(t.v), wave: t.wave }));
                true
            }
            Op::NdMerge => {
                // Serialized mode only (overlap_safe rejects it): one
                // wave in flight, so first-come with port-0 priority is
                // exactly TokenSim's rule.
                if self.full(node.outs[0]) {
                    return false;
                }
                let (i0, i1, out) = (node.ins[0], node.ins[1], node.outs[0]);
                let t = if self.full(i0) {
                    self.take(i0)
                } else if self.full(i1) {
                    self.take(i1)
                } else {
                    return false;
                };
                self.waves[t.wave as usize].firings += 1;
                staged.push((out, t));
                true
            }
            Op::DMerge => {
                if self.full(node.outs[0]) {
                    return false;
                }
                let ctl = match self.tokens[node.ins[0].0 as usize] {
                    Some(c) => c,
                    None => return false,
                };
                let sel = if ctl.v != 0 { node.ins[1] } else { node.ins[2] };
                match self.tokens[sel.0 as usize] {
                    Some(d) if d.wave == ctl.wave => {}
                    Some(_) => {
                        self.tag_stalls += 1;
                        return false;
                    }
                    None => return false,
                }
                let out = node.outs[0];
                let c = self.take(node.ins[0]);
                let d = self.take(sel);
                self.waves[c.wave as usize].alive -= 1; // 2 in, 1 out
                self.waves[c.wave as usize].firings += 1;
                staged.push((out, d));
                true
            }
            Op::Branch => {
                let ctl = match self.tokens[node.ins[0].0 as usize] {
                    Some(c) => c,
                    None => return false,
                };
                match self.tokens[node.ins[1].0 as usize] {
                    Some(d) if d.wave == ctl.wave => {}
                    Some(_) => {
                        self.tag_stalls += 1;
                        return false;
                    }
                    None => return false,
                }
                let out = if ctl.v != 0 { node.outs[0] } else { node.outs[1] };
                if self.full(out) {
                    return false;
                }
                let c = self.take(node.ins[0]);
                let d = self.take(node.ins[1]);
                self.waves[c.wave as usize].alive -= 1; // 2 in, 1 out
                self.waves[c.wave as usize].firings += 1;
                staged.push((out, d));
                true
            }
            Op::Fifo(k) => {
                // Firing attribution: the wave is credited when a token
                // *leaves* the FIFO (the enqueue half of a pass-through
                // round is part of the same logical firing), so
                // session-level `firings` — which counts acted rounds,
                // like `TokenSim` — can exceed the per-wave sum on
                // FIFO-bearing graphs. See `wave_outcome`.
                let mut acted = false;
                if self.full(node.ins[0]) && self.fifos[ni].len() < k as usize {
                    let t = self.take(node.ins[0]);
                    self.fifos[ni].push_back(t);
                    acted = true;
                }
                if !self.full(node.outs[0]) {
                    if let Some(t) = self.fifos[ni].pop_front() {
                        self.waves[t.wave as usize].firings += 1;
                        staged.push((node.outs[0], t));
                        acted = true;
                    }
                }
                acted
            }
            // All remaining ops are 2-in/1-out ALU or decider nodes.
            _ => {
                let (a, b) = (node.ins[0], node.ins[1]);
                match (self.tokens[a.0 as usize], self.tokens[b.0 as usize]) {
                    (Some(x), Some(y)) if x.wave != y.wave => {
                        self.tag_stalls += 1;
                        return false;
                    }
                    (Some(_), Some(_)) => {}
                    _ => return false,
                }
                if self.full(node.outs[0]) {
                    return false;
                }
                let out = node.outs[0];
                let x = self.take(a);
                let y = self.take(b);
                self.waves[x.wave as usize].alive -= 1; // 2 in, 1 out
                self.waves[x.wave as usize].firings += 1;
                staged.push((out, Tok { v: op.eval2(x.v, y.v), wave: x.wave }));
                true
            }
        }
    }

    /// Attribute a refused firing attempt of `ni` to exactly one
    /// [`StallCause`], mirroring [`Self::try_fire`]'s refusal order —
    /// the first failing precondition is the cause. A wave-tag mismatch
    /// holding a token back classifies as gate-closed (the tag gate
    /// doing its job). Read-only: `tag_stalls` is bumped by `try_fire`
    /// itself, never here.
    fn classify_stall(&self, ni: usize) -> StallCause {
        let node = &self.g.nodes[ni];
        match node.op {
            Op::Const(_) => {
                if self.const_pending[ni].is_empty() {
                    StallCause::GateClosed
                } else {
                    StallCause::OutputBlocked
                }
            }
            Op::Copy | Op::Not => {
                if !self.full(node.ins[0]) {
                    StallCause::InputStarved
                } else {
                    StallCause::OutputBlocked
                }
            }
            Op::NdMerge => {
                if self.full(node.outs[0]) {
                    StallCause::OutputBlocked
                } else {
                    StallCause::InputStarved
                }
            }
            Op::DMerge => {
                if self.full(node.outs[0]) {
                    return StallCause::OutputBlocked;
                }
                let ctl = match self.tokens[node.ins[0].0 as usize] {
                    Some(c) => c,
                    None => return StallCause::InputStarved,
                };
                let sel = if ctl.v != 0 { node.ins[1] } else { node.ins[2] };
                match self.tokens[sel.0 as usize] {
                    None => StallCause::InputStarved,
                    // A same-wave pairing would have fired; the
                    // surviving case is the tag gate holding it back.
                    Some(_) => StallCause::GateClosed,
                }
            }
            Op::Branch => {
                let ctl = match self.tokens[node.ins[0].0 as usize] {
                    Some(c) => c,
                    None => return StallCause::InputStarved,
                };
                match self.tokens[node.ins[1].0 as usize] {
                    None => StallCause::InputStarved,
                    Some(d) if d.wave != ctl.wave => StallCause::GateClosed,
                    // Same-wave pair in place ⇒ the selected output arc
                    // must have been full.
                    Some(_) => StallCause::OutputBlocked,
                }
            }
            Op::Fifo(k) => {
                if self.full(node.ins[0]) && self.fifos[ni].len() >= k as usize {
                    StallCause::GateClosed
                } else if !self.fifos[ni].is_empty() && self.full(node.outs[0]) {
                    StallCause::OutputBlocked
                } else {
                    StallCause::InputStarved
                }
            }
            _ => {
                let (a, b) = (node.ins[0], node.ins[1]);
                match (self.tokens[a.0 as usize], self.tokens[b.0 as usize]) {
                    (Some(x), Some(y)) if x.wave != y.wave => StallCause::GateClosed,
                    (Some(_), Some(_)) => StallCause::OutputBlocked,
                    _ => StallCause::InputStarved,
                }
            }
        }
    }

    /// Serialized mode: the wave currently in flight has reached a
    /// fixpoint short of draining. Flush its residue (a hardware reset
    /// between input sets) so the next wave starts clean, and mark it
    /// done but not quiescent.
    fn flush_stalled_wave(&mut self) {
        debug_assert_eq!(self.mode, WaveMode::Serialized);
        let w = self.next_done;
        if w >= self.waves.len() || self.waves[w].done.is_some() {
            return;
        }
        for t in self.tokens.iter_mut() {
            if t.is_some() {
                *t = None;
            }
        }
        for q in self.fifos.iter_mut() {
            q.clear();
        }
        for (_, q) in self.pending.iter_mut() {
            q.clear();
        }
        for q in self.const_pending.iter_mut() {
            q.clear();
        }
        let st = &mut self.waves[w];
        st.alive = 0;
        st.quiescent = false;
        st.done = Some(self.rounds);
        if st.started.is_none() {
            st.started = Some(self.rounds);
        }
        self.next_done += 1;
        self.maybe_release();
    }

    /// Drive the session until every admitted wave is done or
    /// `max_rounds` is reached. Can be called repeatedly as more waves
    /// are admitted.
    pub fn run(&mut self, max_rounds: u64) {
        while self.rounds < max_rounds && self.next_done < self.waves.len() {
            let progress = self.step();
            if progress == 0 {
                self.stall += 1;
                // One idle round is a true fixpoint under snapshot
                // semantics; confirm once to mirror TokenSim's drain
                // round, then resolve the stall.
                if self.stall >= 2 {
                    match self.mode {
                        WaveMode::Serialized => {
                            self.flush_stalled_wave();
                            self.stall = 0;
                        }
                        WaveMode::Pipelined => break,
                    }
                }
            } else {
                self.stall = 0;
            }
        }
    }

    /// Has wave `w` fully drained (or been flushed)?
    pub fn wave_done(&self, w: u32) -> bool {
        self.waves[w as usize].done.is_some()
    }

    /// Per-wave output streams, demultiplexed by wave tag.
    pub fn wave_outputs(&self, w: u32) -> &BTreeMap<String, Vec<Word>> {
        &self.waves[w as usize].outputs
    }

    /// Per-wave view in the common [`SimOutcome`] shape: `cycles` is
    /// the wave's latency (first token in → last token out), `firings`
    /// are the firings attributed to its tokens. Attribution note: a
    /// FIFO round that only *accepts* a token counts toward the
    /// session's total (matching `TokenSim`) but is credited to the
    /// wave when the token is later emitted, so on FIFO-bearing graphs
    /// the per-wave sum can run below the session total.
    pub fn wave_outcome(&self, w: u32) -> SimOutcome {
        let st = &self.waves[w as usize];
        let cycles = match (st.started, st.done) {
            (Some(s), Some(d)) => d.saturating_sub(s).max(1),
            _ => self.rounds,
        };
        SimOutcome {
            outputs: st.outputs.clone(),
            cycles,
            firings: st.firings,
            quiescent: st.done.is_some() && st.quiescent,
        }
    }

    /// Capture the full session state between rounds as a portable
    /// [`StreamCheckpoint`]. The capture is complete — restoring it on
    /// the same graph and continuing produces byte-identical outputs
    /// to the uninterrupted run (the `ckpt_*` conformance properties).
    ///
    /// Panics if called mid-round (staged writes outstanding), which
    /// cannot happen from the public API — [`Self::step`] fully drains
    /// its stage before returning.
    pub fn snapshot(&self) -> StreamCheckpoint {
        assert!(
            self.staged.is_empty(),
            "checkpoint mid-round: staged writes outstanding"
        );
        StreamCheckpoint {
            fingerprint: self.g.fingerprint(),
            mode: self.mode,
            tokens: self
                .tokens
                .iter()
                .map(|t| t.map(|t| (t.v, t.wave)))
                .collect(),
            fifos: self
                .fifos
                .iter()
                .map(|q| q.iter().map(|t| (t.v, t.wave)).collect())
                .collect(),
            const_pending: self
                .const_pending
                .iter()
                .map(|q| q.iter().copied().collect())
                .collect(),
            pending: self
                .pending
                .iter()
                .map(|(_, q)| q.iter().map(|t| (t.v, t.wave)).collect())
                .collect(),
            gate: self.gate.iter().cloned().collect(),
            waves: self
                .waves
                .iter()
                .map(|st| WaveCkpt {
                    alive: st.alive,
                    started: st.started,
                    done: st.done,
                    quiescent: st.quiescent,
                    firings: st.firings,
                    outputs: st.outputs.clone(),
                })
                .collect(),
            rounds: self.rounds,
            firings: self.firings,
            tokens_out: self.tokens_out,
            tag_stalls: self.tag_stalls,
            next_done: self.next_done as u64,
            stall: self.stall,
        }
    }

    /// Rebuild a session from a checkpoint taken on the *same* graph
    /// (same [`Graph::fingerprint`]). Fails with a typed
    /// [`CheckpointError`] on any other graph or on an image whose
    /// shape disagrees with the graph — restore never indexes out of
    /// bounds on corrupt input.
    pub fn restore(g: &'g Graph, ck: &StreamCheckpoint) -> Result<Self, CheckpointError> {
        let got = g.fingerprint();
        if ck.fingerprint != got {
            return Err(CheckpointError::FingerprintMismatch {
                want: ck.fingerprint,
                got,
            });
        }
        let mut s = Self::with_mode_unchecked(g, ck.mode);
        if ck.tokens.len() != s.tokens.len() {
            return Err(CheckpointError::ShapeMismatch(format!(
                "{} arcs captured, graph has {}",
                ck.tokens.len(),
                s.tokens.len()
            )));
        }
        if ck.fifos.len() != s.fifos.len() || ck.const_pending.len() != s.const_pending.len() {
            return Err(CheckpointError::ShapeMismatch(format!(
                "{}/{} nodes captured, graph has {}",
                ck.fifos.len(),
                ck.const_pending.len(),
                s.fifos.len()
            )));
        }
        if ck.pending.len() != s.pending.len() {
            return Err(CheckpointError::ShapeMismatch(format!(
                "{} input ports captured, graph has {}",
                ck.pending.len(),
                s.pending.len()
            )));
        }
        let n_waves = ck.waves.len() as u32;
        let tag_ok = |w: u32| w < n_waves;
        let tags_ok = ck.tokens.iter().flatten().all(|&(_, w)| tag_ok(w))
            && ck.fifos.iter().flatten().all(|&(_, w)| tag_ok(w))
            && ck.const_pending.iter().flatten().all(|&w| tag_ok(w))
            && ck.pending.iter().flatten().all(|&(_, w)| tag_ok(w))
            && ck.gate.iter().all(|&(w, _)| tag_ok(w));
        if !tags_ok {
            return Err(CheckpointError::ShapeMismatch(format!(
                "wave tag out of range (only {n_waves} waves captured)"
            )));
        }
        if ck.next_done > u64::from(n_waves) {
            return Err(CheckpointError::ShapeMismatch(format!(
                "next_done {} exceeds {n_waves} captured waves",
                ck.next_done
            )));
        }
        for (w, wv) in ck.waves.iter().enumerate() {
            for p in &s.out_ports {
                let name = &g.arc(*p).name;
                if !wv.outputs.contains_key(name) {
                    return Err(CheckpointError::ShapeMismatch(format!(
                        "wave {w} is missing output port `{name}`"
                    )));
                }
            }
        }
        s.tokens = ck
            .tokens
            .iter()
            .map(|t| t.map(|(v, wave)| Tok { v, wave }))
            .collect();
        for (q, src) in s.fifos.iter_mut().zip(&ck.fifos) {
            q.extend(src.iter().map(|&(v, wave)| Tok { v, wave }));
        }
        for (q, src) in s.const_pending.iter_mut().zip(&ck.const_pending) {
            q.extend(src.iter().copied());
        }
        for ((_, q), src) in s.pending.iter_mut().zip(&ck.pending) {
            q.extend(src.iter().map(|&(v, wave)| Tok { v, wave }));
        }
        s.gate = ck.gate.iter().cloned().collect();
        s.waves = ck
            .waves
            .iter()
            .map(|wv| WaveState {
                alive: wv.alive,
                started: wv.started,
                done: wv.done,
                quiescent: wv.quiescent,
                firings: wv.firings,
                outputs: wv.outputs.clone(),
            })
            .collect();
        s.rounds = ck.rounds;
        s.firings = ck.firings;
        s.tokens_out = ck.tokens_out;
        s.tag_stalls = ck.tag_stalls;
        s.next_done = ck.next_done as usize;
        s.stall = ck.stall;
        Ok(s)
    }

    /// Sustained-throughput metrics so far.
    pub fn metrics(&self) -> StreamMetrics {
        StreamMetrics {
            mode: self.mode,
            rounds: self.rounds,
            firings: self.firings,
            tokens_out: self.tokens_out,
            waves_admitted: self.waves.len() as u32,
            waves_completed: self.next_done as u32,
            tag_stalls: self.tag_stalls,
            latencies: self
                .waves
                .iter()
                .filter_map(|st| match (st.started, st.done) {
                    (Some(s), Some(d)) => Some(d.saturating_sub(s).max(1)),
                    _ => None,
                })
                .collect(),
        }
    }
}

/// The lane-backed admission path for [`WaveMode::Serialized`]
/// workloads: waves are mutually independent input sets by definition,
/// so instead of admitting them one at a time through the resident
/// graph (paying one full drain-and-reset per wave), run up to
/// [`MAX_LANES`](super::MAX_LANES) of them *concurrently* — one lane
/// each — through one compiled [`Program`](super::Program). Lane
/// isolation
/// gives exactly the wave isolation the serialized policy exists to
/// guarantee, so per-wave output streams stay byte-identical to
/// serialized admission and to isolated [`run_token`](super::run_token)
/// runs (conformance-enforced); a stalled wave parks in its lane
/// without delaying the others. The returned outcomes differ from
/// [`StreamSession::wave_outcome`] only in accounting: `cycles` is the
/// lane chunk's shared pass count, not the wave's solo latency.
pub fn run_stream_lanes(
    g: &Graph,
    waves: &[WaveInput],
    max_cycles_per_wave: u64,
) -> Vec<SimOutcome> {
    let prog = super::Program::compile(g);
    let cfgs: Vec<super::SimConfig> = waves
        .iter()
        .map(|w| {
            let mut c = super::SimConfig::new().max_cycles(max_cycles_per_wave);
            for (p, s) in w {
                c = c.inject(p, s.clone());
            }
            c
        })
        .collect();
    super::run_lanes(&prog, &cfgs)
}

/// Convenience: admit every wave, run to completion (or `max_rounds`),
/// and return the per-wave outcomes plus session metrics. Waves that
/// fail pipelined admission fall back to a serialized session for the
/// whole batch (mixed admission would reorder waves).
pub fn run_stream(
    g: &Graph,
    waves: &[WaveInput],
    max_rounds: u64,
) -> (Vec<SimOutcome>, StreamMetrics) {
    // `run_stream_session` demotes to Serialized when the graph is not
    // overlap-safe, so this is exactly the auto-selected widest policy.
    run_stream_session(g, waves, max_rounds, WaveMode::Pipelined)
}

/// [`run_stream`] under a caller-chosen admission policy. A
/// `Pipelined` request pays exactly one `overlap_safe` walk to
/// validate it and is demoted to `Serialized` when the graph is not
/// overlap-safe or any wave fails unit-rate admission (mixed admission
/// would reorder waves), so the call is total for every graph/wave
/// combination. A `Serialized` request performs no structural walk at
/// all — callers holding a cached `overlap_safe == false` (the serving
/// tier's [`WarmState`](crate::serve::WarmState)) skip it entirely.
pub fn run_stream_session(
    g: &Graph,
    waves: &[WaveInput],
    max_rounds: u64,
    mode: WaveMode,
) -> (Vec<SimOutcome>, StreamMetrics) {
    let mode = if mode == WaveMode::Pipelined && overlap_safe(g) {
        WaveMode::Pipelined
    } else {
        WaveMode::Serialized
    };
    run_stream_prevalidated(g, waves, max_rounds, mode)
}

/// Crate-internal [`run_stream_session`] for callers that have already
/// established the admission class — the serving tier's cached
/// `WarmState::overlap_safe` — so a warm streamed batch pays **zero**
/// structural walks. The unit-rate wave probe still demotes to
/// `Serialized` on mismatched waves.
pub(crate) fn run_stream_prevalidated(
    g: &Graph,
    waves: &[WaveInput],
    max_rounds: u64,
    mode: WaveMode,
) -> (Vec<SimOutcome>, StreamMetrics) {
    debug_assert!(
        mode != WaveMode::Pipelined || overlap_safe(g),
        "caller claimed `{}` overlap-safe without checking",
        g.name
    );
    let mut session = StreamSession::with_mode_unchecked(g, mode);
    if session.mode() == WaveMode::Pipelined
        && waves
            .iter()
            .any(|w| session.pipelined_admit_error(w).is_some())
    {
        session = StreamSession::with_mode(g, WaveMode::Serialized);
    }
    for w in waves {
        session.admit(w).expect("serialized admission is total");
    }
    session.run(max_rounds);
    let outcomes = (0..session.n_waves()).map(|w| session.wave_outcome(w)).collect();
    (outcomes, session.metrics())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::GraphBuilder;
    use crate::sim::{run_token, SimConfig};

    fn adder() -> Graph {
        let mut b = GraphBuilder::new("adder");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        b.finish().unwrap()
    }

    /// a 4-deep pipeline: z = not((a + b) * c) stage-by-stage.
    fn deep_pipeline() -> Graph {
        let mut b = GraphBuilder::new("pipe");
        let a = b.input_port("a");
        let x = b.input_port("b");
        let c = b.input_port("c");
        let s = b.op2(Op::Add, a, x);
        let f = b.node(Op::Fifo(2), &[s], &[]);
        let fo = b.out_arc(f, 0);
        let m = b.op2(Op::Mul, fo, c);
        let z = b.output_port("z");
        b.node(Op::Not, &[m], &[z]);
        b.finish().unwrap()
    }

    #[test]
    fn adder_is_overlap_safe_loops_are_not() {
        assert!(overlap_safe(&adder()));
        assert!(overlap_safe(&deep_pipeline()));
        for b in crate::bench_defs::BenchId::ALL {
            assert!(
                !overlap_safe(&crate::bench_defs::build(b)),
                "{} has loops/merges and must be serialized",
                b.slug()
            );
        }
    }

    #[test]
    fn pipelined_waves_are_demuxed_and_match_isolated_runs() {
        let g = deep_pipeline();
        let waves: Vec<WaveInput> = (0..5)
            .map(|w| {
                BTreeMap::from([
                    ("a".to_string(), vec![w as Word, w as Word + 1]),
                    ("b".to_string(), vec![10, 20]),
                    ("c".to_string(), vec![3, 3]),
                ])
            })
            .collect();
        let (outs, metrics) = run_stream(&g, &waves, 100_000);
        assert_eq!(metrics.waves_completed, 5);
        assert_eq!(metrics.tag_stalls, 0);
        for (w, wave) in waves.iter().enumerate() {
            let mut cfg = SimConfig::new();
            for (p, s) in wave {
                cfg = cfg.inject(p, s.clone());
            }
            let alone = run_token(&g, &cfg);
            assert_eq!(outs[w].outputs, alone.outputs, "wave {w}");
            assert!(outs[w].quiescent);
        }
    }

    #[test]
    fn pipelined_beats_run_to_completion() {
        let g = deep_pipeline();
        let waves: Vec<WaveInput> = (0..16)
            .map(|w| {
                BTreeMap::from([
                    ("a".to_string(), vec![w as Word]),
                    ("b".to_string(), vec![2]),
                    ("c".to_string(), vec![5]),
                ])
            })
            .collect();
        let mut r2c_cycles = 0u64;
        for wave in &waves {
            let mut cfg = SimConfig::new();
            for (p, s) in wave {
                cfg = cfg.inject(p, s.clone());
            }
            r2c_cycles += run_token(&g, &cfg).cycles;
        }
        let (_, m) = run_stream(&g, &waves, 100_000);
        assert!(
            m.rounds < r2c_cycles,
            "streamed {} rounds vs run-to-completion {}",
            m.rounds,
            r2c_cycles
        );
        assert_eq!(m.waves_completed, 16);
    }

    #[test]
    fn serialized_waves_match_isolated_runs_on_a_loop_graph() {
        let g = crate::bench_defs::build(crate::bench_defs::BenchId::Fibonacci);
        let mut session = StreamSession::new(&g);
        assert_eq!(session.mode(), WaveMode::Serialized);
        let waves: Vec<WaveInput> = [3i16, 7, 0, 11]
            .iter()
            .map(|&n| BTreeMap::from([("n".to_string(), vec![n])]))
            .collect();
        for w in &waves {
            session.admit(w).unwrap();
        }
        session.run(1_000_000);
        for (w, wave) in waves.iter().enumerate() {
            let mut cfg = SimConfig::new();
            for (p, s) in wave {
                cfg = cfg.inject(p, s.clone());
            }
            let alone = run_token(&g, &cfg);
            assert_eq!(
                session.wave_outputs(w as u32),
                &alone.outputs,
                "wave {w} (n={})",
                wave["n"][0]
            );
            assert!(session.wave_done(w as u32));
        }
        assert_eq!(session.metrics().tag_stalls, 0);
    }

    #[test]
    fn serialized_flushes_stalled_waves() {
        // An adder fed only one operand stalls; the next wave must still
        // run clean and produce its own result.
        let g = adder();
        let mut session = StreamSession::with_mode(&g, WaveMode::Serialized);
        session
            .admit(&BTreeMap::from([("a".to_string(), vec![1])]))
            .unwrap();
        session
            .admit(&BTreeMap::from([
                ("a".to_string(), vec![2]),
                ("b".to_string(), vec![40]),
            ]))
            .unwrap();
        session.run(10_000);
        let w0 = session.wave_outcome(0);
        let w1 = session.wave_outcome(1);
        assert_eq!(w0.stream("z"), &[] as &[Word]);
        assert!(!w0.quiescent, "stalled wave is not quiescent");
        assert_eq!(w1.stream("z"), &[42]);
        assert!(w1.quiescent);
    }

    #[test]
    fn pipelined_admission_rejects_rate_mismatch() {
        let g = adder();
        let mut session = StreamSession::new(&g);
        assert_eq!(session.mode(), WaveMode::Pipelined);
        let bad = BTreeMap::from([("a".to_string(), vec![1, 2])]);
        assert!(matches!(
            session.admit(&bad),
            Err(StreamError::RateMismatch(_))
        ));
        let unknown = BTreeMap::from([
            ("a".to_string(), vec![1]),
            ("b".to_string(), vec![2]),
            ("zz".to_string(), vec![3]),
        ]);
        assert!(matches!(
            session.admit(&unknown),
            Err(StreamError::UnknownPort(_))
        ));
    }

    #[test]
    fn lane_backed_serialized_path_matches_session_and_isolated_runs() {
        let g = crate::bench_defs::build(crate::bench_defs::BenchId::Fibonacci);
        let waves: Vec<WaveInput> = [2i16, 6, 0, 9]
            .iter()
            .map(|&n| BTreeMap::from([("n".to_string(), vec![n])]))
            .collect();
        let lanes = run_stream_lanes(&g, &waves, 200_000);
        let mut session = StreamSession::with_mode(&g, WaveMode::Serialized);
        for w in &waves {
            session.admit(w).unwrap();
        }
        session.run(1_000_000);
        for (i, wave) in waves.iter().enumerate() {
            let mut cfg = SimConfig::new();
            for (p, s) in wave {
                cfg = cfg.inject(p, s.clone());
            }
            let alone = run_token(&g, &cfg);
            assert_eq!(lanes[i].outputs, alone.outputs, "wave {i} vs isolated");
            assert_eq!(
                &lanes[i].outputs,
                session.wave_outputs(i as u32),
                "wave {i} vs serialized session"
            );
        }
    }

    #[test]
    fn lane_backed_path_parks_stalled_waves_without_blocking() {
        // Same shape as `serialized_flushes_stalled_waves`, but the
        // stalled wave just idles in its lane — no flush needed for the
        // second wave to finish.
        let g = adder();
        let waves: Vec<WaveInput> = vec![
            BTreeMap::from([("a".to_string(), vec![1])]),
            BTreeMap::from([("a".to_string(), vec![2]), ("b".to_string(), vec![40])]),
        ];
        let outs = run_stream_lanes(&g, &waves, 10_000);
        assert_eq!(outs[0].stream("z"), &[] as &[Word]);
        assert!(!outs[0].quiescent);
        assert_eq!(outs[1].stream("z"), &[42]);
        assert!(outs[1].quiescent);
    }

    #[test]
    fn metrics_and_histogram_are_sane() {
        let g = adder();
        let waves: Vec<WaveInput> = (0..8)
            .map(|w| {
                BTreeMap::from([
                    ("a".to_string(), vec![w as Word]),
                    ("b".to_string(), vec![1]),
                ])
            })
            .collect();
        let (_, m) = run_stream(&g, &waves, 10_000);
        assert_eq!(m.waves_completed, 8);
        assert!(m.tokens_per_cycle() > 0.0);
        assert!(m.occupancy(1) > 0.0 && m.occupancy(1) <= 1.0);
        let hist = m.latency_histogram(4);
        let total: usize = hist.iter().map(|r| r.2).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn snapshot_restore_mid_wave_finishes_identically() {
        // Interrupt a pipelined session mid-flight, restore, and finish:
        // outputs and counters must match the uninterrupted run.
        let g = deep_pipeline();
        let waves: Vec<WaveInput> = (0..6)
            .map(|w| {
                BTreeMap::from([
                    ("a".to_string(), vec![w as Word, w as Word + 2]),
                    ("b".to_string(), vec![4, 5]),
                    ("c".to_string(), vec![2, 2]),
                ])
            })
            .collect();
        let mut whole = StreamSession::new(&g);
        for w in &waves {
            whole.admit(w).unwrap();
        }
        whole.run(100_000);

        let mut interrupted = StreamSession::new(&g);
        for w in &waves {
            interrupted.admit(w).unwrap();
        }
        for _ in 0..3 {
            interrupted.step();
        }
        let ck = interrupted.snapshot();
        // Byte-identity round trip: snapshot → bytes → restore → snapshot.
        let bytes = ck.to_bytes();
        let decoded = StreamCheckpoint::from_bytes(&bytes).expect("decode");
        let mut resumed = StreamSession::restore(&g, &decoded).expect("restore");
        assert_eq!(resumed.snapshot().to_bytes(), bytes);
        resumed.run(100_000);
        for w in 0..waves.len() as u32 {
            assert_eq!(
                resumed.wave_outputs(w),
                whole.wave_outputs(w),
                "wave {w} diverged after restore"
            );
        }
        assert_eq!(resumed.metrics().rounds, whole.metrics().rounds);
        assert_eq!(resumed.metrics().firings, whole.metrics().firings);
    }

    #[test]
    fn profiling_observes_streams_and_stays_out_of_checkpoints() {
        let g = deep_pipeline();
        let waves: Vec<WaveInput> = (0..4)
            .map(|w| {
                BTreeMap::from([
                    ("a".to_string(), vec![w as Word, w as Word + 1]),
                    ("b".to_string(), vec![10, 20]),
                    ("c".to_string(), vec![3, 3]),
                ])
            })
            .collect();
        let mut plain = StreamSession::new(&g);
        let mut profiled = StreamSession::new(&g);
        profiled.enable_profiling(crate::obs::ProfileLevel::Full);
        for w in &waves {
            plain.admit(w).unwrap();
            profiled.admit(w).unwrap();
        }
        for _ in 0..3 {
            plain.step();
            profiled.step();
        }
        // The profile never leaks into the checkpoint image.
        assert_eq!(profiled.snapshot().to_bytes(), plain.snapshot().to_bytes());
        plain.run(100_000);
        profiled.run(100_000);
        for w in 0..waves.len() as u32 {
            assert_eq!(profiled.wave_outputs(w), plain.wave_outputs(w), "wave {w}");
        }
        let (pm, m) = (profiled.metrics(), plain.metrics());
        assert_eq!(pm.rounds, m.rounds);
        assert_eq!(pm.firings, m.firings);
        let prof = profiled.take_profile().expect("profile enabled");
        assert_eq!(prof.engine, "stream");
        assert_eq!(prof.total_firings, m.firings);
        assert_eq!(prof.cycles, m.rounds);
        assert!(prof.arc_occupancy.iter().any(|&o| o > 0));
        assert!(prof.nodes.iter().any(|n| n.stall_total() > 0));
        // Off deallocates: the satellite-3 structural guarantee.
        let mut off = StreamSession::new(&g);
        off.enable_profiling(crate::obs::ProfileLevel::Off);
        assert!(off.take_profile().is_none());
    }

    #[test]
    fn restore_rejects_wrong_graph_and_corrupt_shapes() {
        let g = adder();
        let mut session = StreamSession::new(&g);
        session
            .admit(&BTreeMap::from([
                ("a".to_string(), vec![1]),
                ("b".to_string(), vec![2]),
            ]))
            .unwrap();
        let ck = session.snapshot();
        let other = deep_pipeline();
        assert!(matches!(
            StreamSession::restore(&other, &ck),
            Err(CheckpointError::FingerprintMismatch { .. })
        ));
        let mut bad = ck.clone();
        bad.tokens.push(None);
        assert!(matches!(
            StreamSession::restore(&g, &bad),
            Err(CheckpointError::ShapeMismatch(_))
        ));
        let mut bad_tag = ck;
        bad_tag.pending[0].push((7, 99));
        assert!(matches!(
            StreamSession::restore(&g, &bad_tag),
            Err(CheckpointError::ShapeMismatch(_))
        ));
    }
}
