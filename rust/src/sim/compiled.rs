//! Pre-compiled programs: dense, cache-friendly execution metadata for
//! the lane engine ([`super::lanes`]).
//!
//! [`crate::dfg::Graph`] is built for construction and analysis: every
//! node owns a `Vec<ArcId>` per port direction, so walking the graph in
//! the interpreter hot loop chases two heap indirections per node per
//! round. [`Program::compile`] flattens that once: one [`CNode`] per
//! node with **inline port arrays** (`[u32; 3]` inputs / `[u32; 2]`
//! outputs, padded with [`NO_ARC`] — no operator in the paper's set has
//! more than 3 inputs or 2 outputs) and the opcode alongside, so a
//! firing pass is a single linear scan over one contiguous table.
//!
//! For **acyclic unit-rate** graphs (no `branch`/`dmerge`/`ndmerge`/
//! `const`, no cycles — the same structural predicate as
//! [`super::overlap_safe`]) compilation additionally emits a
//! producer-before-consumer **topological firing list**. On such graphs
//! every operator consumes one token per input and produces one per
//! output each firing, so the j-th token on every arc provably belongs
//! to the j-th injected input position and the per-port output streams
//! are independent of the firing schedule. The lane engine exploits
//! this to fire nodes in topo order with immediate (non-staged) arc
//! updates: a token ripples through the whole pipeline in one pass and
//! the worklist machinery of the scalar engine disappears entirely.
//! Graphs outside this class keep snapshot-round semantics (staged
//! occupancy updates, table-order scan). See DESIGN.md §6 for why the
//! fast path is legal exactly on this class.

use crate::dfg::{Graph, Op, OpClass};

/// Padding value for unused [`CNode`] port slots.
pub const NO_ARC: u32 = u32::MAX;

/// One operator in compiled form: opcode plus inline port arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CNode {
    pub op: Op,
    /// Input arcs in operator-port order, padded with [`NO_ARC`].
    pub ins: [u32; 3],
    /// Output arcs in operator-port order, padded with [`NO_ARC`].
    pub outs: [u32; 2],
}

/// A [`Graph`] flattened for execution (see module docs).
#[derive(Debug, Clone)]
pub struct Program {
    /// Source graph name (diagnostics).
    pub name: String,
    /// Arc count — the size of the lane engine's token storage.
    pub n_arcs: usize,
    /// The dense opcode/port table, in original node order.
    pub nodes: Vec<CNode>,
    /// Producer-before-consumer firing order; `Some` exactly when the
    /// graph is acyclic and unit-rate (the topo fast path is legal —
    /// module docs). `None` graphs are fired in table order under
    /// snapshot-round semantics.
    pub topo: Option<Vec<u32>>,
    /// `(arc, label)` per input port, in arc-id order.
    pub input_ports: Vec<(u32, String)>,
    /// `(arc, label)` per output port, in arc-id order.
    pub output_ports: Vec<(u32, String)>,
}

impl Program {
    /// Flatten `g` into a [`Program`].
    pub fn compile(g: &Graph) -> Program {
        let nodes = g
            .nodes
            .iter()
            .map(|n| {
                debug_assert!(n.ins.len() <= 3 && n.outs.len() <= 2);
                let mut ins = [NO_ARC; 3];
                let mut outs = [NO_ARC; 2];
                for (slot, &a) in ins.iter_mut().zip(&n.ins) {
                    *slot = a.0;
                }
                for (slot, &a) in outs.iter_mut().zip(&n.outs) {
                    *slot = a.0;
                }
                CNode { op: n.op, ins, outs }
            })
            .collect();
        Program {
            name: g.name.clone(),
            n_arcs: g.n_arcs(),
            nodes,
            topo: topo_order(g),
            input_ports: g
                .input_ports()
                .into_iter()
                .map(|a| (a.0, g.arc(a).name.clone()))
                .collect(),
            output_ports: g
                .output_ports()
                .into_iter()
                .map(|a| (a.0, g.arc(a).name.clone()))
                .collect(),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// Unit-rate operators: exactly one token consumed per input and one
/// produced per output each firing (the classes [`super::overlap_safe`]
/// admits). `branch`/`dmerge` consume or produce conditionally,
/// `ndmerge` is arrival-order dependent, `const` fires once per reset.
fn unit_rate(op: Op) -> bool {
    matches!(
        op.class(),
        OpClass::Copy | OpClass::Alu1 | OpClass::Alu2 | OpClass::Decider | OpClass::Fifo
    )
}

/// Kahn topological order over the node-to-node arc adjacency, as node
/// indices; `None` for cyclic graphs or graphs with non-unit-rate
/// operators (where a topo firing schedule would not be output-
/// equivalent to snapshot rounds).
fn topo_order(g: &Graph) -> Option<Vec<u32>> {
    if g.nodes.iter().any(|n| !unit_rate(n.op)) {
        return None;
    }
    let nn = g.n_nodes();
    let mut indeg = vec![0usize; nn];
    for a in &g.arcs {
        if let (Some(_), Some((d, _))) = (a.src, a.dst) {
            indeg[d.0 as usize] += 1;
        }
    }
    let mut order: Vec<u32> = (0..nn as u32).filter(|&i| indeg[i as usize] == 0).collect();
    // Process as a FIFO so the order is deterministic in node-id order
    // per rank (only legality matters for correctness, not the order
    // within a rank).
    let mut head = 0usize;
    while head < order.len() {
        let ni = order[head] as usize;
        head += 1;
        for &a in &g.nodes[ni].outs {
            if let Some((d, _)) = g.arc(a).dst {
                let d = d.0 as usize;
                indeg[d] -= 1;
                if indeg[d] == 0 {
                    order.push(d as u32);
                }
            }
        }
    }
    (order.len() == nn).then_some(order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};
    use crate::dfg::GraphBuilder;

    #[test]
    fn compile_preserves_shape_and_ports() {
        for b in BenchId::ALL {
            let g = bench_defs::build(b);
            let p = Program::compile(&g);
            assert_eq!(p.n_nodes(), g.n_nodes(), "{}", b.slug());
            assert_eq!(p.n_arcs, g.n_arcs(), "{}", b.slug());
            assert_eq!(p.input_ports.len(), g.input_ports().len());
            assert_eq!(p.output_ports.len(), g.output_ports().len());
            for (cn, n) in p.nodes.iter().zip(&g.nodes) {
                assert_eq!(cn.op, n.op);
                for (pi, &a) in n.ins.iter().enumerate() {
                    assert_eq!(cn.ins[pi], a.0);
                }
                for (pi, &a) in n.outs.iter().enumerate() {
                    assert_eq!(cn.outs[pi], a.0);
                }
                for slot in &cn.ins[n.ins.len()..] {
                    assert_eq!(*slot, NO_ARC);
                }
            }
        }
    }

    #[test]
    fn topo_fast_path_matches_overlap_safe() {
        // The topo list exists exactly for the graphs the streaming tier
        // may overlap — same structural predicate.
        for b in BenchId::ALL {
            let g = bench_defs::build(b);
            let p = Program::compile(&g);
            assert_eq!(
                p.topo.is_some(),
                crate::sim::overlap_safe(&g),
                "{}",
                b.slug()
            );
            assert!(p.topo.is_none(), "{} is a loop schema", b.slug());
        }
        let saxpy = bench_defs::saxpy::build();
        let p = Program::compile(&saxpy);
        assert!(p.topo.is_some());
    }

    #[test]
    fn topo_order_is_producer_before_consumer() {
        let g = bench_defs::saxpy::build();
        let p = Program::compile(&g);
        let order = p.topo.unwrap();
        assert_eq!(order.len(), g.n_nodes());
        let mut rank = vec![0usize; g.n_nodes()];
        for (i, &ni) in order.iter().enumerate() {
            rank[ni as usize] = i;
        }
        for a in &g.arcs {
            if let (Some((s, _)), Some((d, _))) = (a.src, a.dst) {
                assert!(
                    rank[s.0 as usize] < rank[d.0 as usize],
                    "arc `{}` violates topo order",
                    a.name
                );
            }
        }
    }

    #[test]
    fn cyclic_unit_rate_graph_gets_no_topo() {
        // A fifo feeding an adder that feeds it back: every operator is
        // unit-rate, but the cycle must still disqualify the fast path.
        let mut b = GraphBuilder::new("cyc");
        let a = b.input_port("a");
        let back = b.wire();
        let s = b.op2(Op::Add, a, back);
        b.node(Op::Fifo(2), &[s], &[back]);
        let g = b.graph().clone();
        assert!(topo_order(&g).is_none());
    }
}
