//! Pre-compiled programs: dense, cache-friendly execution metadata for
//! the lane engine ([`super::lanes`]).
//!
//! [`crate::dfg::Graph`] is built for construction and analysis: every
//! node owns a `Vec<ArcId>` per port direction, so walking the graph in
//! the interpreter hot loop chases two heap indirections per node per
//! round. [`Program::compile`] flattens that once: one [`CNode`] per
//! node with **inline port arrays** (`[u32; 3]` inputs / `[u32; 2]`
//! outputs, padded with [`NO_ARC`] — no operator in the paper's set has
//! more than 3 inputs or 2 outputs) and the opcode alongside, so a
//! firing pass is a single linear scan over one contiguous table.
//!
//! For **acyclic unit-rate** graphs (no `branch`/`dmerge`/`ndmerge`/
//! `const`, no cycles — the same structural predicate as
//! [`super::overlap_safe`]) compilation additionally emits a
//! producer-before-consumer **topological firing list**. On such graphs
//! every operator consumes one token per input and produces one per
//! output each firing, so the j-th token on every arc provably belongs
//! to the j-th injected input position and the per-port output streams
//! are independent of the firing schedule. The lane engine exploits
//! this to fire nodes in topo order with immediate (non-staged) arc
//! updates: a token ripples through the whole pipeline in one pass and
//! the worklist machinery of the scalar engine disappears entirely.
//! Graphs outside this class keep snapshot-round semantics (staged
//! occupancy updates, table-order scan). See DESIGN.md §6 for why the
//! fast path is legal exactly on this class.
//!
//! # Superinstruction fusion
//!
//! On the topo fast path, compilation further collapses linear chains
//! of single-output unit-rate operators into [`FusedChain`]
//! superinstructions, dispatched as one [`ExecUnit`] each. A chain
//! member's output arc that feeds the next member (the *link arc*)
//! is elided at run time: the intermediate value stays in a register
//! row instead of bouncing through token storage, and the interpreter
//! pays one dispatch for the whole chain. The legality rule is
//! structural and `OptLevel`-independent (DESIGN.md §6):
//!
//! * fusion happens only where the topo list exists (acyclic,
//!   unit-rate — so never across `branch`/`*merge`/`const`);
//! * every member has exactly one output arc (rules out fan-out
//!   `copy`; with the builder's one-consumer-per-arc invariant this
//!   makes each link arc single-producer/single-consumer);
//! * ALU and decider members compute; `fifo` and single-output `copy`
//!   members fuse as pure transport (identity) steps — on an acyclic
//!   unit-rate graph a FIFO's buffering depth affects only *when*
//!   tokens move, never which tokens reach which port (the Kahn
//!   determinism argument of DESIGN.md §6), so eliding it is
//!   output-invisible;
//! * link arcs are internal by construction (a port arc has no
//!   consumer node, so a chain can only *end* on one).
//!
//! Each chain is scheduled at its **last** member's topo position.
//! Every external input's producer topologically precedes some member
//! and hence (transitively) the last one, so all external tokens a
//! pass can supply are present by the time the chain fires — the fused
//! schedule is pass-for-pass as productive as the unfused one.

use crate::dfg::{Graph, Op, OpClass};

/// Padding value for unused [`CNode`] port slots.
pub const NO_ARC: u32 = u32::MAX;

/// One operator in compiled form: opcode plus inline port arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CNode {
    pub op: Op,
    /// Input arcs in operator-port order, padded with [`NO_ARC`].
    pub ins: [u32; 3],
    /// Output arcs in operator-port order, padded with [`NO_ARC`].
    pub outs: [u32; 2],
}

/// Where one [`FusedStep`] operand comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusedSrc {
    /// A real arc's value row (an external chain input).
    Arc(u32),
    /// The previous step's result — the elided link arc.
    Prev,
    /// Unused operand slot (1-input opcodes and transport steps).
    None,
}

/// One member of a [`FusedChain`], with its operands resolved to
/// either external arcs or the chain-internal register.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusedStep {
    pub op: Op,
    pub a: FusedSrc,
    pub b: FusedSrc,
}

/// A linear run of single-output unit-rate operators executed as one
/// table entry (module docs: *Superinstruction fusion*).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedChain {
    /// Member node indices in producer order (diagnostics + firing
    /// accounting: each member still counts one firing per token).
    pub nodes: Vec<u32>,
    /// One step per member; step 0 never reads [`FusedSrc::Prev`].
    pub steps: Vec<FusedStep>,
    /// Every [`FusedSrc::Arc`] operand, in step order. The chain fires
    /// on exactly the lanes where *all* of these hold a token (and the
    /// output is free) — distinct by the one-consumer-per-arc builder
    /// invariant, and never produced by a chain member.
    pub ext_ins: Vec<u32>,
    /// The last member's output arc — the only token the chain emits.
    pub out: u32,
}

/// One entry of the fused topo schedule: a plain table row or a whole
/// chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecUnit {
    Node(u32),
    Chain(u32),
}

/// A [`Graph`] flattened for execution (see module docs).
#[derive(Debug, Clone)]
pub struct Program {
    /// Source graph name (diagnostics).
    pub name: String,
    /// Arc count — the size of the lane engine's token storage.
    pub n_arcs: usize,
    /// The dense opcode/port table, in original node order.
    pub nodes: Vec<CNode>,
    /// Producer-before-consumer firing order; `Some` exactly when the
    /// graph is acyclic and unit-rate (the topo fast path is legal —
    /// module docs). `None` graphs are fired in table order under
    /// snapshot-round semantics.
    pub topo: Option<Vec<u32>>,
    /// The fused firing schedule for the topo fast path: one entry per
    /// surviving table row, producer-before-consumer, with each
    /// multi-node chain placed at its *last* member's topo position.
    /// Empty exactly when `topo` is `None`.
    pub exec: Vec<ExecUnit>,
    /// Chain bodies referenced by [`ExecUnit::Chain`].
    pub chains: Vec<FusedChain>,
    /// `(arc, label)` per input port, in arc-id order.
    pub input_ports: Vec<(u32, String)>,
    /// `(arc, label)` per output port, in arc-id order.
    pub output_ports: Vec<(u32, String)>,
}

impl Program {
    /// Flatten `g` into a [`Program`], fusing superinstruction chains
    /// on the topo fast path (module docs).
    pub fn compile(g: &Graph) -> Program {
        Self::compile_with(g, true)
    }

    /// [`Program::compile`] without fusion — every topo entry stays a
    /// plain table row. The differential harness and `bench --no-fuse`
    /// use this as the comparison baseline.
    pub fn compile_unfused(g: &Graph) -> Program {
        Self::compile_with(g, false)
    }

    fn compile_with(g: &Graph, fuse: bool) -> Program {
        let nodes = g
            .nodes
            .iter()
            .map(|n| {
                debug_assert!(n.ins.len() <= 3 && n.outs.len() <= 2);
                let mut ins = [NO_ARC; 3];
                let mut outs = [NO_ARC; 2];
                for (slot, &a) in ins.iter_mut().zip(&n.ins) {
                    *slot = a.0;
                }
                for (slot, &a) in outs.iter_mut().zip(&n.outs) {
                    *slot = a.0;
                }
                CNode { op: n.op, ins, outs }
            })
            .collect();
        let topo = topo_order(g);
        let (exec, chains) = match &topo {
            Some(order) => build_exec(g, order, fuse),
            None => (Vec::new(), Vec::new()),
        };
        Program {
            name: g.name.clone(),
            n_arcs: g.n_arcs(),
            nodes,
            topo,
            exec,
            chains,
            input_ports: g
                .input_ports()
                .into_iter()
                .map(|a| (a.0, g.arc(a).name.clone()))
                .collect(),
            output_ports: g
                .output_ports()
                .into_iter()
                .map(|a| (a.0, g.arc(a).name.clone()))
                .collect(),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Number of fused superinstruction chains.
    pub fn n_chains(&self) -> usize {
        self.chains.len()
    }

    /// Total nodes covered by fused chains (bench reporting).
    pub fn fused_nodes(&self) -> usize {
        self.chains.iter().map(|c| c.nodes.len()).sum()
    }
}

/// Unit-rate operators: exactly one token consumed per input and one
/// produced per output each firing (the classes [`super::overlap_safe`]
/// admits). `branch`/`dmerge` consume or produce conditionally,
/// `ndmerge` is arrival-order dependent, `const` fires once per reset.
fn unit_rate(op: Op) -> bool {
    matches!(
        op.class(),
        OpClass::Copy | OpClass::Alu1 | OpClass::Alu2 | OpClass::Decider | OpClass::Fifo
    )
}

/// Chain-member eligibility (module docs): unit-rate with exactly one
/// output arc. `OpClass::Copy` with two consumers keeps its own table
/// row — its fire rule needs both outputs free at once.
fn chainable(g: &Graph, ni: usize) -> bool {
    let n = &g.nodes[ni];
    n.outs.len() == 1
        && matches!(
            n.op.class(),
            OpClass::Alu1 | OpClass::Alu2 | OpClass::Decider | OpClass::Fifo | OpClass::Copy
        )
}

/// Greedy chain formation over the topo order: a chainable node joins
/// the chain whose current tail produces one of its inputs, else opens
/// a chain of its own. Singleton "chains" stay plain table rows.
fn build_exec(g: &Graph, order: &[u32], fuse: bool) -> (Vec<ExecUnit>, Vec<FusedChain>) {
    if !fuse {
        return (order.iter().map(|&n| ExecUnit::Node(n)).collect(), Vec::new());
    }
    let nn = g.n_nodes();
    let mut members: Vec<Vec<u32>> = Vec::new();
    // Chain index whose tail is this node, if any — `take`n on join so
    // each tail extends at most once (chains stay linear).
    let mut tail_of: Vec<Option<usize>> = vec![None; nn];
    for &ni in order {
        let u = ni as usize;
        if !chainable(g, u) {
            continue;
        }
        let mut joined = false;
        for &ia in &g.nodes[u].ins {
            let Some((v, _)) = g.arc(ia).src else { continue };
            if let Some(ci) = tail_of[v.0 as usize].take() {
                members[ci].push(ni);
                tail_of[u] = Some(ci);
                joined = true;
                break;
            }
        }
        if !joined {
            members.push(vec![ni]);
            tail_of[u] = Some(members.len() - 1);
        }
    }

    let mut chains: Vec<FusedChain> = Vec::new();
    // Non-last members vanish from the schedule; last members carry
    // their whole chain at their topo position.
    let mut swallowed = vec![false; nn];
    let mut chain_at: Vec<Option<u32>> = vec![None; nn];
    for m in members {
        if m.len() < 2 {
            continue;
        }
        let last = *m.last().expect("non-empty chain") as usize;
        for &x in &m[..m.len() - 1] {
            swallowed[x as usize] = true;
        }
        chain_at[last] = Some(chains.len() as u32);
        chains.push(build_chain(g, &m));
    }
    let mut exec = Vec::with_capacity(order.len());
    for &ni in order {
        let u = ni as usize;
        if swallowed[u] {
            continue;
        }
        match chain_at[u] {
            Some(ci) => exec.push(ExecUnit::Chain(ci)),
            None => exec.push(ExecUnit::Node(ni)),
        }
    }
    (exec, chains)
}

fn build_chain(g: &Graph, members: &[u32]) -> FusedChain {
    let mut steps = Vec::with_capacity(members.len());
    let mut ext_ins = Vec::new();
    let mut prev_link: Option<u32> = None;
    for &m in members {
        let n = &g.nodes[m as usize];
        let mut srcs = [FusedSrc::None; 2];
        for (slot, &ia) in srcs.iter_mut().zip(&n.ins) {
            if prev_link == Some(ia.0) {
                *slot = FusedSrc::Prev;
            } else {
                *slot = FusedSrc::Arc(ia.0);
                ext_ins.push(ia.0);
            }
        }
        // Every external input must come from outside the chain: a
        // member's single output either *is* the link consumed by the
        // next member or terminates the chain, so this can only trip
        // if the eligibility rule above is broken.
        debug_assert!(
            n.ins
                .iter()
                .all(|&ia| prev_link == Some(ia.0)
                    || g.arc(ia)
                        .src
                        .map_or(true, |(v, _)| !members.contains(&v.0))),
            "fused chain input produced by a chain member"
        );
        steps.push(FusedStep { op: n.op, a: srcs[0], b: srcs[1] });
        prev_link = Some(n.outs[0].0);
    }
    FusedChain {
        nodes: members.to_vec(),
        steps,
        ext_ins,
        out: prev_link.expect("non-empty chain"),
    }
}

/// Kahn topological order over the node-to-node arc adjacency, as node
/// indices; `None` for cyclic graphs or graphs with non-unit-rate
/// operators (where a topo firing schedule would not be output-
/// equivalent to snapshot rounds).
fn topo_order(g: &Graph) -> Option<Vec<u32>> {
    if g.nodes.iter().any(|n| !unit_rate(n.op)) {
        return None;
    }
    let nn = g.n_nodes();
    let mut indeg = vec![0usize; nn];
    for a in &g.arcs {
        if let (Some(_), Some((d, _))) = (a.src, a.dst) {
            indeg[d.0 as usize] += 1;
        }
    }
    let mut order: Vec<u32> = (0..nn as u32).filter(|&i| indeg[i as usize] == 0).collect();
    // Process as a FIFO so the order is deterministic in node-id order
    // per rank (only legality matters for correctness, not the order
    // within a rank).
    let mut head = 0usize;
    while head < order.len() {
        let ni = order[head] as usize;
        head += 1;
        for &a in &g.nodes[ni].outs {
            if let Some((d, _)) = g.arc(a).dst {
                let d = d.0 as usize;
                indeg[d] -= 1;
                if indeg[d] == 0 {
                    order.push(d as u32);
                }
            }
        }
    }
    (order.len() == nn).then_some(order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};
    use crate::dfg::GraphBuilder;

    #[test]
    fn compile_preserves_shape_and_ports() {
        for b in BenchId::ALL {
            let g = bench_defs::build(b);
            let p = Program::compile(&g);
            assert_eq!(p.n_nodes(), g.n_nodes(), "{}", b.slug());
            assert_eq!(p.n_arcs, g.n_arcs(), "{}", b.slug());
            assert_eq!(p.input_ports.len(), g.input_ports().len());
            assert_eq!(p.output_ports.len(), g.output_ports().len());
            for (cn, n) in p.nodes.iter().zip(&g.nodes) {
                assert_eq!(cn.op, n.op);
                for (pi, &a) in n.ins.iter().enumerate() {
                    assert_eq!(cn.ins[pi], a.0);
                }
                for (pi, &a) in n.outs.iter().enumerate() {
                    assert_eq!(cn.outs[pi], a.0);
                }
                for slot in &cn.ins[n.ins.len()..] {
                    assert_eq!(*slot, NO_ARC);
                }
            }
        }
    }

    #[test]
    fn topo_fast_path_matches_overlap_safe() {
        // The topo list exists exactly for the graphs the streaming tier
        // may overlap — same structural predicate.
        for b in BenchId::ALL {
            let g = bench_defs::build(b);
            let p = Program::compile(&g);
            assert_eq!(
                p.topo.is_some(),
                crate::sim::overlap_safe(&g),
                "{}",
                b.slug()
            );
            assert!(p.topo.is_none(), "{} is a loop schema", b.slug());
            // No topo → no fused schedule either.
            assert!(p.exec.is_empty() && p.chains.is_empty(), "{}", b.slug());
        }
        let saxpy = bench_defs::saxpy::build();
        let p = Program::compile(&saxpy);
        assert!(p.topo.is_some());
    }

    #[test]
    fn topo_order_is_producer_before_consumer() {
        let g = bench_defs::saxpy::build();
        let p = Program::compile(&g);
        let order = p.topo.unwrap();
        assert_eq!(order.len(), g.n_nodes());
        let mut rank = vec![0usize; g.n_nodes()];
        for (i, &ni) in order.iter().enumerate() {
            rank[ni as usize] = i;
        }
        for a in &g.arcs {
            if let (Some((s, _)), Some((d, _))) = (a.src, a.dst) {
                assert!(
                    rank[s.0 as usize] < rank[d.0 as usize],
                    "arc `{}` violates topo order",
                    a.name
                );
            }
        }
    }

    #[test]
    fn cyclic_unit_rate_graph_gets_no_topo() {
        // A fifo feeding an adder that feeds it back: every operator is
        // unit-rate, but the cycle must still disqualify the fast path.
        let mut b = GraphBuilder::new("cyc");
        let a = b.input_port("a");
        let back = b.wire();
        let s = b.op2(Op::Add, a, back);
        b.node(Op::Fifo(2), &[s], &[back]);
        let g = b.graph().clone();
        assert!(topo_order(&g).is_none());
    }

    #[test]
    fn saxpy_fuses_into_one_superinstruction() {
        // mul → fifo → add is one linear single-output run: the whole
        // pipeline becomes a single dispatch, fifo as pure transport.
        let g = bench_defs::saxpy::build();
        let p = Program::compile(&g);
        assert_eq!(p.n_chains(), 1);
        assert_eq!(p.exec.len(), 1);
        let c = &p.chains[0];
        assert_eq!(c.nodes.len(), 3);
        assert_eq!(c.steps.len(), 3);
        assert_eq!(c.ext_ins.len(), 3, "a, x, y stay external");
        assert!(matches!(c.steps[1], FusedStep { a: FusedSrc::Prev, b: FusedSrc::None, .. }));
        assert_eq!(p.fused_nodes(), 3);
        let z = g.output_ports()[0].0;
        assert_eq!(c.out, z);

        let u = Program::compile_unfused(&g);
        assert_eq!(u.n_chains(), 0);
        assert_eq!(u.exec.len(), 3, "unfused: one unit per table row");
        assert_eq!(u.topo, p.topo, "fusion never changes the topo list");
    }

    #[test]
    fn chains_break_at_fanout_copies() {
        // add → copy(2 out): the copy needs both outputs free at once,
        // so it must keep its own table row and end the chain.
        let mut b = GraphBuilder::new("fan");
        let a = b.input_port("a");
        let x = b.input_port("x");
        let z1 = b.output_port("z1");
        let z2 = b.output_port("z2");
        let s = b.op2(Op::Add, a, x);
        b.node(Op::Copy, &[s], &[z1, z2]);
        let g = b.finish().unwrap();
        let p = Program::compile(&g);
        assert!(p.topo.is_some());
        assert_eq!(p.n_chains(), 0, "no run of >=2 single-output nodes");
        assert_eq!(p.exec.len(), 2);
    }

    #[test]
    fn chain_steps_wire_prev_into_the_consuming_slot() {
        // not → sub(ext, prev): the link may feed either operand slot.
        let mut b = GraphBuilder::new("slots");
        let a = b.input_port("a");
        let x = b.input_port("x");
        let z = b.output_port("z");
        let na = b.wire();
        b.node(Op::Not, &[a], &[na]);
        b.node(Op::Sub, &[x, na], &[z]);
        let g = b.finish().unwrap();
        let p = Program::compile(&g);
        assert_eq!(p.n_chains(), 1);
        let c = &p.chains[0];
        assert_eq!(c.steps.len(), 2);
        assert!(matches!(
            c.steps[0],
            FusedStep { op: Op::Not, a: FusedSrc::Arc(_), b: FusedSrc::None }
        ));
        assert!(matches!(
            c.steps[1],
            FusedStep { op: Op::Sub, a: FusedSrc::Arc(_), b: FusedSrc::Prev }
        ));
        assert_eq!(c.ext_ins.len(), 2);
    }
}
