//! The dynamic dataflow extension (the paper's §6 future work).
//!
//! Arcs are k-bounded FIFO queues instead of one-place buffers, so an
//! operator can fire again before its previous result is consumed — the
//! tagged-token model restricted to well-ordered (FIFO) tags. For acyclic
//! stream pipelines this recovers full pipelining; the ablation bench
//! (`benches/ablation_dynamic.rs`) measures the gap against the static
//! rule the paper implemented.

use super::{SimConfig, SimOutcome};
use crate::dfg::{ArcId, Graph, Op, Word};
use std::collections::{BTreeMap, VecDeque};

/// Queue-per-arc simulator.
pub struct DynamicSim<'g> {
    g: &'g Graph,
    /// FIFO per arc, bounded by `bound`.
    q: Vec<VecDeque<Word>>,
    bound: usize,
    fifos: Vec<VecDeque<Word>>,
    const_done: Vec<bool>,
    pending: Vec<(ArcId, VecDeque<Word>)>,
    out_ports: Vec<ArcId>,
    collected: BTreeMap<String, Vec<Word>>,
    firings: u64,
}

impl<'g> DynamicSim<'g> {
    /// `bound` is the per-arc queue capacity (the paper's static model is
    /// exactly `bound == 1`).
    pub fn new(g: &'g Graph, cfg: &SimConfig, bound: usize) -> Self {
        assert!(bound >= 1);
        let mut pending = Vec::new();
        for a in g.input_ports() {
            let stream = cfg
                .inject
                .get(&g.arc(a).name)
                .map(|v| v.iter().copied().collect())
                .unwrap_or_default();
            pending.push((a, stream));
        }
        let out_ports = g.output_ports();
        let mut collected = BTreeMap::new();
        for &p in &out_ports {
            collected.insert(g.arc(p).name.clone(), Vec::new());
        }
        DynamicSim {
            g,
            q: vec![VecDeque::new(); g.n_arcs()],
            bound,
            fifos: g.nodes.iter().map(|_| VecDeque::new()).collect(),
            const_done: vec![false; g.n_nodes()],
            pending,
            out_ports,
            collected,
            firings: 0,
        }
    }

    #[inline]
    fn has(&self, a: ArcId) -> bool {
        !self.q[a.0 as usize].is_empty()
    }

    #[inline]
    fn front(&self, a: ArcId) -> Option<Word> {
        self.q[a.0 as usize].front().copied()
    }

    #[inline]
    fn pop(&mut self, a: ArcId) -> Word {
        self.q[a.0 as usize].pop_front().expect("token present")
    }

    /// One synchronous round; every enabled node fires once (snapshot
    /// occupancies, staged pushes). Returns firings this round.
    pub fn step(&mut self) -> u64 {
        for (arc, stream) in &mut self.pending {
            if !stream.is_empty() && self.q[arc.0 as usize].len() < self.bound {
                let v = stream.pop_front().unwrap();
                self.q[arc.0 as usize].push_back(v);
            }
        }
        for &p in &self.out_ports {
            while let Some(v) = self.q[p.0 as usize].pop_front() {
                let name = &self.g.arc(p).name;
                self.collected.get_mut(name).unwrap().push(v);
            }
        }

        // Snapshot head-room so all decisions see round-start state.
        let room: Vec<usize> = self.q.iter().map(|q| self.bound - q.len()).collect();
        let mut staged: Vec<(ArcId, Word)> = Vec::new();
        let mut fired = 0u64;
        for ni in 0..self.g.nodes.len() {
            let node = &self.g.nodes[ni];
            let op = node.op;
            let can_out = |a: ArcId| room[a.0 as usize] > 0;
            let ok = match op {
                Op::Const(v) => {
                    if !self.const_done[ni] && can_out(node.outs[0]) {
                        self.const_done[ni] = true;
                        staged.push((node.outs[0], v));
                        true
                    } else {
                        false
                    }
                }
                Op::Copy => {
                    if self.has(node.ins[0]) && can_out(node.outs[0]) && can_out(node.outs[1]) {
                        let (o0, o1) = (node.outs[0], node.outs[1]);
                        let v = self.pop(node.ins[0]);
                        staged.push((o0, v));
                        staged.push((o1, v));
                        true
                    } else {
                        false
                    }
                }
                Op::Not => {
                    if self.has(node.ins[0]) && can_out(node.outs[0]) {
                        let o = node.outs[0];
                        let v = self.pop(node.ins[0]);
                        staged.push((o, op.eval1(v)));
                        true
                    } else {
                        false
                    }
                }
                Op::NdMerge => {
                    if can_out(node.outs[0]) && (self.has(node.ins[0]) || self.has(node.ins[1])) {
                        let o = node.outs[0];
                        let src = if self.has(node.ins[0]) {
                            node.ins[0]
                        } else {
                            node.ins[1]
                        };
                        let v = self.pop(src);
                        staged.push((o, v));
                        true
                    } else {
                        false
                    }
                }
                Op::DMerge => {
                    if let Some(c) = self.front(node.ins[0]) {
                        let sel = if c != 0 { node.ins[1] } else { node.ins[2] };
                        if self.has(sel) && can_out(node.outs[0]) {
                            let o = node.outs[0];
                            self.pop(node.ins[0]);
                            let v = self.pop(sel);
                            staged.push((o, v));
                            true
                        } else {
                            false
                        }
                    } else {
                        false
                    }
                }
                Op::Branch => {
                    if let Some(c) = self.front(node.ins[0]) {
                        let out = if c != 0 { node.outs[0] } else { node.outs[1] };
                        if self.has(node.ins[1]) && can_out(out) {
                            self.pop(node.ins[0]);
                            let v = self.pop(node.ins[1]);
                            staged.push((out, v));
                            true
                        } else {
                            false
                        }
                    } else {
                        false
                    }
                }
                Op::Fifo(k) => {
                    let mut acted = false;
                    if self.has(node.ins[0]) && self.fifos[ni].len() < k as usize {
                        let v = self.pop(node.ins[0]);
                        self.fifos[ni].push_back(v);
                        acted = true;
                    }
                    if can_out(node.outs[0]) {
                        if let Some(v) = self.fifos[ni].pop_front() {
                            staged.push((node.outs[0], v));
                            acted = true;
                        }
                    }
                    acted
                }
                _ => {
                    if self.has(node.ins[0]) && self.has(node.ins[1]) && can_out(node.outs[0]) {
                        let o = node.outs[0];
                        let a = self.pop(node.ins[0]);
                        let b = self.pop(node.ins[1]);
                        staged.push((o, op.eval2(a, b)));
                        true
                    } else {
                        false
                    }
                }
            };
            if ok {
                fired += 1;
            }
        }
        for (a, v) in staged {
            self.q[a.0 as usize].push_back(v);
        }
        self.firings += fired;
        fired
    }

    /// Run to quiescence or the round limit.
    pub fn run(mut self, cfg: &SimConfig) -> SimOutcome {
        let mut cycles = 0u64;
        let mut quiescent = false;
        while cycles < cfg.max_cycles {
            let fired = self.step();
            cycles += 1;
            if fired == 0 && self.pending.iter().all(|(_, s)| s.is_empty()) {
                self.step();
                cycles += 1;
                if self.q.iter().all(|q| q.is_empty())
                    && self.fifos.iter().all(|q| q.is_empty())
                {
                    quiescent = true;
                }
                break;
            }
        }
        SimOutcome {
            outputs: self.collected,
            cycles,
            firings: self.firings,
            quiescent,
        }
    }
}

/// Convenience: build + run in one call.
pub fn run_dynamic(g: &Graph, cfg: &SimConfig, bound: usize) -> SimOutcome {
    DynamicSim::new(g, cfg, bound).run(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::GraphBuilder;
    use crate::sim::token::run_token;

    /// A 3-stage pipeline: ((a+b)*c among streams).
    fn pipeline() -> Graph {
        let mut b = GraphBuilder::new("pipe");
        let a = b.input_port("a");
        let x = b.input_port("x");
        let c = b.input_port("c");
        let s = b.op2(Op::Add, a, x);
        let m = b.op2(Op::Mul, s, c);
        let z = b.output_port("z");
        b.node(Op::Not, &[m], &[z]);
        b.finish().unwrap()
    }

    #[test]
    fn bound_one_equals_static_engine() {
        let g = pipeline();
        let cfg = SimConfig::new()
            .inject("a", vec![1, 2, 3, 4])
            .inject("x", vec![5, 6, 7, 8])
            .inject("c", vec![2, 2, 2, 2]);
        let dyn1 = run_dynamic(&g, &cfg, 1);
        let tok = run_token(&g, &cfg);
        assert_eq!(dyn1.outputs, tok.outputs);
    }

    #[test]
    fn deeper_queues_never_change_results_on_pipelines() {
        let g = pipeline();
        let cfg = SimConfig::new()
            .inject("a", (0..32).collect::<Vec<_>>())
            .inject("x", (0..32).map(|v| v * 3).collect::<Vec<_>>())
            .inject("c", vec![5; 32]);
        let d1 = run_dynamic(&g, &cfg, 1);
        let d4 = run_dynamic(&g, &cfg, 4);
        let d16 = run_dynamic(&g, &cfg, 16);
        assert_eq!(d1.outputs, d4.outputs);
        assert_eq!(d4.outputs, d16.outputs);
        // Deeper queues can only help round count.
        assert!(d16.cycles <= d1.cycles);
    }

    #[test]
    fn dynamic_pipelines_faster_than_static() {
        // With per-arc queues a new (a,x,c) triple enters every round;
        // static needs the whole handshake to drain. On long streams the
        // dynamic engine should finish in fewer rounds.
        let g = pipeline();
        let n = 128i16;
        let cfg = SimConfig::new()
            .inject("a", (0..n).collect::<Vec<_>>())
            .inject("x", (0..n).collect::<Vec<_>>())
            .inject("c", vec![1; n as usize]);
        let stat = run_token(&g, &cfg);
        let dynb = run_dynamic(&g, &cfg, 8);
        assert_eq!(stat.outputs, dynb.outputs);
        assert!(
            dynb.cycles <= stat.cycles,
            "dynamic {} vs static {}",
            dynb.cycles,
            stat.cycles
        );
    }
}
