//! Simulation of the static dataflow fabric.
//!
//! Three engines, one semantics:
//!
//! * [`TokenSim`] — the fast engine. Arcs are one-place token buffers (the
//!   static rule, §3.1); every fireable operator fires once per synchronous
//!   round. This is the engine benchmarks and the coordinator use.
//! * [`FsmSim`] — the cycle-accurate engine. Every operator runs the
//!   paper's four-state ASM chart (Fig. 6) and every arc carries the
//!   explicit `str`/`ack` handshake (Fig. 3); a firing costs the same
//!   number of clock edges the VHDL implementation pays. Used for latency
//!   numbers and for property-testing the handshake protocol itself.
//! * [`DynamicSim`] — the paper's *future work*: a tagged-token engine with
//!   k-bounded FIFO arcs, used by the ablation bench to quantify how much
//!   the static single-token rule costs.
//!
//! All three must agree on final port outputs; integration tests and
//! proptests enforce this.
//!
//! On top of the engines sits the **streaming tier** ([`stream`]): a
//! [`StreamSession`] keeps one graph resident and admits successive
//! independent input *waves*, overlapping them inside the fabric when
//! the graph is unit-rate ([`overlap_safe`]) and serializing them with
//! a reset in between otherwise. Per-wave outputs are byte-identical to
//! running each wave alone through [`TokenSim`]
//! (`rust/tests/conformance.rs` enforces this).
//!
//! Orthogonal to both sits the **lane tier** ([`compiled`] +
//! [`lanes`]): [`Program::compile`] flattens a graph into a dense
//! opcode/port table once — fusing linear operator runs into
//! superinstruction chains on acyclic unit-rate graphs — and
//! [`LaneSim`] runs up to [`MAX_LANES`] independent input sets in
//! lockstep through it using structure-of-arrays token storage
//! (per-arc multi-word occupancy bitmasks + value rows), so one pass
//! over the node table advances every lane at once. Per-lane outputs
//! are byte-identical to [`TokenSim`] — the same conformance contract
//! as the streaming tier.

pub mod ckpt;
pub mod compiled;
mod dynamic;
mod fsm;
pub mod lanes;
pub mod stream;
mod token;

pub use ckpt::{CheckpointError, StreamCheckpoint, TokenCheckpoint, WaveCkpt};
pub use compiled::{CNode, ExecUnit, FusedChain, FusedSrc, FusedStep, Program, NO_ARC};
pub use dynamic::{run_dynamic, DynamicSim};
pub use fsm::{run_fsm, FsmSim, HandshakeEvent, HandshakeKind};
pub use lanes::{run_lanes, run_lanes_profiled, LaneSim, LANES, MAX_LANES};
pub use stream::{
    overlap_safe, run_stream, run_stream_lanes, run_stream_session, StreamError, StreamMetrics,
    StreamSession, WaveInput, WaveMode,
};
pub use token::{run_token, AluReq, TokenSim};

use crate::dfg::Word;
use std::collections::BTreeMap;

/// Per-run configuration: what to inject and how long to wait.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Token streams to feed each input port, by arc label. Tokens are
    /// injected in order, one at a time, as the fabric accepts them — the
    /// environment behaves like one more handshaking sender per port.
    pub inject: BTreeMap<String, Vec<Word>>,
    /// Hard cycle limit (deadlock/livelock guard).
    pub max_cycles: u64,
}

impl SimConfig {
    pub fn new() -> Self {
        SimConfig {
            inject: BTreeMap::new(),
            max_cycles: 1_000_000,
        }
    }

    pub fn inject(mut self, port: &str, tokens: impl Into<Vec<Word>>) -> Self {
        self.inject.insert(port.to_string(), tokens.into());
        self
    }

    pub fn max_cycles(mut self, c: u64) -> Self {
        self.max_cycles = c;
        self
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        Self::new()
    }
}

/// What a run produced.
#[derive(Debug, Clone, PartialEq)]
pub struct SimOutcome {
    /// Tokens collected at each output port, in arrival order.
    pub outputs: BTreeMap<String, Vec<Word>>,
    /// Clock cycles (FsmSim) or synchronous rounds (TokenSim/DynamicSim)
    /// until quiescence or the cycle limit.
    pub cycles: u64,
    /// Total operator firings.
    pub firings: u64,
    /// True iff the run reached quiescence (no fireable operator, no
    /// pending injection) before `max_cycles`.
    pub quiescent: bool,
}

impl SimOutcome {
    /// The last token seen on `port` (most benchmarks' "result" signal).
    pub fn last(&self, port: &str) -> Option<Word> {
        self.outputs.get(port).and_then(|v| v.last().copied())
    }

    /// All tokens seen on `port`.
    pub fn stream(&self, port: &str) -> &[Word] {
        self.outputs.get(port).map(|v| v.as_slice()).unwrap_or(&[])
    }
}
