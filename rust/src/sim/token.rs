//! The fast token engine.
//!
//! Arcs are `Option<Word>` one-place buffers (the static dataflow rule: at
//! most one token per arc, §3.1). Execution proceeds in synchronous
//! rounds; within a round every operator that is fireable *in the
//! beginning-of-round snapshot* fires exactly once. This is the elastic-
//! pipeline semantics of the paper's clocked implementation (Fig. 1c)
//! with the handshake cycles abstracted away — `FsmSim` charges those.

use super::ckpt::{CheckpointError, TokenCheckpoint};
use super::{SimConfig, SimOutcome};
use crate::dfg::{ArcId, Graph, Op, OpClass, Word};
use crate::obs::{EngineProfile, ProfileLevel, StallCause};
use std::collections::{BTreeMap, VecDeque};

/// An ALU/decider firing extracted from the fabric for external (XLA)
/// evaluation — the offload hook the coordinator's batch engine uses.
#[derive(Debug, Clone, Copy)]
pub struct AluReq {
    /// Node index in the graph (the per-node slot in the fabric batch).
    pub node: u32,
    /// Arc the result must be staged on.
    pub out: ArcId,
    /// `Op::fabric_opcode` value.
    pub opcode: i32,
    pub a: Word,
    pub b: Word,
}

/// Fast single-token-per-arc simulator.
pub struct TokenSim<'g> {
    g: &'g Graph,
    /// One-place buffer per arc.
    tokens: Vec<Option<Word>>,
    /// Per-node FIFO state (only `Op::Fifo` nodes use theirs).
    fifos: Vec<VecDeque<Word>>,
    /// Const nodes that have already emitted their reset token.
    const_done: Vec<bool>,
    /// `Const` nodes still owed a reset emission — kept in sync with
    /// `const_done` so [`TokenSim::consts_pending`] is O(1) instead of
    /// re-scanning every node per reconfig-scheduler poll.
    consts_outstanding: u32,
    /// Pending environment injections per input port.
    pending: Vec<(ArcId, VecDeque<Word>)>,
    /// Port label → index in `pending`, built once at construction so
    /// [`TokenSim::enqueue`] (the sharded executor's per-token
    /// forwarding hook) is a map lookup, not an O(ports) label scan.
    port_slots: BTreeMap<String, usize>,
    /// Output ports (collected every round).
    out_ports: Vec<ArcId>,
    collected: BTreeMap<String, Vec<Word>>,
    firings: u64,
    // scratch: staged writes for the current round
    staged: Vec<(ArcId, Word)>,
    // ---- event-driven scheduling (§Perf) ---------------------------
    // Only nodes whose inputs gained a token or whose outputs were freed
    // since their last examination are re-examined. `arc_src`/`arc_dst`
    // are the producing/consuming node per arc (-1 = environment).
    arc_src: Vec<i32>,
    arc_dst: Vec<i32>,
    marked: Vec<bool>,
    worklist: Vec<u32>,
    scratch_list: Vec<u32>,
    /// Profiling state (`obs::prof`): `None` unless
    /// [`TokenSim::enable_profiling`] was called — the hot path pays one
    /// null check and zero allocations when off. Deliberately excluded
    /// from [`TokenSim::snapshot`]: checkpoints stay byte-identical
    /// whether or not a run was profiled.
    prof: Option<Box<EngineProfile>>,
}

impl<'g> TokenSim<'g> {
    pub fn new(g: &'g Graph, cfg: &SimConfig) -> Self {
        let mut pending = Vec::new();
        let mut port_slots = BTreeMap::new();
        for a in g.input_ports() {
            let name = &g.arc(a).name;
            let stream = cfg
                .inject
                .get(name)
                .map(|v| v.iter().copied().collect())
                .unwrap_or_default();
            port_slots.insert(name.clone(), pending.len());
            pending.push((a, stream));
        }
        let out_ports = g.output_ports();
        let mut collected = BTreeMap::new();
        for &p in &out_ports {
            collected.insert(g.arc(p).name.clone(), Vec::new());
        }
        TokenSim {
            g,
            tokens: vec![None; g.n_arcs()],
            fifos: g
                .nodes
                .iter()
                .map(|n| match n.op {
                    Op::Fifo(k) => VecDeque::with_capacity(k as usize),
                    _ => VecDeque::new(),
                })
                .collect(),
            const_done: vec![false; g.n_nodes()],
            consts_outstanding: g
                .nodes
                .iter()
                .filter(|n| matches!(n.op, Op::Const(_)))
                .count() as u32,
            pending,
            port_slots,
            out_ports,
            collected,
            firings: 0,
            staged: Vec::new(),
            arc_src: g
                .arcs
                .iter()
                .map(|a| a.src.map(|(n, _)| n.0 as i32).unwrap_or(-1))
                .collect(),
            arc_dst: g
                .arcs
                .iter()
                .map(|a| a.dst.map(|(n, _)| n.0 as i32).unwrap_or(-1))
                .collect(),
            marked: vec![true; g.n_nodes()],
            worklist: (0..g.n_nodes() as u32).collect(),
            scratch_list: Vec::new(),
            prof: None,
        }
    }

    /// Turn on `obs::prof` recording at `level`. [`ProfileLevel::Off`] is
    /// an explicit no-op (no state allocated — the documented zero-cost
    /// contract). Counters reset if called again.
    pub fn enable_profiling(&mut self, level: ProfileLevel) {
        self.prof = if level == ProfileLevel::Off {
            None
        } else {
            Some(Box::new(EngineProfile::new(
                "token",
                level,
                self.g.n_nodes(),
                self.g.n_arcs(),
            )))
        };
    }

    /// Harvest the recorded profile (leaves the sim unprofiled).
    pub fn take_profile(&mut self) -> Option<EngineProfile> {
        self.prof.take().map(|p| *p)
    }

    #[inline]
    fn mark(&mut self, ni: i32) {
        if ni >= 0 && !self.marked[ni as usize] {
            self.marked[ni as usize] = true;
            self.worklist.push(ni as u32);
        }
    }

    #[inline]
    fn full(&self, a: ArcId) -> bool {
        self.tokens[a.0 as usize].is_some()
    }

    #[inline]
    fn peek(&self, a: ArcId) -> Option<Word> {
        self.tokens[a.0 as usize]
    }

    #[inline]
    fn take(&mut self, a: ArcId) -> Word {
        // Freeing the arc may re-enable its producer.
        self.mark(self.arc_src[a.0 as usize]);
        self.tokens[a.0 as usize].take().expect("token present")
    }

    /// Run one synchronous round. Returns the number of firings.
    pub fn step(&mut self) -> u64 {
        self.step_inner(None)
    }

    /// Offload phase 1: like [`TokenSim::step`], but ALU/decider/not
    /// firings are *extracted* into `reqs` (inputs consumed, outputs not
    /// yet produced) instead of being evaluated locally. The caller
    /// evaluates the batch (e.g. through the PJRT fabric kernel) and
    /// completes the round with [`TokenSim::apply_alu`].
    pub fn step_offload(&mut self, reqs: &mut Vec<AluReq>) -> u64 {
        self.step_inner(Some(reqs))
    }

    /// Offload phase 2: stage the externally computed results.
    pub fn apply_alu(&mut self, reqs: &[AluReq], z: &[i32]) {
        assert_eq!(reqs.len(), z.len());
        for (r, &v) in reqs.iter().zip(z) {
            debug_assert!(
                self.tokens[r.out.0 as usize].is_none(),
                "ALU result overwrites a token"
            );
            self.tokens[r.out.0 as usize] = Some(v as Word);
            self.mark(self.arc_dst[r.out.0 as usize]);
        }
        self.firings += reqs.len() as u64;
    }

    /// True when nothing further can ever happen without new injections.
    pub fn idle(&self) -> bool {
        !self.injections_pending() && !self.tokens_in_flight()
    }

    /// True while some `Const` node has not yet emitted its reset token —
    /// enabled work that [`TokenSim::idle`] cannot see (a freshly loaded
    /// context has no tokens in flight yet, but its consts will fire on
    /// the first round). The reconfiguration scheduler uses this to avoid
    /// retiring a context that never ran.
    pub fn consts_pending(&self) -> bool {
        self.consts_outstanding > 0
    }

    /// Append a token to the pending injection stream of input port
    /// `port`. This is the sharded executor's forwarding hook: tokens
    /// collected on a cut arc's output half are enqueued onto its input
    /// half in the consuming shard. Returns `false` when the graph has no
    /// input port with that label.
    pub fn enqueue(&mut self, port: &str, v: Word) -> bool {
        match self.port_slots.get(port) {
            Some(&slot) => {
                self.pending[slot].1.push_back(v);
                true
            }
            None => false,
        }
    }

    /// Resolve an input-port label to its injection slot once, so a
    /// repeated forwarder (the sharded executor's cut-arc loop) can use
    /// [`TokenSim::enqueue_at`] and skip the per-token name lookup.
    pub fn port_slot(&self, port: &str) -> Option<usize> {
        self.port_slots.get(port).copied()
    }

    /// [`TokenSim::enqueue`] by pre-resolved slot (O(1); see
    /// [`TokenSim::port_slot`]).
    pub fn enqueue_at(&mut self, slot: usize, v: Word) {
        self.pending[slot].1.push_back(v);
    }

    /// Drain every token collected so far on output port `port` (arrival
    /// order). The other half of the forwarding hook; the port's stream
    /// is left empty. Unknown ports yield an empty vec.
    pub fn take_stream(&mut self, port: &str) -> Vec<Word> {
        self.collected
            .get_mut(port)
            .map(std::mem::take)
            .unwrap_or_default()
    }

    /// Re-arm every `Const` node so it emits its reset token once more —
    /// the software analogue of pulsing the fabric's reset line between
    /// input sets. The streamed sharded/reconfig executors call this at
    /// each wave boundary so a resident graph can process the next wave
    /// exactly as a freshly loaded one would.
    pub fn rearm_consts(&mut self) {
        let mut outstanding = 0u32;
        for (ni, n) in self.g.nodes.iter().enumerate() {
            if matches!(n.op, Op::Const(_)) {
                self.const_done[ni] = false;
                self.mark(ni as i32);
                outstanding += 1;
            }
        }
        self.consts_outstanding = outstanding;
    }

    /// Drop every token still in flight (arcs, FIFO queues, pending
    /// injections) — the rest of the wave-boundary reset. Collected
    /// output streams are left untouched; drain them with
    /// [`TokenSim::take_stream`] before purging.
    pub fn purge(&mut self) {
        for t in self.tokens.iter_mut() {
            *t = None;
        }
        for q in self.fifos.iter_mut() {
            q.clear();
        }
        for (_, q) in self.pending.iter_mut() {
            q.clear();
        }
    }

    /// Total operator firings so far (streamed executors take deltas at
    /// wave boundaries).
    pub fn firings(&self) -> u64 {
        self.firings
    }

    /// Finalize into an outcome (offload driver use).
    pub fn into_outcome(self, cycles: u64, quiescent: bool) -> SimOutcome {
        SimOutcome {
            outputs: self.collected,
            cycles,
            firings: self.firings,
            quiescent,
        }
    }

    fn step_inner(&mut self, mut reqs: Option<&mut Vec<AluReq>>) -> u64 {
        let mut fired = 0u64;

        // 1. Environment: inject pending tokens into empty input ports and
        //    collect tokens from output ports (the environment is always
        //    ready, like the always-acking testbench the paper describes).
        for pi in 0..self.pending.len() {
            let (arc, _) = self.pending[pi];
            if self.tokens[arc.0 as usize].is_none() && !self.pending[pi].1.is_empty() {
                self.tokens[arc.0 as usize] = self.pending[pi].1.pop_front();
                self.mark(self.arc_dst[arc.0 as usize]);
            }
        }
        for pi in 0..self.out_ports.len() {
            let p = self.out_ports[pi];
            if let Some(v) = self.tokens[p.0 as usize].take() {
                self.mark(self.arc_src[p.0 as usize]);
                let name = &self.g.arc(p).name;
                self.collected.get_mut(name).unwrap().push(v);
            }
        }

        // 2. Snapshot-fire every *marked* operator (a node is marked when
        //    an input arc gained a token or an output arc was freed since
        //    its last examination — the event-driven schedule, §Perf).
        //    Writes are staged so fire decisions see round-start state.
        debug_assert!(self.staged.is_empty());
        let mut staged = std::mem::take(&mut self.staged);
        // This round's list; marks made while firing land in the (empty,
        // capacity-recycled) `worklist` for the next round.
        let list = std::mem::replace(&mut self.worklist, std::mem::take(&mut self.scratch_list));
        for &ni in &list {
            self.marked[ni as usize] = false;
        }
        for &ni in &list {
            let ni = ni as usize;
            // Extract ALU-class firings when offloading.
            if let Some(reqs) = reqs.as_deref_mut() {
                let op = self.g.nodes[ni].op;
                match op.class() {
                    OpClass::Alu2 | OpClass::Decider => {
                        let node = &self.g.nodes[ni];
                        if self.full(node.ins[0])
                            && self.full(node.ins[1])
                            && !self.full(node.outs[0])
                        {
                            let (out, i0, i1) = (node.outs[0], node.ins[0], node.ins[1]);
                            let a = self.take(i0);
                            let b = self.take(i1);
                            reqs.push(AluReq {
                                node: ni as u32,
                                out,
                                opcode: op.fabric_opcode(),
                                a,
                                b,
                            });
                        }
                        continue;
                    }
                    OpClass::Alu1 => {
                        let node = &self.g.nodes[ni];
                        if self.full(node.ins[0]) && !self.full(node.outs[0]) {
                            let (out, i0) = (node.outs[0], node.ins[0]);
                            let a = self.take(i0);
                            reqs.push(AluReq {
                                node: ni as u32,
                                out,
                                opcode: op.fabric_opcode(),
                                a,
                                b: 0,
                            });
                        }
                        continue;
                    }
                    _ => {}
                }
            }
            if self.try_fire(ni, &mut staged) {
                fired += 1;
                if let Some(p) = self.prof.as_deref_mut() {
                    p.fire(ni);
                }
            } else if self.prof.is_some() {
                let cause = self.classify_stall(ni);
                if let Some(p) = self.prof.as_deref_mut() {
                    p.stall(ni, cause);
                }
            }
        }
        for i in 0..staged.len() {
            let (a, v) = staged[i];
            debug_assert!(self.tokens[a.0 as usize].is_none(), "token overwrite");
            self.tokens[a.0 as usize] = Some(v);
            // New token may enable the consumer next round.
            self.mark(self.arc_dst[a.0 as usize]);
        }
        staged.clear();
        self.staged = staged;
        // Recycle this round's list capacity.
        let mut list = list;
        list.clear();
        self.scratch_list = list;

        self.firings += fired;
        if let Some(p) = self.prof.as_deref_mut() {
            p.cycles += 1;
            if p.level >= ProfileLevel::Full {
                // Per-arc occupancy integral: +1 for every arc holding a
                // token at the end of the round.
                for (i, t) in self.tokens.iter().enumerate() {
                    if t.is_some() {
                        p.occupy(i, 1);
                    }
                }
            }
        }
        fired
    }

    /// Attribute a refused firing attempt of node `ni` to one cause —
    /// the taxonomy of DESIGN.md §12, mirroring [`TokenSim::try_fire`]'s
    /// refusal conditions in their check order. Only called while
    /// profiling, on round-start state.
    fn classify_stall(&self, ni: usize) -> StallCause {
        let node = &self.g.nodes[ni];
        match node.op {
            Op::Const(_) => {
                if self.const_done[ni] {
                    StallCause::GateClosed
                } else {
                    StallCause::OutputBlocked
                }
            }
            Op::NdMerge => {
                if self.full(node.outs[0]) {
                    StallCause::OutputBlocked
                } else {
                    StallCause::InputStarved
                }
            }
            Op::DMerge => {
                if self.full(node.outs[0]) {
                    return StallCause::OutputBlocked;
                }
                match self.peek(node.ins[0]) {
                    None => StallCause::InputStarved,
                    Some(ctl) => {
                        let sel = if ctl != 0 { node.ins[1] } else { node.ins[2] };
                        if self.full(sel) {
                            StallCause::GateClosed
                        } else {
                            StallCause::InputStarved
                        }
                    }
                }
            }
            Op::Branch => match self.peek(node.ins[0]) {
                None => StallCause::InputStarved,
                Some(ctl) => {
                    if !self.full(node.ins[1]) {
                        StallCause::InputStarved
                    } else {
                        let out = if ctl != 0 { node.outs[0] } else { node.outs[1] };
                        if self.full(out) {
                            StallCause::OutputBlocked
                        } else {
                            StallCause::GateClosed
                        }
                    }
                }
            },
            Op::Fifo(k) => {
                // Refused ⇒ could neither accept nor emit this round.
                if self.full(node.ins[0]) && self.fifos[ni].len() >= k as usize {
                    StallCause::GateClosed // queue at capacity
                } else if self.full(node.outs[0]) && !self.fifos[ni].is_empty() {
                    StallCause::OutputBlocked
                } else {
                    StallCause::InputStarved
                }
            }
            // copy / not / ALU / decider: every input required, every
            // output must be free.
            _ => {
                if node.ins.iter().any(|&a| !self.full(a)) {
                    StallCause::InputStarved
                } else if node.outs.iter().any(|&a| self.full(a)) {
                    StallCause::OutputBlocked
                } else {
                    StallCause::GateClosed
                }
            }
        }
    }

    /// Fire node `ni` if enabled; consume inputs now, stage outputs.
    fn try_fire(&mut self, ni: usize, staged: &mut Vec<(ArcId, Word)>) -> bool {
        let node = &self.g.nodes[ni];
        let op = node.op;
        // `staged` writes land after the round, so checking `full` here is
        // the snapshot check. An output already staged this round belongs
        // to another node (single-driver invariant) — cannot collide.
        match op {
            Op::Const(v) => {
                if self.const_done[ni] || self.full(node.outs[0]) {
                    return false;
                }
                self.const_done[ni] = true;
                self.consts_outstanding -= 1;
                staged.push((node.outs[0], v));
                true
            }
            Op::Copy => {
                if !self.full(node.ins[0]) || self.full(node.outs[0]) || self.full(node.outs[1]) {
                    return false;
                }
                let (o0, o1) = (node.outs[0], node.outs[1]);
                let v = self.take(node.ins[0]);
                staged.push((o0, v));
                staged.push((o1, v));
                true
            }
            Op::Not => {
                if !self.full(node.ins[0]) || self.full(node.outs[0]) {
                    return false;
                }
                let out = node.outs[0];
                let v = self.take(node.ins[0]);
                staged.push((out, op.eval1(v)));
                true
            }
            Op::NdMerge => {
                if self.full(node.outs[0]) {
                    return false;
                }
                // First-come-first-served; on a tie, port 0 wins (the
                // hardware arbiter's fixed priority).
                let (i0, i1, out) = (node.ins[0], node.ins[1], node.outs[0]);
                let v = if self.full(i0) {
                    self.take(i0)
                } else if self.full(i1) {
                    self.take(i1)
                } else {
                    return false;
                };
                staged.push((out, v));
                true
            }
            Op::DMerge => {
                // Port 0 is the TRUE/FALSE control; TRUE selects port 1
                // (`a`), FALSE selects port 2 (`b`). The unselected token,
                // if any, stays put (§3.2 item 3: "conditionally read").
                if self.full(node.outs[0]) {
                    return false;
                }
                let ctl = match self.peek(node.ins[0]) {
                    Some(c) => c,
                    None => return false,
                };
                let sel = if ctl != 0 { node.ins[1] } else { node.ins[2] };
                if !self.full(sel) {
                    return false;
                }
                let out = node.outs[0];
                self.take(node.ins[0]);
                let v = self.take(sel);
                staged.push((out, v));
                true
            }
            Op::Branch => {
                // Port 0 is control, port 1 is data; output 0 is the TRUE
                // side, output 1 the FALSE side. Only the selected output
                // must be free (§3.2 item 5).
                let ctl = match self.peek(node.ins[0]) {
                    Some(c) => c,
                    None => return false,
                };
                if !self.full(node.ins[1]) {
                    return false;
                }
                let out = if ctl != 0 { node.outs[0] } else { node.outs[1] };
                if self.full(out) {
                    return false;
                }
                self.take(node.ins[0]);
                let v = self.take(node.ins[1]);
                staged.push((out, v));
                true
            }
            Op::Fifo(k) => {
                // A FIFO both accepts and emits in the same round.
                let mut acted = false;
                if self.full(node.ins[0]) && self.fifos[ni].len() < k as usize {
                    let v = self.take(node.ins[0]);
                    self.fifos[ni].push_back(v);
                    acted = true;
                }
                if !self.full(node.outs[0]) {
                    if let Some(v) = self.fifos[ni].pop_front() {
                        staged.push((node.outs[0], v));
                        acted = true;
                    }
                }
                if acted {
                    // Queue state is internal (not arc events): the FIFO
                    // must re-examine itself while it holds tokens.
                    self.mark(ni as i32);
                }
                acted
            }
            // All remaining ops are 2-in/1-out ALU or decider nodes.
            _ => {
                if !self.full(node.ins[0]) || !self.full(node.ins[1]) || self.full(node.outs[0]) {
                    return false;
                }
                let out = node.outs[0];
                let a = self.take(node.ins[0]);
                let b = self.take(node.ins[1]);
                staged.push((out, op.eval2(a, b)));
                true
            }
        }
    }

    fn injections_pending(&self) -> bool {
        self.pending.iter().any(|(_, s)| !s.is_empty())
    }

    fn tokens_in_flight(&self) -> bool {
        self.tokens.iter().any(|t| t.is_some())
            || self.fifos.iter().any(|f| !f.is_empty())
    }

    /// Run to quiescence or the cycle limit.
    pub fn run(mut self, cfg: &SimConfig) -> SimOutcome {
        let (cycles, quiescent) = self.run_in_place(cfg);
        self.into_outcome(cycles, quiescent)
    }

    /// [`TokenSim::run`] without consuming the sim: returns
    /// `(cycles, quiescent)` and leaves outputs/firings in place for
    /// [`TokenSim::into_outcome`]. The profiled path uses this so
    /// [`TokenSim::take_profile`] can run after the drive loop.
    pub fn run_in_place(&mut self, cfg: &SimConfig) -> (u64, bool) {
        let mut cycles = 0u64;
        let mut quiescent = false;
        while cycles < cfg.max_cycles {
            let fired = self.step();
            cycles += 1;
            if fired == 0 && !self.injections_pending() {
                // One more round may still drain output ports.
                self.step();
                cycles += 1;
                if !self.tokens_in_flight() {
                    quiescent = true;
                }
                break;
            }
        }
        (cycles, quiescent)
    }

    /// Current arc occupancy (for invariant checks in tests).
    pub fn occupancy(&self) -> usize {
        self.tokens.iter().filter(|t| t.is_some()).count()
    }

    /// Capture the full simulator state between rounds as a portable
    /// [`TokenCheckpoint`]. Restoring it on the same graph and
    /// continuing produces the same outputs as the uninterrupted run
    /// (the `ckpt_*` conformance properties); `cycles` restart at the
    /// resume point, so resumed outcomes are compared on outputs.
    pub fn snapshot(&self) -> TokenCheckpoint {
        debug_assert!(self.staged.is_empty(), "staged writes outstanding");
        TokenCheckpoint {
            fingerprint: self.g.fingerprint(),
            tokens: self.tokens.clone(),
            fifos: self.fifos.iter().map(|q| q.iter().copied().collect()).collect(),
            const_done: self.const_done.clone(),
            pending: self
                .pending
                .iter()
                .map(|(_, q)| q.iter().copied().collect())
                .collect(),
            collected: self.collected.clone(),
            firings: self.firings,
        }
    }

    /// Rebuild a simulator from a checkpoint taken on the *same* graph
    /// (same [`Graph::fingerprint`]). Fails with a typed
    /// [`CheckpointError`] on any other graph or on an image whose
    /// shape disagrees with the graph. The event-driven worklist
    /// restarts fully marked — every node is re-examined on the first
    /// resumed round, which is sound (marking is only ever a
    /// may-examine hint) and needs no worklist state in the image.
    pub fn restore(g: &'g Graph, ck: &TokenCheckpoint) -> Result<Self, CheckpointError> {
        let got = g.fingerprint();
        if ck.fingerprint != got {
            return Err(CheckpointError::FingerprintMismatch {
                want: ck.fingerprint,
                got,
            });
        }
        let mut s = Self::new(g, &SimConfig::new());
        if ck.tokens.len() != s.tokens.len() {
            return Err(CheckpointError::ShapeMismatch(format!(
                "{} arcs captured, graph has {}",
                ck.tokens.len(),
                s.tokens.len()
            )));
        }
        if ck.fifos.len() != s.fifos.len() || ck.const_done.len() != s.const_done.len() {
            return Err(CheckpointError::ShapeMismatch(format!(
                "{}/{} nodes captured, graph has {}",
                ck.fifos.len(),
                ck.const_done.len(),
                s.fifos.len()
            )));
        }
        if ck.pending.len() != s.pending.len() {
            return Err(CheckpointError::ShapeMismatch(format!(
                "{} input ports captured, graph has {}",
                ck.pending.len(),
                s.pending.len()
            )));
        }
        for name in s.collected.keys() {
            if !ck.collected.contains_key(name) {
                return Err(CheckpointError::ShapeMismatch(format!(
                    "captured streams are missing output port `{name}`"
                )));
            }
        }
        s.tokens = ck.tokens.clone();
        for (q, src) in s.fifos.iter_mut().zip(&ck.fifos) {
            q.extend(src.iter().copied());
        }
        s.const_done = ck.const_done.clone();
        s.consts_outstanding = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(ni, n)| matches!(n.op, Op::Const(_)) && !ck.const_done[*ni])
            .count() as u32;
        for ((_, q), src) in s.pending.iter_mut().zip(&ck.pending) {
            q.extend(src.iter().copied());
        }
        s.collected = ck.collected.clone();
        s.firings = ck.firings;
        Ok(s)
    }
}

/// Convenience: build + run in one call.
pub fn run_token(g: &Graph, cfg: &SimConfig) -> SimOutcome {
    TokenSim::new(g, cfg).run(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::GraphBuilder;

    fn adder() -> Graph {
        let mut b = GraphBuilder::new("adder");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        b.finish().unwrap()
    }

    #[test]
    fn single_add_fires_once() {
        let g = adder();
        let cfg = SimConfig::new().inject("a", vec![2]).inject("b", vec![3]);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        assert_eq!(out.stream("z"), &[5]);
        assert_eq!(out.firings, 1);
        assert!(out.quiescent);
    }

    #[test]
    fn add_streams_elementwise() {
        let g = adder();
        let cfg = SimConfig::new()
            .inject("a", vec![1, 2, 3, 4])
            .inject("b", vec![10, 20, 30, 40]);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        assert_eq!(out.stream("z"), &[11, 22, 33, 44]);
        assert_eq!(out.firings, 4);
    }

    #[test]
    fn copy_duplicates() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let (x, y) = b.copy(a);
        let z = b.output_port("z");
        b.node(Op::Add, &[x, y], &[z]);
        let g = b.finish().unwrap();
        let cfg = SimConfig::new().inject("a", vec![21]);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        assert_eq!(out.stream("z"), &[42]);
    }

    #[test]
    fn branch_routes_by_control() {
        let mut b = GraphBuilder::new("t");
        let ctl = b.input_port("ctl");
        let data = b.input_port("data");
        let t = b.output_port("t");
        let f = b.output_port("f");
        b.node(Op::Branch, &[ctl, data], &[t, f]);
        let g = b.finish().unwrap();
        let cfg = SimConfig::new()
            .inject("ctl", vec![1, 0, 1])
            .inject("data", vec![10, 20, 30]);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        assert_eq!(out.stream("t"), &[10, 30]);
        assert_eq!(out.stream("f"), &[20]);
    }

    #[test]
    fn dmerge_keeps_unselected_token() {
        let mut b = GraphBuilder::new("t");
        let ctl = b.input_port("ctl");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::DMerge, &[ctl, a, c], &[z]);
        let g = b.finish().unwrap();
        // ctl TRUE selects `a`; the token on `b` must survive for the
        // second (FALSE) control token.
        let cfg = SimConfig::new()
            .inject("ctl", vec![1, 0])
            .inject("a", vec![7])
            .inject("b", vec![9]);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        assert_eq!(out.stream("z"), &[7, 9]);
        assert!(out.quiescent);
    }

    #[test]
    fn ndmerge_forwards_everything() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::NdMerge, &[a, c], &[z]);
        let g = b.finish().unwrap();
        let cfg = SimConfig::new().inject("a", vec![1, 2]).inject("b", vec![3]);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        let mut got = out.stream("z").to_vec();
        got.sort();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn const_emits_once() {
        let mut b = GraphBuilder::new("t");
        let k = b.constant(42);
        let a = b.input_port("a");
        let z = b.output_port("z");
        b.node(Op::Add, &[k, a], &[z]);
        let g = b.finish().unwrap();
        let cfg = SimConfig::new().inject("a", vec![1, 2]);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        // Only one const token: the second `a` token can never pair.
        assert_eq!(out.stream("z"), &[43]);
        assert!(!out.quiescent); // token stuck on `a`-side register
    }

    #[test]
    fn fifo_buffers_stream() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let z = b.output_port("z");
        b.node(Op::Fifo(8), &[a], &[z]);
        let g = b.finish().unwrap();
        let cfg = SimConfig::new().inject("a", vec![5, 6, 7]);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        assert_eq!(out.stream("z"), &[5, 6, 7]);
    }

    #[test]
    fn consts_pending_counter_tracks_fire_and_rearm() {
        let mut b = GraphBuilder::new("t");
        let k1 = b.constant(1);
        let k2 = b.constant(2);
        let z = b.output_port("z");
        b.node(Op::Add, &[k1, k2], &[z]);
        let g = b.finish().unwrap();
        let cfg = SimConfig::new();
        let mut sim = TokenSim::new(&g, &cfg);
        assert!(sim.consts_pending());
        while sim.step() > 0 {}
        assert!(!sim.consts_pending(), "both consts fired");
        sim.purge();
        assert!(!sim.consts_pending(), "purge does not re-arm consts");
        sim.rearm_consts();
        assert!(sim.consts_pending());
        while sim.step() > 0 {}
        assert!(!sim.consts_pending());
    }

    #[test]
    fn enqueue_resolves_ports_through_the_index() {
        let g = adder();
        let cfg = SimConfig::new();
        let mut sim = TokenSim::new(&g, &cfg);
        assert!(sim.enqueue("a", 40));
        assert!(sim.enqueue("b", 2));
        assert!(!sim.enqueue("nope", 1));
        assert_eq!(sim.port_slot("nope"), None);
        let slot = sim.port_slot("b").unwrap();
        sim.enqueue_at(slot, 0); // stranded second token on `b`
        let out = sim.run(&cfg);
        assert_eq!(out.stream("z"), &[42]);
        assert!(!out.quiescent, "extra `b` token is stranded");
    }

    #[test]
    fn profiling_observes_without_perturbing() {
        let g = crate::bench_defs::build(crate::bench_defs::BenchId::Fibonacci);
        let cfg = SimConfig::new().inject("n", vec![9]);
        let plain = run_token(&g, &cfg);

        let mut sim = TokenSim::new(&g, &cfg);
        sim.enable_profiling(ProfileLevel::Full);
        let (cycles, quiescent) = sim.run_in_place(&cfg);
        let prof = sim.take_profile().expect("profile enabled");
        let out = sim.into_outcome(cycles, quiescent);
        assert_eq!(out.outputs, plain.outputs);
        assert_eq!(out.cycles, plain.cycles);
        assert_eq!(out.firings, plain.firings);
        assert_eq!(prof.total_firings, out.firings, "profile accounting");
        assert_eq!(prof.engine, "token");
        assert_eq!(prof.cycles, out.cycles);
        assert!(prof.arc_occupancy.iter().any(|&o| o > 0), "Full occupancy");
        // A loop graph necessarily stalls somewhere while tokens cycle.
        assert!(prof.nodes.iter().any(|n| n.stall_total() > 0));
    }

    #[test]
    fn profiling_off_is_a_no_op_and_stays_out_of_checkpoints() {
        let g = adder();
        let cfg = SimConfig::new().inject("a", vec![2]).inject("b", vec![3]);
        let mut sim = TokenSim::new(&g, &cfg);
        sim.enable_profiling(ProfileLevel::Off);
        assert!(sim.take_profile().is_none(), "Off allocates nothing");

        // Checkpoint bytes are identical with and without profiling.
        let mut plain = TokenSim::new(&g, &cfg);
        let mut profiled = TokenSim::new(&g, &cfg);
        profiled.enable_profiling(ProfileLevel::Full);
        plain.step();
        profiled.step();
        assert_eq!(
            plain.snapshot().to_bytes(),
            profiled.snapshot().to_bytes(),
            "profiling leaks into the checkpoint image"
        );
    }

    #[test]
    fn cycle_limit_catches_deadlock() {
        // add with only one operand ever arriving → never fires.
        let g = adder();
        let cfg = SimConfig::new().inject("a", vec![1]).max_cycles(100);
        let out = TokenSim::new(&g, &cfg).run(&cfg);
        assert_eq!(out.stream("z"), &[] as &[i16]);
        assert!(!out.quiescent);
    }

    #[test]
    fn snapshot_restore_mid_run_finishes_identically() {
        // A loop graph keeps tokens in flight for many rounds — interrupt
        // one mid-run and the restored sim must finish with the same
        // outputs (and the same total firings) as the straight run.
        let g = crate::bench_defs::build(crate::bench_defs::BenchId::Fibonacci);
        let cfg = SimConfig::new().inject("n", vec![9]);
        let whole = run_token(&g, &cfg);

        let mut sim = TokenSim::new(&g, &cfg);
        for _ in 0..7 {
            sim.step();
        }
        let ck = sim.snapshot();
        let bytes = ck.to_bytes();
        let decoded = TokenCheckpoint::from_bytes(&bytes).expect("decode");
        let resumed = TokenSim::restore(&g, &decoded).expect("restore");
        assert_eq!(resumed.snapshot().to_bytes(), bytes, "round trip bytes");
        let out = resumed.run(&SimConfig::new().max_cycles(1_000_000));
        assert_eq!(out.outputs, whole.outputs);
        assert_eq!(out.firings, whole.firings);
        assert!(out.quiescent);
    }

    #[test]
    fn restore_rejects_wrong_graph() {
        let g = adder();
        let cfg = SimConfig::new().inject("a", vec![1]);
        let ck = TokenSim::new(&g, &cfg).snapshot();
        let other = crate::bench_defs::build(crate::bench_defs::BenchId::Max);
        assert!(matches!(
            TokenSim::restore(&other, &ck),
            Err(CheckpointError::FingerprintMismatch { .. })
        ));
        let mut bad = ck;
        bad.const_done.push(true);
        assert!(matches!(
            TokenSim::restore(&g, &bad),
            Err(CheckpointError::ShapeMismatch(_))
        ));
    }
}
