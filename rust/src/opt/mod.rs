//! The DFG optimizer: a fixed-point pass pipeline over [`Graph`].
//!
//! Lowered graphs — especially `frontend::lower`'s output with its
//! lazy-copy discipline — carry redundant copy chains, constant
//! subgraphs and duplicated expressions that burn fabric slots, bus
//! channels and firings on every engine. The pipeline removes them
//! while preserving the graph's *observable* behaviour:
//!
//! * **canonicalize** — commutative operands into a deterministic
//!   order, shift counts masked to the 4-bit barrel-shifter range;
//! * **fold-consts** — `const`-only ALU/decider/`not` subgraphs
//!   evaluated at compile time with the exact [`Op::eval2`]/
//!   [`Op::eval1`] word semantics `TokenSim::try_fire` uses;
//! * **strength** — `mul` by a constant power of two → `shl`;
//! * **elide-copies** — copies whose second output dangles
//!   anonymously are wires; chains collapse to zero;
//! * **cse** ([`OptLevel::Aggressive`] only) — duplicate pure
//!   computations merge into one, fanned out through a `copy`;
//! * **dce** — nodes with no forward path to a *named* output port.
//!
//! Every pass, and the pipeline as a whole, is held to the
//! differential obligation enforced by `rust/tests/conformance.rs`:
//! on every workload that quiesces on the raw graph, every execution
//! engine produces byte-identical streams on the named output ports
//! of the optimized graph, and the named external port set is
//! preserved exactly. DESIGN.md §9 catalogues the per-pass legality
//! conditions (and the rewrites that are deliberately *absent* —
//! `x+0` elision and constant-control routing folds are rate changes
//! in static dataflow, not simplifications).

mod editor;
mod passes;

use crate::dfg::Graph;
use std::fmt;

/// How hard to optimize. `None` is the identity (and is tested to be);
/// `Default` runs the always-profitable structural passes; `Aggressive`
/// adds common-subexpression elimination, which trades a little
/// operator coupling (the fan-out `copy`) for fewer ALU slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum OptLevel {
    None,
    #[default]
    Default,
    Aggressive,
}

impl OptLevel {
    pub const ALL: [OptLevel; 3] = [OptLevel::None, OptLevel::Default, OptLevel::Aggressive];

    pub fn name(self) -> &'static str {
        match self {
            OptLevel::None => "none",
            OptLevel::Default => "default",
            OptLevel::Aggressive => "aggressive",
        }
    }

    pub fn from_name(s: &str) -> Option<OptLevel> {
        OptLevel::ALL.iter().copied().find(|l| l.name() == s)
    }
}

impl fmt::Display for OptLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Structural delta one pass application produced (crate-internal
/// accumulator; [`PassStats`] is the reported aggregate).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct PassDelta {
    pub applications: u64,
    /// Net node-count change (negative = removed). CSE is net zero on
    /// its own; its wins surface through the cleanup passes.
    pub nodes: i64,
    pub arcs: i64,
    /// In-place rewrites that moved no nodes or arcs (operand swaps,
    /// opcode changes).
    pub rewrites: u64,
}

/// What one pass did over the whole pipeline run.
#[derive(Debug, Clone)]
pub struct PassStats {
    pub name: &'static str,
    pub applications: u64,
    pub nodes_delta: i64,
    pub arcs_delta: i64,
    pub rewrites: u64,
}

impl PassStats {
    fn new(name: &'static str) -> Self {
        PassStats {
            name,
            applications: 0,
            nodes_delta: 0,
            arcs_delta: 0,
            rewrites: 0,
        }
    }

    fn absorb(&mut self, d: PassDelta) {
        self.applications += d.applications;
        self.nodes_delta += d.nodes;
        self.arcs_delta += d.arcs;
        self.rewrites += d.rewrites;
    }

    fn merge(&mut self, o: &PassStats) {
        debug_assert_eq!(self.name, o.name);
        self.applications += o.applications;
        self.nodes_delta += o.nodes_delta;
        self.arcs_delta += o.arcs_delta;
        self.rewrites += o.rewrites;
    }
}

/// What the pipeline did to one graph.
#[derive(Debug, Clone)]
pub struct OptReport {
    pub level: OptLevel,
    pub nodes_before: usize,
    pub nodes_after: usize,
    pub arcs_before: usize,
    pub arcs_after: usize,
    /// Full pipeline sweeps until the joint fixpoint.
    pub iterations: u64,
    /// Per-pass aggregates, in pipeline order.
    pub passes: Vec<PassStats>,
}

impl OptReport {
    pub fn nodes_removed(&self) -> i64 {
        self.nodes_before as i64 - self.nodes_after as i64
    }

    pub fn arcs_removed(&self) -> i64 {
        self.arcs_before as i64 - self.arcs_after as i64
    }

    /// Any pass applied at least one rewrite.
    pub fn changed(&self) -> bool {
        self.passes.iter().any(|p| p.applications > 0)
    }

    /// One-line counter summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "opt[{}]: nodes {} -> {}, arcs {} -> {} ({} iteration(s))",
            self.level,
            self.nodes_before,
            self.nodes_after,
            self.arcs_before,
            self.arcs_after,
            self.iterations
        )
    }
}

impl fmt::Display for OptReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.summary())?;
        writeln!(
            f,
            "  {:<14} {:>6} {:>8} {:>8} {:>9}",
            "pass", "apps", "d-nodes", "d-arcs", "rewrites"
        )?;
        for p in &self.passes {
            writeln!(
                f,
                "  {:<14} {:>6} {:>8} {:>8} {:>9}",
                p.name, p.applications, p.nodes_delta, p.arcs_delta, p.rewrites
            )?;
        }
        Ok(())
    }
}

/// The [`OptLevel::Default`] pipeline, in order.
pub const PASSES_DEFAULT: [&str; 5] = [
    "canonicalize",
    "fold-consts",
    "strength",
    "elide-copies",
    "dce",
];

/// The [`OptLevel::Aggressive`] pipeline: default plus CSE (before the
/// cleanup passes re-run at the next sweep).
pub const PASSES_AGGRESSIVE: [&str; 6] = [
    "canonicalize",
    "fold-consts",
    "strength",
    "elide-copies",
    "cse",
    "dce",
];

/// The pass names a level runs, in pipeline order.
pub fn pass_names(level: OptLevel) -> &'static [&'static str] {
    match level {
        OptLevel::None => &[],
        OptLevel::Default => &PASSES_DEFAULT,
        OptLevel::Aggressive => &PASSES_AGGRESSIVE,
    }
}

fn canonical_pass_name(pass: &str) -> &'static str {
    PASSES_AGGRESSIVE
        .iter()
        .copied()
        .find(|&n| n == pass)
        .unwrap_or_else(|| panic!("unknown optimizer pass `{pass}`"))
}

fn apply_once(g: &Graph, pass: &'static str) -> Option<(Graph, PassDelta)> {
    match pass {
        "canonicalize" => passes::canonicalize(g),
        "fold-consts" => passes::fold_consts(g),
        "strength" => passes::strength(g),
        "elide-copies" => passes::elide_copies(g),
        "cse" => passes::cse(g),
        "dce" => passes::dce(g),
        other => panic!("unknown optimizer pass `{other}`"),
    }
}

/// Generous bound on single-pass self-applications (each application
/// strictly shrinks the graph or fixes a one-way rewrite, so real
/// graphs converge in far fewer).
const PASS_FIXPOINT_CAP: usize = 100_000;

/// Bound on full pipeline sweeps.
const DRIVER_CAP: u64 = 64;

fn run_pass_inner(g: &Graph, name: &'static str) -> Option<(Graph, PassStats)> {
    let mut stats = PassStats::new(name);
    let mut cur: Option<Graph> = None;
    for _ in 0..PASS_FIXPOINT_CAP {
        let src = cur.as_ref().unwrap_or(g);
        match apply_once(src, name) {
            Some((next, d)) => {
                stats.absorb(d);
                cur = Some(next);
            }
            None => break,
        }
    }
    cur.map(|g| (g, stats))
}

/// Run a single pass to its own fixpoint — the entry the pass-level
/// differential harness drives. Unknown names panic.
pub fn run_pass(g: &Graph, pass: &str) -> (Graph, PassStats) {
    let name = canonical_pass_name(pass);
    run_pass_inner(g, name).unwrap_or_else(|| (g.clone(), PassStats::new(name)))
}

/// Optimize `g` at `level`: run every pass of the level's pipeline to
/// its fixpoint, and sweep the pipeline until a whole sweep changes
/// nothing. The result is validated after every rewrite; a graph that
/// is already optimal comes back byte-identical (idempotence — the
/// conformance harness pins it).
pub fn optimize(g: &Graph, level: OptLevel) -> (Graph, OptReport) {
    let mut report = OptReport {
        level,
        nodes_before: g.n_nodes(),
        nodes_after: g.n_nodes(),
        arcs_before: g.n_arcs(),
        arcs_after: g.n_arcs(),
        iterations: 0,
        passes: pass_names(level).iter().map(|&n| PassStats::new(n)).collect(),
    };
    if level == OptLevel::None {
        return (g.clone(), report);
    }
    let mut cur = g.clone();
    for _ in 0..DRIVER_CAP {
        let mut changed = false;
        report.iterations += 1;
        for (pi, &name) in pass_names(level).iter().enumerate() {
            let name = canonical_pass_name(name);
            if let Some((next, st)) = run_pass_inner(&cur, name) {
                report.passes[pi].merge(&st);
                cur = next;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    report.nodes_after = cur.n_nodes();
    report.arcs_after = cur.n_arcs();
    (cur, report)
}

/// [`optimize`] at [`OptLevel::Default`], dropping the report — the
/// convenience the frontend and examples use.
pub fn optimize_default(g: &Graph) -> Graph {
    optimize(g, OptLevel::Default).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};
    use crate::dfg::{GraphBuilder, Op};
    use crate::frontend;
    use crate::sim::{run_fsm, run_token};

    #[test]
    fn removes_dangling_copy() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let (u, _rest) = b.copy(a); // rest dangles
        let k = b.constant(1);
        let z = b.output_port("z");
        b.node(Op::Add, &[u, k], &[z]);
        let g = b.finish().unwrap();
        let (opt, report) = optimize(&g, OptLevel::Default);
        assert_eq!(opt.n_nodes(), g.n_nodes() - 1);
        assert!(opt.op_census().get("copy").is_none());
        assert_eq!(report.nodes_removed(), 1);
        let cfg = crate::sim::SimConfig::new().inject("a", vec![41]);
        assert_eq!(run_token(&opt, &cfg).stream("z"), &[42]);
    }

    #[test]
    fn preserves_port_names_through_fusion() {
        // `r = x + 0;` lowers to copy(x) feeding the add; eliminating
        // the copy must keep both port names on the fused arcs.
        let g = frontend::compile_with("t", "in int x; out int r; r = x + 0;", OptLevel::None)
            .unwrap();
        let (opt, _) = optimize(&g, OptLevel::Default);
        assert!(opt.arc_by_name("r").is_some());
        assert!(opt.arc_by_name("x").is_some());
        let cfg = crate::sim::SimConfig::new().inject("x", vec![9]);
        assert_eq!(run_token(&opt, &cfg).stream("r"), &[9]);
    }

    #[test]
    fn shrinks_all_compiled_benchmarks_semantics_preserved() {
        for bench in BenchId::ALL {
            let g = frontend::compile_with(
                bench.slug(),
                bench_defs::c_source(bench),
                OptLevel::None,
            )
            .unwrap();
            let (opt, report) = optimize(&g, OptLevel::Default);
            assert!(
                opt.n_nodes() < g.n_nodes(),
                "{}: {} !< {}",
                bench.slug(),
                opt.n_nodes(),
                g.n_nodes()
            );
            assert_eq!(
                report.nodes_removed(),
                g.n_nodes() as i64 - opt.n_nodes() as i64
            );
            let wl = bench_defs::workload(bench, 6, 17);
            let mut cfg = wl.sim_config();
            cfg.max_cycles *= 4;
            let tok = run_token(&opt, &cfg);
            let fsm = run_fsm(&opt, &cfg);
            for (port, want) in &wl.expect {
                assert_eq!(tok.stream(port), want.as_slice(), "{} token", bench.slug());
                assert_eq!(fsm.stream(port), want.as_slice(), "{} fsm", bench.slug());
            }
        }
    }

    #[test]
    fn optimized_graphs_approach_hand_built_size() {
        // Aggregate: the pipeline recovers a large share of the
        // lazy-copy overhead the frontend introduces vs the hand-built
        // graphs.
        let mut raw = 0usize;
        let mut opt_total = 0usize;
        let mut hand = 0usize;
        for bench in BenchId::ALL {
            let g = frontend::compile_with(
                bench.slug(),
                bench_defs::c_source(bench),
                OptLevel::None,
            )
            .unwrap();
            raw += g.n_nodes();
            opt_total += optimize_default(&g).n_nodes();
            hand += bench_defs::build(bench).n_nodes();
        }
        assert!(opt_total < raw, "optimizer removed nothing");
        let overhead_before = raw as f64 / hand as f64;
        let overhead_after = opt_total as f64 / hand as f64;
        assert!(
            overhead_after < overhead_before,
            "{overhead_after:.2} !< {overhead_before:.2}"
        );
    }

    #[test]
    fn idempotent_to_the_byte() {
        for level in [OptLevel::Default, OptLevel::Aggressive] {
            let g = frontend::compile_with(
                "fib",
                bench_defs::c_source(BenchId::Fibonacci),
                OptLevel::None,
            )
            .unwrap();
            let (o1, _) = optimize(&g, level);
            let (o2, r2) = optimize(&o1, level);
            assert!(!r2.changed(), "{level}: second run must be a no-op");
            assert_eq!(
                crate::asm::print(&o1),
                crate::asm::print(&o2),
                "{level}: fixpoint not byte-stable"
            );
        }
    }

    #[test]
    fn level_none_is_identity() {
        let g = bench_defs::build(BenchId::DotProd);
        let (o, report) = optimize(&g, OptLevel::None);
        assert_eq!(crate::asm::print(&o), crate::asm::print(&g));
        assert!(!report.changed());
        assert_eq!(report.iterations, 0);
        assert!(report.passes.is_empty());
    }

    #[test]
    fn report_renders() {
        let g = frontend::compile_with(
            "fib",
            bench_defs::c_source(BenchId::Fibonacci),
            OptLevel::None,
        )
        .unwrap();
        let (_, report) = optimize(&g, OptLevel::Default);
        let text = format!("{report}");
        assert!(text.contains("elide-copies"), "{text}");
        assert!(report.summary().contains("opt[default]"), "{}", report.summary());
        assert_eq!(OptLevel::from_name("aggressive"), Some(OptLevel::Aggressive));
        assert_eq!(OptLevel::from_name("bogus"), None);
    }
}
