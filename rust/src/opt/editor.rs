//! Mutable graph-rewrite substrate for the optimizer passes.
//!
//! A [`GraphEditor`] holds a tombstoned copy of a [`Graph`]: nodes and
//! arcs keep their original indices while a pass deletes, rewires and
//! adds elements, and [`GraphEditor::finish`] compacts the survivors
//! back into a dense, validated [`Graph`] (stable order: original
//! elements first, additions after). Keeping all rewiring behind a
//! handful of invariant-preserving operations means every pass shares
//! one correctness argument for the structural bookkeeping — the
//! `validate` call at the end is a backstop, not the mechanism.

use crate::dfg::{is_anon_label, validate, Arc, ArcId, Graph, Node, NodeId, Op};

/// An editable operator instance (indices are editor slots, not
/// [`NodeId`]s — those are assigned at [`GraphEditor::finish`]).
#[derive(Debug, Clone)]
pub struct ENode {
    pub op: Op,
    pub ins: Vec<usize>,
    pub outs: Vec<usize>,
}

/// An editable arc.
#[derive(Debug, Clone)]
pub struct EArc {
    pub src: Option<(usize, u8)>,
    pub dst: Option<(usize, u8)>,
    pub name: String,
}

#[derive(Debug)]
pub struct GraphEditor {
    name: String,
    nodes: Vec<Option<ENode>>,
    arcs: Vec<Option<EArc>>,
    next_anon: u32,
}

impl GraphEditor {
    pub fn new(g: &Graph) -> Self {
        let mut next_anon = 1u32;
        for a in &g.arcs {
            if is_anon_label(&a.name) {
                // Labels too large for u32 cannot collide with the
                // small fresh numbers allocated here.
                let n: u32 = a.name[1..].parse().unwrap_or(0);
                next_anon = next_anon.max(n.saturating_add(1));
            }
        }
        GraphEditor {
            name: g.name.clone(),
            nodes: g
                .nodes
                .iter()
                .map(|n| {
                    Some(ENode {
                        op: n.op,
                        ins: n.ins.iter().map(|a| a.0 as usize).collect(),
                        outs: n.outs.iter().map(|a| a.0 as usize).collect(),
                    })
                })
                .collect(),
            arcs: g
                .arcs
                .iter()
                .map(|a| {
                    Some(EArc {
                        src: a.src.map(|(n, p)| (n.0 as usize, p)),
                        dst: a.dst.map(|(n, p)| (n.0 as usize, p)),
                        name: a.name.clone(),
                    })
                })
                .collect(),
            next_anon,
        }
    }

    /// Allocate a fresh anonymous label (`sN`) guaranteed unique in
    /// this graph.
    pub fn fresh_anon(&mut self) -> String {
        let n = self.next_anon;
        self.next_anon += 1;
        format!("s{n}")
    }

    /// Add an arc; `None` gets a fresh anonymous label.
    pub fn add_arc(&mut self, name: Option<String>) -> usize {
        let name = name.unwrap_or_else(|| self.fresh_anon());
        self.arcs.push(Some(EArc {
            src: None,
            dst: None,
            name,
        }));
        self.arcs.len() - 1
    }

    /// Add a node wired to the given (unclaimed) input/output arcs.
    pub fn add_node(&mut self, op: Op, ins: &[usize], outs: &[usize]) -> usize {
        assert_eq!(ins.len(), op.n_in(), "{op:?} arity");
        assert_eq!(outs.len(), op.n_out(), "{op:?} arity");
        let id = self.nodes.len();
        for (p, &a) in ins.iter().enumerate() {
            let arc = self.arcs[a].as_mut().expect("live arc");
            assert!(arc.dst.is_none(), "arc `{}` already consumed", arc.name);
            arc.dst = Some((id, p as u8));
        }
        for (p, &a) in outs.iter().enumerate() {
            let arc = self.arcs[a].as_mut().expect("live arc");
            assert!(arc.src.is_none(), "arc `{}` already driven", arc.name);
            arc.src = Some((id, p as u8));
        }
        self.nodes.push(Some(ENode {
            op,
            ins: ins.to_vec(),
            outs: outs.to_vec(),
        }));
        id
    }

    /// Delete a node, detaching every incident arc (in-arcs lose their
    /// consumer, out-arcs their driver; the arcs themselves survive).
    pub fn delete_node(&mut self, i: usize) {
        let n = self.nodes[i].take().expect("live node");
        for a in n.ins {
            if let Some(arc) = self.arcs[a].as_mut() {
                arc.dst = None;
            }
        }
        for a in n.outs {
            if let Some(arc) = self.arcs[a].as_mut() {
                arc.src = None;
            }
        }
    }

    /// Delete a fully detached arc.
    pub fn delete_arc(&mut self, i: usize) {
        let a = self.arcs[i].take().expect("live arc");
        assert!(
            a.src.is_none() && a.dst.is_none(),
            "deleting connected arc `{}`",
            a.name
        );
    }

    /// Give arc `i`'s consumer slot to `(node, port)` — the node's input
    /// at that port must currently be unwired from `i`'s perspective
    /// (i.e. this is the re-attachment half of a fuse).
    pub fn attach_dst(&mut self, i: usize, node: usize, port: u8) {
        let arc = self.arcs[i].as_mut().expect("live arc");
        assert!(arc.dst.is_none(), "arc `{}` already consumed", arc.name);
        arc.dst = Some((node, port));
        self.nodes[node].as_mut().expect("live node").ins[port as usize] = i;
    }

    /// Drop arc `i`'s consumer endpoint. The consuming node's input
    /// slot still references `i` until the caller re-points it with
    /// [`GraphEditor::attach_dst`] on a replacement arc — transient
    /// only, inside one rewrite.
    pub fn detach_dst(&mut self, i: usize) {
        self.arcs[i].as_mut().expect("live arc").dst = None;
    }

    /// Replace the opcode in place (arity classes must match).
    pub fn set_op(&mut self, i: usize, op: Op) {
        let n = self.nodes[i].as_mut().expect("live node");
        assert_eq!(n.ins.len(), op.n_in(), "set_op arity");
        assert_eq!(n.outs.len(), op.n_out(), "set_op arity");
        n.op = op;
    }

    /// Swap the two inputs of a binary node (commutative rewires only).
    pub fn swap_ins2(&mut self, i: usize) {
        let n = self.nodes[i].as_mut().expect("live node");
        assert_eq!(n.ins.len(), 2, "swap_ins2 on non-binary node");
        n.ins.swap(0, 1);
        let (a0, a1) = (n.ins[0], n.ins[1]);
        self.arcs[a0].as_mut().expect("live arc").dst = Some((i, 0));
        self.arcs[a1].as_mut().expect("live arc").dst = Some((i, 1));
    }

    pub fn rename_arc(&mut self, i: usize, name: String) {
        self.arcs[i].as_mut().expect("live arc").name = name;
    }

    /// Compact into a dense, validated [`Graph`]. Surviving elements
    /// keep their relative order, so repeated optimization of an
    /// already-optimal graph is byte-stable.
    pub fn finish(self, pass: &str) -> Graph {
        let mut node_map = vec![u32::MAX; self.nodes.len()];
        let mut next = 0u32;
        for (i, n) in self.nodes.iter().enumerate() {
            if n.is_some() {
                node_map[i] = next;
                next += 1;
            }
        }
        let mut arc_map = vec![u32::MAX; self.arcs.len()];
        let mut next = 0u32;
        for (i, a) in self.arcs.iter().enumerate() {
            if a.is_some() {
                arc_map[i] = next;
                next += 1;
            }
        }
        let mut g = Graph::new(self.name.clone());
        for (i, a) in self.arcs.iter().enumerate() {
            let Some(a) = a else { continue };
            let map_ep = |ep: Option<(usize, u8)>| {
                ep.map(|(n, p)| {
                    debug_assert!(
                        self.nodes[n].is_some(),
                        "pass `{pass}`: arc `{}` references deleted node",
                        a.name
                    );
                    (NodeId(node_map[n]), p)
                })
            };
            g.arcs.push(Arc {
                id: ArcId(arc_map[i]),
                src: map_ep(a.src),
                dst: map_ep(a.dst),
                name: a.name.clone(),
            });
        }
        for (i, n) in self.nodes.iter().enumerate() {
            let Some(n) = n else { continue };
            g.nodes.push(Node {
                id: NodeId(node_map[i]),
                op: n.op,
                ins: n.ins.iter().map(|&a| ArcId(arc_map[a])).collect(),
                outs: n.outs.iter().map(|&a| ArcId(arc_map[a])).collect(),
            });
        }
        validate(&g)
            .unwrap_or_else(|e| panic!("optimizer pass `{pass}` broke structural validity: {e}"));
        g
    }
}
