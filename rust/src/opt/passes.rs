//! The individual rewrite passes.
//!
//! Every pass takes a valid [`Graph`] and either returns `None` (no
//! candidate — the pass is at its fixpoint) or a rewritten valid graph
//! plus the structural delta it caused. Legality conditions per pass
//! are catalogued in DESIGN.md §9; the short version of the contract:
//!
//! * the **named** external port set (input ports and non-anonymous
//!   output ports) is preserved exactly — anonymous dangling `sN` arcs
//!   are drain wires and may appear or disappear;
//! * on every execution that quiesces on the raw graph, the streams
//!   collected at named output ports are byte-identical (the same
//!   contract under which the PR 2 cross-engine comparisons are
//!   defined — buffer-capacity changes are unobservable exactly at
//!   quiescence);
//! * rewrites that would change a `const`'s one-shot pairing (x+0 → x)
//!   or a one-shot routing decision (`branch`/`dmerge` under constant
//!   control) are *not* performed — those are rate changes, not
//!   simplifications, in static dataflow.

use super::editor::GraphEditor;
use super::PassDelta;
use crate::dfg::{is_anon_label, ArcId, Graph, Op, OpClass, Word};
use std::collections::BTreeMap;

fn is_commutative(op: Op) -> bool {
    matches!(op, Op::Add | Op::Mul | Op::And | Op::Or | Op::Xor | Op::IfEq | Op::IfDf)
}

/// Pure value operators: no routing, no state, no one-shot semantics.
fn is_pure(op: Op) -> bool {
    matches!(op.class(), OpClass::Alu1 | OpClass::Alu2 | OpClass::Decider)
}

/// The `Const` node driving `a`, if any, as `(node index, value)`.
fn const_src(g: &Graph, a: ArcId) -> Option<(usize, Word)> {
    let (n, _) = g.arc(a).src?;
    match g.node(n).op {
        Op::Const(v) => Some((n.0 as usize, v)),
        _ => None,
    }
}

/// A deterministic total order on a node's operand arcs: node-driven
/// operands sort by (driver index, driver port), environment ports by
/// label. Used to put commutative operands in a canonical order.
fn operand_key<'g>(g: &'g Graph, a: ArcId) -> (u8, u32, u8, &'g str) {
    match g.arc(a).src {
        Some((n, p)) => (0, n.0, p, ""),
        None => (1, 0, 0, g.arc(a).name.as_str()),
    }
}

// ---- canonicalize ------------------------------------------------------

/// Commutative operands into canonical order; shift counts masked to
/// the barrel shifter's 4 bits (`shl x, #17` ≡ `shl x, #1`). Pure
/// rewrites — node and arc counts never change.
pub(super) fn canonicalize(g: &Graph) -> Option<(Graph, PassDelta)> {
    let mut swaps: Vec<usize> = Vec::new();
    let mut masks: Vec<(usize, Word)> = Vec::new();
    for n in &g.nodes {
        match n.op {
            Op::Shl | Op::Shr => {
                if let Some((cn, v)) = const_src(g, n.ins[1]) {
                    let m = v & 0xf;
                    if m != v {
                        masks.push((cn, m));
                    }
                }
            }
            op if is_commutative(op) => {
                if operand_key(g, n.ins[1]) < operand_key(g, n.ins[0]) {
                    swaps.push(n.id.0 as usize);
                }
            }
            _ => {}
        }
    }
    if swaps.is_empty() && masks.is_empty() {
        return None;
    }
    let rewrites = (swaps.len() + masks.len()) as u64;
    let mut ed = GraphEditor::new(g);
    for i in swaps {
        ed.swap_ins2(i);
    }
    for (cn, m) in masks {
        ed.set_op(cn, Op::Const(m));
    }
    Some((
        ed.finish("canonicalize"),
        PassDelta {
            applications: rewrites,
            rewrites,
            ..PassDelta::default()
        },
    ))
}

// ---- fold-consts -------------------------------------------------------

/// One constant fold: an ALU/decider/`not` node with all-const inputs
/// becomes a single `const` (exact: one token in produces one token
/// out, before and after — the fold even *shrinks* every per-class
/// demand, so a graph that placed raw always places folded).
struct Fold {
    node: usize,
    consts: Vec<(usize, ArcId)>,
    val: Word,
}

pub(super) fn fold_consts(g: &Graph) -> Option<(Graph, PassDelta)> {
    let mut folds: Vec<Fold> = Vec::new();
    for n in &g.nodes {
        match n.op.class() {
            OpClass::Alu2 | OpClass::Decider => {
                if let (Some((c0, v0)), Some((c1, v1))) =
                    (const_src(g, n.ins[0]), const_src(g, n.ins[1]))
                {
                    folds.push(Fold {
                        node: n.id.0 as usize,
                        consts: vec![(c0, n.ins[0]), (c1, n.ins[1])],
                        val: n.op.eval2(v0, v1),
                    });
                }
            }
            OpClass::Alu1 => {
                if let Some((c0, v0)) = const_src(g, n.ins[0]) {
                    folds.push(Fold {
                        node: n.id.0 as usize,
                        consts: vec![(c0, n.ins[0])],
                        val: n.op.eval1(v0),
                    });
                }
            }
            _ => {}
        }
    }
    if folds.is_empty() {
        return None;
    }
    let mut delta = PassDelta::default();
    let mut ed = GraphEditor::new(g);
    for Fold { node, consts, val } in folds {
        let out = g.nodes[node].outs[0].0 as usize;
        ed.delete_node(node);
        for (cn, arc) in &consts {
            ed.delete_node(*cn);
            ed.delete_arc(arc.0 as usize);
        }
        ed.add_node(Op::Const(val), &[], &[out]);
        delta.applications += 1;
        delta.nodes -= consts.len() as i64;
        delta.arcs -= consts.len() as i64;
    }
    Some((ed.finish("fold-consts"), delta))
}

// ---- strength ----------------------------------------------------------

/// `k` such that multiplying by `v` equals `shl` by `k` in wrapping
/// 16-bit arithmetic. `i16::MIN` is 2¹⁵ mod 2¹⁶; `1` is excluded (a
/// `shl #0` is no cheaper and the identity elision itself would be a
/// rate change — see DESIGN.md §9).
fn pow2_shift(v: Word) -> Option<Word> {
    if v == Word::MIN {
        return Some(15);
    }
    if v >= 2 && (v & (v - 1)) == 0 {
        return Some(v.trailing_zeros() as Word);
    }
    None
}

/// `mul` by a constant power of two → `shl` (exact for every input in
/// wrapping arithmetic). `div` by a power of two is deliberately *not*
/// reduced: `wrapping_div` truncates toward zero while `shr` is an
/// arithmetic (flooring) shift, so they disagree on negative odd
/// dividends (−3/2 = −1 but −3>>1 = −2).
pub(super) fn strength(g: &Graph) -> Option<(Graph, PassDelta)> {
    let mut plans: Vec<(usize, usize, Word, bool)> = Vec::new();
    for n in &g.nodes {
        if n.op != Op::Mul {
            continue;
        }
        let (c0, c1) = (const_src(g, n.ins[0]), const_src(g, n.ins[1]));
        if c0.is_some() && c1.is_some() {
            continue; // fold-consts territory
        }
        let (swap, konst) = match (c0, c1) {
            (_, Some(c)) => (false, c),
            (Some(c), _) => (true, c),
            _ => continue,
        };
        if let Some(k) = pow2_shift(konst.1) {
            plans.push((n.id.0 as usize, konst.0, k, swap));
        }
    }
    if plans.is_empty() {
        return None;
    }
    let applications = plans.len() as u64;
    let mut ed = GraphEditor::new(g);
    for (node, cn, k, swap) in plans {
        if swap {
            ed.swap_ins2(node);
        }
        ed.set_op(node, Op::Shl);
        ed.set_op(cn, Op::Const(k));
    }
    Some((
        ed.finish("strength"),
        PassDelta {
            applications,
            rewrites: applications,
            ..PassDelta::default()
        },
    ))
}

// ---- elide-copies ------------------------------------------------------

/// Copy-chain elision: a `copy` with an anonymous unconsumed output is
/// a one-place buffer (the dangling side always drains), so the node
/// is removed and its input fused with its live output; chains
/// collapse over the fixpoint loop. Guards: named dangles are
/// interface, never dead; a copy repeating an input port straight to
/// an output port is load-bearing (removing it would leave a
/// disconnected pin that *echoes* injections); fusing onto a named
/// output port must not rename it.
pub(super) fn elide_copies(g: &Graph) -> Option<(Graph, PassDelta)> {
    for n in &g.nodes {
        if n.op != Op::Copy {
            continue;
        }
        let in_arc = n.ins[0];
        if in_arc == n.outs[0] || in_arc == n.outs[1] {
            continue; // degenerate self-loop
        }
        let dead = |a: ArcId| {
            let arc = g.arc(a);
            arc.dst.is_none() && is_anon_label(&arc.name)
        };
        let (d0, d1) = (dead(n.outs[0]), dead(n.outs[1]));
        let in_is_port = g.arc(in_arc).src.is_none();
        let in_anon = is_anon_label(&g.arc(in_arc).name);
        let ni = n.id.0 as usize;

        if d0 && d1 {
            // Pure drain. Removing it leaves the input arc as the
            // drain, which only works when that arc may dangle
            // anonymously itself.
            if in_is_port || !in_anon {
                continue;
            }
            let mut ed = GraphEditor::new(g);
            ed.delete_node(ni);
            ed.delete_arc(n.outs[0].0 as usize);
            ed.delete_arc(n.outs[1].0 as usize);
            return Some((
                ed.finish("elide-copies"),
                PassDelta {
                    applications: 1,
                    nodes: -1,
                    arcs: -2,
                    ..PassDelta::default()
                },
            ));
        }
        if d0 || d1 {
            let (dead_arc, live_arc) = if d0 {
                (n.outs[0], n.outs[1])
            } else {
                (n.outs[1], n.outs[0])
            };
            let live = g.arc(live_arc);
            let live_dst = live.dst;
            if live_dst.is_none() {
                // The live side is a *named* output port (anonymous
                // would be dead). The fused input arc must be able to
                // take over both the portness and the label.
                if in_is_port || !in_anon {
                    continue;
                }
            }
            let live_name = live.name.clone();
            let mut ed = GraphEditor::new(g);
            ed.delete_node(ni);
            if let Some((c, p)) = live_dst {
                // Free the live arc's consumer slot, then hand it to
                // the copy's input arc (the fuse).
                ed.detach_dst(live_arc.0 as usize);
                ed.attach_dst(in_arc.0 as usize, c.0 as usize, p);
            }
            if in_anon && !is_anon_label(&live_name) {
                ed.rename_arc(in_arc.0 as usize, live_name);
            }
            ed.delete_arc(live_arc.0 as usize);
            ed.delete_arc(dead_arc.0 as usize);
            return Some((
                ed.finish("elide-copies"),
                PassDelta {
                    applications: 1,
                    nodes: -1,
                    arcs: -2,
                    ..PassDelta::default()
                },
            ));
        }
    }
    None
}

// ---- cse ---------------------------------------------------------------

/// Value-number every arc that is acyclically computable: environment
/// ports get fresh classes, `const #v` interns on its value, `copy`
/// propagates its input class to both outputs, pure operators intern
/// on (opcode, operand classes — sorted when commutative), `fifo #k`
/// interns on (depth, input class), and routing operators
/// (`branch`/`dmerge`/`ndmerge`) always get fresh classes (their
/// output streams are data-dependent subsequences). Arcs inside
/// cycles never resolve and stay `None` — loop bodies are thereby
/// excluded from CSE.
fn value_classes(g: &Graph) -> Vec<Option<u32>> {
    type Key = (&'static str, i32, Vec<u32>);
    fn intern(interned: &mut BTreeMap<Key, u32>, next: &mut u32, key: Key) -> u32 {
        *interned.entry(key).or_insert_with(|| {
            let c = *next;
            *next += 1;
            c
        })
    }
    let mut class: Vec<Option<u32>> = vec![None; g.n_arcs()];
    let mut next = 0u32;
    let mut interned: BTreeMap<Key, u32> = BTreeMap::new();
    for a in &g.arcs {
        if a.src.is_none() {
            class[a.id.0 as usize] = Some(next);
            next += 1;
        }
    }
    loop {
        let mut progress = false;
        for n in &g.nodes {
            if n.outs.iter().all(|o| class[o.0 as usize].is_some()) {
                continue;
            }
            if !n.ins.iter().all(|i| class[i.0 as usize].is_some()) {
                continue;
            }
            match n.op {
                Op::Const(v) => {
                    let c = intern(&mut interned, &mut next, ("const", v as i32, vec![]));
                    class[n.outs[0].0 as usize] = Some(c);
                }
                Op::Copy => {
                    let c = class[n.ins[0].0 as usize];
                    class[n.outs[0].0 as usize] = c;
                    class[n.outs[1].0 as usize] = c;
                }
                Op::Fifo(k) => {
                    let c = class[n.ins[0].0 as usize].unwrap();
                    let c = intern(&mut interned, &mut next, ("fifo", k as i32, vec![c]));
                    class[n.outs[0].0 as usize] = Some(c);
                }
                Op::NdMerge | Op::DMerge | Op::Branch => {
                    for o in &n.outs {
                        class[o.0 as usize] = Some(next);
                        next += 1;
                    }
                }
                op => {
                    debug_assert!(is_pure(op));
                    let mut operands: Vec<u32> = n
                        .ins
                        .iter()
                        .map(|i| class[i.0 as usize].unwrap())
                        .collect();
                    if is_commutative(op) {
                        operands.sort_unstable();
                    }
                    let c = intern(&mut interned, &mut next, (op.mnemonic(), 0, operands));
                    class[n.outs[0].0 as usize] = Some(c);
                }
            }
            progress = true;
        }
        if !progress {
            break;
        }
    }
    class
}

/// Local CSE for pure operators (never `const`, never routing, never
/// `fifo` — see DESIGN.md §9): two value-equivalent pure nodes merge
/// into one computation fanned out through a fresh `copy`; the
/// victim's orphaned operand tree is cleaned up by `elide-copies` and
/// `dce` on later fixpoint rounds. One merge per call.
pub(super) fn cse(g: &Graph) -> Option<(Graph, PassDelta)> {
    let class = value_classes(g);
    let mut by_class: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
    for n in &g.nodes {
        if !is_pure(n.op) {
            continue;
        }
        let out = g.arc(n.outs[0]);
        if out.dst.is_none() && is_anon_label(&out.name) {
            continue; // pure drain — merging buys nothing, costs coupling
        }
        if let Some(c) = class[n.outs[0].0 as usize] {
            by_class.entry(c).or_default().push(n.id.0 as usize);
        }
    }
    for members in by_class.values().filter(|m| m.len() >= 2) {
        // A victim must be fully rewireable: every operand node-driven
        // through an anonymous arc (detaching a named arc or a port
        // would change the external interface).
        let can_be_victim = |&ni: &usize| {
            g.nodes[ni].ins.iter().all(|&a| {
                let arc = g.arc(a);
                arc.src.is_some() && is_anon_label(&arc.name)
            })
        };
        for &victim in members.iter() {
            if !can_be_victim(&victim) {
                continue;
            }
            let Some(&keeper) = members.iter().find(|&&k| k != victim) else {
                continue;
            };
            // Defensive: never merge producer/consumer pairs (value
            // numbering makes them distinct classes, but the rewire
            // below must not dangle onto a deleted node).
            let a1 = g.nodes[keeper].outs[0];
            let a2 = g.nodes[victim].outs[0];
            let consumes = |arc: ArcId, node: usize| {
                matches!(g.arc(arc).dst, Some((d, _)) if d.0 as usize == node)
            };
            if consumes(a1, victim) || consumes(a2, keeper) {
                continue;
            }
            return Some(merge_pair(g, keeper, victim));
        }
    }
    None
}

fn merge_pair(g: &Graph, keeper: usize, victim: usize) -> (Graph, PassDelta) {
    let a1 = g.nodes[keeper].outs[0];
    let a2 = g.nodes[victim].outs[0];
    let a1_dst = g.arc(a1).dst;
    let a1_name = g.arc(a1).name.clone();

    let mut ed = GraphEditor::new(g);
    // A fresh arc takes over the keeper output's public identity
    // (consumer or named portness); the old arc becomes the internal
    // wire feeding the new copy.
    let o0 = ed.add_arc(None);
    if let Some((c, p)) = a1_dst {
        ed.detach_dst(a1.0 as usize);
        ed.attach_dst(o0, c.0 as usize, p);
    }
    if !is_anon_label(&a1_name) {
        let fresh = ed.fresh_anon();
        ed.rename_arc(a1.0 as usize, fresh);
        ed.rename_arc(o0, a1_name);
    }
    // The victim's operand arcs dangle after this; `elide-copies` and
    // `dce` collect them on later fixpoint rounds.
    ed.delete_node(victim);
    ed.add_node(Op::Copy, &[a1.0 as usize], &[o0, a2.0 as usize]);
    (
        ed.finish("cse"),
        PassDelta {
            applications: 1,
            nodes: 0,
            arcs: 1,
            ..PassDelta::default()
        },
    )
}

// ---- dce ---------------------------------------------------------------

/// Dead-node elimination. Roots are the *named* output ports; a node
/// with no forward path to any of them computes nothing observable.
/// Two protections keep removal exact and interface-preserving:
/// a node directly fed by an input port is kept (deleting it would
/// leave the port as a disconnected pin that echoes injections), and
/// the removable set is shrunk to a fixpoint so no removed node feeds
/// a kept node and no kept node feeds a removed node through a
/// *named* arc (a named dangle would join the interface).
pub(super) fn dce(g: &Graph) -> Option<(Graph, PassDelta)> {
    let nn = g.n_nodes();
    let mut live = vec![false; nn];
    let mut stack: Vec<usize> = Vec::new();
    let mut any_named_out = false;
    for a in &g.arcs {
        if a.dst.is_none() && !is_anon_label(&a.name) {
            any_named_out = true;
            if let Some((n, _)) = a.src {
                if !live[n.0 as usize] {
                    live[n.0 as usize] = true;
                    stack.push(n.0 as usize);
                }
            }
        }
    }
    if !any_named_out {
        // An all-drain graph (no named outputs) is pure sink hardware;
        // there is nothing observable to preserve *or* remove safely.
        return None;
    }
    while let Some(ni) = stack.pop() {
        for &ia in &g.nodes[ni].ins {
            if let Some((p, _)) = g.arc(ia).src {
                if !live[p.0 as usize] {
                    live[p.0 as usize] = true;
                    stack.push(p.0 as usize);
                }
            }
        }
    }
    let mut kept = live;
    for n in &g.nodes {
        if n.ins.iter().any(|&a| g.arc(a).src.is_none()) {
            kept[n.id.0 as usize] = true;
        }
    }
    loop {
        let mut changed = false;
        for n in &g.nodes {
            let ni = n.id.0 as usize;
            if kept[ni] {
                continue;
            }
            let feeds_kept = n
                .outs
                .iter()
                .any(|&a| matches!(g.arc(a).dst, Some((d, _)) if kept[d.0 as usize]));
            let named_in_from_kept = n.ins.iter().any(|&a| {
                let arc = g.arc(a);
                !is_anon_label(&arc.name)
                    && matches!(arc.src, Some((s, _)) if kept[s.0 as usize])
            });
            if feeds_kept || named_in_from_kept {
                kept[ni] = true;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    let removed: Vec<usize> = (0..nn).filter(|&i| !kept[i]).collect();
    if removed.is_empty() {
        return None;
    }
    // Every out-arc of a removed node goes with it (its consumer is
    // removed too, or it was an anonymous dangle); in-arcs from kept
    // nodes survive as anonymous drain wires.
    let mut dead_arcs: Vec<usize> = Vec::new();
    for a in &g.arcs {
        if matches!(a.src, Some((s, _)) if !kept[s.0 as usize]) {
            dead_arcs.push(a.id.0 as usize);
        }
    }
    let mut ed = GraphEditor::new(g);
    for &ni in &removed {
        ed.delete_node(ni);
    }
    for &ai in &dead_arcs {
        ed.delete_arc(ai);
    }
    Some((
        ed.finish("dce"),
        PassDelta {
            applications: removed.len() as u64,
            nodes: -(removed.len() as i64),
            arcs: -(dead_arcs.len() as i64),
            ..PassDelta::default()
        },
    ))
}
