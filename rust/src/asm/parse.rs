//! Assembler parser.

use crate::dfg::{Arc, ArcId, Graph, Node, NodeId, Op};
use std::collections::HashMap;

#[derive(Debug)]
pub enum AsmError {
    UnknownOp {
        line: usize,
        op: String,
    },
    BadArity {
        line: usize,
        op: String,
        expected: usize,
        found: usize,
    },
    DoubleDriver {
        line: usize,
        label: String,
    },
    DoubleConsumer {
        line: usize,
        label: String,
    },
    MissingImmediate {
        line: usize,
        op: String,
    },
    BadImmediate {
        line: usize,
        imm: String,
    },
    MissingSemicolon {
        line: usize,
    },
    Empty {
        line: usize,
    },
    Invalid(crate::dfg::ValidateError),
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AsmError::UnknownOp { line, op } => {
                write!(f, "line {line}: unknown operator `{op}`")
            }
            AsmError::BadArity {
                line,
                op,
                expected,
                found,
            } => write!(
                f,
                "line {line}: `{op}` takes {expected} arguments, found {found}"
            ),
            AsmError::DoubleDriver { line, label } => {
                write!(f, "line {line}: arc `{label}` already has a driver")
            }
            AsmError::DoubleConsumer { line, label } => {
                write!(f, "line {line}: arc `{label}` already has a consumer")
            }
            AsmError::MissingImmediate { line, op } => write!(
                f,
                "line {line}: `{op}` requires an immediate first argument (e.g. `#42`)"
            ),
            AsmError::BadImmediate { line, imm } => {
                write!(f, "line {line}: bad immediate `{imm}`")
            }
            AsmError::MissingSemicolon { line } => {
                write!(f, "line {line}: statement missing terminating `;`")
            }
            AsmError::Empty { line } => write!(f, "line {line}: empty statement"),
            AsmError::Invalid(e) => write!(f, "graph validation failed: {e}"),
        }
    }
}

impl std::error::Error for AsmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AsmError::Invalid(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::dfg::ValidateError> for AsmError {
    fn from(e: crate::dfg::ValidateError) -> Self {
        AsmError::Invalid(e)
    }
}

/// Strip `# ...` and `// ...` comments.
fn strip_comment(line: &str) -> &str {
    let mut end = line.len();
    if let Some(i) = line.find('#') {
        // `#` inside an immediate like `#42` is preceded by a comma/space
        // and followed by a digit or `-`; a comment `#` is not. Disambiguate
        // by checking the next char.
        let rest = &line[i + 1..];
        if !rest.starts_with(|c: char| c.is_ascii_digit() || c == '-') {
            end = end.min(i);
        }
    }
    if let Some(i) = line.find("//") {
        end = end.min(i);
    }
    &line[..end]
}

/// Parse assembler `src` into a graph named `name`.
pub fn parse(name: &str, src: &str) -> Result<Graph, AsmError> {
    let mut g = Graph::new(name);
    let mut labels: HashMap<String, ArcId> = HashMap::new();

    let mut intern = |g: &mut Graph, label: &str| -> ArcId {
        if let Some(&a) = labels.get(label) {
            return a;
        }
        let id = ArcId(g.arcs.len() as u32);
        g.arcs.push(Arc {
            id,
            src: None,
            dst: None,
            name: label.to_string(),
        });
        labels.insert(label.to_string(), id);
        id
    };

    // Statements are `;`-terminated and may span lines; split on `;` but
    // report errors with the 1-based line of the statement start.
    let clean: String = src
        .lines()
        .map(strip_comment)
        .collect::<Vec<_>>()
        .join("\n");
    let mut offset = 0usize;
    let chunks: Vec<&str> = clean.split(';').collect();
    let n_chunks = chunks.len();
    for (ci, raw_stmt) in chunks.into_iter().enumerate() {
        let lead_ws = raw_stmt.len() - raw_stmt.trim_start().len();
        let stmt_start = offset + lead_ws;
        let stmt_line = clean[..stmt_start.min(clean.len())]
            .chars()
            .filter(|&c| c == '\n')
            .count()
            + 1;
        offset += raw_stmt.len() + 1; // +1 for the consumed `;`
        let stmt = raw_stmt.trim();
        if stmt.is_empty() {
            continue;
        }
        // Everything after the final `;` must be whitespace — a trailing
        // statement with no terminator is an error, not a statement.
        if ci == n_chunks - 1 {
            return Err(AsmError::MissingSemicolon { line: stmt_line });
        }
        // Optional leading `N.` line number.
        let stmt = match stmt.split_once('.') {
            Some((n, rest)) if !n.trim().is_empty() && n.trim().chars().all(|c| c.is_ascii_digit()) => {
                rest.trim()
            }
            _ => stmt,
        };
        if stmt.is_empty() {
            // A numbered statement with no body, e.g. `3. ;`.
            return Err(AsmError::Empty { line: stmt_line });
        }
        let (mnem, args_str) = match stmt.split_once(char::is_whitespace) {
            Some((m, a)) => (m.trim(), a.trim()),
            None => (stmt, ""),
        };
        let mut args: Vec<&str> = args_str
            .split(',')
            .map(|a| a.trim())
            .filter(|a| !a.is_empty())
            .collect();

        // Parameterized substrate ops: immediate first argument.
        let op = if mnem == "const" || mnem == "fifo" {
            let imm_str = args
                .first()
                .filter(|a| a.starts_with('#'))
                .ok_or(AsmError::MissingImmediate {
                    line: stmt_line,
                    op: mnem.to_string(),
                })?
                .to_string();
            args.remove(0);
            let imm: i32 = imm_str[1..]
                .parse()
                .map_err(|_| AsmError::BadImmediate {
                    line: stmt_line,
                    imm: imm_str.clone(),
                })?;
            let bad = AsmError::BadImmediate {
                line: stmt_line,
                imm: imm_str.clone(),
            };
            if mnem == "const" {
                // Must fit the 16-bit data bus.
                Op::Const(i16::try_from(imm).map_err(|_| bad)?)
            } else {
                // A FIFO must hold at least one token and no more than
                // the physical slot provisioning allows.
                match u16::try_from(imm) {
                    Ok(k) if (1..=crate::dfg::MAX_FIFO_DEPTH).contains(&k) => Op::Fifo(k),
                    _ => return Err(bad),
                }
            }
        } else {
            Op::from_mnemonic(mnem).ok_or(AsmError::UnknownOp {
                line: stmt_line,
                op: mnem.to_string(),
            })?
        };

        let (n_in, n_out) = (op.n_in(), op.n_out());
        if args.len() != n_in + n_out {
            return Err(AsmError::BadArity {
                line: stmt_line,
                op: mnem.to_string(),
                expected: n_in + n_out,
                found: args.len(),
            });
        }

        let nid = NodeId(g.nodes.len() as u32);
        let mut ins = Vec::with_capacity(n_in);
        let mut outs = Vec::with_capacity(n_out);
        for (i, &label) in args.iter().enumerate() {
            let a = intern(&mut g, label);
            if i < n_in {
                if g.arcs[a.0 as usize].dst.is_some() {
                    return Err(AsmError::DoubleConsumer {
                        line: stmt_line,
                        label: label.to_string(),
                    });
                }
                g.arcs[a.0 as usize].dst = Some((nid, i as u8));
                ins.push(a);
            } else {
                if g.arcs[a.0 as usize].src.is_some() {
                    return Err(AsmError::DoubleDriver {
                        line: stmt_line,
                        label: label.to_string(),
                    });
                }
                g.arcs[a.0 as usize].src = Some((nid, (i - n_in) as u8));
                outs.push(a);
            }
        }
        g.nodes.push(Node {
            id: nid,
            op,
            ins,
            outs,
        });
    }

    crate::dfg::validate(&g)?;
    Ok(g)
}
