//! Assembler printer — the inverse of [`super::parse`].

use crate::dfg::{Graph, Op};
use std::fmt::Write;

/// Render a graph in Listing-1 syntax (numbered statements, inputs first
/// then outputs). `parse(print(g))` reproduces the graph up to arc-id
/// renumbering, and `print` is a fixpoint over that round trip.
pub fn print(g: &Graph) -> String {
    let mut out = String::new();
    for (i, n) in g.nodes.iter().enumerate() {
        let mut args: Vec<&str> = Vec::with_capacity(n.ins.len() + n.outs.len());
        for &a in n.ins.iter().chain(n.outs.iter()) {
            args.push(&g.arc(a).name);
        }
        let imm = match n.op {
            Op::Const(v) => format!("#{v}, "),
            Op::Fifo(k) => format!("#{k}, "),
            _ => String::new(),
        };
        writeln!(
            out,
            "{}. {} {}{};",
            i + 1,
            n.op.mnemonic(),
            imm,
            args.join(", ")
        )
        .unwrap();
    }
    out
}
