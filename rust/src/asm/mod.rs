//! The paper's dataflow assembler language (Listing 1).
//!
//! One statement per operator:
//!
//! ```text
//! 1. ndmerge s7, dadob, s1;
//! 4. gtdecider dadoa, s4, s5;
//! 7. branch s9, s8, s10, pf;
//! ```
//!
//! Arguments are arc labels, **inputs first, then outputs** in operator
//! port order (the convention Listing 1 follows: `copy s3, s4, s9` reads
//! `s3` and drives `s4`, `s9`). Optional leading `N.` line numbers and
//! `#`/`//` comments are accepted. The parameterized substrate operators
//! take an immediate first argument: `const #42, z;` and `fifo #8, a, z;`.
//!
//! An arc label that no statement *drives* is an input port; one that no
//! statement *consumes* is an output port — exactly how the paper's
//! `dadoa..dadoj` / `fibo` / `pf` signals work.

mod parse;
mod print;

pub use parse::{parse, AsmError};
pub use print::print;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{GraphBuilder, Op};
    use crate::sim::{run_token, SimConfig};

    #[test]
    fn parses_simple_adder() {
        let g = parse("adder", "add a, b, z;").unwrap();
        assert_eq!(g.n_nodes(), 1);
        assert_eq!(g.input_ports().len(), 2);
        assert_eq!(g.output_ports().len(), 1);
    }

    #[test]
    fn accepts_line_numbers_and_comments() {
        let src = "
            # a two-node graph
            1. copy a, s1, s2;   // duplicate
            2. add s1, s2, z;
        ";
        let g = parse("t", src).unwrap();
        assert_eq!(g.n_nodes(), 2);
        let cfg = SimConfig::new().inject("a", vec![4]);
        assert_eq!(run_token(&g, &cfg).stream("z"), &[8]);
    }

    #[test]
    fn const_and_fifo_take_immediates() {
        let g = parse("t", "const #21, s1; add s1, a, z;").unwrap();
        let cfg = SimConfig::new().inject("a", vec![21]);
        assert_eq!(run_token(&g, &cfg).stream("z"), &[42]);
        let g = parse("t", "fifo #4, a, z;").unwrap();
        let cfg = SimConfig::new().inject("a", vec![1, 2, 3]);
        assert_eq!(run_token(&g, &cfg).stream("z"), &[1, 2, 3]);
    }

    #[test]
    fn rejects_unknown_mnemonic() {
        assert!(matches!(
            parse("t", "frobnicate a, b, z;"),
            Err(AsmError::UnknownOp { .. })
        ));
    }

    #[test]
    fn rejects_bad_arity() {
        assert!(matches!(
            parse("t", "add a, z;"),
            Err(AsmError::BadArity { .. })
        ));
    }

    #[test]
    fn rejects_double_driver() {
        assert!(matches!(
            parse("t", "copy a, s1, s2; copy b, s1, s3;"),
            Err(AsmError::DoubleDriver { .. })
        ));
    }

    #[test]
    fn rejects_missing_immediate() {
        assert!(matches!(
            parse("t", "const s1;"),
            Err(AsmError::MissingImmediate { .. })
        ));
    }

    #[test]
    fn rejects_bad_immediate() {
        assert!(matches!(
            parse("t", "const #x2, s1; add s1, a, z;"),
            Err(AsmError::BadImmediate { .. })
        ));
        assert!(matches!(
            parse("t", "fifo #99999999, a, z;"),
            Err(AsmError::BadImmediate { .. })
        ));
    }

    #[test]
    fn rejects_double_consumer() {
        assert!(matches!(
            parse("t", "not a, s1; not a, s2;"),
            Err(AsmError::DoubleConsumer { .. })
        ));
    }

    #[test]
    fn rejects_missing_semicolon() {
        let err = parse("t", "add a, b, z;\nnot z2, q").unwrap_err();
        match err {
            AsmError::MissingSemicolon { line } => assert_eq!(line, 2),
            other => panic!("wrong error: {other:?}"),
        }
    }

    #[test]
    fn rejects_empty_numbered_statement() {
        assert!(matches!(
            parse("t", "1. ;\n2. add a, b, z;"),
            Err(AsmError::Empty { .. })
        ));
    }

    #[test]
    fn error_messages_carry_line_numbers() {
        let err = parse("t", "add a, b, z;\nfrobnicate c, d, e;").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let err = parse("t", "copy a, s1, s2;\ncopy b, s1, s3;").unwrap_err();
        assert!(err.to_string().contains("s1"), "{err}");
    }

    #[test]
    fn print_parse_fixpoint() {
        let mut b = GraphBuilder::new("fix");
        let a = b.input_port("a");
        let (x, y) = b.copy(a);
        let k = b.constant(3);
        let s = b.op2(Op::Add, x, k);
        let m = b.op2(Op::Mul, s, y);
        let z = b.output_port("z");
        b.node(Op::Not, &[m], &[z]);
        let g = b.finish().unwrap();
        let text = print(&g);
        let g2 = parse("fix", &text).unwrap();
        assert_eq!(print(&g2), text, "print∘parse must be a fixpoint");
        // And semantics must survive the round trip.
        let cfg = SimConfig::new().inject("a", vec![5]);
        assert_eq!(
            run_token(&g, &cfg).outputs,
            run_token(&g2, &cfg).outputs
        );
    }

    #[test]
    fn print_parse_fixpoint_on_optimized_graphs() {
        // The optimizer's output is ordinary assembler: it prints,
        // re-parses to the same shape, and re-optimizing the re-parsed
        // graph changes nothing (the conformance harness extends this
        // to every benchmark and level).
        let g = crate::frontend::compile_with(
            "dot_prod",
            crate::bench_defs::c_source(crate::bench_defs::BenchId::DotProd),
            crate::opt::OptLevel::None,
        )
        .unwrap();
        let (og, _) = crate::opt::optimize(&g, crate::opt::OptLevel::Default);
        let text = print(&og);
        let g2 = parse("dot_prod", &text).unwrap();
        assert_eq!(g2.n_nodes(), og.n_nodes());
        assert_eq!(print(&g2), text);
        let (g3, report) = crate::opt::optimize(&g2, crate::opt::OptLevel::Default);
        assert!(!report.changed(), "re-optimize must be a fixed point");
        assert_eq!(print(&g3), text);
    }

    /// Listing 1 from the paper, verbatim (including its duplicated line
    /// 12/13 pair, which we reject as a double-driver — the listing has a
    /// typo; see bench_defs::fibonacci for the corrected graph).
    #[test]
    fn paper_listing1_structure() {
        let listing1_fixed = "
            1. ndmerge s7, dadob, s1;
            2. dmerge s2, dadoc, s1, s3;
            3. ndmerge dadod, s11, s2;
            4. gtdecider dadoa, s4, s5;
            5. copy s3, s4, s9;
            6. copy s5, s6, s8;
            7. branch s9, s8, s10, pf;
            8. copy s6, s7, s12;
            9. add s10, dadoe, s11;
        ";
        // The loop-control half of Listing 1 parses and is well-formed.
        let g = parse("fib_ctl", listing1_fixed).unwrap();
        assert_eq!(g.n_nodes(), 9);
        assert!(g.arc_by_name("pf").is_some());
        // `s12` never gets a consumer → it is an (unused) output port.
        assert!(g.output_ports().len() >= 2);
    }
}
