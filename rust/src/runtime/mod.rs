//! PJRT runtime — loads and executes the AOT-compiled fabric kernels.
//!
//! `make artifacts` (build time, Python) lowers the Layer-2 `fabric_step`
//! to HLO **text** per `(batch, nodes)` shape and writes
//! `artifacts/manifest.txt`. At run time this module:
//!
//! 1. creates one `PjRtClient` (CPU in this environment),
//! 2. parses each HLO text file (`HloModuleProto::from_text_file` — text,
//!    not serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that
//!    xla_extension 0.5.1 rejects),
//! 3. compiles one executable per artifact shape,
//! 4. serves `step` calls from the coordinator's hot path.
//!
//! Python never runs on this path; the Rust binary is self-contained once
//! `artifacts/` exists.
//!
//! The `xla` bindings crate (xla_extension) is not vendored in this build
//! environment, so the PJRT-backed implementation is gated behind the
//! off-by-default `xla` cargo feature. Without it [`FabricRuntime`] is a
//! stub whose `load` always fails. Workers holding a runtime fall back
//! to the native ALU engine per batch when a call fails, but explicitly
//! requesting `Engine::Xla` is a *startup* error by design
//! (`Coordinator::start` validates the artifact load up front), so
//! `sweep --engine xla` reports the stub's message and exits rather than
//! silently serving native results.

/// One fabric tick's worth of dense operator state (see
/// `python/compile/model.py::fabric_step`).
#[derive(Debug, Clone)]
pub struct FabricBatch {
    pub batch: usize,
    pub nodes: usize,
    /// `i32[nodes]` per-node opcode.
    pub opcode: Vec<i32>,
    /// `i32[batch * nodes]`, row-major.
    pub a: Vec<i32>,
    pub b: Vec<i32>,
    pub fire: Vec<i32>,
}

impl FabricBatch {
    pub fn zeroed(batch: usize, nodes: usize) -> Self {
        FabricBatch {
            batch,
            nodes,
            opcode: vec![0; nodes],
            a: vec![0; batch * nodes],
            b: vec![0; batch * nodes],
            fire: vec![0; batch * nodes],
        }
    }

    #[inline]
    pub fn slot(&self, instance: usize, node: usize) -> usize {
        instance * self.nodes + node
    }
}

// The real PJRT path references the external `xla` crate, which is not
// vendored here; fail the build with an instructive message instead of
// E0433 if someone enables the feature without supplying it. Remove this
// guard once an `xla` dependency is added to Cargo.toml.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` cargo feature requires the external `xla` (xla_extension) bindings crate, \
     which is not vendored in this offline build environment; add it to rust/Cargo.toml \
     and delete this compile_error! before enabling the feature"
);

#[cfg(feature = "xla")]
mod pjrt {
    use super::FabricBatch;
    use anyhow::{anyhow, bail, Context, Result};
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};

    /// A compiled fabric executable for one artifact shape.
    struct Exe {
        exe: xla::PjRtLoadedExecutable,
        batch: usize,
        nodes: usize,
    }

    /// The artifact registry + PJRT client.
    pub struct FabricRuntime {
        _client: xla::PjRtClient,
        exes: BTreeMap<(usize, usize), Exe>,
    }

    impl FabricRuntime {
        /// Load every artifact listed in `<dir>/manifest.txt`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref();
            let manifest = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {manifest:?}; run `make artifacts` first"))?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
            let mut exes = BTreeMap::new();
            for line in text.lines() {
                let mut parts = line.split_whitespace();
                let (Some(b), Some(n), Some(file)) = (parts.next(), parts.next(), parts.next())
                else {
                    bail!("malformed manifest line: `{line}`");
                };
                let batch: usize = b.parse()?;
                let nodes: usize = n.parse()?;
                let path: PathBuf = dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
                exes.insert((batch, nodes), Exe { exe, batch, nodes });
            }
            if exes.is_empty() {
                bail!("no artifacts in {manifest:?}");
            }
            Ok(FabricRuntime {
                _client: client,
                exes,
            })
        }

        /// Artifact shapes available, sorted.
        pub fn shapes(&self) -> Vec<(usize, usize)> {
            self.exes.keys().copied().collect()
        }

        /// Smallest artifact that fits `batch` instances of `nodes` nodes.
        pub fn fit(&self, batch: usize, nodes: usize) -> Option<(usize, usize)> {
            self.exes
                .keys()
                .copied()
                .filter(|&(b, n)| b >= batch && n >= nodes)
                .min_by_key(|&(b, n)| b * n)
        }

        /// Execute one fabric tick. The batch must exactly match an artifact
        /// shape (use [`FabricRuntime::fit`] + [`FabricBatch::zeroed`] padding).
        pub fn step(&self, fb: &FabricBatch) -> Result<Vec<i32>> {
            let exe = self
                .exes
                .get(&(fb.batch, fb.nodes))
                .ok_or_else(|| anyhow!("no artifact for shape {}x{}", fb.batch, fb.nodes))?;
            let dims = [exe.batch as i64, exe.nodes as i64];
            let op = xla::Literal::vec1(&fb.opcode);
            let a = xla::Literal::vec1(&fb.a)
                .reshape(&dims)
                .map_err(|e| anyhow!("{e:?}"))?;
            let b = xla::Literal::vec1(&fb.b)
                .reshape(&dims)
                .map_err(|e| anyhow!("{e:?}"))?;
            let fire = xla::Literal::vec1(&fb.fire)
                .reshape(&dims)
                .map_err(|e| anyhow!("{e:?}"))?;
            let result = exe
                .exe
                .execute::<xla::Literal>(&[op, a, b, fire])
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{e:?}"))?;
            // aot.py lowers with return_tuple=True → a 1-tuple.
            let out = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
            out.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))
        }
    }
}

#[cfg(not(feature = "xla"))]
mod pjrt {
    use super::FabricBatch;
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Stub runtime: the crate was built without the `xla` feature, so no
    /// PJRT client exists. `load` always fails and callers fall back to
    /// the native ALU engine.
    pub struct FabricRuntime {
        _unconstructable: std::convert::Infallible,
    }

    impl FabricRuntime {
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            bail!(
                "PJRT fabric runtime unavailable: built without the `xla` feature \
                 (artifact dir {:?} ignored)",
                dir.as_ref()
            );
        }

        pub fn shapes(&self) -> Vec<(usize, usize)> {
            Vec::new()
        }

        pub fn fit(&self, _batch: usize, _nodes: usize) -> Option<(usize, usize)> {
            None
        }

        pub fn step(&self, _fb: &FabricBatch) -> Result<Vec<i32>> {
            bail!("PJRT fabric runtime unavailable: built without the `xla` feature");
        }
    }
}

pub use pjrt::FabricRuntime;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::Op;

    fn runtime() -> Option<FabricRuntime> {
        // Tests are skipped gracefully when artifacts are not built.
        FabricRuntime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).ok()
    }

    #[test]
    fn loads_manifest_and_fits_shapes() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(!rt.shapes().is_empty());
        let (b, n) = rt.fit(4, 100).expect("a shape fits 4x100");
        assert!(b >= 4 && n >= 100);
    }

    #[test]
    fn xla_alu_matches_rust_eval2_exhaustively_per_opcode() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (bsz, nodes) = rt.fit(8, 128).unwrap();
        let ops = [
            Op::Add,
            Op::Sub,
            Op::Mul,
            Op::Div,
            Op::And,
            Op::Or,
            Op::Xor,
            Op::Shl,
            Op::Shr,
            Op::IfGt,
            Op::IfGe,
            Op::IfLt,
            Op::IfLe,
            Op::IfEq,
            Op::IfDf,
        ];
        let mut rng = crate::util::Rng::new(99);
        let mut fb = FabricBatch::zeroed(bsz, nodes);
        let mut want = vec![0i32; bsz * nodes];
        for i in 0..bsz {
            for n in 0..nodes {
                let op = ops[rng.below(ops.len())];
                let a = rng.word(-32768, 32768);
                let b = rng.word(-32768, 32768);
                let s = fb.slot(i, n);
                fb.opcode[n] = op.fabric_opcode(); // overwritten per row; see below
                fb.a[s] = a as i32;
                fb.b[s] = b as i32;
                fb.fire[s] = 1;
            }
        }
        // opcode is per-node (shared across batch): recompute expectations
        // against the final opcode row.
        for i in 0..bsz {
            for n in 0..nodes {
                let s = fb.slot(i, n);
                let op = ops
                    .iter()
                    .copied()
                    .find(|o| o.fabric_opcode() == fb.opcode[n])
                    .unwrap();
                want[s] = op.eval2(fb.a[s] as i16, fb.b[s] as i16) as i32;
            }
        }
        let got = rt.step(&fb).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn fire_mask_is_respected() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (bsz, nodes) = rt.fit(8, 128).unwrap();
        let mut fb = FabricBatch::zeroed(bsz, nodes);
        for n in 0..nodes {
            fb.opcode[n] = Op::Add.fabric_opcode();
        }
        let s = fb.slot(0, 0);
        fb.a[s] = 20;
        fb.b[s] = 22;
        fb.fire[s] = 1;
        let got = rt.step(&fb).unwrap();
        assert_eq!(got[s], 42);
        assert!(got.iter().enumerate().all(|(i, &v)| i == s || v == 0));
    }
}
