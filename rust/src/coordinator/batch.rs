//! Lockstep batch simulation of B graph instances.
//!
//! Every tick: each instance's `TokenSim` runs offload phase 1 (fires
//! structural operators locally, extracts ALU firings), the extracted
//! requests are packed into one dense `(B, N)` fabric batch, evaluated in
//! a single PJRT call, and scattered back. Instances finish independently
//! (the fire mask simply goes quiet for drained instances).

use crate::dfg::Graph;
use crate::fabric::{self, FabricTopology, PartitionPlan};
use crate::par::Executor;
use crate::runtime::{FabricBatch, FabricRuntime};
use crate::sim::{
    run_token, AluReq, LaneSim, Program, SimConfig, SimOutcome, TokenSim, WaveInput, MAX_LANES,
};
use anyhow::{bail, Result};

/// How a batch evaluates its operator ALUs.
pub enum BatchEngine<'rt> {
    /// In-process Rust ALU (baseline; used for differential testing).
    Native,
    /// One PJRT fabric-kernel call per tick for the whole batch.
    Xla(&'rt FabricRuntime),
}

/// Run `cfgs.len()` instances of `g` in lockstep.
pub fn run_batch(g: &Graph, cfgs: &[SimConfig], engine: &BatchEngine) -> Result<Vec<SimOutcome>> {
    let b = cfgs.len();
    if b == 0 {
        return Ok(Vec::new());
    }
    let n_nodes = g.n_nodes();

    // Pick + pad the artifact shape for the XLA path.
    let (mut fb, opcode_ready) = match engine {
        BatchEngine::Xla(rt) => {
            let Some((ab, an)) = rt.fit(b, n_nodes) else {
                bail!(
                    "no fabric artifact fits batch {b} × nodes {n_nodes} \
                     (available: {:?})",
                    rt.shapes()
                );
            };
            let mut fb = FabricBatch::zeroed(ab, an);
            for (ni, node) in g.nodes.iter().enumerate() {
                fb.opcode[ni] = node.op.fabric_opcode();
            }
            (Some(fb), true)
        }
        BatchEngine::Native => (None, false),
    };
    let _ = opcode_ready;

    let mut sims: Vec<TokenSim> = cfgs.iter().map(|c| TokenSim::new(g, c)).collect();
    let mut reqs: Vec<Vec<AluReq>> = vec![Vec::new(); b];
    let mut zbuf: Vec<Vec<i32>> = vec![Vec::new(); b];
    let max_cycles = cfgs.iter().map(|c| c.max_cycles).max().unwrap();

    let mut cycles = 0u64;
    let mut idle_rounds = 0u32;
    while cycles < max_cycles {
        let mut fired = 0u64;
        let mut total_reqs = 0usize;
        for (i, sim) in sims.iter_mut().enumerate() {
            reqs[i].clear();
            fired += sim.step_offload(&mut reqs[i]);
            total_reqs += reqs[i].len();
        }
        if total_reqs > 0 {
            match engine {
                BatchEngine::Native => {
                    for (i, sim) in sims.iter_mut().enumerate() {
                        if reqs[i].is_empty() {
                            continue;
                        }
                        zbuf[i].clear();
                        zbuf[i].extend(reqs[i].iter().map(|r| {
                            if r.opcode == crate::dfg::Op::Not.fabric_opcode() {
                                (!r.a) as i32
                            } else {
                                op_from_code(r.opcode).eval2(r.a, r.b) as i32
                            }
                        }));
                        sim.apply_alu(&reqs[i], &zbuf[i]);
                    }
                }
                BatchEngine::Xla(rt) => {
                    let fb = fb.as_mut().unwrap();
                    fb.a.fill(0);
                    fb.b.fill(0);
                    fb.fire.fill(0);
                    for (i, rs) in reqs.iter().enumerate() {
                        for r in rs {
                            let s = fb.slot(i, r.node as usize);
                            fb.a[s] = r.a as i32;
                            fb.b[s] = r.b as i32;
                            fb.fire[s] = 1;
                        }
                    }
                    let z = rt.step(fb)?;
                    for (i, (sim, rs)) in sims.iter_mut().zip(&reqs).enumerate() {
                        if rs.is_empty() {
                            continue;
                        }
                        zbuf[i].clear();
                        zbuf[i].extend(rs.iter().map(|r| z[i * fb.nodes + r.node as usize]));
                        sim.apply_alu(rs, &zbuf[i]);
                    }
                }
            }
        }
        cycles += 1;
        if fired == 0 && total_reqs == 0 {
            idle_rounds += 1;
            // Two idle rounds: one to drain output ports, one to confirm.
            if idle_rounds >= 2 {
                break;
            }
        } else {
            idle_rounds = 0;
        }
    }

    Ok(sims
        .into_iter()
        .map(|s| {
            let quiescent = s.idle();
            s.into_outcome(cycles, quiescent)
        })
        .collect())
}

fn op_from_code(code: i32) -> crate::dfg::Op {
    use crate::dfg::Op;
    match code {
        0 => Op::Add,
        1 => Op::Sub,
        2 => Op::Mul,
        3 => Op::Div,
        4 => Op::And,
        5 => Op::Or,
        6 => Op::Xor,
        7 => Op::Shl,
        8 => Op::Shr,
        9 => Op::IfGt,
        10 => Op::IfGe,
        11 => Op::IfLt,
        12 => Op::IfLe,
        13 => Op::IfEq,
        14 => Op::IfDf,
        other => panic!("not a 2-input fabric opcode: {other}"),
    }
}

/// Convenience: batch with the native ALU.
pub fn run_batch_native(g: &Graph, cfgs: &[SimConfig]) -> Vec<SimOutcome> {
    run_batch(g, cfgs, &BatchEngine::Native).expect("native engine is infallible")
}

/// Accounting for one lane-routed batch (see [`run_batch_lanes`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LaneBatchStats {
    /// Lane chunks executed (`ceil(batch / MAX_LANES)`).
    pub chunks: usize,
    /// Items re-run on the scalar engine because their lane did not
    /// quiesce — the lanes→placed fallback.
    pub scalar_reruns: usize,
}

/// The lane-vectorized batch path: compile `g` once, then run the batch
/// in [`MAX_LANES`]-wide chunks through [`LaneSim`] (multi-word
/// occupancy masks: 256 items per chunk) — one pass over the compiled
/// node table advances every item at once, instead of one interpreter
/// walk per item (`run_batch_native`).
///
/// Conformance contract: per-item output streams are byte-identical to
/// `run_batch_native` / single-instance `TokenSim` (scoped, as for the
/// sharded executor, to graphs whose `ndmerge` arbitration is
/// uncontended — the loop-schema invariant every benchmark holds; see
/// `sim::lanes` module docs). Lane execution guarantees this at
/// fixpoint; an item whose lane does NOT quiesce (its own deadlock, or
/// a chunk-shared round budget cut short by a smaller per-item
/// `max_cycles`) is transparently re-run on the scalar engine under
/// its own config — the lanes→placed fallback the router's metrics
/// expose.
pub fn run_batch_lanes(g: &Graph, cfgs: &[SimConfig]) -> Vec<SimOutcome> {
    run_batch_lanes_with_stats(g, cfgs).0
}

/// [`run_batch_lanes`], returning the chunk/fallback accounting.
pub fn run_batch_lanes_with_stats(
    g: &Graph,
    cfgs: &[SimConfig],
) -> (Vec<SimOutcome>, LaneBatchStats) {
    let prog = Program::compile(g);
    run_batch_lanes_prog(g, &prog, cfgs)
}

/// [`run_batch_lanes_with_stats`] with a pre-compiled program — the
/// session-cache hot path: the serving tier and the router compile a
/// graph once per fingerprint ([`crate::serve::SessionCache`]) and
/// reuse the program for every subsequent batch, so only the cache
/// miss pays `Program::compile`. `prog` must be compiled from `g`
/// (the scalar rerun fallback runs `g` itself).
pub fn run_batch_lanes_prog(
    g: &Graph,
    prog: &Program,
    cfgs: &[SimConfig],
) -> (Vec<SimOutcome>, LaneBatchStats) {
    if cfgs.is_empty() {
        return (Vec::new(), LaneBatchStats::default());
    }
    let mut stats = LaneBatchStats::default();
    let mut outcomes = Vec::with_capacity(cfgs.len());
    for chunk in cfgs.chunks(MAX_LANES) {
        stats.chunks += 1;
        let mut sim = LaneSim::new(prog, chunk);
        sim.run();
        for (cfg, out) in chunk.iter().zip(sim.into_outcomes()) {
            if out.quiescent {
                outcomes.push(out);
            } else {
                stats.scalar_reruns += 1;
                outcomes.push(run_token(g, cfg));
            }
        }
    }
    (outcomes, stats)
}

/// The streaming batch path: instead of B lockstep run-to-completion
/// instances, pipeline the whole batch as successive waves through ONE
/// resident [`crate::sim::StreamSession`]. Overlap-safe graphs admit
/// wave k+1 while wave k is still draining (the Fig. 1c behaviour);
/// loop-schema graphs run serialized over the resident session. Output
/// streams per wave are byte-identical to `run_batch_native` /
/// single-instance `TokenSim` (the conformance harness enforces this).
pub fn run_batch_streamed(g: &Graph, cfgs: &[SimConfig]) -> Vec<SimOutcome> {
    if cfgs.is_empty() {
        return Vec::new();
    }
    let waves: Vec<crate::sim::WaveInput> = cfgs.iter().map(|c| c.inject.clone()).collect();
    // Budget: the whole batch shares one round counter, so the session
    // gets the sum of the per-item budgets.
    let budget: u64 = cfgs.iter().map(|c| c.max_cycles).sum();
    let (outcomes, _metrics) = crate::sim::run_stream(g, &waves, budget);
    outcomes
}

/// Serve a same-graph batch through the sharded executor — one route
/// arm of the placed → sharded → reconfig → fallback lattice, shared
/// by the router and the service tier so the wave-vs-isolated policy
/// lives in exactly one place. With `waves_resident` the batch streams
/// as successive waves through one resident shard rack
/// ([`fabric::run_sharded_waves`]); otherwise each item runs isolated.
pub fn run_batch_sharded(
    plan: &PartitionPlan,
    cfgs: &[SimConfig],
    waves_resident: bool,
) -> Vec<SimOutcome> {
    if waves_resident && !cfgs.is_empty() {
        let waves: Vec<WaveInput> = cfgs.iter().map(|c| c.inject.clone()).collect();
        let budget = cfgs.iter().map(|c| c.max_cycles).max().unwrap();
        fabric::run_sharded_waves(plan, &waves, budget)
    } else {
        cfgs.iter().map(|c| fabric::run_sharded(plan, c)).collect()
    }
}

/// The reconfiguration (time-multiplexed single instance) analogue of
/// [`run_batch_sharded`].
pub fn run_batch_reconfig(
    plan: &PartitionPlan,
    topo: &FabricTopology,
    cfgs: &[SimConfig],
    waves_resident: bool,
) -> Vec<SimOutcome> {
    if waves_resident && !cfgs.is_empty() {
        let waves: Vec<WaveInput> = cfgs.iter().map(|c| c.inject.clone()).collect();
        let budget = cfgs.iter().map(|c| c.max_cycles).max().unwrap();
        fabric::run_reconfig_waves(plan, topo, &waves, budget).0
    } else {
        cfgs.iter()
            .map(|c| fabric::run_reconfig(plan, topo, c).0)
            .collect()
    }
}

/// Parallel [`run_batch_lanes_prog`]: the batch's fixed
/// [`MAX_LANES`]-wide chunks are mapped across the executor's workers,
/// so each worker advances 256 items per node-table pass. Chunk
/// boundaries depend only on the batch length — never on the worker
/// count — and chunks share no state (each gets its own [`LaneSim`];
/// scalar reruns happen inside the owning task), so the result is
/// byte-identical to the serial path at every worker count. With one
/// worker this *is* the serial path.
pub fn run_batch_lanes_par(
    g: &Graph,
    prog: &Program,
    cfgs: &[SimConfig],
    exec: &Executor,
) -> (Vec<SimOutcome>, LaneBatchStats) {
    if exec.workers() <= 1 || cfgs.len() <= MAX_LANES {
        return run_batch_lanes_prog(g, prog, cfgs);
    }
    let chunks: Vec<&[SimConfig]> = cfgs.chunks(MAX_LANES).collect();
    let per_chunk = exec.map(chunks.len(), |i| {
        let chunk = chunks[i];
        let mut sim = LaneSim::new(prog, chunk);
        sim.run();
        let mut outs = Vec::with_capacity(chunk.len());
        let mut reruns = 0usize;
        for (cfg, out) in chunk.iter().zip(sim.into_outcomes()) {
            if out.quiescent {
                outs.push(out);
            } else {
                reruns += 1;
                outs.push(run_token(g, cfg));
            }
        }
        (outs, reruns)
    });
    let mut stats = LaneBatchStats {
        chunks: chunks.len(),
        scalar_reruns: 0,
    };
    let mut outcomes = Vec::with_capacity(cfgs.len());
    for (outs, reruns) in per_chunk {
        stats.scalar_reruns += reruns;
        outcomes.extend(outs);
    }
    (outcomes, stats)
}

/// Parallel [`run_batch_sharded`]. Isolated items are independent by
/// construction and map one-per-task. Resident waves split into
/// contiguous per-worker spans ([`crate::par::split_ranges`]), each
/// span streaming through its own shard rack: `run_sharded_waves`
/// purges and re-arms every shard between waves, so a rack starting at
/// wave k is in exactly the state the serial rack reaches after wave
/// k-1 — outcomes (including the `done - started` cycle counts, which
/// restart per wave) are byte-identical to the serial rack. Each span
/// keeps the same max-budget the serial path would use.
pub fn run_batch_sharded_par(
    plan: &PartitionPlan,
    cfgs: &[SimConfig],
    waves_resident: bool,
    exec: &Executor,
) -> Vec<SimOutcome> {
    if exec.workers() <= 1 || cfgs.len() <= 1 {
        return run_batch_sharded(plan, cfgs, waves_resident);
    }
    if waves_resident {
        let waves: Vec<WaveInput> = cfgs.iter().map(|c| c.inject.clone()).collect();
        let budget = cfgs.iter().map(|c| c.max_cycles).max().unwrap();
        let spans = crate::par::split_ranges(waves.len(), exec.workers());
        let per_span = exec.map(spans.len(), |i| {
            fabric::run_sharded_waves(plan, &waves[spans[i].clone()], budget)
        });
        per_span.into_iter().flatten().collect()
    } else {
        exec.map(cfgs.len(), |i| fabric::run_sharded(plan, &cfgs[i]))
    }
}

/// Parallel serialized-stream batch: the wave list splits into
/// contiguous per-worker spans, each streaming through its own
/// serialized [`crate::sim::StreamSession`]. Serialized admission
/// fully drains and resets the session between waves (tokens, FIFOs,
/// gating — see `sim::stream`), so wave k's outcome is independent of
/// which session ran waves 0..k, and the concatenated spans are
/// byte-identical to one serial session at every worker count. Each
/// span's session gets the sum of its own items' budgets — the same
/// per-wave headroom the serial whole-batch sum provides.
///
/// Pipelined (overlap-safe) batches are *not* split: overlapping waves
/// inside one fabric is the whole point of that mode, and a wave's
/// latency there depends on its neighbours. Callers wanting overlap
/// keep using [`run_batch_streamed`] serially.
pub fn run_batch_sstream_par(g: &Graph, cfgs: &[SimConfig], exec: &Executor) -> Vec<SimOutcome> {
    if cfgs.is_empty() {
        return Vec::new();
    }
    let waves: Vec<WaveInput> = cfgs.iter().map(|c| c.inject.clone()).collect();
    if exec.workers() <= 1 || cfgs.len() <= 1 {
        let budget: u64 = cfgs.iter().map(|c| c.max_cycles).sum();
        return crate::sim::run_stream_session(g, &waves, budget, crate::sim::WaveMode::Serialized)
            .0;
    }
    let spans = crate::par::split_ranges(waves.len(), exec.workers());
    let per_span = exec.map(spans.len(), |i| {
        let span = spans[i].clone();
        let budget: u64 = cfgs[span.clone()].iter().map(|c| c.max_cycles).sum();
        crate::sim::run_stream_session(g, &waves[span], budget, crate::sim::WaveMode::Serialized).0
    });
    per_span.into_iter().flatten().collect()
}

/// Convenience: batch through the PJRT fabric kernel.
pub fn run_batch_xla(
    g: &Graph,
    cfgs: &[SimConfig],
    rt: &FabricRuntime,
) -> Result<Vec<SimOutcome>> {
    run_batch(g, cfgs, &BatchEngine::Xla(rt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};
    use crate::sim::run_token;

    #[test]
    fn native_batch_matches_single_instance() {
        for bench in [BenchId::Fibonacci, BenchId::DotProd, BenchId::PopCount] {
            let g = bench_defs::build(bench);
            let cfgs: Vec<_> = (0..5)
                .map(|s| bench_defs::workload(bench, 4 + s, s as u64).sim_config())
                .collect();
            let batch = run_batch_native(&g, &cfgs);
            for (i, cfg) in cfgs.iter().enumerate() {
                let single = run_token(&g, cfg);
                assert_eq!(
                    batch[i].outputs,
                    single.outputs,
                    "{} instance {i}",
                    bench.slug()
                );
            }
        }
    }

    #[test]
    fn offload_phases_equal_plain_step() {
        // step_offload + native apply == step, per benchmark workload.
        for bench in BenchId::ALL {
            let g = bench_defs::build(bench);
            let wl = bench_defs::workload(bench, 5, 3);
            let cfg = wl.sim_config();
            let plain = run_token(&g, &cfg);
            let batch = run_batch_native(&g, std::slice::from_ref(&cfg));
            assert_eq!(batch[0].outputs, plain.outputs, "{}", bench.slug());
            assert_eq!(batch[0].firings, plain.firings, "{}", bench.slug());
        }
    }

    #[test]
    fn streamed_batch_matches_native_batch() {
        for bench in BenchId::ALL {
            let g = bench_defs::build(bench);
            let cfgs: Vec<_> = (0..4)
                .map(|s| bench_defs::workload(bench, 3 + s, s as u64).sim_config())
                .collect();
            let native = run_batch_native(&g, &cfgs);
            let streamed = run_batch_streamed(&g, &cfgs);
            assert_eq!(streamed.len(), native.len(), "{}", bench.slug());
            for i in 0..cfgs.len() {
                assert_eq!(
                    streamed[i].outputs,
                    native[i].outputs,
                    "{} wave {i}",
                    bench.slug()
                );
            }
        }
    }

    #[test]
    fn lane_batch_matches_native_batch() {
        for bench in BenchId::ALL {
            let g = bench_defs::build(bench);
            let cfgs: Vec<_> = (0..6)
                .map(|s| bench_defs::workload(bench, 3 + s, s as u64).sim_config())
                .collect();
            let native = run_batch_native(&g, &cfgs);
            let (lanes, stats) = run_batch_lanes_with_stats(&g, &cfgs);
            assert_eq!(stats.chunks, 1, "{}", bench.slug());
            assert_eq!(lanes.len(), native.len(), "{}", bench.slug());
            for i in 0..cfgs.len() {
                assert_eq!(
                    lanes[i].outputs,
                    native[i].outputs,
                    "{} item {i}",
                    bench.slug()
                );
            }
        }
    }

    #[test]
    fn precompiled_program_path_matches_compiling_path() {
        let g = bench_defs::build(BenchId::VectorSum);
        let cfgs: Vec<_> = (0..3)
            .map(|s| bench_defs::workload(BenchId::VectorSum, 3 + s, s as u64).sim_config())
            .collect();
        let prog = Program::compile(&g);
        let (a, sa) = run_batch_lanes_with_stats(&g, &cfgs);
        let (b, sb) = run_batch_lanes_prog(&g, &prog, &cfgs);
        assert_eq!(sa, sb);
        for i in 0..cfgs.len() {
            assert_eq!(a[i].outputs, b[i].outputs, "item {i}");
        }
    }

    #[test]
    fn lane_batch_reruns_non_quiescent_items_on_the_scalar_engine() {
        use crate::dfg::{GraphBuilder, Op};
        let mut b = GraphBuilder::new("adder");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        let g = b.finish().unwrap();
        let cfgs = vec![
            SimConfig::new().inject("a", vec![1]).inject("b", vec![2]),
            // Deadlocked item: no `b` operand, and a much smaller own
            // budget than the chunk's shared one.
            SimConfig::new().inject("a", vec![9]).max_cycles(10),
            SimConfig::new().inject("a", vec![3]).inject("b", vec![4]),
        ];
        let (outs, stats) = run_batch_lanes_with_stats(&g, &cfgs);
        assert_eq!(stats.scalar_reruns, 1);
        for (cfg, out) in cfgs.iter().zip(&outs) {
            let alone = run_token(&g, cfg);
            assert_eq!(out.outputs, alone.outputs);
        }
    }

    #[test]
    fn par_lane_batch_matches_serial_at_every_worker_count() {
        let bench = BenchId::DotProd;
        let g = bench_defs::build(bench);
        // > 2 chunks so parallel chunk dispatch is real work.
        let cfgs: Vec<_> = (0..(2 * MAX_LANES + 5))
            .map(|s| bench_defs::workload(bench, 3 + (s % 5), s as u64).sim_config())
            .collect();
        let prog = Program::compile(&g);
        let (serial, serial_stats) = run_batch_lanes_prog(&g, &prog, &cfgs);
        for workers in [1usize, 2, 4] {
            let exec = Executor::new(workers);
            let (par, stats) = run_batch_lanes_par(&g, &prog, &cfgs, &exec);
            assert_eq!(stats, serial_stats, "workers={workers}");
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn par_sstream_batch_matches_serial_serialized_session() {
        for bench in [BenchId::Fibonacci, BenchId::PopCount] {
            let g = bench_defs::build(bench);
            let cfgs: Vec<_> = (0..9)
                .map(|s| bench_defs::workload(bench, 3 + (s % 4), s as u64).sim_config())
                .collect();
            let serial = run_batch_sstream_par(&g, &cfgs, &Executor::single());
            for workers in [2usize, 4] {
                let exec = Executor::new(workers);
                let par = run_batch_sstream_par(&g, &cfgs, &exec);
                assert_eq!(par, serial, "{} workers={workers}", bench.slug());
            }
        }
    }

    #[test]
    fn xla_batch_matches_native_batch() {
        let Ok(rt) = FabricRuntime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for bench in [BenchId::Fibonacci, BenchId::Max, BenchId::VectorSum] {
            let g = bench_defs::build(bench);
            let cfgs: Vec<_> = (0..8)
                .map(|s| bench_defs::workload(bench, 3 + s % 4, s as u64).sim_config())
                .collect();
            let nat = run_batch_native(&g, &cfgs);
            let xla = run_batch_xla(&g, &cfgs, &rt).unwrap();
            for i in 0..cfgs.len() {
                assert_eq!(nat[i].outputs, xla[i].outputs, "{} #{i}", bench.slug());
            }
        }
    }

    #[test]
    fn xla_batch_verifies_workload_expectations() {
        let Ok(rt) = FabricRuntime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let bench = BenchId::DotProd;
        let g = bench_defs::build(bench);
        let wls: Vec<_> = (0..6).map(|s| bench_defs::workload(bench, 8, s)).collect();
        let cfgs: Vec<_> = wls.iter().map(|w| w.sim_config()).collect();
        let outs = run_batch_xla(&g, &cfgs, &rt).unwrap();
        for (wl, out) in wls.iter().zip(&outs) {
            for (port, want) in &wl.expect {
                assert_eq!(out.stream(port), want.as_slice());
            }
        }
    }
}
