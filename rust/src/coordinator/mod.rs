//! The Layer-3 coordinator: request routing, batching, and the
//! XLA-offloaded batch fabric engine.
//!
//! The paper's FPGA runs one graph instance in hardware. The acceleration
//! story at system level is *throughput over many instances* (parameter
//! sweeps, benchmark suites, multi-tenant requests): the coordinator
//! batches simulation requests per benchmark and runs B instances in
//! lockstep, evaluating all B×N operator ALUs per tick through the
//! AOT-compiled fabric kernel (`runtime`) — Rust keeps the token and
//! handshake state (branchy, irregular), the kernel does the dense math.
//!
//! * [`batch`] — the lockstep batch engine (native and XLA ALU paths).
//! * [`router`] — request router / dynamic batcher / worker pool with
//!   metrics, in the vLLM-router mould (std::thread + mpsc; the vendored
//!   environment has no tokio). Batches are routed round-robin over a
//!   [`crate::fabric::FabricPool`] of physical fabric instances; graphs
//!   that exceed one instance are partitioned and served by the sharded
//!   executor ([`crate::fabric::shard`]). Warm per-graph state (built
//!   graph, compiled lane program, fabric route) is shared across
//!   workers through a [`crate::serve::SessionCache`] keyed by graph
//!   fingerprint (`cache_hits` in [`Metrics`]); the engine-selection
//!   lattice itself is exposed through [`crate::serve::sched`] so the
//!   service tier can drive the same engines without this module's
//!   queue.

pub mod batch;
pub mod router;

pub use batch::{
    run_batch_lanes, run_batch_lanes_par, run_batch_lanes_prog, run_batch_lanes_with_stats,
    run_batch_native, run_batch_reconfig, run_batch_sharded, run_batch_sharded_par,
    run_batch_sstream_par, run_batch_streamed, run_batch_xla, BatchEngine, LaneBatchStats,
};
pub use router::{
    metric, BatchMode, Coordinator, Engine, Metrics, MetricsSnapshot, Request, Response,
};
