//! Request router / dynamic batcher / worker pool.
//!
//! The serving shape (vllm-router style, scaled to this system): clients
//! submit [`Request`]s (benchmark + workload parameters); a dispatcher
//! thread groups them **per benchmark graph** into dynamic batches (a
//! batch closes when it reaches `max_batch` or when the queue drains);
//! worker threads execute whole batches on the batch fabric engine and
//! deliver [`Response`]s through per-request channels. Metrics count
//! requests, fabric ticks and end-to-end latency.
//!
//! No tokio in the vendored environment: std::thread + mpsc. The PJRT
//! runtime is shared behind a mutex — batches (not ticks) amortize it.

use super::batch::{run_batch, BatchEngine};
use crate::bench_defs::{self, BenchId};
use crate::fabric::{FabricPool, FabricTopology};
use crate::obs::CounterSet;
use crate::runtime::FabricRuntime;
use crate::serve::{RoutePlan, SessionCache};
use crate::sim::SimOutcome;
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Which ALU engine the workers use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Native,
    Xla,
}

/// How a worker executes a batch on its fabric route.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// One run-to-completion execution per request (lockstep across the
    /// batch on the placed path).
    RunToCompletion,
    /// Pipeline the whole batch as successive waves through one
    /// resident fabric/session (see [`crate::sim::StreamSession`]).
    Streamed,
}

/// One simulation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub bench: BenchId,
    /// Workload size (vector length / fib argument).
    pub n: usize,
    pub seed: u64,
}

/// The result of one request.
#[derive(Debug)]
pub struct Response {
    pub request: Request,
    pub outcome: SimOutcome,
    /// Outputs matched the benchmark's software reference.
    pub verified: bool,
    pub latency: Duration,
}

/// Counter indices into the coordinator's [`CounterSet`] family —
/// the one place the names and the order are declared.
pub mod metric {
    pub const SUBMITTED: usize = 0;
    pub const COMPLETED: usize = 1;
    pub const VERIFIED: usize = 2;
    pub const BATCHES: usize = 3;
    pub const FABRIC_CYCLES: usize = 4;
    pub const TOTAL_LATENCY_US: usize = 5;
    /// Batches whose graph placed whole on one fabric instance.
    pub const PLACED: usize = 6;
    /// Batches whose graph exceeded one instance and ran sharded.
    pub const SHARDED: usize = 7;
    /// Batches whose graph exceeded one instance on a single-instance
    /// pool and ran time-multiplexed (context swapping).
    pub const RECONFIG: usize = 8;
    /// Batches whose graph fit no partition of the pool's topology and
    /// fell back to the infinite-fabric simulation.
    pub const FALLBACK: usize = 9;
    /// Waves pipelined through resident sessions (streamed mode only).
    pub const STREAMED_WAVES: usize = 10;
    /// Placed batches served by the lane-vectorized engine (native
    /// run-to-completion mode; subset of `PLACED`).
    pub const LANES: usize = 11;
    /// Items within lane batches re-run on the scalar engine because
    /// their lane did not quiesce (the lanes→placed fallback).
    pub const LANE_SCALAR_RERUNS: usize = 12;
    /// Batches whose warm state (built graph, compiled program, fabric
    /// route) came out of the shared session cache — the graph's
    /// build/compile/place cold-start work was skipped entirely.
    pub const CACHE_HITS: usize = 13;
    /// Placed batches whose *raw* graph overflowed one fabric instance
    /// and only place because the optimizer shrank it (subset of
    /// `PLACED`; see [`crate::serve::WarmState::opt_rescued_place`]).
    pub const OPT_PLACED: usize = 14;

    pub const NAMES: [&str; 15] = [
        "submitted",
        "completed",
        "verified",
        "batches",
        "fabric_cycles",
        "total_latency_us",
        "placed",
        "sharded",
        "reconfig",
        "fallback",
        "streamed_waves",
        "lanes",
        "lane_scalar_reruns",
        "cache_hits",
        "opt_placed",
    ];
}

/// Aggregate counters (lock-free reads) — a thin view over one
/// [`CounterSet`] family (`coordinator`), so the serving stack's
/// observability registry sees exactly what [`Metrics::summary`] sees.
#[derive(Debug)]
pub struct Metrics {
    counters: CounterSet,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            counters: CounterSet::new("coordinator", &metric::NAMES),
        }
    }
}

/// A coherent point-in-time copy of [`Metrics`]: plain `u64` fields,
/// cheap to clone, compare, and serialize. "Coherent" here means each
/// field is an atomic load — counters incremented by in-flight workers
/// between two loads can skew by a request or two, which is the usual
/// contract for monitoring snapshots (and exact once workers quiesce).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub verified: u64,
    pub batches: u64,
    pub fabric_cycles: u64,
    pub total_latency_us: u64,
    pub placed: u64,
    pub sharded: u64,
    pub reconfig: u64,
    pub fallback: u64,
    pub streamed_waves: u64,
    pub lanes: u64,
    pub lane_scalar_reruns: u64,
    pub cache_hits: u64,
    pub opt_placed: u64,
}

impl MetricsSnapshot {
    /// Mean end-to-end request latency in milliseconds.
    pub fn mean_latency_ms(&self) -> f64 {
        self.total_latency_us as f64 / self.completed.max(1) as f64 / 1000.0
    }
}

impl Metrics {
    /// Bump counter `idx` (see [`metric`]) by one.
    pub fn incr(&self, idx: usize) {
        self.counters.incr(idx);
    }

    /// Add `n` to counter `idx`.
    pub fn add(&self, idx: usize, n: u64) {
        self.counters.add(idx, n);
    }

    /// Read counter `idx` with a relaxed load.
    pub fn get(&self, idx: usize) -> u64 {
        self.counters.get(idx)
    }

    /// The underlying registry family, for export alongside the other
    /// counter families ([`crate::obs::ObsArtifact`]).
    pub fn counters(&self) -> &CounterSet {
        &self.counters
    }

    /// Snapshot every counter with relaxed loads.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.get(metric::SUBMITTED),
            completed: self.get(metric::COMPLETED),
            verified: self.get(metric::VERIFIED),
            batches: self.get(metric::BATCHES),
            fabric_cycles: self.get(metric::FABRIC_CYCLES),
            total_latency_us: self.get(metric::TOTAL_LATENCY_US),
            placed: self.get(metric::PLACED),
            sharded: self.get(metric::SHARDED),
            reconfig: self.get(metric::RECONFIG),
            fallback: self.get(metric::FALLBACK),
            streamed_waves: self.get(metric::STREAMED_WAVES),
            lanes: self.get(metric::LANES),
            lane_scalar_reruns: self.get(metric::LANE_SCALAR_RERUNS),
            cache_hits: self.get(metric::CACHE_HITS),
            opt_placed: self.get(metric::OPT_PLACED),
        }
    }

    pub fn summary(&self) -> String {
        let s = self.snapshot();
        format!(
            "requests {}/{} verified {} | batches {} (placed {} [opt-placed {}], sharded {}, \
             reconfig {}, fallback {}) | cache hits {} | lanes {} (scalar reruns {}) | \
             streamed waves {} | fabric cycles {} | mean latency {:.1} ms",
            s.completed,
            s.submitted,
            s.verified,
            s.batches,
            s.placed,
            s.opt_placed,
            s.sharded,
            s.reconfig,
            s.fallback,
            s.cache_hits,
            s.lanes,
            s.lane_scalar_reruns,
            s.streamed_waves,
            s.fabric_cycles,
            s.mean_latency_ms(),
        )
    }
}

struct Job {
    request: Request,
    submitted: Instant,
    reply: mpsc::Sender<Response>,
}

enum Msg {
    Job(Job),
    Shutdown,
}

/// The router + batcher + worker pool.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    dispatcher: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    /// The spatially sharded fabric rack batches are routed onto.
    pub pool: Arc<FabricPool>,
    /// Warm compile/place state shared by every worker, keyed by graph
    /// fingerprint ([`crate::serve::SessionCache`]). The first batch
    /// of a benchmark pays build + `Program::compile` + place/
    /// partition once; every later batch — from *any* worker — is a
    /// `cache_hits` lookup.
    pub cache: Arc<SessionCache>,
}

impl Coordinator {
    /// Start a coordinator with `workers` worker threads and a fabric
    /// pool of one paper-scale instance per worker. `artifact_dir` is
    /// only needed for [`Engine::Xla`].
    pub fn start(
        workers: usize,
        engine: Engine,
        artifact_dir: Option<&str>,
        max_batch: usize,
    ) -> anyhow::Result<Self> {
        let topo = FabricTopology::paper();
        Self::start_with_fabric(workers, engine, artifact_dir, max_batch, topo)
    }

    /// Start with an explicit fabric topology (the pool holds one
    /// instance per worker). Graphs that do not place on one instance
    /// are partitioned and served by the sharded executor.
    pub fn start_with_fabric(
        workers: usize,
        engine: Engine,
        artifact_dir: Option<&str>,
        max_batch: usize,
        topo: FabricTopology,
    ) -> anyhow::Result<Self> {
        Self::start_inner(
            workers,
            engine,
            artifact_dir,
            max_batch,
            topo,
            BatchMode::RunToCompletion,
        )
    }

    /// Start a streaming coordinator: workers pipeline each batch as
    /// successive waves through one resident session/rack instead of
    /// running each request to completion (native ALU only — the
    /// streaming path keeps all state in-process).
    pub fn start_streamed(workers: usize, max_batch: usize) -> anyhow::Result<Self> {
        Self::start_streamed_with_fabric(workers, max_batch, FabricTopology::paper())
    }

    /// Streaming coordinator over an explicit fabric topology.
    pub fn start_streamed_with_fabric(
        workers: usize,
        max_batch: usize,
        topo: FabricTopology,
    ) -> anyhow::Result<Self> {
        Self::start_inner(
            workers,
            Engine::Native,
            None,
            max_batch,
            topo,
            BatchMode::Streamed,
        )
    }

    fn start_inner(
        workers: usize,
        engine: Engine,
        artifact_dir: Option<&str>,
        max_batch: usize,
        topo: FabricTopology,
        mode: BatchMode,
    ) -> anyhow::Result<Self> {
        let metrics = Arc::new(Metrics::default());
        // One cache per coordinator: routes depend on (topology, pool
        // size), both fixed for its lifetime. Capacity covers the full
        // benchmark suite with headroom for ad-hoc graphs.
        let cache = Arc::new(SessionCache::new(topo.clone(), workers.max(1), 32));
        let pool = Arc::new(FabricPool::new(topo, workers.max(1)));
        // PJRT handles are not Send: each XLA worker creates its own
        // client + executables inside its thread. Validate the artifact
        // directory up front so a bad path fails fast on the caller.
        let dir = artifact_dir.unwrap_or("artifacts").to_string();
        if engine == Engine::Xla {
            FabricRuntime::load(&dir)?;
        }

        let (tx, rx) = mpsc::channel::<Msg>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Job>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // Workers: execute whole batches. The warm state per benchmark
        // (built graph, compiled program, fabric route) depends only on
        // the graph and the pool topology, both fixed for the
        // coordinator's lifetime, so all workers share one session
        // cache instead of re-building/re-partitioning per batch.
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let batch_rx = Arc::clone(&batch_rx);
            let metrics = Arc::clone(&metrics);
            let pool = Arc::clone(&pool);
            let cache = Arc::clone(&cache);
            let dir = dir.clone();
            handles.push(std::thread::spawn(move || {
                let runtime = match engine {
                    Engine::Xla => FabricRuntime::load(&dir).ok(),
                    Engine::Native => None,
                };
                loop {
                    let jobs = {
                        let rx = batch_rx.lock().unwrap();
                        rx.recv()
                    };
                    let Ok(jobs) = jobs else { break };
                    run_jobs(jobs, &metrics, runtime.as_ref(), &pool, &cache, mode);
                }
            }));
        }

        // Dispatcher: group by benchmark into dynamic batches.
        let metrics_d = Arc::clone(&metrics);
        let dispatcher = std::thread::spawn(move || {
            let mut queues: BTreeMap<BenchId, Vec<Job>> = BTreeMap::new();
            let mut running = true;
            while running {
                // Block for one message, then drain opportunistically —
                // the dynamic-batching window.
                match rx.recv() {
                    Ok(Msg::Job(j)) => {
                        metrics_d.incr(metric::SUBMITTED);
                        queues.entry(j.request.bench).or_default().push(j);
                    }
                    Ok(Msg::Shutdown) | Err(_) => running = false,
                }
                loop {
                    match rx.try_recv() {
                        Ok(Msg::Job(j)) => {
                            metrics_d.incr(metric::SUBMITTED);
                            queues.entry(j.request.bench).or_default().push(j);
                        }
                        Ok(Msg::Shutdown) => {
                            running = false;
                            break;
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            running = false;
                            break;
                        }
                    }
                }
                // Flush every queue in max_batch chunks.
                for (_, q) in queues.iter_mut() {
                    while !q.is_empty() {
                        let take = q.len().min(max_batch);
                        let chunk: Vec<Job> = q.drain(..take).collect();
                        if batch_tx.send(chunk).is_err() {
                            running = false;
                            break;
                        }
                    }
                }
            }
            // Dropping batch_tx stops the workers.
            drop(batch_tx);
            for h in handles {
                let _ = h.join();
            }
        });

        Ok(Coordinator {
            tx,
            dispatcher: Some(dispatcher),
            metrics,
            pool,
            cache,
        })
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, request: Request) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        let job = Job {
            request,
            submitted: Instant::now(),
            reply,
        };
        self.tx.send(Msg::Job(job)).expect("coordinator running");
        rx
    }

    /// Drain and stop.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

fn run_jobs(
    jobs: Vec<Job>,
    metrics: &Metrics,
    runtime: Option<&FabricRuntime>,
    pool: &FabricPool,
    cache: &SessionCache,
    mode: BatchMode,
) {
    if jobs.is_empty() {
        return;
    }
    let bench = jobs[0].request.bench;
    debug_assert!(jobs.iter().all(|j| j.request.bench == bench));
    // Warm state (graph, compiled program, fabric route) from the
    // shared session cache: only the first batch of a benchmark pays
    // the build/compile/place cold start. Hint hits skip even the
    // graph build.
    let (state, cache_hit) = cache.warm_keyed(bench.slug(), || bench_defs::build(bench));
    if cache_hit {
        metrics.incr(metric::CACHE_HITS);
    }
    let g = state.graph.as_ref();
    let workloads: Vec<_> = jobs
        .iter()
        .map(|j| bench_defs::workload(bench, j.request.n, j.request.seed))
        .collect();
    let cfgs: Vec<_> = workloads.iter().map(|w| w.sim_config()).collect();

    let streamed = mode == BatchMode::Streamed;
    if streamed {
        metrics.add(metric::STREAMED_WAVES, cfgs.len() as u64);
    }
    // Spatial sharding: a graph that places whole occupies one fabric
    // instance; one that exceeds a single instance is partitioned and
    // occupies one instance per shard (or time-multiplexes one instance
    // when the pool has no spare), cut arcs riding the inter-fabric
    // channels.
    let outcomes = match &state.route {
        RoutePlan::Placed => {
            metrics.incr(metric::PLACED);
            if state.opt_rescued_place {
                metrics.incr(metric::OPT_PLACED);
            }
            pool.route_healthy();
            if streamed {
                super::batch::run_batch_streamed(g, &cfgs)
            } else {
                match runtime {
                    Some(rt) => run_batch(g, &cfgs, &BatchEngine::Xla(rt))
                        .unwrap_or_else(|_| super::batch::run_batch_native(g, &cfgs)),
                    // Native run-to-completion batches take the lane-
                    // vectorized engine with the cached compiled
                    // program; items whose lane does not quiesce fall
                    // back to the scalar placed engine (counted in
                    // `lane_scalar_reruns`).
                    None => {
                        let (outs, stats) =
                            super::batch::run_batch_lanes_prog(g, &state.program, &cfgs);
                        metrics.incr(metric::LANES);
                        metrics.add(metric::LANE_SCALAR_RERUNS, stats.scalar_reruns as u64);
                        outs
                    }
                }
            }
        }
        RoutePlan::Sharded(plan) => {
            metrics.incr(metric::SHARDED);
            // A sharded batch occupies one instance per shard.
            for _ in 0..plan.n_shards() {
                pool.route_healthy();
            }
            super::batch::run_batch_sharded(plan, &cfgs, streamed)
        }
        RoutePlan::Reconfig(plan) => {
            metrics.incr(metric::RECONFIG);
            pool.route_healthy();
            super::batch::run_batch_reconfig(plan, pool.topology(), &cfgs, streamed)
        }
        RoutePlan::Fallback => {
            metrics.incr(metric::FALLBACK);
            if streamed {
                super::batch::run_batch_streamed(g, &cfgs)
            } else {
                super::batch::run_batch_native(g, &cfgs)
            }
        }
    };

    metrics.incr(metric::BATCHES);
    for ((job, wl), outcome) in jobs.into_iter().zip(workloads).zip(outcomes) {
        let verified = wl
            .expect
            .iter()
            .all(|(port, want)| outcome.stream(port) == want.as_slice());
        metrics.incr(metric::COMPLETED);
        if verified {
            metrics.incr(metric::VERIFIED);
        }
        metrics.add(metric::FABRIC_CYCLES, outcome.cycles);
        let latency = job.submitted.elapsed();
        metrics.add(metric::TOTAL_LATENCY_US, latency.as_micros() as u64);
        let _ = job.reply.send(Response {
            request: job.request,
            outcome,
            verified,
            latency,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The graph the session cache actually routes: tests that size
    /// fabrics to force a route class must size against this.
    fn optimized(b: BenchId) -> crate::dfg::Graph {
        crate::opt::optimize(&crate::bench_defs::build(b), Default::default()).0
    }

    #[test]
    fn serves_mixed_requests_native() {
        let c = Coordinator::start(2, Engine::Native, None, 8).unwrap();
        let mut rxs = Vec::new();
        for (i, bench) in BenchId::ALL.iter().cycle().take(18).enumerate() {
            rxs.push(c.submit(Request {
                bench: *bench,
                n: 3 + i % 5,
                seed: i as u64,
            }));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.verified, "{:?} failed verification", resp.request);
        }
        assert_eq!(c.metrics.get(metric::COMPLETED), 18);
        assert_eq!(c.metrics.get(metric::VERIFIED), 18);
        c.shutdown();
    }

    #[test]
    fn metrics_snapshot_is_exact_after_concurrent_increments() {
        let m = Arc::new(Metrics::default());
        let threads = 4;
        let per_thread = 1000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for i in 0..per_thread {
                        m.incr(metric::SUBMITTED);
                        m.incr(metric::COMPLETED);
                        m.add(metric::TOTAL_LATENCY_US, 2);
                        if (t as u64 + i) % 2 == 0 {
                            m.incr(metric::VERIFIED);
                        }
                        if i % 10 == 0 {
                            m.incr(metric::BATCHES);
                            m.incr(metric::CACHE_HITS);
                        }
                    }
                });
            }
        });
        let total = threads as u64 * per_thread;
        let s = m.snapshot();
        assert_eq!(s.submitted, total);
        assert_eq!(s.completed, total);
        assert_eq!(s.verified, total / 2);
        assert_eq!(s.batches, total / 10);
        assert_eq!(s.cache_hits, total / 10);
        assert_eq!(s.total_latency_us, total * 2);
        // Derived view and quiescent re-snapshot agree.
        assert!((s.mean_latency_ms() - 0.002).abs() < 1e-12);
        assert_eq!(m.snapshot(), s);
        assert!(m.summary().contains(&format!("requests {total}/{total}")));
    }

    #[test]
    fn native_placed_batches_take_the_lane_engine() {
        let c = Coordinator::start(2, Engine::Native, None, 8).unwrap();
        let rxs: Vec<_> = (0..10)
            .map(|i| {
                c.submit(Request {
                    bench: BenchId::DotProd,
                    n: 3 + i % 4,
                    seed: i as u64,
                })
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.verified, "{:?} failed on lane route", resp.request);
        }
        assert!(c.metrics.get(metric::LANES) >= 1);
        assert!(c.metrics.get(metric::PLACED) >= 1);
        // Benchmark workloads quiesce — no scalar fallback expected.
        assert_eq!(c.metrics.get(metric::LANE_SCALAR_RERUNS), 0);
        assert!(c.metrics.summary().contains("lanes"));
        c.shutdown();
    }

    #[test]
    fn streamed_mode_bypasses_the_lane_engine() {
        // The lanes route serves native run-to-completion batches only;
        // streamed batches keep the resident-session path (the
        // placed/streamed side of the route lattice).
        let c = Coordinator::start_streamed(1, 4).unwrap();
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                c.submit(Request {
                    bench: BenchId::Fibonacci,
                    n: 4 + i,
                    seed: i as u64,
                })
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(60)).unwrap().verified);
        }
        assert_eq!(c.metrics.get(metric::LANES), 0);
        assert!(c.metrics.get(metric::STREAMED_WAVES) >= 4);
        c.shutdown();
    }

    #[test]
    fn repeat_batches_hit_the_session_cache() {
        let c = Coordinator::start(1, Engine::Native, None, 2).unwrap();
        // 8 same-bench requests, batch cap 2 → ≥ 4 batches; only the
        // first pays the build/compile/place cold start.
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                c.submit(Request {
                    bench: BenchId::DotProd,
                    n: 3,
                    seed: i,
                })
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(60)).unwrap().verified);
        }
        let batches = c.metrics.get(metric::BATCHES);
        let hits = c.metrics.get(metric::CACHE_HITS);
        assert!(batches >= 4);
        assert_eq!(c.cache.misses(), 1, "one cold start for one benchmark");
        assert_eq!(hits, batches - 1, "every later batch is warm");
        assert!(c.metrics.summary().contains("cache hits"));
        c.shutdown();
    }

    #[test]
    fn batches_group_same_benchmark() {
        let c = Coordinator::start(1, Engine::Native, None, 16).unwrap();
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                c.submit(Request {
                    bench: BenchId::Fibonacci,
                    n: 5,
                    seed: i,
                })
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(60)).unwrap();
        }
        // 16 same-bench requests in ≤ a handful of batches (timing-
        // dependent, but far fewer than 16 if batching works at all).
        let batches = c.metrics.get(metric::BATCHES);
        assert!(batches <= 16);
        assert!(batches >= 1);
        c.shutdown();
    }

    #[test]
    fn metrics_summary_renders() {
        let m = Metrics::default();
        m.add(metric::SUBMITTED, 4);
        m.add(metric::COMPLETED, 4);
        m.add(metric::OPT_PLACED, 2);
        assert!(m.summary().contains("requests 4/4"));
        assert!(m.summary().contains("opt-placed 2"));
        // The registry view exposes the same numbers under one family.
        let fam = m.counters().snapshot();
        assert_eq!(fam.family, "coordinator");
        assert_eq!(fam.get("submitted"), 4);
        assert_eq!(fam.get("opt_placed"), 2);
        assert_eq!(fam.vals.len(), metric::NAMES.len());
    }

    #[test]
    fn tiny_fabric_serves_via_sharded_executor() {
        // A half-size fabric fits none of the benchmarks whole, so every
        // batch must take the partition + sharded-execution path — and
        // still verify against the software references. The fabric is
        // sized against the *optimized* graph (what the session cache
        // routes); the pool must hold one instance per shard, so give
        // it as many workers as the partition produces shards.
        let g = optimized(BenchId::DotProd);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = crate::fabric::partition(&g, &topo).unwrap();
        let workers = plan.n_shards().max(2);
        let c = Coordinator::start_with_fabric(workers, Engine::Native, None, 4, topo).unwrap();
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                c.submit(Request {
                    bench: BenchId::DotProd,
                    n: 4 + i % 3,
                    seed: i as u64,
                })
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.verified, "{:?} failed on sharded path", resp.request);
        }
        assert!(c.metrics.get(metric::SHARDED) >= 1);
        assert_eq!(c.metrics.get(metric::PLACED), 0);
        assert!(c
            .pool
            .summary()
            .contains(&format!("{workers} instance(s)")));
        c.shutdown();
    }

    #[test]
    fn single_instance_pool_takes_reconfig_route() {
        // One worker = one fabric instance; an oversized graph cannot
        // shard spatially, so it must time-multiplex — and still verify.
        let g = optimized(BenchId::Max);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let c = Coordinator::start_with_fabric(1, Engine::Native, None, 4, topo).unwrap();
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                c.submit(Request {
                    bench: BenchId::Max,
                    n: 3 + i,
                    seed: i as u64,
                })
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.verified, "{:?} failed on reconfig path", resp.request);
        }
        assert!(c.metrics.get(metric::RECONFIG) >= 1);
        assert_eq!(c.metrics.get(metric::SHARDED), 0);
        assert_eq!(c.metrics.get(metric::PLACED), 0);
        assert_eq!(c.metrics.get(metric::FALLBACK), 0);
        c.shutdown();
    }

    #[test]
    fn unpartitionable_topology_takes_fallback_route() {
        // A channel pool smaller than any node's arc degree defeats the
        // partitioner outright (placement rejection), so the router must
        // fall back to the infinite-fabric engine — and still verify.
        let topo = FabricTopology::new(
            "undersized",
            FabricTopology::paper().slots,
            1, // below every operator's arc degree
            64,
        );
        let c = Coordinator::start_with_fabric(2, Engine::Native, None, 4, topo).unwrap();
        let rxs: Vec<_> = (0..3)
            .map(|i| {
                c.submit(Request {
                    bench: BenchId::Fibonacci,
                    n: 5 + i,
                    seed: i as u64,
                })
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.verified, "{:?} failed on fallback path", resp.request);
        }
        assert!(c.metrics.get(metric::FALLBACK) >= 1);
        assert_eq!(c.metrics.get(metric::PLACED), 0);
        assert_eq!(c.metrics.get(metric::SHARDED), 0);
        assert_eq!(c.metrics.get(metric::RECONFIG), 0);
        c.shutdown();
    }

    #[test]
    fn streamed_coordinator_serves_and_verifies() {
        let c = Coordinator::start_streamed(2, 8).unwrap();
        let mut rxs = Vec::new();
        for (i, bench) in BenchId::ALL.iter().cycle().take(12).enumerate() {
            rxs.push(c.submit(Request {
                bench: *bench,
                n: 3 + i % 4,
                seed: i as u64,
            }));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.verified, "{:?} failed streamed", resp.request);
        }
        assert_eq!(c.metrics.get(metric::COMPLETED), 12);
        assert_eq!(c.metrics.get(metric::STREAMED_WAVES), 12);
        assert!(c.metrics.summary().contains("streamed waves 12"));
        c.shutdown();
    }

    #[test]
    fn streamed_sharded_route_verifies() {
        let g = optimized(BenchId::VectorSum);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let workers = crate::fabric::partition(&g, &topo).unwrap().n_shards().max(2);
        let c = Coordinator::start_streamed_with_fabric(workers, 4, topo).unwrap();
        let rxs: Vec<_> = (0..5)
            .map(|i| {
                c.submit(Request {
                    bench: BenchId::VectorSum,
                    n: 3 + i % 3,
                    seed: i as u64,
                })
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.verified, "{:?} failed streamed+sharded", resp.request);
        }
        assert!(c.metrics.get(metric::SHARDED) >= 1);
        assert!(c.metrics.get(metric::STREAMED_WAVES) >= 5);
        c.shutdown();
    }

    #[test]
    fn default_pool_places_all_benchmarks() {
        let c = Coordinator::start(2, Engine::Native, None, 8).unwrap();
        let rxs: Vec<_> = BenchId::ALL
            .iter()
            .map(|b| {
                c.submit(Request {
                    bench: *b,
                    n: 4,
                    seed: 9,
                })
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(60)).unwrap().verified);
        }
        assert_eq!(c.metrics.get(metric::SHARDED), 0);
        assert!(c.metrics.get(metric::PLACED) >= 1);
        // The hand-built benchmarks place raw on the paper fabric, so
        // none of these placements needed the optimizer's rescue.
        assert_eq!(c.metrics.get(metric::OPT_PLACED), 0);
        c.shutdown();
    }
}
