//! `par` — a std-only work-stealing executor for the host-side tiers.
//!
//! The paper's fabric wins by firing many operators concurrently; the
//! host-side reproduction gets its concurrency here instead. The
//! executor runs a fixed pool of `std::thread` scoped workers, each
//! owning a private deque, fed by one global injector queue:
//!
//! * `submit` pushes a sequence-tagged task onto the injector;
//! * an idle worker grabs a fair share (`len / workers`, min 1) of the
//!   injector into its own deque, so a burst of same-graph batches
//!   spreads across the pool in one pass;
//! * a worker whose deque runs dry steals single tasks from the *back*
//!   of a victim's deque (classic Chase–Lev discipline, approximated
//!   with mutexed `VecDeque`s since we are std-only by construction);
//! * workers park on a `Condvar` when the whole system is empty and are
//!   woken by `submit` / shutdown.
//!
//! **Determinism contract.** Tasks must be pure functions of their
//! captured inputs. The executor tags every task with its submission
//! index and sorts results back into submission order, so `map` and
//! `pipeline` return byte-identical results regardless of worker count
//! or steal schedule. The conformance harness (`par_determinism_*`)
//! enforces this end to end across the lane, shard, and stream tiers.
//!
//! No new crates: `Mutex` + `Condvar` + atomics + `thread::scope` only.

use crate::obs::CounterSet;
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

type Task<'env, T> = Box<dyn FnOnce() -> T + Send + 'env>;

/// Counter indices into the executor's [`CounterSet`] family (`par`).
pub mod metric {
    /// Tasks executed to completion.
    pub const EXECUTED: usize = 0;
    /// Tasks obtained by stealing from another worker's deque.
    pub const STEALS: usize = 1;
    /// Total nanoseconds spent inside task bodies, summed over workers.
    pub const BUSY_NS: usize = 2;

    pub const NAMES: [&str; 3] = ["executed", "steals", "busy_ns"];
}

/// Cumulative executor counters, snapshotted via [`Executor::stats`].
///
/// `busy_ns` sums task execution time across *all* workers, so on an
/// N-worker pool it can exceed wall time by up to a factor of N — that
/// ratio is exactly the utilization number `util::bench` and
/// `report::serve` report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ParStats {
    /// Tasks executed to completion.
    pub executed: u64,
    /// Tasks obtained by stealing from another worker's deque (as
    /// opposed to the worker's own deque or the global injector).
    pub steals: u64,
    /// Total nanoseconds spent inside task bodies, summed over workers.
    pub busy_ns: u64,
}

/// Per-worker tallies folded into the executor atomics at join time.
#[derive(Default)]
struct WorkerTally {
    executed: u64,
    steals: u64,
    busy_ns: u64,
}

struct Shared<'env, T: Send> {
    injector: Mutex<VecDeque<(u64, Task<'env, T>)>>,
    locals: Vec<Mutex<VecDeque<(u64, Task<'env, T>)>>>,
    /// Guards the park/notify handshake; `submit` takes it before
    /// notifying so a wakeup can never slip between a worker's empty
    /// check and its wait.
    sleep: Mutex<()>,
    bell: Condvar,
    closed: AtomicBool,
    next_seq: AtomicU64,
}

impl<'env, T: Send> Shared<'env, T> {
    fn new(workers: usize) -> Self {
        Shared {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            sleep: Mutex::new(()),
            bell: Condvar::new(),
            closed: AtomicBool::new(false),
            next_seq: AtomicU64::new(0),
        }
    }

    fn push(&self, job: Task<'env, T>) {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        self.injector.lock().unwrap().push_back((seq, job));
        let _g = self.sleep.lock().unwrap();
        self.bell.notify_one();
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        let _g = self.sleep.lock().unwrap();
        self.bell.notify_all();
    }

    fn has_work(&self) -> bool {
        if !self.injector.lock().unwrap().is_empty() {
            return true;
        }
        self.locals.iter().any(|l| !l.lock().unwrap().is_empty())
    }

    /// Pop the next task for worker `wi`: own deque front, then a fair
    /// share of the injector, then a steal from a victim's back.
    /// Returns `None` only once the pool is closed and fully drained.
    fn next_task(&self, wi: usize, tally: &mut WorkerTally) -> Option<(u64, Task<'env, T>)> {
        loop {
            if let Some(t) = self.locals[wi].lock().unwrap().pop_front() {
                return Some(t);
            }
            {
                let mut inj = self.injector.lock().unwrap();
                if !inj.is_empty() {
                    let grab = (inj.len() / self.locals.len()).max(1);
                    let first = inj.pop_front().unwrap();
                    if grab > 1 {
                        let mut local = self.locals[wi].lock().unwrap();
                        for _ in 1..grab {
                            match inj.pop_front() {
                                Some(t) => local.push_back(t),
                                None => break,
                            }
                        }
                    }
                    return Some(first);
                }
            }
            for k in 1..self.locals.len() {
                let victim = (wi + k) % self.locals.len();
                if let Some(t) = self.locals[victim].lock().unwrap().pop_back() {
                    tally.steals += 1;
                    return Some(t);
                }
            }
            if self.closed.load(Ordering::Acquire) {
                // Drained and closed: one final sweep above found
                // nothing, and nothing new can arrive.
                if !self.has_work() {
                    return None;
                }
                continue;
            }
            // Park. The timeout is belt-and-braces only; the sleep
            // mutex handshake already rules out lost wakeups.
            let guard = self.sleep.lock().unwrap();
            if self.has_work() || self.closed.load(Ordering::Acquire) {
                continue;
            }
            let (guard, _timed_out) =
                self.bell.wait_timeout(guard, Duration::from_millis(1)).unwrap();
            drop(guard);
        }
    }
}

fn worker_loop<'env, T: Send>(
    shared: &Shared<'env, T>,
    wi: usize,
) -> (Vec<(u64, T)>, WorkerTally) {
    let mut out = Vec::new();
    let mut tally = WorkerTally::default();
    while let Some((seq, job)) = shared.next_task(wi, &mut tally) {
        let t0 = Instant::now();
        out.push((seq, job()));
        tally.busy_ns += t0.elapsed().as_nanos() as u64;
        tally.executed += 1;
    }
    (out, tally)
}

/// Handle for submitting tasks from inside [`Executor::pipeline`].
pub struct Submitter<'scope, 'env, T: Send> {
    shared: &'scope Shared<'env, T>,
}

impl<'scope, 'env, T: Send> Submitter<'scope, 'env, T> {
    /// Queue a task. Results come back from `pipeline` sorted by
    /// submission order, independent of which worker ran what.
    pub fn submit(&self, job: impl FnOnce() -> T + Send + 'env) {
        self.shared.push(Box::new(job));
    }
}

/// A work-stealing thread-pool executor. Cheap to construct; each
/// `map`/`pipeline` call spawns its own scoped workers so borrowed data
/// flows into tasks without `'static` bounds, and the pool fully
/// quiesces before the call returns.
pub struct Executor {
    workers: usize,
    counters: CounterSet,
}

impl Executor {
    /// An executor with `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> Self {
        Executor {
            workers: workers.max(1),
            counters: CounterSet::new("par", &metric::NAMES),
        }
    }

    /// A single-worker executor: every `map`/`pipeline` call runs
    /// inline on the caller thread (no threads spawned at all).
    pub fn single() -> Self {
        Executor::new(1)
    }

    /// Hardware parallelism, defaulting to 1 when unknowable.
    pub fn available_parallelism() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Snapshot of cumulative counters across all calls so far —
    /// a thin view over the `par` registry family.
    pub fn stats(&self) -> ParStats {
        ParStats {
            executed: self.counters.get(metric::EXECUTED),
            steals: self.counters.get(metric::STEALS),
            busy_ns: self.counters.get(metric::BUSY_NS),
        }
    }

    /// The underlying registry family, for export alongside the other
    /// counter families ([`crate::obs::ObsArtifact`]).
    pub fn counters(&self) -> &CounterSet {
        &self.counters
    }

    fn absorb(&self, tally: &WorkerTally) {
        self.counters.add(metric::EXECUTED, tally.executed);
        self.counters.add(metric::STEALS, tally.steals);
        self.counters.add(metric::BUSY_NS, tally.busy_ns);
    }

    /// Run `f(0..n)` across the pool and return results in index order.
    /// With one worker (or `n <= 1`) this runs inline on the caller
    /// thread — the serial fast path the determinism tests compare
    /// against.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.workers <= 1 || n <= 1 {
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                let t0 = Instant::now();
                out.push(f(i));
                self.counters
                    .add(metric::BUSY_NS, t0.elapsed().as_nanos() as u64);
                self.counters.incr(metric::EXECUTED);
            }
            return out;
        }
        let fr = &f;
        let (_, results) = self.pipeline(|sub| {
            for i in 0..n {
                sub.submit(move || fr(i));
            }
        });
        results
    }

    /// Run `drive` on the caller thread while the pool executes
    /// whatever it submits; returns `drive`'s value plus all task
    /// results sorted into submission order. This is the open-loop
    /// shape `serve::sched` needs: the tick loop keeps admitting and
    /// dispatching while earlier batches are still executing.
    pub fn pipeline<'env, T, X, F>(&self, drive: F) -> (X, Vec<T>)
    where
        T: Send + 'env,
        F: for<'scope> FnOnce(&Submitter<'scope, 'env, T>) -> X,
    {
        if self.workers <= 1 {
            // Inline: queue submissions, then drain them on this
            // thread in submission order once `drive` returns.
            let shared = Shared::new(1);
            let x = drive(&Submitter { shared: &shared });
            shared.close();
            let (mut tagged, tally) = worker_loop(&shared, 0);
            self.absorb(&tally);
            tagged.sort_unstable_by_key(|(seq, _)| *seq);
            return (x, tagged.into_iter().map(|(_, t)| t).collect());
        }
        let shared = Shared::new(self.workers);
        let mut tagged: Vec<(u64, T)> = Vec::new();
        let x = std::thread::scope(|s| {
            let handles: Vec<_> = (0..self.workers)
                .map(|wi| {
                    let sh = &shared;
                    s.spawn(move || worker_loop(sh, wi))
                })
                .collect();
            let x = drive(&Submitter { shared: &shared });
            shared.close();
            for h in handles {
                let (res, tally) = h.join().expect("par worker panicked");
                self.absorb(&tally);
                tagged.extend(res);
            }
            x
        });
        tagged.sort_unstable_by_key(|(seq, _)| *seq);
        (x, tagged.into_iter().map(|(_, t)| t).collect())
    }
}

/// Split `0..n` into at most `parts` contiguous ranges whose lengths
/// differ by at most one. Deterministic in `n` and `parts` only — this
/// is what keeps per-worker wave chunks reproducible.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn map_preserves_order_across_worker_counts() {
        let inputs: Vec<u64> = (0..257).map(|i| i * 31 + 7).collect();
        let expect: Vec<u64> = inputs.iter().map(|x| x.wrapping_mul(*x)).collect();
        for workers in [1, 2, 4, 7] {
            let exec = Executor::new(workers);
            let got = exec.map(inputs.len(), |i| inputs[i].wrapping_mul(inputs[i]));
            assert_eq!(got, expect, "workers={workers}");
        }
    }

    #[test]
    fn map_runs_every_task_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        let exec = Executor::new(4);
        exec.map(hits.len(), |i| hits[i].fetch_add(1, Ordering::Relaxed));
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
        assert_eq!(exec.stats().executed, 100);
    }

    #[test]
    fn pipeline_returns_results_in_submission_order() {
        let exec = Executor::new(3);
        let (count, results) = exec.pipeline(|sub| {
            for i in 0..64u64 {
                // Uneven task costs provoke out-of-order completion.
                sub.submit(move || {
                    let mut acc = i;
                    for k in 0..(i % 9) * 1000 {
                        acc = acc.wrapping_mul(31).wrapping_add(k);
                    }
                    (i, acc)
                });
            }
            64usize
        });
        assert_eq!(count, 64);
        assert_eq!(results.len(), 64);
        for (idx, (i, _)) in results.iter().enumerate() {
            assert_eq!(*i as usize, idx);
        }
    }

    #[test]
    fn pipeline_handles_empty_and_single_submissions() {
        let exec = Executor::new(4);
        let (_, empty) = exec.pipeline::<u32, _, _>(|_sub| ());
        assert!(empty.is_empty());
        let (_, one) = exec.pipeline(|sub| sub.submit(|| 42u32));
        assert_eq!(one, vec![42]);
    }

    #[test]
    fn tasks_can_borrow_caller_state() {
        let data = vec![1u64, 2, 3, 4, 5];
        let exec = Executor::new(2);
        let sums = exec.map(data.len(), |i| data[i] + 10);
        assert_eq!(sums, vec![11, 12, 13, 14, 15]);
    }

    #[test]
    fn stats_accumulate_busy_time() {
        let exec = Executor::new(2);
        exec.map(32, |i| {
            let mut s = 0u64;
            for k in 0..2000u64 {
                s = s.wrapping_add(k * i as u64);
            }
            s
        });
        let st = exec.stats();
        assert_eq!(st.executed, 32);
        assert!(st.busy_ns > 0);
        // The registry view and the snapshot struct agree.
        let fam = exec.counters().snapshot();
        assert_eq!(fam.family, "par");
        assert_eq!(fam.get("executed"), st.executed);
        assert_eq!(fam.get("steals"), st.steals);
        assert_eq!(fam.get("busy_ns"), st.busy_ns);
    }

    #[test]
    fn single_worker_runs_inline() {
        let exec = Executor::single();
        assert_eq!(exec.workers(), 1);
        let got = exec.map(10, |i| i * 2);
        assert_eq!(got, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn split_ranges_covers_exactly_once() {
        for n in [0usize, 1, 5, 64, 65, 131, 1000] {
            for parts in [1usize, 2, 3, 4, 7, 16] {
                let ranges = split_ranges(n, parts);
                let mut covered = 0;
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "contiguous n={n} parts={parts}");
                    assert!(r.end > r.start, "non-empty n={n} parts={parts}");
                    covered += r.len();
                    next = r.end;
                }
                assert_eq!(covered, n, "n={n} parts={parts}");
                if n > 0 {
                    assert!(ranges.len() <= parts);
                    let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                    let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                    assert!(hi - lo <= 1, "balanced n={n} parts={parts}");
                }
            }
        }
    }
}
