//! Elastic serving: load-driven online repartitioning of the fabric
//! pool, with a rolling drain that never loses a request.
//!
//! The static serve tier hands every instance its whole topology up
//! front and never revisits the split. This runner starts from a
//! deliberately conservative partition instead — each instance exposes
//! only [`ElasticPolicy::initial_slots`] operator slots per class and
//! [`ElasticPolicy::initial_channels`] bus channels; the rest of the
//! fabric is held in reserve, modeled as a [`FabricHealth`]-style
//! overlay exactly like the fault layer's quarantine views — and then
//! reshapes that partition **online** from observed demand:
//!
//! 1. **Epoch loop.** Every [`ElasticPolicy::epoch_ticks`] virtual
//!    ticks the runner snapshots per-tenant demand (requests
//!    dispatched this epoch, plus the per-class operator/channel
//!    demand of each tenant's graphs, via
//!    [`FabricTopology::demand_cover`]) and recomputes the per-class
//!    slot floors the hot tenants need. Demand is read from the
//!    deterministic dispatch stream only — never from execution
//!    results — so the elastic run's schedule is byte-identical to a
//!    static-allocation run of the same profile.
//! 2. **Rolling repartition.** When the wanted reserve differs from
//!    the current one, instances are retopologized **one at a time**:
//!    instance `i` leaves the routing rotation for the drain window
//!    `(E + i·drain, E + (i+1)·drain]`, is drained, carries the new
//!    effective view, and is readmitted. A streamed batch whose
//!    residency overlaps its instance's drain window is checkpointed
//!    ([`StreamSession::snapshot`] → bytes → restore, the chaos tier's
//!    migration wire format) and finishes on the readmitted instance;
//!    [`StreamSession::run`] budgets *cumulative* rounds, so the
//!    drained session produces byte-identical outcomes. Batches the
//!    drain forces to wait are charged explicitly
//!    ([`ElasticStats::delayed_waves`] + queue-wait ticks).
//! 3. **Promotion.** After a repartition, every memoized route is
//!    recomputed against the new effective topology. A tenant whose
//!    graph now fits higher up the placed → sharded → reconfig →
//!    fallback lattice is *promoted*: its warm cache entry is dropped
//!    with a **targeted** invalidation
//!    ([`SessionCache::invalidate_hint`] — never the wholesale purge
//!    the fault layer uses) and the next batch serves on the better
//!    engine.
//!
//! The gate ([`crate::report::elastic`], `serve --elastic`): zero lost
//! requests, exact accounting, at least one rolling repartition and
//! one promotion, and per-request [`output_digest`]s byte-identical to
//! the static-allocation baseline — this same runner with
//! [`ElasticPolicy::static_allocation`] (epoch loop off, same initial
//! reserve). DESIGN.md §13 states the policy and the determinism
//! argument.

use super::loadgen::{self, LoadProfile, ServeRequest, WorkItem};
use super::sched::{
    batch_configs, choose_engine_routed, drive_profile, outcome_digest, output_digest,
    verify_outcomes, BatchResult, DispatchRec, EngineChoice, ExecutedBatch, Pending, ServeOptions,
};
use super::session::{route_graph, RoutePlan, SessionCache};
use super::stats::{elastic_metric, ElasticStats, ServeCollector, ServeReport};
use crate::coordinator::batch::{
    run_batch_lanes_prog, run_batch_native, run_batch_reconfig, run_batch_sharded,
};
use crate::dfg::{Graph, OpClass};
use crate::fabric::{FabricHealth, FabricPool, FabricTopology};
use crate::obs::{CounterSet, FlightRecorder, SpanKind, TraceBuf, TraceEvent};
use crate::opt::OptLevel;
use crate::sim::stream::run_stream_prevalidated;
use crate::sim::{SimOutcome, StreamCheckpoint, StreamSession, WaveInput, WaveMode};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

/// The repartitioner's knobs. Everything is in virtual ticks and
/// request counts, so a policy plus a profile seed fully determines
/// the elastic schedule.
#[derive(Debug, Clone)]
pub struct ElasticPolicy {
    /// Operator slots per class each instance exposes at start (the
    /// rest of the base topology is held in reserve).
    pub initial_slots: usize,
    /// Bus channels each instance exposes at start.
    pub initial_channels: usize,
    /// Demand-evaluation period in virtual ticks. `0` disables the
    /// epoch loop entirely — the static-allocation baseline.
    pub epoch_ticks: u64,
    /// Rolling-drain window per instance, in ticks: during a
    /// repartition instance `i` is out of rotation for
    /// `(E + i·drain_ticks, E + (i+1)·drain_ticks]`.
    pub drain_ticks: u64,
    /// Requests a tenant must have dispatched within one epoch to
    /// count as *hot* (and have its graphs' demand un-reserved).
    pub hot_requests: u64,
}

impl ElasticPolicy {
    /// The CLI preset: start with *nothing* un-reserved, so every
    /// tenant opens on the fallback engine and the first epoch's
    /// repartition has something to promote.
    pub fn scarce() -> Self {
        ElasticPolicy {
            initial_slots: 0,
            initial_channels: 0,
            epoch_ticks: 4,
            drain_ticks: 1,
            hot_requests: 4,
        }
    }

    /// A policy exposing the whole base topology from tick one —
    /// elastic machinery armed but with nothing to do; routes match
    /// the static serve tier's exactly.
    pub fn unreserved() -> Self {
        ElasticPolicy {
            initial_slots: usize::MAX,
            initial_channels: usize::MAX,
            epoch_ticks: 0,
            drain_ticks: 1,
            hot_requests: 1,
        }
    }

    /// This policy with the epoch loop disabled: the same initial
    /// reserve, never revisited. The digest gate's baseline.
    pub fn static_allocation(&self) -> Self {
        ElasticPolicy {
            epoch_ticks: 0,
            ..self.clone()
        }
    }
}

/// What one elastic run produced — the chaos outcome's shape, with the
/// repartition counters in place of the fault census.
#[derive(Debug)]
pub struct ElasticOutcome {
    pub report: ServeReport,
    /// The deterministic dispatch sequence — identical to the static
    /// baseline's, because the epoch loop never touches scheduling.
    pub dispatches: Vec<DispatchRec>,
    /// `(tenant, request seq)` → [`outcome_digest`]. Informational:
    /// promotions legitimately change cycle counters.
    pub digests: BTreeMap<(usize, usize), u64>,
    /// `(tenant, request seq)` → [`output_digest`]. The gate: must
    /// equal the static-allocation baseline's exactly.
    pub output_digests: BTreeMap<(usize, usize), u64>,
    /// Repartition/promotion counters (also in `report.elastic`).
    pub elastic: ElasticStats,
    /// Tenants promoted up the route lattice at least once, sorted.
    pub promoted_tenants: Vec<usize>,
    /// The run's full event stream in canonical trace order.
    pub events: Vec<TraceEvent>,
    /// Per-tenant event tails for gate-failure dumps.
    pub flight: FlightRecorder,
}

/// Observability context for the elastic runner — the `"elastic"`
/// counter family plus the same buffer/flight/external fanout the
/// chaos runner threads through its fault layer.
struct ElasticRt {
    counters: CounterSet,
    buf: TraceBuf,
    flight: FlightRecorder,
    external: Option<Arc<TraceBuf>>,
}

impl ElasticRt {
    fn new(n_tenants: usize, external: Option<Arc<TraceBuf>>) -> Self {
        ElasticRt {
            counters: CounterSet::new("elastic", &elastic_metric::NAMES),
            buf: TraceBuf::new(TraceBuf::DEFAULT_CAPACITY),
            flight: FlightRecorder::new(n_tenants, FlightRecorder::DEFAULT_TAIL),
            external,
        }
    }

    fn event(&mut self, ev: TraceEvent) {
        self.buf.record(ev);
        self.flight.record(ev);
        if let Some(tr) = &self.external {
            tr.record(ev);
        }
    }
}

/// One memoized elastic route: the graph it was computed for, the
/// tenant that first dispatched it (promotion attribution), and the
/// current route against the *elastic* effective topology — which the
/// session cache, keyed to the immutable base topology, cannot carry.
struct RouteEntry {
    tenant: usize,
    graph: Arc<Graph>,
    route: RoutePlan,
}

/// Lattice height, for promotion detection: strictly higher is a
/// strictly better residency.
fn rank(route: &RoutePlan) -> u8 {
    match route {
        RoutePlan::Fallback => 0,
        RoutePlan::Reconfig(_) => 1,
        RoutePlan::Sharded(_) => 2,
        RoutePlan::Placed => 3,
    }
}

/// The reserve overlay keeping `floors[class].max(min_slots)` slots
/// per class and `channels` channels effective, quarantining the rest
/// of `base` — the elastic analogue of a fault-layer health view,
/// consumed by the same [`FabricHealth::effective`] projection.
fn reserve_overlay(
    base: &FabricTopology,
    floors: &BTreeMap<OpClass, usize>,
    min_slots: usize,
    channels: usize,
) -> FabricHealth {
    let mut h = FabricHealth::healthy();
    for (&class, &have) in &base.slots {
        let keep = floors.get(&class).copied().unwrap_or(0).max(min_slots).min(have);
        if have > keep {
            h.lost_slots.insert(class, have - keep);
        }
    }
    h.lost_channels = base.channels.saturating_sub(channels);
    h
}

/// The repartitioner's whole mutable state, owned by the dispatch sink.
struct ElasticState {
    policy: ElasticPolicy,
    base: FabricTopology,
    /// The current reserve, uniform across instances.
    overlay: FabricHealth,
    /// Requests dispatched per tenant in the current epoch window.
    demand: Vec<u64>,
    /// Per cache hint: the elastic route memo (see [`RouteEntry`]).
    memo: BTreeMap<String, RouteEntry>,
    /// Next epoch boundary (0 = epoch loop disabled).
    next_epoch: u64,
    /// Per instance: the last rolling-drain window `(from, until]` —
    /// `until == from` means no drain has been scheduled yet.
    drain_from: Vec<u64>,
    drain_until: Vec<u64>,
    promoted: BTreeSet<usize>,
}

impl ElasticState {
    fn new(policy: &ElasticPolicy, base: FabricTopology, n_tenants: usize, pool: usize) -> Self {
        let overlay = reserve_overlay(
            &base,
            &BTreeMap::new(),
            policy.initial_slots,
            policy.initial_channels,
        );
        ElasticState {
            next_epoch: policy.epoch_ticks,
            policy: policy.clone(),
            base,
            overlay,
            demand: vec![0; n_tenants],
            memo: BTreeMap::new(),
            drain_from: vec![0; pool],
            drain_until: vec![0; pool],
            promoted: BTreeSet::new(),
        }
    }

    /// Is instance `i` out of rotation at `tick` (mid-drain)?
    fn draining(&self, i: usize, tick: u64) -> bool {
        self.drain_from[i] < tick && tick <= self.drain_until[i]
    }

    /// The reserve this epoch's demand wants: the demand cover of
    /// every hot tenant's memoized graphs un-reserved, everything
    /// else back behind the initial floor.
    fn wanted_overlay(&self) -> FabricHealth {
        let hot_graphs: Vec<&Graph> = self
            .memo
            .values()
            .filter(|e| self.demand[e.tenant] >= self.policy.hot_requests)
            .map(|e| e.graph.as_ref())
            .collect();
        let (floors, channels) = FabricTopology::demand_cover(hot_graphs);
        reserve_overlay(
            &self.base,
            &floors,
            self.policy.initial_slots,
            channels.max(self.policy.initial_channels),
        )
    }
}

/// Run `profile` to completion under `policy`. With
/// `policy.epoch_ticks == 0` this is the static-allocation baseline:
/// the initial reserve applies for the whole run and the epoch loop
/// never fires. Serial dispatch only, like the chaos runner — the
/// worker-invariance story is proven separately (DESIGN.md §10), and
/// composing it with repartitioning would blur what a digest mismatch
/// indicts.
pub fn run_profile_elastic(
    profile: &LoadProfile,
    opts: &ServeOptions,
    policy: &ElasticPolicy,
) -> ElasticOutcome {
    let wall0 = Instant::now();
    let cache = SessionCache::with_stripes(
        opts.topo.clone(),
        opts.pool_size,
        opts.cache_cap,
        OptLevel::Default,
        opts.cache_stripes,
    );
    let pool = FabricPool::new(opts.topo.clone(), opts.pool_size);
    let mut el = ElasticState::new(policy, opts.topo.clone(), profile.tenants.len(), pool.size());
    let mut rt = ElasticRt::new(profile.tenants.len(), opts.trace.clone());
    let names: Vec<String> = profile.tenants.iter().map(|t| t.name.clone()).collect();
    let mut collector = ServeCollector::new(&names);
    let mut executed: Vec<ExecutedBatch> = Vec::new();
    let (ticks, dispatches) =
        drive_profile(profile, &opts.cfg, &mut collector, |tick, tenant, batch| {
            if el.policy.epoch_ticks > 0 {
                process_epochs(&mut el, tick, &pool, &cache, &mut rt);
            }
            el.demand[tenant] += batch.len() as u64;
            for p in &batch {
                rt.event(TraceEvent {
                    kind: SpanKind::Admit,
                    tenant: tenant as u32,
                    seq: p.req.seq as u64,
                    tick: p.admitted_tick,
                    cycles: 0,
                    engine: "sched",
                    detail: 0,
                });
                rt.event(TraceEvent {
                    kind: SpanKind::BatchForm,
                    tenant: tenant as u32,
                    seq: p.req.seq as u64,
                    tick,
                    cycles: 0,
                    engine: "sched",
                    detail: batch.len() as u64,
                });
            }
            executed.push(exec_one_elastic(
                &cache, &pool, &mut el, tick, tenant, &batch, &mut rt,
            ));
        });
    // Record phase: identical bookkeeping to the chaos runner, plus
    // the outputs-only digest map the gate compares.
    let mut digests = BTreeMap::new();
    let mut output_digests = BTreeMap::new();
    let mut busy_ns = 0u64;
    let mut tokens_out = 0u64;
    let mut seen_hints: BTreeSet<&str> = BTreeSet::new();
    for eb in &executed {
        let (seq0, _, _) = eb.items[0];
        let cold = seen_hints.insert(eb.hint.as_str());
        rt.event(TraceEvent {
            kind: SpanKind::RouteSelect,
            tenant: eb.tenant as u32,
            seq: seq0 as u64,
            tick: eb.tick,
            cycles: 0,
            engine: eb.result.engine,
            detail: eb.items.len() as u64,
        });
        if cold {
            for kind in [SpanKind::Place, SpanKind::Compile] {
                rt.event(TraceEvent {
                    kind,
                    tenant: eb.tenant as u32,
                    seq: seq0 as u64,
                    tick: eb.tick,
                    cycles: 0,
                    engine: eb.result.engine,
                    detail: 0,
                });
            }
        }
        busy_ns += eb.exec_ns;
        collector.batch(eb.tenant, eb.result.engine, eb.items.len());
        collector.lane_scalar_reruns(eb.result.lane_scalar_reruns);
        for ((item, out), verified) in eb
            .items
            .iter()
            .zip(&eb.result.outcomes)
            .zip(&eb.result.verified)
        {
            let (seq, wait, latency) = *item;
            rt.event(TraceEvent {
                kind: SpanKind::Execute,
                tenant: eb.tenant as u32,
                seq: seq as u64,
                tick: eb.tick,
                cycles: out.cycles,
                engine: eb.result.engine,
                detail: 0,
            });
            collector.completed(eb.tenant, *verified, latency, wait, out.cycles);
            tokens_out += out.outputs.values().map(|s| s.len() as u64).sum::<u64>();
            digests.insert((eb.tenant, seq), outcome_digest(out));
            output_digests.insert((eb.tenant, seq), output_digest(out));
        }
    }
    let elastic = ElasticStats::from_counters(&rt.counters);
    let mut report = collector.finish(&cache, ticks);
    report.workers = 1;
    report.wall_ns = wall0.elapsed().as_nanos() as u64;
    report.busy_ns = busy_ns;
    report.tokens_out = tokens_out;
    report.elastic = Some(elastic);
    ElasticOutcome {
        report,
        dispatches,
        digests,
        output_digests,
        elastic,
        promoted_tenants: el.promoted.iter().copied().collect(),
        events: rt.buf.drain_sorted(),
        flight: rt.flight,
    }
}

/// Fold every epoch boundary `<= tick` that has not fired yet: demand
/// snapshot, reserve recomputation, and — when the wanted reserve
/// differs — the rolling repartition plus promotion sweep. Boundaries
/// are processed lazily at dispatch time (the sink only runs when a
/// batch dispatches), but always *at the boundary's own tick values*,
/// so the schedule of drains and promotions is a pure function of the
/// dispatch stream, exactly like the chaos runner's event cursor.
fn process_epochs(
    el: &mut ElasticState,
    tick: u64,
    pool: &FabricPool,
    cache: &SessionCache,
    rt: &mut ElasticRt,
) {
    while el.next_epoch <= tick {
        let e = el.next_epoch;
        el.next_epoch += el.policy.epoch_ticks;
        rt.counters.incr(elastic_metric::EPOCHS);
        let want = el.wanted_overlay();
        if want != el.overlay {
            rt.counters.incr(elastic_metric::REPARTITIONS);
            // Rolling drain: one instance at a time leaves the
            // rotation, swaps to the new effective view, and is
            // readmitted one drain window later.
            for i in 0..pool.size() {
                let from = e + i as u64 * el.policy.drain_ticks;
                el.drain_from[i] = from;
                el.drain_until[i] = from + el.policy.drain_ticks;
                rt.counters.incr(elastic_metric::DRAINS);
                rt.counters.incr(elastic_metric::RESTORES);
                rt.event(TraceEvent {
                    kind: SpanKind::Repartition,
                    tenant: TraceEvent::NO_TENANT,
                    seq: 0,
                    tick: el.drain_until[i],
                    cycles: 0,
                    engine: "elastic",
                    detail: i as u64,
                });
            }
            el.overlay = want;
            // Promotion sweep: every memoized route is recomputed
            // against the retopologized fabric. Climbing the lattice
            // is a promotion — the tenant's warm entry is dropped with
            // a *targeted* invalidation so only it pays a re-warm;
            // descending (a cooled tenant's reserve reclaimed) just
            // updates the memo.
            let eff = el.overlay.effective(&el.base);
            for (hint, entry) in el.memo.iter_mut() {
                let re = route_graph(entry.graph.as_ref(), &eff, pool.size());
                if rank(&re) > rank(&entry.route) {
                    rt.counters.incr(elastic_metric::PROMOTIONS);
                    el.promoted.insert(entry.tenant);
                    rt.event(TraceEvent {
                        kind: SpanKind::Promote,
                        tenant: entry.tenant as u32,
                        seq: 0,
                        tick: e,
                        cycles: 0,
                        engine: re.name(),
                        detail: el.demand[entry.tenant],
                    });
                    if cache.invalidate_hint(hint) {
                        rt.counters.incr(elastic_metric::TARGETED_INVALIDATIONS);
                    }
                }
                entry.route = re;
            }
        }
        el.demand.fill(0);
    }
}

/// [`super::sched::exec_one`] with the elastic layer underneath:
/// routes around draining instances, serves on the memoized elastic
/// route, drains resident stream sessions through the checkpoint wire
/// format, and charges drain stalls to the batch's queue-wait ticks.
#[allow(clippy::too_many_arguments)]
fn exec_one_elastic(
    cache: &SessionCache,
    pool: &FabricPool,
    el: &mut ElasticState,
    tick: u64,
    tenant: usize,
    batch: &[Pending],
    rt: &mut ElasticRt,
) -> ExecutedBatch {
    let reqs: Vec<ServeRequest> = batch.iter().map(|p| p.req.clone()).collect();
    let t0 = Instant::now();
    let (result, extra_wait) = execute_batch_elastic(cache, pool, el, tick, &reqs, rt);
    let exec_ns = t0.elapsed().as_nanos() as u64;
    let items = batch
        .iter()
        .map(|p| {
            (
                p.req.seq,
                tick.saturating_sub(p.admitted_tick) + extra_wait,
                p.submitted.elapsed().as_nanos() as u64,
            )
        })
        .collect();
    ExecutedBatch {
        tenant,
        tick,
        hint: batch[0].hint.clone(),
        result,
        items,
        exec_ns,
    }
}

/// Execute one same-graph batch under the elastic overlay. Returns the
/// batch result plus the virtual-tick drain delay (0 when no drain
/// interfered). Routes come from the elastic memo — the session
/// cache's routes are computed against the immutable base topology,
/// so the memo is what tracks the repartitioned world; the cache still
/// supplies the warm graph/program state.
fn execute_batch_elastic(
    cache: &SessionCache,
    pool: &FabricPool,
    el: &mut ElasticState,
    tick: u64,
    reqs: &[ServeRequest],
    rt: &mut ElasticRt,
) -> (BatchResult, u64) {
    assert!(!reqs.is_empty(), "empty batch");
    let hint = reqs[0].cache_hint();
    let (tenant, seq0) = (reqs[0].tenant, reqs[0].seq as u64);
    let (state, cache_hit) = cache.warm_keyed(&hint, || loadgen::build_graph(&reqs[0]));
    let items: Vec<WorkItem> = reqs.iter().map(loadgen::work_item).collect();
    let cfgs = batch_configs(&items);
    let g = state.graph.as_ref();

    // Memoize the elastic route on first sight of this graph, against
    // the *current* effective topology.
    let eff = el.overlay.effective(&el.base);
    let route = el
        .memo
        .entry(hint)
        .or_insert_with(|| RouteEntry {
            tenant,
            graph: Arc::clone(&state.graph),
            route: route_graph(g, &eff, pool.size()),
        })
        .route
        .clone();

    // Quarantine/readmit instances according to the rolling drain
    // schedule, then route around whatever is mid-drain. With the
    // whole pool draining at once (pool of 1), the batch waits for the
    // earliest readmission — charged explicitly, like a chaos retry.
    for i in 0..pool.size() {
        pool.set_down(i, el.draining(i, tick));
    }
    let mut extra_wait = 0u64;
    let instance = match pool.route_healthy() {
        Some(i) => i,
        None => {
            let i = (0..pool.size())
                .min_by_key(|&i| el.drain_until[i])
                .expect("pool has at least one instance");
            extra_wait = (el.drain_until[i] + 1).saturating_sub(tick);
            rt.counters
                .add(elastic_metric::DELAYED_WAVES, reqs.len() as u64);
            i
        }
    };

    let engine = choose_engine_routed(&route, state.overlap_safe, reqs.len());
    let waves_resident = cfgs.len() >= 2;
    let mut lane_scalar_reruns = 0u64;
    let outcomes: Vec<SimOutcome> = match (engine, &route) {
        (EngineChoice::Streamed, _) => {
            let waves: Vec<WaveInput> = items.iter().map(|it| it.inject.clone()).collect();
            let budget: u64 = cfgs.iter().map(|c| c.max_cycles).sum();
            // The batch is resident on `instance` over (T, T + waves].
            // A drain window opening inside that residency lands
            // mid-wave: checkpoint, hold through the drain, restore on
            // the readmitted instance.
            let horizon = tick + reqs.len() as u64;
            let drains_mid = el.drain_until[instance] > el.drain_from[instance]
                && el.drain_from[instance] >= tick
                && el.drain_from[instance] < horizon;
            if drains_mid {
                rt.event(TraceEvent {
                    kind: SpanKind::Migrate,
                    tenant: tenant as u32,
                    seq: seq0,
                    tick,
                    cycles: 0,
                    engine: "stream",
                    detail: instance as u64,
                });
                rt.counters
                    .add(elastic_metric::DELAYED_WAVES, reqs.len() as u64);
                extra_wait = extra_wait.max(el.policy.drain_ticks);
                run_streamed_drained(g, &waves, budget, rt)
            } else {
                run_stream_prevalidated(g, &waves, budget, WaveMode::Pipelined).0
            }
        }
        (EngineChoice::Lanes, _) => {
            let (outs, stats) = run_batch_lanes_prog(g, &state.program, &cfgs);
            lane_scalar_reruns = stats.scalar_reruns as u64;
            outs
        }
        (EngineChoice::Sharded, RoutePlan::Sharded(p)) => {
            run_batch_sharded(p, &cfgs, waves_resident)
        }
        (EngineChoice::Reconfig, RoutePlan::Reconfig(p)) => {
            run_batch_reconfig(p, pool.topology(), &cfgs, waves_resident)
        }
        (EngineChoice::Fallback, _) => run_batch_native(g, &cfgs),
        _ => unreachable!("engine choice always follows the memoized route"),
    };
    let verified = verify_outcomes(g, &items, &cfgs, &outcomes);
    (
        BatchResult {
            engine: engine.name(),
            cache_hit,
            lane_scalar_reruns,
            outcomes,
            verified,
        },
        extra_wait,
    )
}

/// Drain a streamed batch through the checkpoint wire format: run the
/// prefix on the instance being drained, snapshot, serialize to bytes,
/// decode, restore on the readmitted instance, finish. Identical
/// machinery to the chaos tier's outage migration
/// ([`super::chaos`]) — [`StreamSession::run`] budgets *cumulative*
/// rounds, so the drained session produces byte-identical per-wave
/// outcomes to an undrained run.
fn run_streamed_drained(
    g: &Graph,
    waves: &[WaveInput],
    budget: u64,
    rt: &mut ElasticRt,
) -> Vec<SimOutcome> {
    // Admission mirrors `run_stream_prevalidated`: pipelined first,
    // whole-batch demotion to a fresh serialized session if any wave
    // is rejected (mixed admission would reorder waves).
    let mut session = StreamSession::with_mode(g, WaveMode::Pipelined);
    if waves.iter().any(|w| session.admit(w).is_err()) {
        session = StreamSession::with_mode(g, WaveMode::Serialized);
        for w in waves {
            session.admit(w).expect("serialized admission is total");
        }
    }
    // A couple of prefix rounds so the drain genuinely lands with
    // tokens in flight; `run` caps cumulative rounds, so the restored
    // session still observes the one true budget.
    session.run(budget.clamp(1, 2));
    let image = session.snapshot().to_bytes();
    drop(session); // the old partition is gone; only the image survives
    let ck = StreamCheckpoint::from_bytes(&image).expect("self-produced checkpoint image decodes");
    rt.counters
        .add(elastic_metric::MIGRATED_WAVES, ck.waves_in_flight() as u64);
    let mut resumed =
        StreamSession::restore(g, &ck).expect("checkpoint restores onto the same graph content");
    resumed.run(budget);
    (0..resumed.n_waves()).map(|w| resumed.wave_outcome(w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{build, BenchId};
    use crate::serve::loadgen::{LoadProfile, TenantSpec, WorkKind};
    use crate::serve::{run_profile, Arrival, ServeCfg};

    fn opts() -> ServeOptions {
        ServeOptions::default()
    }

    #[test]
    fn unreserved_static_policy_matches_the_plain_serial_runner() {
        // With the whole base topology exposed and the epoch loop off,
        // the elastic runner IS run_profile's serial path: same
        // dispatch schedule, same full digests (counters included),
        // all-zero elastic counters.
        let p = loadgen::fairness_profile(2, 6, 11);
        let base = run_profile(&p, &opts());
        let el = run_profile_elastic(&p, &opts(), &ElasticPolicy::unreserved());
        assert_eq!(el.dispatches, base.dispatches);
        assert_eq!(el.digests, base.digests);
        assert_eq!(el.elastic, ElasticStats::default());
        assert_eq!(el.report.global.lost(), 0);
        assert!(el.promoted_tenants.is_empty());
        assert_eq!(
            el.report.elastic,
            Some(ElasticStats::default()),
            "an elastic run always reports its counters, even all-zero"
        );
    }

    #[test]
    fn scarce_start_promotes_the_hot_tenant_with_baseline_outputs() {
        // Pool of 1, everything reserved at start: every batch opens on
        // the fallback engine. The heavy all-SAXPY tenant (weight 4,
        // window 8, max_batch 4) dispatches 12 requests by the first
        // epoch boundary (tick 4) — hot — so the boundary un-reserves
        // the SAXPY demand cover, promotes the tenant fallback→placed
        // with a targeted invalidation, and starts the rolling drain of
        // instance 0 over (4, 5]. The promoted batch dispatched at tick
        // 4 itself goes streamed with that drain inside its residency,
        // so it is checkpoint-drained and restored. The light tenant
        // never crosses the hot threshold and stays where it started.
        let p = LoadProfile {
            tenants: vec![
                TenantSpec {
                    name: "heavy".to_string(),
                    weight: 4,
                    quota: 64,
                    window: 8,
                    mix: vec![WorkKind::Saxpy],
                    requests: 24,
                },
                TenantSpec {
                    name: "light".to_string(),
                    weight: 1,
                    quota: 16,
                    window: 2,
                    mix: vec![WorkKind::Bench(BenchId::Fibonacci)],
                    requests: 6,
                },
            ],
            arrival: Arrival::Closed,
            n: 6,
            seed: 3,
        };
        let o = ServeOptions {
            pool_size: 1,
            cfg: ServeCfg {
                max_batch: 4,
                ..Default::default()
            },
            ..opts()
        };
        let policy = ElasticPolicy {
            initial_slots: 0,
            initial_channels: 0,
            epoch_ticks: 4,
            drain_ticks: 1,
            hot_requests: 6,
        };
        let stat = run_profile_elastic(&p, &o, &policy.static_allocation());
        let el = run_profile_elastic(&p, &o, &policy);
        // The static baseline never repartitions anything.
        assert_eq!(stat.elastic, ElasticStats::default());
        assert!(stat.promoted_tenants.is_empty());
        // The elastic run did the whole dance...
        assert!(el.elastic.epochs >= 2, "{:?}", el.elastic);
        assert!(el.elastic.repartitions >= 1, "{:?}", el.elastic);
        assert!(el.elastic.promotions >= 1, "{:?}", el.elastic);
        assert_eq!(el.elastic.drains, el.elastic.restores);
        assert!(el.elastic.drains >= 1, "{:?}", el.elastic);
        assert!(el.elastic.migrated_waves >= 1, "{:?}", el.elastic);
        assert!(el.elastic.delayed_waves >= 1, "{:?}", el.elastic);
        assert!(el.elastic.targeted_invalidations >= 1, "{:?}", el.elastic);
        assert_eq!(el.promoted_tenants, vec![0], "only the hot tenant promotes");
        // ...and none of it is visible in the results: same dispatch
        // schedule, zero lost, exact accounting, byte-identical output
        // digests against the static-allocation baseline.
        assert_eq!(el.dispatches, stat.dispatches);
        assert_eq!(el.report.global.lost(), 0);
        let g = &el.report.global;
        assert_eq!(g.completed + g.shed(), g.submitted);
        assert_eq!(el.output_digests, stat.output_digests);
        // The promoted tenant genuinely served on a better engine.
        assert!(
            el.report.global.engine_requests.contains_key("streamed")
                || el.report.global.engine_requests.contains_key("lanes"),
            "{:?}",
            el.report.global.engine_requests
        );
        assert!(
            stat.report.global.engine_requests.keys().all(|&e| e == "fallback"),
            "{:?}",
            stat.report.global.engine_requests
        );
        // The timeline carries the repartition story.
        assert!(el.events.iter().any(|e| e.kind == SpanKind::Repartition));
        assert!(el.events.iter().any(|e| e.kind == SpanKind::Promote));
        assert!(el.events.iter().any(|e| e.kind == SpanKind::Migrate));
        assert!(stat.events.iter().all(|e| !matches!(
            e.kind,
            SpanKind::Repartition | SpanKind::Promote | SpanKind::Migrate
        )));
    }

    #[test]
    fn wanted_overlay_tracks_hot_demand_and_reclaims_when_cold() {
        // Pure policy check, no execution: a hot tenant's graph demand
        // is un-reserved; a cold epoch reclaims back to the initial
        // floor.
        let base = FabricTopology::serving();
        let policy = ElasticPolicy {
            initial_slots: 0,
            initial_channels: 0,
            epoch_ticks: 4,
            drain_ticks: 1,
            hot_requests: 4,
        };
        let mut el = ElasticState::new(&policy, base.clone(), 1, 1);
        let initial = el.overlay.clone();
        // Everything reserved at start: zero effective capacity.
        assert_eq!(el.overlay.effective(&base).total_slots(), 0);
        assert_eq!(el.overlay.effective(&base).channels, 0);
        let g = Arc::new(build(BenchId::DotProd));
        el.memo.insert(
            "bench:dot-product".to_string(),
            RouteEntry {
                tenant: 0,
                graph: Arc::clone(&g),
                route: RoutePlan::Fallback,
            },
        );
        // Cold tenant: the wanted reserve is the initial one.
        el.demand[0] = policy.hot_requests - 1;
        assert_eq!(el.wanted_overlay(), initial);
        // Hot tenant: the effective topology now covers its graph.
        el.demand[0] = policy.hot_requests;
        let want = el.wanted_overlay();
        assert_ne!(want, initial);
        assert!(want.effective(&base).fits(&g), "hot demand un-reserved");
        // And back: demand cools, the reserve reclaims.
        el.demand[0] = 0;
        assert_eq!(el.wanted_overlay(), initial);
    }
}
