//! Chaos serving: replay a seeded [`FaultPlan`] against the serving
//! pool while a load profile runs, and prove the recovery lattice
//! loses nothing.
//!
//! The runner mirrors [`run_profile`](super::run_profile)'s serial
//! path exactly — same tick loop ([`drive_profile`]), same collector,
//! same record phase — and layers the fault machinery underneath the
//! dispatch sink:
//!
//! 1. **Event application.** Before each dispatch, every due
//!    [`FaultEvent`] mutates its instance's [`FabricHealth`] view,
//!    flips the pool's quarantine flag, and purges the session cache's
//!    warm routes ([`SessionCache::invalidate_routes`]) — a stale
//!    `RoutePlan` against a changed topology is the classic
//!    silent-corruption bug, so invalidation is wholesale.
//! 2. **Routing.** [`FabricPool::route_healthy`] skips quarantined
//!    instances. With the whole pool dark, the runner probes the
//!    plan's own deterministic timeline ([`FaultPlan::healthy_at`]) at
//!    `T+1, T+3, T+7` — bounded virtual-tick backoff that keeps the
//!    chaos schedule a pure function of `(profile seed, fault seed)` —
//!    and charges the wait to the rescued requests. Only when the pool
//!    stays dark past the last probe does the batch demote to the
//!    infinite-fabric fallback engine.
//! 3. **Demotion.** A degraded-but-up instance re-routes the batch
//!    against what is actually left of it
//!    ([`FabricHealth::effective`]) through the same
//!    placed → sharded → reconfig → fallback lattice cold routing
//!    uses ([`route_graph`]), with the same engine policy
//!    ([`choose_engine_routed`]) — so a faulted route is never a
//!    special case, just a smaller topology.
//! 4. **Migration.** A streamed batch resident on an instance that the
//!    plan will take down mid-residency is checkpointed
//!    ([`StreamSession::snapshot`]), serialized to bytes, decoded, and
//!    restored on a healthy instance — and because
//!    [`StreamSession::run`] budgets *cumulative* rounds, the resumed
//!    session finishes the exact rounds the uninterrupted one would
//!    have: even the per-wave cycle counters match, byte for byte.
//!
//! The gate ([`crate::report::chaos`], `serve --chaos`): zero lost
//! requests, exact accounting (`completed + shed == submitted`), and
//! per-request [`output_digest`]s equal to a fault-free baseline run.
//! The baseline is this same runner under [`FaultPlan::empty`] — the
//! tick loop never reads execution results, so both runs make
//! identical dispatch decisions and the digest maps compare key for
//! key.

use super::loadgen::{self, LoadProfile, ServeRequest, WorkItem};
use super::sched::{
    batch_configs, choose_engine_routed, drive_profile, outcome_digest, output_digest,
    verify_outcomes, BatchResult, DispatchRec, EngineChoice, ExecutedBatch, Pending, ServeOptions,
};
use super::session::{route_graph, RoutePlan, SessionCache};
use super::stats::{chaos_metric, ChaosStats, ServeCollector, ServeReport};
use crate::coordinator::batch::{
    run_batch_lanes_prog, run_batch_native, run_batch_reconfig, run_batch_sharded,
};
use crate::dfg::Graph;
use crate::fabric::{FabricHealth, FabricPool, FaultKind, FaultPlan};
use crate::obs::{CounterSet, FlightRecorder, SpanKind, TraceBuf, TraceEvent};
use crate::opt::OptLevel;
use crate::sim::stream::run_stream_prevalidated;
use crate::sim::{SimOutcome, StreamCheckpoint, StreamSession, WaveInput, WaveMode};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

/// Virtual-tick backoff schedule for a batch that finds the whole pool
/// dark: probe `T+1`, then `T+3`, then `T+7`. Bounded — a pool still
/// dark at the last probe demotes to the fallback engine rather than
/// waiting forever — and deterministic, since the probes consult the
/// fault plan's timeline, not live state.
const RETRY_BACKOFF: [u64; 3] = [1, 3, 7];

/// What one chaos run produced: the usual profile outcome plus the
/// fault/recovery counters and the outputs-only digest map the gate
/// compares against the fault-free baseline.
#[derive(Debug)]
pub struct ChaosOutcome {
    pub report: ServeReport,
    /// The deterministic dispatch sequence — identical to the
    /// baseline's, because the tick loop never reads execution results.
    pub dispatches: Vec<DispatchRec>,
    /// `(tenant, request seq)` → [`outcome_digest`] (outputs *and*
    /// cycle/firing counters). Informational: demotions legitimately
    /// change counters, so this map is not the gate.
    pub digests: BTreeMap<(usize, usize), u64>,
    /// `(tenant, request seq)` → [`output_digest`] (output streams
    /// only). The gate: this map must equal the baseline's exactly.
    pub output_digests: BTreeMap<(usize, usize), u64>,
    /// Fault and recovery counters (also embedded in
    /// `report.chaos`).
    pub chaos: ChaosStats,
    /// The chaos run's full event stream in the canonical trace order
    /// (virtual ticks only) — the chaos path always records, because a
    /// chaos run's whole point is a reconstructible timeline.
    pub events: Vec<TraceEvent>,
    /// Flight recorder: the last-N per-tenant event tails, so a failed
    /// digest gate can dump exactly what happened to the diverging
    /// tenant ([`crate::report::chaos`]).
    pub flight: FlightRecorder,
}

/// The chaos runner's observability context, threaded through the
/// fault layer in place of the old bare `&mut ChaosStats`: the
/// `"chaos"` counter family ([`chaos_metric`]), an internal event
/// buffer, the per-tenant flight recorder, and an optional external
/// sink mirror ([`ServeOptions::trace`]).
struct ChaosRt {
    counters: CounterSet,
    buf: TraceBuf,
    flight: FlightRecorder,
    external: Option<Arc<TraceBuf>>,
}

impl ChaosRt {
    fn new(n_tenants: usize, external: Option<Arc<TraceBuf>>) -> Self {
        ChaosRt {
            counters: CounterSet::new("chaos", &chaos_metric::NAMES),
            buf: TraceBuf::new(TraceBuf::DEFAULT_CAPACITY),
            flight: FlightRecorder::new(n_tenants, FlightRecorder::DEFAULT_TAIL),
            external,
        }
    }

    /// Record one event everywhere it is wanted: the run's own buffer,
    /// the tenant's flight-recorder tail, and any external sink.
    fn event(&mut self, ev: TraceEvent) {
        self.buf.record(ev);
        self.flight.record(ev);
        if let Some(tr) = &self.external {
            tr.record(ev);
        }
    }
}

/// Run `profile` to completion while replaying `plan` against the
/// serving pool. Serial dispatch only: chaos runs are about fault
/// recovery, and the worker-count invariance story is already proven
/// separately (DESIGN.md §10) — composing both would blur which
/// machinery a digest mismatch indicts.
///
/// Every submitted request still ends completed or explicitly shed;
/// [`ChaosOutcome::chaos`] counts what the fault layer had to do to
/// keep that true.
pub fn run_profile_chaos(
    profile: &LoadProfile,
    opts: &ServeOptions,
    plan: &FaultPlan,
) -> ChaosOutcome {
    let wall0 = Instant::now();
    let cache = SessionCache::with_stripes(
        opts.topo.clone(),
        opts.pool_size,
        opts.cache_cap,
        OptLevel::Default,
        opts.cache_stripes,
    );
    let pool = FabricPool::new(opts.topo.clone(), opts.pool_size);
    let mut health: Vec<FabricHealth> = (0..pool.size()).map(|_| FabricHealth::default()).collect();
    let mut rt = ChaosRt::new(profile.tenants.len(), opts.trace.clone());
    let mut next_event = 0usize;
    let names: Vec<String> = profile.tenants.iter().map(|t| t.name.clone()).collect();
    let mut collector = ServeCollector::new(&names);
    let mut executed: Vec<ExecutedBatch> = Vec::new();
    let (ticks, dispatches) =
        drive_profile(profile, &opts.cfg, &mut collector, |tick, tenant, batch| {
            apply_due_events(plan, tick, &mut next_event, &pool, &cache, &mut health, &mut rt);
            for p in &batch {
                rt.event(TraceEvent {
                    kind: SpanKind::Admit,
                    tenant: tenant as u32,
                    seq: p.req.seq as u64,
                    tick: p.admitted_tick,
                    cycles: 0,
                    engine: "sched",
                    detail: 0,
                });
                rt.event(TraceEvent {
                    kind: SpanKind::BatchForm,
                    tenant: tenant as u32,
                    seq: p.req.seq as u64,
                    tick,
                    cycles: 0,
                    engine: "sched",
                    detail: batch.len() as u64,
                });
            }
            executed.push(exec_one_chaos(
                &cache, &pool, &health, plan, tick, tenant, &batch, &mut rt,
            ));
        });
    // Late events (after the last dispatch) still count as injected —
    // the seeded plan's guarantees are about the plan, not about how
    // fast the profile drained.
    apply_due_events(plan, u64::MAX, &mut next_event, &pool, &cache, &mut health, &mut rt);
    // Record phase: identical bookkeeping to `run_profile`, plus the
    // outputs-only digest map the gate compares.
    let mut digests = BTreeMap::new();
    let mut output_digests = BTreeMap::new();
    let mut busy_ns = 0u64;
    let mut tokens_out = 0u64;
    let mut seen_hints: BTreeSet<&str> = BTreeSet::new();
    for eb in &executed {
        let (seq0, _, _) = eb.items[0];
        let cold = seen_hints.insert(eb.hint.as_str());
        rt.event(TraceEvent {
            kind: SpanKind::RouteSelect,
            tenant: eb.tenant as u32,
            seq: seq0 as u64,
            tick: eb.tick,
            cycles: 0,
            engine: eb.result.engine,
            detail: eb.items.len() as u64,
        });
        if cold {
            for kind in [SpanKind::Place, SpanKind::Compile] {
                rt.event(TraceEvent {
                    kind,
                    tenant: eb.tenant as u32,
                    seq: seq0 as u64,
                    tick: eb.tick,
                    cycles: 0,
                    engine: eb.result.engine,
                    detail: 0,
                });
            }
        }
        busy_ns += eb.exec_ns;
        collector.batch(eb.tenant, eb.result.engine, eb.items.len());
        collector.lane_scalar_reruns(eb.result.lane_scalar_reruns);
        for ((item, out), verified) in eb
            .items
            .iter()
            .zip(&eb.result.outcomes)
            .zip(&eb.result.verified)
        {
            let (seq, wait, latency) = *item;
            rt.event(TraceEvent {
                kind: SpanKind::Execute,
                tenant: eb.tenant as u32,
                seq: seq as u64,
                tick: eb.tick,
                cycles: out.cycles,
                engine: eb.result.engine,
                detail: 0,
            });
            collector.completed(eb.tenant, *verified, latency, wait, out.cycles);
            tokens_out += out.outputs.values().map(|s| s.len() as u64).sum::<u64>();
            digests.insert((eb.tenant, seq), outcome_digest(out));
            output_digests.insert((eb.tenant, seq), output_digest(out));
        }
    }
    rt.counters
        .add(chaos_metric::ROUTE_INVALIDATIONS, cache.invalidations());
    let chaos = ChaosStats::from_counters(&rt.counters);
    let mut report = collector.finish(&cache, ticks);
    report.workers = 1;
    report.wall_ns = wall0.elapsed().as_nanos() as u64;
    report.busy_ns = busy_ns;
    report.tokens_out = tokens_out;
    report.chaos = Some(chaos);
    ChaosOutcome {
        report,
        dispatches,
        digests,
        output_digests,
        chaos,
        events: rt.buf.drain_sorted(),
        flight: rt.flight,
    }
}

/// Apply every plan event with `event.tick <= tick` that has not been
/// applied yet: mutate the instance's health view, sync the pool's
/// quarantine flag, purge warm routes, and count.
#[allow(clippy::too_many_arguments)]
fn apply_due_events(
    plan: &FaultPlan,
    tick: u64,
    next: &mut usize,
    pool: &FabricPool,
    cache: &SessionCache,
    health: &mut [FabricHealth],
    rt: &mut ChaosRt,
) {
    let events = plan.events();
    while *next < events.len() && events[*next].tick <= tick {
        let ev = events[*next];
        *next += 1;
        let idx = match ev.kind {
            FaultKind::SlotFail { .. } => chaos_metric::SLOT_FAULTS,
            FaultKind::BusFail { .. } => chaos_metric::BUS_FAULTS,
            FaultKind::Outage => chaos_metric::OUTAGES,
            FaultKind::Repair => chaos_metric::REPAIRS,
        };
        rt.counters.incr(idx);
        if let Some(h) = health.get_mut(ev.instance) {
            h.apply(ev.kind);
            pool.set_down(ev.instance, h.down);
            // The fabric under every cached RoutePlan just changed
            // shape; a stale warm route is a correctness bug, so the
            // purge is wholesale (re-warming is cheap next to a wrong
            // answer).
            cache.invalidate_routes();
            // Tenant-less pool-level instant: warm routes evicted
            // because instance `detail` changed shape at `ev.tick`.
            rt.event(TraceEvent {
                kind: SpanKind::Evict,
                tenant: TraceEvent::NO_TENANT,
                seq: 0,
                tick: ev.tick,
                cycles: 0,
                engine: "chaos",
                detail: ev.instance as u64,
            });
        }
    }
}

/// [`super::sched::exec_one`] with the fault layer underneath: routes
/// around quarantined instances, re-routes against degraded
/// topologies, migrates doomed stream residencies, and charges any
/// retry backoff to the batch's queue-wait ticks.
#[allow(clippy::too_many_arguments)]
fn exec_one_chaos(
    cache: &SessionCache,
    pool: &FabricPool,
    health: &[FabricHealth],
    plan: &FaultPlan,
    tick: u64,
    tenant: usize,
    batch: &[Pending],
    rt: &mut ChaosRt,
) -> ExecutedBatch {
    let reqs: Vec<ServeRequest> = batch.iter().map(|p| p.req.clone()).collect();
    let t0 = Instant::now();
    let (result, extra_wait) = execute_batch_chaos(cache, pool, health, plan, tick, &reqs, rt);
    let exec_ns = t0.elapsed().as_nanos() as u64;
    let items = batch
        .iter()
        .map(|p| {
            (
                p.req.seq,
                tick.saturating_sub(p.admitted_tick) + extra_wait,
                p.submitted.elapsed().as_nanos() as u64,
            )
        })
        .collect();
    ExecutedBatch {
        tenant,
        tick,
        hint: batch[0].hint.clone(),
        result,
        items,
        exec_ns,
    }
}

/// Execute one same-graph batch under the fault plan. Returns the
/// batch result plus the virtual-tick retry delay (0 when an instance
/// was available immediately). Under [`FaultPlan::empty`] this is
/// observably identical to [`super::execute_batch`]'s serial path —
/// that equivalence is what makes the baseline comparison honest.
fn execute_batch_chaos(
    cache: &SessionCache,
    pool: &FabricPool,
    health: &[FabricHealth],
    plan: &FaultPlan,
    tick: u64,
    reqs: &[ServeRequest],
    rt: &mut ChaosRt,
) -> (BatchResult, u64) {
    assert!(!reqs.is_empty(), "empty batch");
    let hint = reqs[0].cache_hint();
    let (tenant, seq0) = (reqs[0].tenant as u32, reqs[0].seq as u64);
    let (state, cache_hit) = cache.warm_keyed(&hint, || loadgen::build_graph(&reqs[0]));
    let items: Vec<WorkItem> = reqs.iter().map(loadgen::work_item).collect();
    let cfgs = batch_configs(&items);
    let g = state.graph.as_ref();

    // Route to an instance still in rotation. With the whole pool dark,
    // probe the plan's own timeline. An instance found *up* at a future
    // probe tick is not necessarily *whole* — slot/bus quarantine can
    // survive the tick that ended its outage — so the probe replays
    // the full health view ([`FaultPlan::health_at`]) and routes
    // against it, exactly as the live overlay would at that tick.
    let mut extra_wait = 0u64;
    let routed: Option<(usize, FabricHealth)> = match pool.route_healthy() {
        Some(i) => Some((i, health[i].clone())),
        None => {
            let mut found = None;
            for delta in RETRY_BACKOFF {
                rt.counters.incr(chaos_metric::RETRIES);
                rt.event(TraceEvent {
                    kind: SpanKind::Retry,
                    tenant,
                    seq: seq0,
                    tick,
                    cycles: 0,
                    engine: "chaos",
                    detail: delta,
                });
                let probe = (0..pool.size())
                    .map(|i| (i, plan.health_at(tick + delta, i)))
                    .find(|(_, h)| !h.down);
                if let Some((i, h)) = probe {
                    extra_wait = delta;
                    found = Some((i, h));
                    break;
                }
            }
            found
        }
    };

    // Retry exhausted with the pool still dark. The request must still
    // complete — the zero-lost invariant outranks placement — so it
    // demotes to the lattice's bottom: the infinite-fabric engine.
    let Some((instance, inst_health)) = routed else {
        rt.counters.incr(chaos_metric::DEMOTIONS);
        rt.event(TraceEvent {
            kind: SpanKind::Demote,
            tenant,
            seq: seq0,
            tick,
            cycles: 0,
            engine: EngineChoice::Fallback.name(),
            detail: 0,
        });
        let outcomes = run_batch_native(g, &cfgs);
        let verified = verify_outcomes(g, &items, &cfgs, &outcomes);
        return (
            BatchResult {
                engine: EngineChoice::Fallback.name(),
                cache_hit,
                lane_scalar_reruns: 0,
                outcomes,
                verified,
            },
            extra_wait,
        );
    };

    // A degraded instance re-routes against what is actually left of
    // it. Crossing a lattice tier (placed batch now needs sharding,
    // shardable graph now needs reconfig swapping, …) is a demotion;
    // same tier on a smaller fabric is not.
    let route = if inst_health.is_degraded() {
        let eff = inst_health.effective(pool.topology());
        let re = route_graph(g, &eff, pool.healthy_count().max(1));
        if re.name() != state.route.name() {
            rt.counters.incr(chaos_metric::DEMOTIONS);
            rt.event(TraceEvent {
                kind: SpanKind::Demote,
                tenant,
                seq: seq0,
                tick,
                cycles: 0,
                engine: re.name(),
                detail: 1,
            });
        }
        re
    } else {
        state.route.clone()
    };

    let engine = choose_engine_routed(&route, state.overlap_safe, reqs.len());
    let waves_resident = cfgs.len() >= 2;
    let mut lane_scalar_reruns = 0u64;
    let outcomes: Vec<SimOutcome> = match (engine, &route) {
        (EngineChoice::Streamed, _) => {
            let waves: Vec<WaveInput> = items.iter().map(|it| it.inject.clone()).collect();
            let budget: u64 = cfgs.iter().map(|c| c.max_cycles).sum();
            // The batch is resident on `instance` for its whole
            // multi-wave run — model that residency as the tick window
            // (T, T + waves]. An outage scheduled inside it lands
            // mid-wave: checkpoint, move, resume.
            let horizon = tick + reqs.len() as u64;
            let doomed = plan.events().iter().any(|e| {
                e.instance == instance
                    && e.kind == FaultKind::Outage
                    && e.tick > tick
                    && e.tick <= horizon
            });
            if doomed {
                rt.event(TraceEvent {
                    kind: SpanKind::Migrate,
                    tenant,
                    seq: seq0,
                    tick,
                    cycles: 0,
                    engine: "stream",
                    detail: instance as u64,
                });
                run_streamed_migrated(g, &waves, budget, rt)
            } else {
                run_stream_prevalidated(g, &waves, budget, WaveMode::Pipelined).0
            }
        }
        (EngineChoice::Lanes, _) => {
            let (outs, stats) = run_batch_lanes_prog(g, &state.program, &cfgs);
            lane_scalar_reruns = stats.scalar_reruns as u64;
            outs
        }
        (EngineChoice::Sharded, RoutePlan::Sharded(p)) => run_batch_sharded(p, &cfgs, waves_resident),
        (EngineChoice::Reconfig, RoutePlan::Reconfig(p)) => {
            run_batch_reconfig(p, pool.topology(), &cfgs, waves_resident)
        }
        (EngineChoice::Fallback, _) => run_batch_native(g, &cfgs),
        _ => unreachable!("engine choice always follows the chosen route"),
    };
    let verified = verify_outcomes(g, &items, &cfgs, &outcomes);
    (
        BatchResult {
            engine: engine.name(),
            cache_hit,
            lane_scalar_reruns,
            outcomes,
            verified,
        },
        extra_wait,
    )
}

/// Run a streamed batch whose instance dies mid-residency: run the
/// prefix on the doomed instance, checkpoint, serialize the image to
/// bytes (the migration wire format), decode, restore on a healthy
/// instance, and finish. [`StreamSession::run`] budgets *cumulative*
/// rounds — the checkpoint carries the round counter — so the
/// resumed session executes exactly the rounds the uninterrupted run
/// would have, and every wave's outcome (outputs *and* cycle
/// accounting) is byte-identical to a fault-free run.
fn run_streamed_migrated(
    g: &Graph,
    waves: &[WaveInput],
    budget: u64,
    rt: &mut ChaosRt,
) -> Vec<SimOutcome> {
    rt.counters.incr(chaos_metric::MIGRATIONS);
    // Admission mirrors `run_stream_prevalidated`: pipelined first,
    // and any wave the pipelined policy rejects demotes the whole
    // batch to a fresh serialized session (mixed admission would
    // reorder waves). Rebuilding from scratch lands in the same state
    // the probe-first path does.
    let mut session = StreamSession::with_mode(g, WaveMode::Pipelined);
    if waves.iter().any(|w| session.admit(w).is_err()) {
        session = StreamSession::with_mode(g, WaveMode::Serialized);
        for w in waves {
            session.admit(w).expect("serialized admission is total");
        }
    }
    // Prefix on the doomed instance: a couple of rounds, not a share
    // of the (huge) budget — the budget is a timeout, and any real
    // wave outlives two rounds, so the outage genuinely lands with
    // tokens in flight. `run` caps *cumulative* rounds, so the resumed
    // session still observes the one true budget.
    session.run(budget.clamp(1, 2));
    let image = session.snapshot().to_bytes();
    drop(session); // the instance is gone; only the image survives
    let ck = StreamCheckpoint::from_bytes(&image).expect("self-produced checkpoint image decodes");
    rt.counters
        .add(chaos_metric::RESCUED_WAVES, ck.waves_in_flight() as u64);
    let mut resumed =
        StreamSession::restore(g, &ck).expect("checkpoint restores onto the same graph content");
    resumed.run(budget);
    (0..resumed.n_waves()).map(|w| resumed.wave_outcome(w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FaultEvent;
    use crate::serve::loadgen::{fairness_profile, tenant_trace, LoadProfile, TenantSpec, WorkKind};
    use crate::serve::{run_profile, Arrival};

    fn opts() -> ServeOptions {
        ServeOptions::default()
    }

    #[test]
    fn empty_plan_matches_the_plain_serial_runner() {
        // The chaos runner under no faults IS run_profile's serial
        // path: same dispatch schedule, same per-request digests (the
        // full ones, counters included), no fault counters.
        let p = fairness_profile(2, 6, 11);
        let base = run_profile(&p, &opts());
        let chaos = run_profile_chaos(&p, &opts(), &FaultPlan::empty());
        assert_eq!(chaos.dispatches, base.dispatches);
        assert_eq!(chaos.digests, base.digests);
        assert_eq!(chaos.chaos, ChaosStats::default());
        assert_eq!(chaos.report.global.lost(), 0);
        assert_eq!(
            chaos.report.chaos,
            Some(ChaosStats::default()),
            "a chaos run always reports its counters, even all-zero"
        );
    }

    #[test]
    fn outage_mid_residency_migrates_and_outputs_match_baseline() {
        // One all-SAXPY tenant, window == max_batch == requests == 8:
        // tick 1 admits all 8, forming one full streamed batch resident
        // over ticks (1, 9]. An outage at tick 2 on its (only)
        // instance lands mid-residency → checkpoint migration.
        let p = LoadProfile {
            tenants: vec![TenantSpec {
                name: "heavy".to_string(),
                weight: 1,
                quota: 64,
                window: 8,
                mix: vec![WorkKind::Saxpy],
                requests: 8,
            }],
            arrival: Arrival::Closed,
            n: 6,
            seed: 3,
        };
        let o = ServeOptions {
            pool_size: 1,
            cfg: crate::serve::ServeCfg {
                max_batch: 8,
                ..Default::default()
            },
            ..opts()
        };
        let plan = FaultPlan::new(vec![
            FaultEvent {
                tick: 2,
                instance: 0,
                kind: FaultKind::Outage,
            },
            FaultEvent {
                tick: 4,
                instance: 0,
                kind: FaultKind::Repair,
            },
        ]);
        let base = run_profile_chaos(&p, &o, &FaultPlan::empty());
        let faulted = run_profile_chaos(&p, &o, &plan);
        assert_eq!(faulted.chaos.migrations, 1, "{:?}", faulted.chaos);
        assert!(faulted.chaos.rescued_waves > 0, "{:?}", faulted.chaos);
        assert_eq!(faulted.chaos.outages, 1);
        assert_eq!(faulted.chaos.repairs, 1);
        assert_eq!(faulted.report.global.lost(), 0);
        // Migration is invisible in the results — not just outputs:
        // cumulative round budgeting makes even the cycle counters
        // match, so the FULL digests agree.
        assert_eq!(faulted.digests, base.digests);
        assert_eq!(faulted.output_digests, base.output_digests);
        assert!(
            faulted
                .report
                .global
                .engine_requests
                .contains_key("streamed"),
            "{:?}",
            faulted.report.global.engine_requests
        );
        // The chaos run records its own timeline: the migration and the
        // route eviction show up as events, and the tenant's
        // flight-recorder tail holds the migration for gate dumps.
        assert!(faulted.events.iter().any(|e| e.kind == SpanKind::Migrate));
        assert!(faulted.events.iter().any(|e| e.kind == SpanKind::Evict));
        let tl = faulted.flight.timeline(0);
        assert!(tl.iter().any(|e| e.kind == SpanKind::Migrate), "{tl:?}");
        assert!(tl.iter().any(|e| e.kind == SpanKind::Execute), "{tl:?}");
        // The fault-free baseline records lifecycle events only.
        assert!(base.events.iter().all(|e| !matches!(
            e.kind,
            SpanKind::Migrate | SpanKind::Retry | SpanKind::Demote | SpanKind::Evict
        )));
        assert!(!base.events.is_empty());
    }

    #[test]
    fn dark_pool_retries_on_the_plan_timeline_and_loses_nothing() {
        // Pool of 1, outage from tick 1. Dispatches finding the pool
        // dark probe the plan timeline; once the repair (tick 6) is
        // inside a probe window the batch waits the probed delay and
        // serves at base capacity (a batch whose probes all missed
        // would demote to fallback instead). Either way: zero lost,
        // outputs match the fault-free baseline.
        let p = fairness_profile(1, 5, 7);
        let o = ServeOptions {
            pool_size: 1,
            ..opts()
        };
        let plan = FaultPlan::new(vec![
            FaultEvent {
                tick: 1,
                instance: 0,
                kind: FaultKind::Outage,
            },
            FaultEvent {
                tick: 6,
                instance: 0,
                kind: FaultKind::Repair,
            },
        ]);
        let base = run_profile_chaos(&p, &o, &FaultPlan::empty());
        let faulted = run_profile_chaos(&p, &o, &plan);
        assert!(faulted.chaos.retries > 0, "{:?}", faulted.chaos);
        assert_eq!(faulted.report.global.lost(), 0);
        let g = &faulted.report.global;
        assert_eq!(g.completed + g.shed(), g.submitted);
        assert_eq!(faulted.output_digests, base.output_digests);
    }

    #[test]
    fn probe_found_instance_keeps_its_quarantine_and_is_not_treated_as_whole() {
        // Regression for the retry probe conjuring
        // `FabricHealth::default()`: pool of 1, dark from tick 1, whose
        // repair at tick 3 is followed by a slot quarantine at tick 4 —
        // exactly the tick the T+3 probe lands on. The probed instance
        // is up but NOT whole; the batch must re-route against its
        // degraded effective topology (a demotion), not serve on the
        // full base capacity the old probe assumed. Pre-fix this
        // records zero demotions and the assertion fails.
        let p = LoadProfile {
            tenants: vec![TenantSpec {
                name: "heavy".to_string(),
                weight: 1,
                quota: 64,
                window: 8,
                mix: vec![WorkKind::Saxpy],
                requests: 8,
            }],
            arrival: Arrival::Closed,
            n: 6,
            seed: 3,
        };
        let o = ServeOptions {
            pool_size: 1,
            cfg: crate::serve::ServeCfg {
                max_batch: 8,
                ..Default::default()
            },
            ..opts()
        };
        let plan = FaultPlan::new(vec![
            FaultEvent {
                tick: 1,
                instance: 0,
                kind: FaultKind::Outage,
            },
            FaultEvent {
                tick: 3,
                instance: 0,
                kind: FaultKind::Repair,
            },
            FaultEvent {
                tick: 4,
                instance: 0,
                kind: FaultKind::SlotFail {
                    class: crate::dfg::OpClass::Alu2,
                    count: 1 << 10,
                },
            },
            FaultEvent {
                tick: 9,
                instance: 0,
                kind: FaultKind::Repair,
            },
        ]);
        // The T+1 probe (tick 2) misses — still in outage; the T+3
        // probe (tick 4) finds the instance up and quarantined.
        assert!(!plan.healthy_at(2, 0));
        assert!(plan.healthy_at(4, 0));
        assert!(plan.health_at(4, 0).is_degraded());
        let base = run_profile_chaos(&p, &o, &FaultPlan::empty());
        let faulted = run_profile_chaos(&p, &o, &plan);
        assert!(faulted.chaos.retries > 0, "{:?}", faulted.chaos);
        assert!(
            faulted.chaos.demotions > 0,
            "probe treated a degraded-but-up instance as whole: {:?}",
            faulted.chaos
        );
        assert_eq!(faulted.report.global.lost(), 0);
        let g = &faulted.report.global;
        assert_eq!(g.completed + g.shed(), g.submitted);
        assert_eq!(faulted.output_digests, base.output_digests);
    }

    #[test]
    fn degraded_capacity_demotes_down_the_lattice_with_identical_outputs() {
        // Slot+bus faults big enough to clamp the instance to zero
        // capacity (but not an outage): batches re-route against the
        // degraded topology — a demotion — and still produce baseline
        // outputs.
        let p = fairness_profile(1, 5, 13);
        let o = ServeOptions {
            pool_size: 1,
            ..opts()
        };
        let plan = FaultPlan::new(vec![
            FaultEvent {
                tick: 2,
                instance: 0,
                kind: FaultKind::SlotFail {
                    class: crate::dfg::OpClass::Alu2,
                    count: 1 << 10,
                },
            },
            FaultEvent {
                tick: 2,
                instance: 0,
                kind: FaultKind::BusFail {
                    channels: 1 << 10,
                },
            },
            FaultEvent {
                tick: 9,
                instance: 0,
                kind: FaultKind::Repair,
            },
        ]);
        let base = run_profile_chaos(&p, &o, &FaultPlan::empty());
        let faulted = run_profile_chaos(&p, &o, &plan);
        assert_eq!(faulted.chaos.slot_faults, 1);
        assert_eq!(faulted.chaos.bus_faults, 1);
        assert!(faulted.chaos.demotions > 0, "{:?}", faulted.chaos);
        assert!(faulted.chaos.route_invalidations > 0);
        assert_eq!(faulted.report.global.lost(), 0);
        assert_eq!(faulted.output_digests, base.output_digests);
    }

    #[test]
    fn seeded_plan_gate_holds_on_the_fairness_profile() {
        // The CLI gate in miniature: seeded plan over a 2-instance
        // pool, 10:1 fairness profile — at least one of each fault
        // kind injected, zero lost, exact accounting, byte-identical
        // outputs vs baseline.
        let p = fairness_profile(2, 6, 21);
        let o = ServeOptions {
            pool_size: 2,
            ..opts()
        };
        let plan = FaultPlan::seeded(21, 2);
        let c = plan.counts();
        assert!(c.slot >= 1 && c.bus >= 1 && c.outage >= 1);
        let base = run_profile_chaos(&p, &o, &FaultPlan::empty());
        let faulted = run_profile_chaos(&p, &o, &plan);
        assert!(faulted.chaos.faults_injected() >= 3, "{:?}", faulted.chaos);
        assert_eq!(faulted.report.global.lost(), 0);
        let g = &faulted.report.global;
        assert_eq!(g.completed + g.shed(), g.submitted);
        assert_eq!(faulted.dispatches, base.dispatches);
        assert_eq!(faulted.output_digests, base.output_digests);
        // Both runs completed the same request set (digest maps equal
        // ⇒ same keys), and every heavy request is in there.
        let heavy = tenant_trace(&p, 0).len();
        assert!(faulted.output_digests.keys().filter(|(t, _)| *t == 0).count() <= heavy);
    }
}
