//! Deterministic load generation over mixed workloads: all seven
//! benchmarks (the paper's six loop schemas plus SAXPY) and seeded
//! random DFGs from [`crate::util::proptest`], organized into tenants
//! with weights, quotas and arrival patterns.
//!
//! Everything derives from the profile seed: the per-tenant request
//! *trace* (kind, size, workload seed per sequence number) is a pure
//! function of `(profile.seed, tenant index)`, so the same seed always
//! offers the same load — the property `rust/tests/serve.rs` pins.
//! What is *not* deterministic is wall-clock latency; the scheduler
//! therefore keys all scheduling decisions off virtual ticks and uses
//! wall time only for the reported histograms.

use crate::bench_defs::{self, BenchId};
use crate::dfg::{Graph, Word};
use crate::util::proptest::{random_dfg, random_workload, GenGraph};
use crate::util::Rng;
use std::collections::BTreeMap;

/// One unit of work a tenant can request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkKind {
    /// One of the paper's six benchmarks.
    Bench(BenchId),
    /// The pipelineable SAXPY workload.
    Saxpy,
    /// A seeded random DFG from the conformance generator. The graph
    /// identity is derived from the request seed (see
    /// [`ServeRequest::graph_seed`]), so tenants revisit a small graph
    /// family and the session cache gets realistic reuse.
    Random { branchy: bool },
}

/// One fully-specified request in a tenant's trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeRequest {
    pub tenant: usize,
    /// Monotonic per-tenant sequence number.
    pub seq: usize,
    pub kind: WorkKind,
    /// Workload size (vector length / trip count).
    pub n: usize,
    /// Workload seed (inputs derive from it).
    pub seed: u64,
}

/// Distinct random graphs per `Random` arm — small, so repeat requests
/// hit warm sessions the way repeat tenants would in production.
const RANDOM_GRAPH_FAMILY: u64 = 5;

impl ServeRequest {
    /// The seed that fixes a `Random` request's *graph* (as opposed to
    /// its workload): folded into a small family for cache reuse.
    pub fn graph_seed(&self) -> u64 {
        self.seed % RANDOM_GRAPH_FAMILY
    }

    /// A cache key stable across requests for the same graph content —
    /// what [`crate::serve::SessionCache::warm_keyed`] indexes by.
    pub fn cache_hint(&self) -> String {
        match self.kind {
            WorkKind::Bench(b) => format!("bench:{}", b.slug()),
            WorkKind::Saxpy => "saxpy".to_string(),
            WorkKind::Random { branchy } => {
                format!("gen:{}:{}", branchy as u8, self.graph_seed())
            }
        }
    }
}

/// Build (or for `Random`, regenerate) the request's graph. Cache
/// misses only; hits resolve through the hint index without building.
pub fn build_graph(req: &ServeRequest) -> Graph {
    match req.kind {
        WorkKind::Bench(b) => bench_defs::build(b),
        WorkKind::Saxpy => bench_defs::saxpy::build(),
        WorkKind::Random { branchy } => gen_graph(req, branchy).graph,
    }
}

fn gen_graph(req: &ServeRequest, branchy: bool) -> GenGraph {
    let mut r = Rng::new(0x6E6E_6772 ^ (req.graph_seed() << 8) ^ branchy as u64);
    random_dfg(&mut r, branchy)
}

/// A request's injection streams, expected outputs (when the workload
/// has a closed-form reference) and round budget.
#[derive(Debug, Clone)]
pub struct WorkItem {
    pub inject: BTreeMap<String, Vec<Word>>,
    /// `None` means the oracle is a scalar `TokenSim` run (random
    /// DFGs); the executor computes and compares it after the engine.
    pub expect: Option<BTreeMap<String, Vec<Word>>>,
    pub max_cycles: u64,
}

/// Materialize the workload half of a request (the graph half goes
/// through the session cache).
pub fn work_item(req: &ServeRequest) -> WorkItem {
    match req.kind {
        WorkKind::Bench(b) => {
            let wl = bench_defs::workload(b, req.n, req.seed);
            WorkItem {
                inject: wl.inject,
                expect: Some(wl.expect),
                max_cycles: wl.max_cycles,
            }
        }
        WorkKind::Saxpy => {
            let (inject, z) = bench_defs::saxpy::wave(req.n, req.seed);
            WorkItem {
                inject,
                expect: Some(BTreeMap::from([("z".to_string(), z)])),
                max_cycles: 100_000,
            }
        }
        WorkKind::Random { branchy } => {
            // Regenerating the GenGraph here (per item) is deliberate:
            // only its *port contract* is needed to shape the workload,
            // the graphs are tiny (≲ a few dozen nodes), and every
            // random item already pays a full scalar `TokenSim` oracle
            // run at verification — graph generation is noise next to
            // that. The expensive half (compile/place/route) still
            // comes from the session cache.
            let gg = gen_graph(req, branchy);
            let mut r = Rng::new(req.seed ^ 0x5EED_F00D);
            // Short streams: random routing strands tokens, so budgets
            // stay modest and deadlocked items are cheap to flush.
            let inject = random_workload(&mut r, &gg, req.n.clamp(1, 4));
            WorkItem {
                inject,
                expect: None,
                max_cycles: 200_000,
            }
        }
    }
}

/// One tenant's offered load and service parameters.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Weighted-fair share: dispatch credits per scheduler refill.
    pub weight: u32,
    /// Max requests this tenant may have queued; admission sheds
    /// beyond it (explicitly).
    pub quota: usize,
    /// Closed-loop window: target outstanding (queued) requests.
    pub window: usize,
    /// The request mix, sampled uniformly per request.
    pub mix: Vec<WorkKind>,
    /// Total requests the tenant offers over the profile.
    pub requests: usize,
}

/// How requests arrive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Closed loop: each tenant tops its queue up to `window` every
    /// tick (the next request "arrives" as soon as a slot frees).
    Closed,
    /// Open loop: `burst` requests per tenant per tick regardless of
    /// completions — the oversubscription / shedding regime.
    Open { burst: usize },
    /// Open-loop concurrency sweep: the per-tenant burst *ramps* —
    /// `base` at tick 1, growing by `step` per tick, capped at `cap` —
    /// so in-flight batches pile up until every worker of an N-worker
    /// pool has independent work. Deterministic in the tick alone:
    /// same seed + same arrival ⇒ same trace, at any worker count.
    BurstSeries {
        base: usize,
        step: usize,
        cap: usize,
    },
}

impl Arrival {
    /// Requests per tenant arriving at `tick` (1-based) for the
    /// open-loop modes; `None` for [`Arrival::Closed`], whose arrivals
    /// depend on queue occupancy rather than the tick.
    pub fn burst_at(self, tick: u64) -> Option<usize> {
        match self {
            Arrival::Closed => None,
            Arrival::Open { burst } => Some(burst.max(1)),
            Arrival::BurstSeries { base, step, cap } => {
                let ramp =
                    base.saturating_add(step.saturating_mul(tick.saturating_sub(1) as usize));
                // Not `clamp`: `cap` may legitimately sit below 1's
                // floor only when misconfigured, and the floor wins.
                let capped = if ramp > cap { cap } else { ramp };
                Some(capped.max(1))
            }
        }
    }
}

/// The burst series sized to saturate an N-worker pool: starts at N
/// per tenant per tick and ramps to 8·N, so the dispatch loop always
/// has several same-graph batches in flight per worker once the ramp
/// tops out.
pub fn burst_series(workers: usize) -> Arrival {
    let w = workers.max(1);
    Arrival::BurstSeries {
        base: w,
        step: w,
        cap: 8 * w,
    }
}

/// A complete load profile: tenants, arrival pattern, workload size,
/// and the seed everything derives from.
#[derive(Debug, Clone)]
pub struct LoadProfile {
    pub tenants: Vec<TenantSpec>,
    pub arrival: Arrival,
    /// Workload size per request.
    pub n: usize,
    pub seed: u64,
}

/// The full deterministic request trace for tenant `t` — same
/// `(profile.seed, t)` ⇒ same trace, independent of scheduling.
pub fn tenant_trace(profile: &LoadProfile, t: usize) -> Vec<ServeRequest> {
    let spec = &profile.tenants[t];
    assert!(
        spec.requests == 0 || !spec.mix.is_empty(),
        "tenant `{}`: a non-empty trace needs a non-empty mix",
        spec.name
    );
    let mut r = Rng::new(profile.seed ^ ((t as u64 + 1) << 40));
    (0..spec.requests)
        .map(|seq| ServeRequest {
            tenant: t,
            seq,
            kind: spec.mix[r.below(spec.mix.len())],
            n: profile.n,
            seed: r.next_u64(),
        })
        .collect()
}

/// The fixed three-tenant mix the `serve` CLI and CI smoke job run:
/// an interactive tenant (weight 4, latency-sensitive benchmarks +
/// SAXPY), a batch tenant (weight 2, the whole suite), and a fuzz
/// tenant (weight 1, random DFGs). `scale` multiplies per-tenant
/// request counts (offered load stays 4:2:1).
pub fn standard_profile(scale: usize, n: usize, seed: u64) -> LoadProfile {
    let scale = scale.max(1);
    LoadProfile {
        tenants: vec![
            TenantSpec {
                name: "interactive".to_string(),
                weight: 4,
                quota: 64,
                window: 8,
                mix: vec![
                    WorkKind::Bench(BenchId::Fibonacci),
                    WorkKind::Bench(BenchId::DotProd),
                    WorkKind::Bench(BenchId::Max),
                    WorkKind::Saxpy,
                ],
                requests: 4 * scale,
            },
            TenantSpec {
                name: "batch".to_string(),
                weight: 2,
                quota: 64,
                window: 4,
                mix: BenchId::ALL
                    .iter()
                    .map(|&b| WorkKind::Bench(b))
                    .chain([WorkKind::Saxpy])
                    .collect(),
                requests: 2 * scale,
            },
            TenantSpec {
                name: "fuzz".to_string(),
                weight: 1,
                quota: 32,
                window: 2,
                mix: vec![
                    WorkKind::Random { branchy: false },
                    WorkKind::Random { branchy: true },
                ],
                requests: scale,
            },
        ],
        arrival: Arrival::Closed,
        n,
        seed,
    }
}

/// The 10:1 fairness profile the chaos gate runs: a heavy all-SAXPY
/// tenant offering ten times the light tenant's load. All-SAXPY keeps
/// every heavy batch on one cache hint and the pipelined streamed
/// engine (SAXPY is overlap-safe) — exactly the resident mid-wave
/// state the checkpoint-migration path must rescue when its instance
/// goes dark — while the light tenant keeps the placed/lane path busy
/// so degraded-capacity demotions have traffic to displace.
pub fn fairness_profile(scale: usize, n: usize, seed: u64) -> LoadProfile {
    let scale = scale.max(1);
    LoadProfile {
        tenants: vec![
            TenantSpec {
                name: "heavy".to_string(),
                weight: 4,
                quota: 64,
                window: 16,
                mix: vec![WorkKind::Saxpy],
                requests: 10 * scale,
            },
            TenantSpec {
                name: "light".to_string(),
                weight: 1,
                quota: 16,
                window: 2,
                mix: vec![
                    WorkKind::Bench(BenchId::Fibonacci),
                    WorkKind::Bench(BenchId::DotProd),
                ],
                requests: scale,
            },
        ],
        arrival: Arrival::Closed,
        n,
        seed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_per_seed() {
        let p = standard_profile(8, 4, 7);
        for t in 0..p.tenants.len() {
            assert_eq!(tenant_trace(&p, t), tenant_trace(&p, t));
        }
        let mut p2 = p.clone();
        p2.seed = 8;
        assert_ne!(tenant_trace(&p, 0), tenant_trace(&p2, 0));
    }

    #[test]
    fn standard_profile_offers_weighted_load() {
        let p = standard_profile(3, 4, 1);
        assert_eq!(p.tenants.len(), 3);
        assert_eq!(p.tenants[0].requests, 12);
        assert_eq!(p.tenants[1].requests, 6);
        assert_eq!(p.tenants[2].requests, 3);
        assert_eq!(
            p.tenants.iter().map(|t| t.weight).collect::<Vec<_>>(),
            vec![4, 2, 1]
        );
    }

    #[test]
    fn fairness_profile_is_ten_to_one_and_streamable() {
        let p = fairness_profile(3, 6, 42);
        assert_eq!(p.tenants[0].requests, 10 * p.tenants[1].requests);
        // Every heavy request shares one cache hint, so the scheduler
        // forms multi-wave SAXPY batches — the streamed engine's (and
        // the migration path's) precondition.
        let hints: std::collections::BTreeSet<String> = tenant_trace(&p, 0)
            .iter()
            .map(|r| r.cache_hint())
            .collect();
        assert_eq!(hints.len(), 1);
        assert!(hints.contains("saxpy"));
    }

    #[test]
    fn random_requests_share_a_small_graph_family() {
        let p = standard_profile(16, 3, 9);
        let fuzz = p.tenants.len() - 1;
        let hints: std::collections::BTreeSet<String> = tenant_trace(&p, fuzz)
            .iter()
            .map(|r| r.cache_hint())
            .collect();
        // Two arms × at most RANDOM_GRAPH_FAMILY graph seeds.
        assert!(hints.len() <= 2 * RANDOM_GRAPH_FAMILY as usize);
        assert!(!hints.is_empty());
    }

    #[test]
    fn burst_series_ramps_and_caps() {
        let a = burst_series(4);
        assert_eq!(a.burst_at(1), Some(4));
        assert_eq!(a.burst_at(2), Some(8));
        assert_eq!(a.burst_at(5), Some(20));
        // Capped at 8 × workers from tick 8 on.
        assert_eq!(a.burst_at(8), Some(32));
        assert_eq!(a.burst_at(1000), Some(32));
        // Closed has no tick-determined burst; Open is flat.
        assert_eq!(Arrival::Closed.burst_at(3), None);
        assert_eq!(Arrival::Open { burst: 4 }.burst_at(999), Some(4));
        // Degenerate worker counts still offer at least one request.
        assert_eq!(burst_series(0), burst_series(1));
        assert!(burst_series(1).burst_at(1).unwrap() >= 1);
    }

    #[test]
    fn burst_series_traces_are_deterministic() {
        // The arrival mode never feeds the trace generator — same seed
        // ⇒ same trace under any arrival, which is what makes the
        // worker-count sweep compare like with like.
        let mut p = standard_profile(6, 4, 11);
        p.arrival = burst_series(4);
        let with_burst: Vec<_> = (0..p.tenants.len()).map(|t| tenant_trace(&p, t)).collect();
        let mut q = p.clone();
        q.arrival = Arrival::Closed;
        for t in 0..p.tenants.len() {
            assert_eq!(with_burst[t], tenant_trace(&q, t));
        }
    }

    #[test]
    fn work_items_match_their_graphs() {
        // Every mix member materializes a workload whose ports exist on
        // the graph it will run against.
        let p = standard_profile(2, 4, 3);
        for t in 0..p.tenants.len() {
            for req in tenant_trace(&p, t) {
                let g = build_graph(&req);
                let item = work_item(&req);
                for port in item.inject.keys() {
                    assert!(
                        g.arc_by_name(port).is_some(),
                        "{:?}: port {port} missing",
                        req.kind
                    );
                }
            }
        }
    }
}
