//! The admission scheduler: bounded queues with explicit shedding,
//! weighted-fair picking across tenants, deadline-aware batch
//! formation, and per-batch engine selection over the warm-state
//! session cache.
//!
//! Scheduling is driven entirely by virtual *ticks*, never wall time,
//! so every decision the scheduler makes — admission, shedding, batch
//! formation, tenant picking — is a deterministic function of the
//! profile seed. Wall clocks appear only in the reported latency
//! histograms.
//!
//! **Fairness invariant** (asserted by `rust/tests/serve.rs`): picking
//! is weighted round-robin with credit refill — each refill grants
//! tenant `t` its `weight` dispatch credits, and credits refill only
//! when no dispatch-ready tenant holds any. A tenant that stays
//! dispatch-ready therefore waits at most `sum(weights) − weight(t)`
//! dispatches between services, no matter how much load the others
//! offer.
//!
//! **Shed invariant**: admission either queues the request or returns
//! an explicit [`Admission::Shed`] with its reason; nothing is dropped
//! silently, so `completed + shed == submitted` once a profile drains.
//!
//! **Batch formation**: a tenant's queue is dispatched from the head
//! as the longest same-graph run (bounded by `max_batch`). A short run
//! waits for batch-mates until the head request's deadline
//! (`deadline_ticks`) expires, then dispatches at whatever size is
//! there — batching never costs more than the configured slack.
//!
//! **Engine selection** per batch, from the cached [`WarmState`]:
//! placed + overlap-safe graphs with ≥ 2 waves go to a pipelined
//! resident [`StreamSession`](crate::sim::StreamSession) (the Fig. 1c
//! throughput case); other placed graphs run-to-completion on the
//! lane engine with the cached compiled program; partitioned graphs
//! take the resident sharded rack or the reconfiguration scheduler;
//! unplaceable graphs fall back to the infinite-fabric engine.

use super::loadgen::{self, Arrival, LoadProfile, ServeRequest, TenantSpec, WorkItem};
use super::session::{RoutePlan, SessionCache, WarmState, DEFAULT_STRIPES};
use super::stats::{ServeCollector, ServeReport, ShedReason};
use crate::coordinator::batch::{
    run_batch_lanes_par, run_batch_lanes_prog, run_batch_native, run_batch_reconfig,
    run_batch_sharded, run_batch_sharded_par,
};
use crate::dfg::Graph;
use crate::fabric::FabricTopology;
use crate::obs::{SpanKind, TraceBuf, TraceEvent};
use crate::opt::OptLevel;
use crate::par::Executor;
use crate::sim::stream::run_stream_prevalidated;
use crate::sim::{run_token, SimConfig, SimOutcome, WaveInput, WaveMode};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;
use std::sync::Arc;
use std::time::Instant;

/// Scheduler knobs.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Global admission-queue bound (all tenants together).
    pub queue_cap: usize,
    /// Largest batch one dispatch may form.
    pub max_batch: usize,
    /// Ticks a head request may wait for same-graph batch-mates before
    /// dispatch is forced (0 = dispatch as soon as picked).
    pub deadline_ticks: u64,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            queue_cap: 256,
            max_batch: 16,
            deadline_ticks: 4,
        }
    }
}

/// Clamp a [`ServeCfg`] into the scheduler's legal domain, mirroring
/// the tenant-spec clamps (`weight.max(1)`, `quota.max(1)`): a
/// zero-capacity queue would shed everything, a zero `max_batch` used
/// to slip through `dispatchable`'s `run >= max_batch` with `run = 1`
/// and silently serve singletons, and a near-`u64::MAX` deadline could
/// overflow the `admitted_tick + deadline_ticks` due test. Degenerate
/// configs now mean what they look like: the smallest sane value.
fn sanitize_cfg(mut cfg: ServeCfg) -> ServeCfg {
    cfg.queue_cap = cfg.queue_cap.max(1);
    cfg.max_batch = cfg.max_batch.max(1);
    cfg.deadline_ticks = cfg.deadline_ticks.min(u64::MAX / 2);
    cfg
}

/// The admission verdict — always explicit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Admitted,
    Shed(ShedReason),
}

/// A request naming a tenant the scheduler was not built with — a
/// caller bug surfaced as a typed error instead of the out-of-bounds
/// panic it used to be.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitError {
    /// The tenant index the request carried.
    pub tenant: usize,
    /// How many tenants this scheduler serves.
    pub tenants: usize,
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "request names tenant {} but the scheduler serves only {} tenant(s)",
            self.tenant, self.tenants
        )
    }
}

impl std::error::Error for AdmitError {}

#[derive(Debug)]
pub(crate) struct Pending {
    pub(crate) req: ServeRequest,
    pub(crate) hint: String,
    pub(crate) admitted_tick: u64,
    pub(crate) submitted: Instant,
}

/// Per-tenant bounded queues + weighted-fair batch picking.
pub struct Scheduler {
    cfg: ServeCfg,
    weights: Vec<u32>,
    quotas: Vec<usize>,
    queues: Vec<VecDeque<Pending>>,
    credits: Vec<u32>,
    queued_total: usize,
}

impl Scheduler {
    pub fn new(tenants: &[TenantSpec], cfg: ServeCfg) -> Self {
        let weights: Vec<u32> = tenants.iter().map(|t| t.weight.max(1)).collect();
        Scheduler {
            credits: weights.clone(),
            weights,
            quotas: tenants.iter().map(|t| t.quota.max(1)).collect(),
            queues: tenants.iter().map(|_| VecDeque::new()).collect(),
            queued_total: 0,
            cfg: sanitize_cfg(cfg),
        }
    }

    /// Requests tenant `t` currently has queued.
    pub fn queued(&self, t: usize) -> usize {
        self.queues[t].len()
    }

    pub fn queued_total(&self) -> usize {
        self.queued_total
    }

    pub fn idle(&self) -> bool {
        self.queued_total == 0
    }

    /// Admit or shed. Shedding is the *response* — the caller owns
    /// telling the tenant; the scheduler never drops silently. A
    /// request naming an unknown tenant is an [`AdmitError`], not the
    /// out-of-bounds panic this used to be.
    pub fn admit(&mut self, tick: u64, req: ServeRequest) -> Result<Admission, AdmitError> {
        let t = req.tenant;
        if t >= self.queues.len() {
            return Err(AdmitError {
                tenant: t,
                tenants: self.queues.len(),
            });
        }
        if self.queued_total >= self.cfg.queue_cap {
            return Ok(Admission::Shed(ShedReason::QueueFull));
        }
        if self.queues[t].len() >= self.quotas[t] {
            return Ok(Admission::Shed(ShedReason::TenantQuota));
        }
        let hint = req.cache_hint();
        self.queues[t].push_back(Pending {
            req,
            hint,
            admitted_tick: tick,
            submitted: Instant::now(),
        });
        self.queued_total += 1;
        Ok(Admission::Admitted)
    }

    /// The same-graph head-run length of tenant `t`'s queue if it is
    /// dispatchable now (full batch, deadline expired, or draining).
    fn dispatchable(&self, t: usize, tick: u64, drain: bool) -> Option<usize> {
        let q = &self.queues[t];
        let head = q.front()?;
        let cap = q.len().min(self.cfg.max_batch);
        let mut run = 1usize;
        while run < cap && q[run].hint == head.hint {
            run += 1;
        }
        let due = tick >= head.admitted_tick.saturating_add(self.cfg.deadline_ticks);
        if run >= self.cfg.max_batch || due || drain {
            Some(run)
        } else {
            None
        }
    }

    /// Pick the next batch under weighted-fair credits. `drain` forces
    /// dispatch of short runs (no more arrivals can ever join them).
    pub(crate) fn next_batch(&mut self, tick: u64, drain: bool) -> Option<(usize, Vec<Pending>)> {
        let runs: Vec<Option<usize>> = (0..self.queues.len())
            .map(|t| self.dispatchable(t, tick, drain))
            .collect();
        if runs.iter().all(|r| r.is_none()) {
            return None;
        }
        // Refill only when no dispatch-ready tenant holds credit — this
        // is what bounds any ready tenant's wait to sum(weights)−w(t).
        if !runs
            .iter()
            .zip(&self.credits)
            .any(|(r, &c)| r.is_some() && c > 0)
        {
            self.credits.copy_from_slice(&self.weights);
        }
        for t in 0..self.queues.len() {
            if self.credits[t] == 0 {
                continue;
            }
            if let Some(run) = runs[t] {
                self.credits[t] -= 1;
                let batch: Vec<Pending> = self.queues[t].drain(..run).collect();
                self.queued_total -= batch.len();
                return Some((t, batch));
            }
        }
        None
    }
}

/// Which engine a batch ran on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineChoice {
    Lanes,
    Streamed,
    Sharded,
    Reconfig,
    Fallback,
}

impl EngineChoice {
    pub fn name(self) -> &'static str {
        match self {
            EngineChoice::Lanes => "lanes",
            EngineChoice::Streamed => "streamed",
            EngineChoice::Sharded => "sharded",
            EngineChoice::Reconfig => "reconfig",
            EngineChoice::Fallback => "fallback",
        }
    }
}

/// The per-batch engine policy (see module docs).
pub fn choose_engine(state: &WarmState, batch_len: usize) -> EngineChoice {
    choose_engine_routed(&state.route, state.overlap_safe, batch_len)
}

/// [`choose_engine`] against an explicit route — the chaos runner
/// re-routes displaced batches against a *degraded* topology and still
/// needs the exact same policy.
pub(crate) fn choose_engine_routed(
    route: &RoutePlan,
    overlap_safe: bool,
    batch_len: usize,
) -> EngineChoice {
    match route {
        RoutePlan::Placed => {
            if overlap_safe && batch_len >= 2 {
                EngineChoice::Streamed
            } else {
                EngineChoice::Lanes
            }
        }
        RoutePlan::Sharded(_) => EngineChoice::Sharded,
        RoutePlan::Reconfig(_) => EngineChoice::Reconfig,
        RoutePlan::Fallback => EngineChoice::Fallback,
    }
}

/// What one batch execution produced.
#[derive(Debug)]
pub struct BatchResult {
    pub engine: &'static str,
    /// The warm-state lookup was a cache hit (compile/place skipped).
    pub cache_hit: bool,
    /// Lane items re-run on the scalar engine (lanes→scalar fallback).
    pub lane_scalar_reruns: u64,
    pub outcomes: Vec<SimOutcome>,
    /// Per item: outputs matched the workload's reference (benchmarks)
    /// or a scalar `TokenSim` oracle (random DFGs).
    pub verified: Vec<bool>,
}

/// Execute one same-graph batch against the session cache. All
/// requests must share a [`ServeRequest::cache_hint`]. Public so tests
/// can drive the cold/warm byte-identity contract directly.
pub fn execute_batch(cache: &SessionCache, reqs: &[ServeRequest]) -> BatchResult {
    execute_batch_inner(cache, reqs, None)
}

/// [`execute_batch`] with intra-batch parallelism: the lane chunks
/// (up to [`crate::sim::MAX_LANES`] = 256 items each, multi-word
/// occupancy masks) and shard items of this ONE batch spread across
/// `exec`'s workers
/// ([`run_batch_lanes_par`] / [`run_batch_sharded_par`]). Outcomes are
/// byte-identical to [`execute_batch`] at every worker count — the
/// `par_determinism_*` conformance properties enforce it. Pipelined
/// stream batches stay serial (waves overlapping inside one resident
/// session are the point of that engine); `run_profile` gets its
/// parallelism for those from batch-level dispatch instead.
pub fn execute_batch_par(
    cache: &SessionCache,
    reqs: &[ServeRequest],
    exec: &Executor,
) -> BatchResult {
    execute_batch_inner(cache, reqs, Some(exec))
}

fn execute_batch_inner(
    cache: &SessionCache,
    reqs: &[ServeRequest],
    exec: Option<&Executor>,
) -> BatchResult {
    assert!(!reqs.is_empty(), "empty batch");
    let hint = reqs[0].cache_hint();
    debug_assert!(
        reqs.iter().all(|r| r.cache_hint() == hint),
        "batch mixes graphs"
    );
    let (state, cache_hit) = cache.warm_keyed(&hint, || loadgen::build_graph(&reqs[0]));
    let items: Vec<WorkItem> = reqs.iter().map(loadgen::work_item).collect();
    let cfgs = batch_configs(&items);
    let engine = choose_engine(&state, reqs.len());
    let g = state.graph.as_ref();
    let mut lane_scalar_reruns = 0u64;
    // Resident racks stream the batch as waves when there is more than
    // one item to keep resident state warm for.
    let waves_resident = cfgs.len() >= 2;
    let outcomes: Vec<SimOutcome> = match (engine, &state.route) {
        (EngineChoice::Streamed, _) => {
            // The whole batch shares one resident session's rounds.
            // The cached `overlap_safe` bit stands in for the
            // structural walk — a warm streamed batch pays none.
            let waves: Vec<WaveInput> = items.iter().map(|it| it.inject.clone()).collect();
            let budget: u64 = cfgs.iter().map(|c| c.max_cycles).sum();
            run_stream_prevalidated(g, &waves, budget, WaveMode::Pipelined).0
        }
        (EngineChoice::Lanes, _) => {
            let (outs, stats) = match exec {
                Some(e) => run_batch_lanes_par(g, &state.program, &cfgs, e),
                None => run_batch_lanes_prog(g, &state.program, &cfgs),
            };
            lane_scalar_reruns = stats.scalar_reruns as u64;
            outs
        }
        (EngineChoice::Sharded, RoutePlan::Sharded(plan)) => match exec {
            Some(e) => run_batch_sharded_par(plan, &cfgs, waves_resident, e),
            None => run_batch_sharded(plan, &cfgs, waves_resident),
        },
        (EngineChoice::Reconfig, RoutePlan::Reconfig(plan)) => {
            run_batch_reconfig(plan, cache.topology(), &cfgs, waves_resident)
        }
        (EngineChoice::Fallback, _) => run_batch_native(g, &cfgs),
        _ => unreachable!("engine choice always follows the cached route"),
    };
    let verified = verify_outcomes(g, &items, &cfgs, &outcomes);
    BatchResult {
        engine: engine.name(),
        cache_hit,
        lane_scalar_reruns,
        outcomes,
        verified,
    }
}

/// Per-item verification shared by every dispatch path: outputs match
/// the workload's reference (benchmarks) or a scalar `TokenSim` oracle
/// (random DFGs).
pub(crate) fn verify_outcomes(
    g: &Graph,
    items: &[WorkItem],
    cfgs: &[SimConfig],
    outcomes: &[SimOutcome],
) -> Vec<bool> {
    items
        .iter()
        .zip(cfgs)
        .zip(outcomes)
        .map(|((item, cfg), out)| match &item.expect {
            Some(want) => want
                .iter()
                .all(|(port, w)| out.stream(port) == w.as_slice()),
            None => run_token(g, cfg).outputs == out.outputs,
        })
        .collect()
}

/// Build per-item [`SimConfig`]s from a batch's work items — shared by
/// the plain and chaos dispatch paths so their budgets cannot diverge.
pub(crate) fn batch_configs(items: &[WorkItem]) -> Vec<SimConfig> {
    items
        .iter()
        .map(|it| {
            let mut c = SimConfig::new().max_cycles(it.max_cycles);
            for (p, s) in &it.inject {
                c = c.inject(p, s.clone());
            }
            c
        })
        .collect()
}

/// Service-tier construction parameters (the coordinator-independent
/// analogue of `Coordinator::start_with_fabric`).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub topo: FabricTopology,
    /// Fabric instances available to the route planner.
    pub pool_size: usize,
    /// Session-cache capacity (distinct warm graphs).
    pub cache_cap: usize,
    /// Session-cache lock stripes ([`crate::serve::session`]).
    pub cache_stripes: usize,
    /// Dispatch workers. 1 = the classic inline loop (no threads).
    /// N > 1 executes dispatched batches on an N-worker stealing pool
    /// ([`crate::par::Executor`]) while the tick loop keeps admitting
    /// and dispatching; the dispatch schedule never reads execution
    /// results, so schedules — and therefore results — are identical
    /// at every worker count (DESIGN.md §10).
    pub workers: usize,
    pub cfg: ServeCfg,
    /// Optional event sink ([`crate::obs::trace`]). When set,
    /// [`run_profile`] records the request lifecycle — Admit,
    /// BatchForm, RouteSelect, Place/Compile (cold path), Execute —
    /// timestamped in virtual ticks and engine cycles only, so the
    /// drained event stream is byte-identical at every worker count
    /// (the `obs_determinism_*` conformance properties).
    pub trace: Option<Arc<TraceBuf>>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            // The serving preset, not `paper()`: random-DFG tenants
            // must place whole so every engine on the placed path
            // keeps its byte-identical TokenSim contract.
            topo: FabricTopology::serving(),
            pool_size: 2,
            cache_cap: 32,
            cache_stripes: DEFAULT_STRIPES,
            workers: 1,
            cfg: ServeCfg::default(),
            trace: None,
        }
    }
}

/// One dispatch, for fairness analysis: which tenant, when, how many
/// requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DispatchRec {
    pub tenant: usize,
    pub tick: u64,
    pub len: usize,
}

/// What a whole profile run produced.
#[derive(Debug)]
pub struct ProfileOutcome {
    pub report: ServeReport,
    /// The deterministic dispatch sequence (tick-driven scheduling).
    pub dispatches: Vec<DispatchRec>,
    /// `(tenant, request seq)` → [`outcome_digest`] of that request's
    /// result, for every completed request. This is the byte-identity
    /// witness: the `--scale-workers` sweep and the conformance
    /// harness require these maps to be *equal* (same completed set,
    /// same digests) across worker counts.
    pub digests: BTreeMap<(usize, usize), u64>,
}

fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Order-stable FNV-1a digest of everything a [`SimOutcome`] asserts:
/// every output stream (port names and token values), cycle count,
/// firing count, and quiescence. Two outcomes digest equal iff the
/// engine produced byte-identical results.
pub fn outcome_digest(out: &SimOutcome) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for (port, stream) in &out.outputs {
        h = fnv(h, port.as_bytes());
        h = fnv(h, &[0xFF]);
        for w in stream {
            h = fnv(h, &w.to_le_bytes());
        }
        h = fnv(h, &[0xFE]);
    }
    h = fnv(h, &out.cycles.to_le_bytes());
    h = fnv(h, &out.firings.to_le_bytes());
    fnv(h, &[u8::from(out.quiescent)])
}

/// [`outcome_digest`] restricted to the *planned* outputs: port names
/// and token streams only, no cycle/firing/quiescence counters. This
/// is the chaos gate's witness — a faulted run may legitimately demote
/// a batch down the route lattice (changing cycles and firings) or
/// migrate a session mid-wave, yet must still hand every tenant
/// byte-identical output streams.
pub fn output_digest(out: &SimOutcome) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for (port, stream) in &out.outputs {
        h = fnv(h, port.as_bytes());
        h = fnv(h, &[0xFF]);
        for w in stream {
            h = fnv(h, &w.to_le_bytes());
        }
        h = fnv(h, &[0xFE]);
    }
    h
}

/// One dispatched batch after execution, carrying everything the
/// post-loop record phase needs (no scheduler state).
pub(crate) struct ExecutedBatch {
    pub(crate) tenant: usize,
    /// Dispatch tick (virtual time) — the trace timestamp for the
    /// batch's RouteSelect/Execute events.
    pub(crate) tick: u64,
    /// The batch's shared cache hint, for cold-path (Place/Compile)
    /// event attribution in dispatch order.
    pub(crate) hint: String,
    pub(crate) result: BatchResult,
    /// Per item: (request seq, wait ticks at dispatch, wall latency in
    /// nanoseconds measured when execution finished).
    pub(crate) items: Vec<(usize, u64, u64)>,
    /// Wall time of `execute_batch` alone — summed over batches this
    /// is the pool's busy time.
    pub(crate) exec_ns: u64,
}

pub(crate) fn exec_one(
    cache: &SessionCache,
    tick: u64,
    tenant: usize,
    batch: &[Pending],
) -> ExecutedBatch {
    let reqs: Vec<ServeRequest> = batch.iter().map(|p| p.req.clone()).collect();
    let t0 = Instant::now();
    let result = execute_batch(cache, &reqs);
    let exec_ns = t0.elapsed().as_nanos() as u64;
    let items = batch
        .iter()
        .map(|p| {
            (
                p.req.seq,
                tick.saturating_sub(p.admitted_tick),
                p.submitted.elapsed().as_nanos() as u64,
            )
        })
        .collect();
    ExecutedBatch {
        tenant,
        tick,
        hint: batch[0].hint.clone(),
        result,
        items,
        exec_ns,
    }
}

/// Record the scheduling half of a batch's lifecycle: one Admit per
/// member (at its admission tick) and one BatchForm per member (at the
/// dispatch tick, detail = batch size). Runs on the tick-loop thread
/// in dispatch order in both serve modes, and writes virtual time
/// only — never wall clock.
fn trace_dispatch(trace: &TraceBuf, tick: u64, tenant: usize, batch: &[Pending]) {
    for p in batch {
        trace.record(TraceEvent {
            kind: SpanKind::Admit,
            tenant: tenant as u32,
            seq: p.req.seq as u64,
            tick: p.admitted_tick,
            cycles: 0,
            engine: "sched",
            detail: 0,
        });
        trace.record(TraceEvent {
            kind: SpanKind::BatchForm,
            tenant: tenant as u32,
            seq: p.req.seq as u64,
            tick,
            cycles: 0,
            engine: "sched",
            detail: batch.len() as u64,
        });
    }
}

/// The tick loop, shared verbatim by the serial and parallel paths:
/// per tick, admit arrivals (closed-loop window top-up or open-loop
/// burst), then hand at most one weighted-fair batch to `sink`. The
/// loop never reads execution results — admission, shedding, batching,
/// and termination depend only on queue state — which is exactly why
/// executing `sink`'s batches asynchronously cannot change the
/// schedule (DESIGN.md §10).
pub(crate) fn drive_profile(
    profile: &LoadProfile,
    cfg: &ServeCfg,
    collector: &mut ServeCollector,
    mut sink: impl FnMut(u64, usize, Vec<Pending>),
) -> (u64, Vec<DispatchRec>) {
    let mut sched = Scheduler::new(&profile.tenants, cfg.clone());
    let traces: Vec<Vec<ServeRequest>> = (0..profile.tenants.len())
        .map(|t| loadgen::tenant_trace(profile, t))
        .collect();
    let mut cursor = vec![0usize; traces.len()];
    let mut dispatches = Vec::new();
    let mut tick = 0u64;
    loop {
        tick += 1;
        for (t, trace) in traces.iter().enumerate() {
            let want = match profile.arrival {
                Arrival::Closed => profile.tenants[t]
                    .window
                    .max(1)
                    .saturating_sub(sched.queued(t)),
                open => open.burst_at(tick).unwrap_or(1),
            };
            for _ in 0..want {
                if cursor[t] >= trace.len() {
                    break;
                }
                let req = trace[cursor[t]].clone();
                cursor[t] += 1;
                collector.submitted(t);
                // Trace requests carry the tenant index they were
                // generated under, so admission cannot fail here.
                match sched.admit(tick, req).expect("trace tenant is known") {
                    Admission::Admitted => {}
                    Admission::Shed(reason) => collector.shed(t, reason),
                }
            }
        }
        collector.queue_depth(sched.queued_total());
        let drained = cursor.iter().zip(&traces).all(|(&c, tr)| c >= tr.len());
        match sched.next_batch(tick, drained) {
            Some((tenant, batch)) => {
                dispatches.push(DispatchRec {
                    tenant,
                    tick,
                    len: batch.len(),
                });
                sink(tick, tenant, batch);
            }
            None => {
                if drained && sched.idle() {
                    break;
                }
            }
        }
    }
    (tick, dispatches)
}

/// Drive a load profile to completion. Runs until every trace is
/// offered and every queue drains; every submitted request ends as
/// completed or explicitly shed.
///
/// With `opts.workers <= 1` dispatched batches execute inline on the
/// caller thread, exactly as before the parallel tier existed. With
/// `opts.workers > 1` they execute on a work-stealing pool while the
/// tick loop keeps going ([`Executor::pipeline`]); results are
/// recorded post-loop in dispatch order, so every report field except
/// wall-clock latencies/steals is identical across worker counts, and
/// the per-request [`ProfileOutcome::digests`] are *byte*-identical.
pub fn run_profile(profile: &LoadProfile, opts: &ServeOptions) -> ProfileOutcome {
    let wall0 = Instant::now();
    let cache = SessionCache::with_stripes(
        opts.topo.clone(),
        opts.pool_size,
        opts.cache_cap,
        OptLevel::Default,
        opts.cache_stripes,
    );
    let names: Vec<String> = profile.tenants.iter().map(|t| t.name.clone()).collect();
    let mut collector = ServeCollector::new(&names);
    let workers = opts.workers.max(1);
    let exec = Executor::new(workers);
    let (ticks, dispatches, executed) = if workers <= 1 {
        let mut executed = Vec::new();
        let (ticks, dispatches) =
            drive_profile(profile, &opts.cfg, &mut collector, |tick, tenant, batch| {
                if let Some(tr) = &opts.trace {
                    trace_dispatch(tr, tick, tenant, &batch);
                }
                executed.push(exec_one(&cache, tick, tenant, &batch));
            });
        (ticks, dispatches, executed)
    } else {
        let cache_ref = &cache;
        let ((ticks, dispatches), executed) = exec.pipeline(|sub| {
            drive_profile(profile, &opts.cfg, &mut collector, |tick, tenant, batch| {
                if let Some(tr) = &opts.trace {
                    trace_dispatch(tr, tick, tenant, &batch);
                }
                sub.submit(move || exec_one(cache_ref, tick, tenant, &batch));
            })
        });
        (ticks, dispatches, executed)
    };
    // Record phase: identical bookkeeping for both modes, in dispatch
    // order (the executor sorts results back into submission order).
    let mut digests = BTreeMap::new();
    let mut busy_ns = 0u64;
    let mut tokens_out = 0u64;
    let mut seen_hints: BTreeSet<&str> = BTreeSet::new();
    for eb in &executed {
        if let Some(tr) = &opts.trace {
            // The executor returns batches in submission (= dispatch)
            // order, so cold-path attribution — the FIRST batch over a
            // graph pays Place + Compile — is deterministic. Keying on
            // the cache-hit flag instead would race under workers > 1.
            let (seq0, _, _) = eb.items[0];
            let cold = seen_hints.insert(eb.hint.as_str());
            tr.record(TraceEvent {
                kind: SpanKind::RouteSelect,
                tenant: eb.tenant as u32,
                seq: seq0 as u64,
                tick: eb.tick,
                cycles: 0,
                engine: eb.result.engine,
                detail: eb.items.len() as u64,
            });
            if cold {
                for kind in [SpanKind::Place, SpanKind::Compile] {
                    tr.record(TraceEvent {
                        kind,
                        tenant: eb.tenant as u32,
                        seq: seq0 as u64,
                        tick: eb.tick,
                        cycles: 0,
                        engine: eb.result.engine,
                        detail: 0,
                    });
                }
            }
            for (item, out) in eb.items.iter().zip(&eb.result.outcomes) {
                let (seq, _, _) = *item;
                tr.record(TraceEvent {
                    kind: SpanKind::Execute,
                    tenant: eb.tenant as u32,
                    seq: seq as u64,
                    tick: eb.tick,
                    cycles: out.cycles,
                    engine: eb.result.engine,
                    detail: 0,
                });
            }
        }
        busy_ns += eb.exec_ns;
        collector.batch(eb.tenant, eb.result.engine, eb.items.len());
        collector.lane_scalar_reruns(eb.result.lane_scalar_reruns);
        for ((item, out), verified) in eb
            .items
            .iter()
            .zip(&eb.result.outcomes)
            .zip(&eb.result.verified)
        {
            let (seq, wait, latency) = *item;
            collector.completed(eb.tenant, *verified, latency, wait, out.cycles);
            tokens_out += out.outputs.values().map(|s| s.len() as u64).sum::<u64>();
            digests.insert((eb.tenant, seq), outcome_digest(out));
        }
    }
    let mut report = collector.finish(&cache, ticks);
    report.workers = workers;
    report.wall_ns = wall0.elapsed().as_nanos() as u64;
    report.busy_ns = busy_ns;
    report.steals = exec.stats().steals;
    report.tokens_out = tokens_out;
    ProfileOutcome {
        report,
        dispatches,
        digests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::BenchId;
    use crate::serve::loadgen::WorkKind;

    fn req(tenant: usize, seq: usize, kind: WorkKind) -> ServeRequest {
        ServeRequest {
            tenant,
            seq,
            kind,
            n: 3,
            seed: seq as u64,
        }
    }

    fn tenant(name: &str, weight: u32, quota: usize) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            weight,
            quota,
            window: 2,
            mix: vec![WorkKind::Bench(BenchId::Fibonacci)],
            requests: 0,
        }
    }

    #[test]
    fn admission_sheds_explicitly_at_quota_and_capacity() {
        let tenants = [tenant("a", 1, 2), tenant("b", 1, 8)];
        let cfg = ServeCfg {
            queue_cap: 5,
            ..ServeCfg::default()
        };
        let mut s = Scheduler::new(&tenants, cfg);
        let k = WorkKind::Bench(BenchId::Max);
        assert_eq!(s.admit(1, req(0, 0, k)), Ok(Admission::Admitted));
        assert_eq!(s.admit(1, req(0, 1, k)), Ok(Admission::Admitted));
        // Tenant 0 quota (2) exhausted.
        assert_eq!(
            s.admit(1, req(0, 2, k)),
            Ok(Admission::Shed(ShedReason::TenantQuota))
        );
        for i in 0..3 {
            assert_eq!(s.admit(1, req(1, i, k)), Ok(Admission::Admitted));
        }
        // Global cap (5) exhausted — even for tenant 1 under quota.
        assert_eq!(
            s.admit(1, req(1, 9, k)),
            Ok(Admission::Shed(ShedReason::QueueFull))
        );
        assert_eq!(s.queued_total(), 5);
    }

    #[test]
    fn admit_rejects_unknown_tenants_with_a_typed_error() {
        // Regression: this indexed `self.queues[req.tenant]` and
        // panicked out-of-bounds on any request naming a tenant the
        // scheduler was not built with.
        let tenants = [tenant("a", 1, 4), tenant("b", 1, 4)];
        let mut s = Scheduler::new(&tenants, ServeCfg::default());
        let err = s
            .admit(1, req(7, 0, WorkKind::Bench(BenchId::Max)))
            .unwrap_err();
        assert_eq!(err, AdmitError { tenant: 7, tenants: 2 });
        assert!(err.to_string().contains("tenant 7"), "{err}");
        assert!(err.to_string().contains("2 tenant(s)"), "{err}");
        assert_eq!(s.queued_total(), 0, "the bad request must not queue");
    }

    #[test]
    fn batches_form_same_graph_runs_and_respect_deadlines() {
        let tenants = [tenant("a", 1, 16)];
        let cfg = ServeCfg {
            queue_cap: 64,
            max_batch: 8,
            deadline_ticks: 3,
        };
        let mut s = Scheduler::new(&tenants, cfg);
        let fib = WorkKind::Bench(BenchId::Fibonacci);
        let max = WorkKind::Bench(BenchId::Max);
        for (i, k) in [fib, fib, max].into_iter().enumerate() {
            s.admit(1, req(0, i, k)).unwrap();
        }
        // Tick 1: run of 2 fibs, not full, deadline (1+3=4) not reached.
        assert!(s.next_batch(1, false).is_none());
        // Tick 4: deadline expired → dispatch the fib run only.
        let (t, batch) = s.next_batch(4, false).expect("due");
        assert_eq!(t, 0);
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|p| p.hint == "bench:fibonacci"));
        // The max request remains; drain forces it out regardless.
        let (_, batch) = s.next_batch(4, true).expect("drain");
        assert_eq!(batch.len(), 1);
        assert!(s.idle());
    }

    #[test]
    fn degenerate_cfg_is_clamped_at_construction() {
        // Regression: `ServeCfg { max_batch: 0 }` used to slip through
        // `dispatchable` — `run >= max_batch` holds for `run = 1` — and
        // dispatch singleton batches from a config that nominally
        // forbids batching at all. The scheduler now clamps the config
        // to its smallest sane values at construction, so a zero
        // max_batch means "batches of 1", explicitly.
        let tenants = [tenant("a", 1, 16)];
        let cfg = ServeCfg {
            queue_cap: 0,
            max_batch: 0,
            deadline_ticks: u64::MAX,
        };
        let mut s = Scheduler::new(&tenants, cfg);
        let k = WorkKind::Bench(BenchId::Fibonacci);
        // queue_cap clamped to 1: the first request admits...
        assert_eq!(s.admit(1, req(0, 0, k)), Ok(Admission::Admitted));
        // ...and the second sheds explicitly instead of both shedding.
        assert_eq!(
            s.admit(1, req(0, 1, k)),
            Ok(Admission::Shed(ShedReason::QueueFull))
        );
        // max_batch clamped to 1: a run of 1 IS a full batch, so it
        // dispatches immediately — the u64::MAX deadline (clamped, and
        // overflow-safe either way) never forces or blocks anything.
        let (t, batch) = s.next_batch(1, false).expect("full batch of 1");
        assert_eq!(t, 0);
        assert_eq!(batch.len(), 1);
        assert!(s.idle());
    }

    #[test]
    fn weighted_credits_bound_waits() {
        // Weights 2:1, both always dispatchable → pattern a,a,b repeats.
        let tenants = [tenant("a", 2, 64), tenant("b", 1, 64)];
        let cfg = ServeCfg {
            queue_cap: 256,
            max_batch: 1,
            deadline_ticks: 0,
        };
        let mut s = Scheduler::new(&tenants, cfg);
        let k = WorkKind::Bench(BenchId::DotProd);
        for i in 0..6 {
            s.admit(1, req(0, i, k)).unwrap();
            s.admit(1, req(1, i, k)).unwrap();
        }
        let picks: Vec<usize> = (0..9)
            .map(|i| s.next_batch(i as u64 + 1, false).expect("backlogged").0)
            .collect();
        assert_eq!(picks, vec![0, 0, 1, 0, 0, 1, 0, 0, 1]);
    }

    #[test]
    fn engine_choice_follows_route_and_admission_class() {
        let cache = SessionCache::new(FabricTopology::paper(), 2, 8);
        let (fib, _) = cache.warm(&crate::bench_defs::build(BenchId::Fibonacci));
        assert_eq!(choose_engine(&fib, 8), EngineChoice::Lanes);
        let (sax, _) = cache.warm(&crate::bench_defs::saxpy::build());
        assert_eq!(choose_engine(&sax, 8), EngineChoice::Streamed);
        assert_eq!(
            choose_engine(&sax, 1),
            EngineChoice::Lanes,
            "a single wave has nothing to overlap"
        );
        let g = crate::bench_defs::build(BenchId::Max);
        // Size against the optimized graph (what the cache routes).
        let og = crate::opt::optimize(&g, Default::default()).0;
        let small = SessionCache::new(FabricTopology::sized_for_shards(&og, 2), 1, 8);
        let (max, _) = small.warm(&g);
        assert_eq!(choose_engine(&max, 4), EngineChoice::Reconfig);
    }

    #[test]
    fn execute_batch_serves_and_verifies_every_mix_member() {
        let cache = SessionCache::new(FabricTopology::serving(), 2, 16);
        for kind in [
            WorkKind::Bench(BenchId::Fibonacci),
            WorkKind::Bench(BenchId::BubbleSort),
            WorkKind::Saxpy,
            WorkKind::Random { branchy: false },
            WorkKind::Random { branchy: true },
        ] {
            // Seed stride 5 keeps `Random` requests on one graph
            // (one batch = one cache hint) with distinct workloads.
            let reqs: Vec<ServeRequest> = (0..3)
                .map(|i| ServeRequest {
                    seed: (i * 5) as u64,
                    ..req(0, i, kind)
                })
                .collect();
            let r = execute_batch(&cache, &reqs);
            assert_eq!(r.outcomes.len(), 3, "{kind:?}");
            assert!(
                r.verified.iter().all(|&v| v),
                "{kind:?} failed verification on {}",
                r.engine
            );
        }
        assert!(cache.misses() > 0);
    }

    #[test]
    fn traced_runs_emit_identical_events_across_worker_counts() {
        let profile = loadgen::standard_profile(1, 3, 7);
        let plain = run_profile(&profile, &ServeOptions::default());
        let mut streams = Vec::new();
        for workers in [1usize, 2] {
            let trace = Arc::new(TraceBuf::new(TraceBuf::DEFAULT_CAPACITY));
            let opts = ServeOptions {
                workers,
                trace: Some(Arc::clone(&trace)),
                ..ServeOptions::default()
            };
            let out = run_profile(&profile, &opts);
            // Tracing is an observer: per-request results are the same
            // maps the untraced run produced.
            assert_eq!(out.digests, plain.digests, "workers={workers}");
            let evs = trace.drain_sorted();
            assert!(!evs.is_empty());
            for kind in [
                SpanKind::Admit,
                SpanKind::BatchForm,
                SpanKind::RouteSelect,
                SpanKind::Place,
                SpanKind::Compile,
                SpanKind::Execute,
            ] {
                assert!(
                    evs.iter().any(|e| e.kind == kind),
                    "missing {kind:?} (workers={workers})"
                );
            }
            streams.push(crate::obs::events_json(&evs));
        }
        // The virtual-tick view is byte-identical across worker counts.
        assert_eq!(streams[0], streams[1]);
    }
}
