//! The warm-state session cache: compile/place results keyed by
//! [`Graph::fingerprint`].
//!
//! Every execution engine in this crate needs per-graph *static* state
//! before the first token moves: the built [`Graph`] itself, the lane
//! tier's compiled [`Program`], and the fabric route (placement check,
//! partition plan). None of that depends on the workload, so a serving
//! tier that rebuilds it per batch wastes the whole cold-start cost on
//! every repeat tenant. [`SessionCache`] interns it once per graph
//! fingerprint; a warm lookup hands back an [`Arc<WarmState>`] and the
//! hot path runs straight into the engines.
//!
//! The resident wave-session state itself (token buffers, FIFOs) is
//! *empty* between batches by construction — serialized admission
//! resets between waves and pipelined admission drains — so a warm
//! session is exactly: cached graph + cached program + cached route +
//! cached admission class, re-wrapped around the engines in O(arcs).
//! The expensive part (graph build, `Program::compile`, `place` /
//! `partition`) is what the cache skips; `hits`/`misses` counters make
//! that observable ([`crate::coordinator::Metrics`] exposes them as
//! `cache_hits`).
//!
//! Invalidation: the fingerprint is content-addressed, so a changed
//! graph *is* a different key — entries are never stale, only cold.
//! Capacity is bounded; least-recently-used entries are evicted.
//!
//! **Striping.** The cache is sharded into K lock-striped segments
//! (fingerprint-hashed) plus K hint-index stripes, so N dispatch
//! workers doing warm lookups contend only when they hash to the same
//! stripe, instead of serializing on one global mutex. Capacity and
//! LRU eviction are per-segment (`ceil(cap / K)` entries each); the
//! hit/miss/eviction counters are process-wide atomics. Lock order is
//! one-way — a segment lock may acquire hint-stripe locks (eviction
//! purge), a held hint lock never acquires a segment lock — so the
//! striped paths cannot deadlock.
//!
//! **Optimization.** A warm miss runs the graph through the
//! [`crate::opt`] pipeline before compiling/placing, and everything
//! downstream (compiled program, route, admission class) is computed
//! from the *optimized* graph. The cache key stays the **pre-opt**
//! fingerprint: the same raw submission always warms the same
//! optimized state, while a pre-optimized submission is different
//! content and therefore its own entry. [`OptLevel`] is the other
//! half of the key — warming the same graph at a different level is a
//! miss, never a silent mismatch.

use crate::dfg::Graph;
use crate::fabric::{self, FabricTopology, PartitionPlan};
use crate::opt::{self, OptLevel, OptReport};
use crate::sim::{overlap_safe, Program};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// How a graph maps onto the serving fabric — the router's placed →
/// sharded → reconfig → fallback lattice, computed once per graph
/// fingerprint instead of once per (worker, benchmark).
#[derive(Debug, Clone)]
pub enum RoutePlan {
    /// Fits one fabric instance whole: batched engines apply.
    Placed,
    /// Exceeds one instance; the pool can host one instance per shard.
    Sharded(PartitionPlan),
    /// Exceeds one instance on a pool with too few instances: serve
    /// time-multiplexed (context swapping) on one instance.
    Reconfig(PartitionPlan),
    /// Fits no partition of the topology: serve on the infinite-fabric
    /// simulation rather than failing.
    Fallback,
}

impl RoutePlan {
    pub fn name(&self) -> &'static str {
        match self {
            RoutePlan::Placed => "placed",
            RoutePlan::Sharded(_) => "sharded",
            RoutePlan::Reconfig(_) => "reconfig",
            RoutePlan::Fallback => "fallback",
        }
    }
}

/// Compute the placed → sharded → reconfig → fallback route for an
/// (already optimized) graph on a pool of `pool_size` instances of
/// `topo`. Factored out of the cache's miss path so the fault layer can
/// re-route a displaced session against a *degraded* topology with
/// exactly the lattice the cold path would choose.
pub fn route_graph(og: &Graph, topo: &FabricTopology, pool_size: usize) -> RoutePlan {
    if topo.fits(og) {
        return RoutePlan::Placed;
    }
    match fabric::partition(og, topo) {
        Ok(plan) if pool_size >= plan.n_shards() => RoutePlan::Sharded(plan),
        Ok(plan) => RoutePlan::Reconfig(plan),
        Err(e) => {
            eprintln!(
                "serve: `{}` is unpartitionable on `{}` ({e}); \
                 falling back to infinite-fabric simulation",
                og.name, topo.name
            );
            RoutePlan::Fallback
        }
    }
}

/// Everything the hot path needs that depends only on the graph (not
/// the workload): the one warm, shareable compile/place state.
#[derive(Debug)]
pub struct WarmState {
    /// [`Graph::fingerprint`] of the *submitted* (pre-optimization)
    /// graph — one half of the cache key.
    pub fingerprint: u64,
    /// The optimizer level this state was built at — the other half.
    pub opt_level: OptLevel,
    /// The optimized graph every engine below runs.
    pub graph: Arc<Graph>,
    /// What the optimizer did (counters feed observability).
    pub opt: OptReport,
    /// The raw graph did *not* fit one fabric instance but the
    /// optimized graph does — placement rescued by optimization
    /// (surfaced as the router's `opt-placed` metric).
    pub opt_rescued_place: bool,
    /// The lane tier's compiled node table ([`Program::compile`]).
    pub program: Arc<Program>,
    pub route: RoutePlan,
    /// Cached [`overlap_safe`] — whether a resident session may overlap
    /// waves (pipelined admission).
    pub overlap_safe: bool,
}

type Key = (u64, OptLevel);

/// One lock-striped cache segment: a fingerprint-keyed map plus its
/// own LRU list. Segments never talk to each other.
#[derive(Default)]
struct Segment {
    by_fp: BTreeMap<Key, Arc<WarmState>>,
    /// Cache keys in this segment, least recently used first.
    lru: VecDeque<Key>,
}

/// Default segment / hint-stripe count ([`SessionCache::new`]).
pub const DEFAULT_STRIPES: usize = 4;

/// A bounded, thread-safe cache of [`WarmState`] keyed by
/// [`Graph::fingerprint`], for one serving tier (one topology + pool).
/// Lock-striped: see the module docs.
pub struct SessionCache {
    topo: FabricTopology,
    pool_size: usize,
    /// Per-segment capacity (`ceil(cap / stripes)`).
    seg_cap: usize,
    /// The level [`SessionCache::warm`]/[`SessionCache::warm_keyed`]
    /// build at; [`SessionCache::warm_at`] overrides per call.
    level: OptLevel,
    segments: Vec<Mutex<Segment>>,
    /// Secondary index: a caller-stable hint key (benchmark slug,
    /// generator seed) → cache key, so hot-path hits skip even the
    /// graph build. Striped separately from the segments.
    hints: Vec<Mutex<BTreeMap<String, Key>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    invalidations: AtomicU64,
}

impl SessionCache {
    /// A cache for a pool of `pool_size` instances of `topo`, holding
    /// at most `cap` distinct graphs across [`DEFAULT_STRIPES`]
    /// segments, optimizing at [`OptLevel::Default`].
    pub fn new(topo: FabricTopology, pool_size: usize, cap: usize) -> Self {
        Self::with_level(topo, pool_size, cap, OptLevel::Default)
    }

    /// [`SessionCache::new`] with an explicit default optimizer level.
    pub fn with_level(
        topo: FabricTopology,
        pool_size: usize,
        cap: usize,
        level: OptLevel,
    ) -> Self {
        Self::with_stripes(topo, pool_size, cap, level, DEFAULT_STRIPES)
    }

    /// Fully explicit constructor: `stripes` lock-striped segments
    /// (clamped to at least 1), each holding `ceil(cap / stripes)`
    /// entries. `stripes = 1` reproduces a single global LRU exactly —
    /// the capacity tests and any caller needing strict whole-cache
    /// LRU semantics use that.
    pub fn with_stripes(
        topo: FabricTopology,
        pool_size: usize,
        cap: usize,
        level: OptLevel,
        stripes: usize,
    ) -> Self {
        let stripes = stripes.max(1);
        SessionCache {
            topo,
            pool_size: pool_size.max(1),
            seg_cap: cap.max(1).div_ceil(stripes).max(1),
            level,
            segments: (0..stripes).map(|_| Mutex::new(Segment::default())).collect(),
            hints: (0..stripes).map(|_| Mutex::new(BTreeMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
        }
    }

    fn segment_of(&self, key: Key) -> usize {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in key.0.to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
        }
        h = (h ^ key.1 as u64).wrapping_mul(0x100_0000_01b3);
        (h % self.segments.len() as u64) as usize
    }

    fn hint_stripe(&self, hint: &str) -> usize {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in hint.as_bytes() {
            h = (h ^ u64::from(*b)).wrapping_mul(0x100_0000_01b3);
        }
        (h % self.hints.len() as u64) as usize
    }

    /// The level parameter-less lookups build at.
    pub fn opt_level(&self) -> OptLevel {
        self.level
    }

    /// The (shared) topology every route in this cache was computed
    /// against.
    pub fn topology(&self) -> &FabricTopology {
        &self.topo
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Whole-cache invalidations so far ([`SessionCache::invalidate_routes`]).
    pub fn invalidations(&self) -> u64 {
        self.invalidations.load(Ordering::Relaxed)
    }

    /// Drop every warm entry and hint. The fault layer calls this when
    /// the fabric's effective capacity changes under the cache (a slot
    /// or bus fault, an outage, a repair): every cached [`RoutePlan`]
    /// was computed against the old capacity, so a warm hit could route
    /// a graph onto resources that no longer exist — or keep a tenant
    /// demoted after the fault that demoted it has been repaired.
    /// Entries are only cold, never wrong, after this; subsequent
    /// lookups rebuild against the current topology. Returns the number
    /// of warm entries purged.
    pub fn invalidate_routes(&self) -> usize {
        let mut purged = 0usize;
        for seg in &self.segments {
            let mut s = seg.lock().unwrap();
            purged += s.by_fp.len();
            s.by_fp.clear();
            s.lru.clear();
        }
        for h in &self.hints {
            h.lock().unwrap().clear();
        }
        self.invalidations.fetch_add(1, Ordering::Relaxed);
        purged
    }

    /// Drop the single warm entry (and hint) behind `hint`, leaving
    /// every other tenant's warm state untouched — the *targeted*
    /// counterpart of [`SessionCache::invalidate_routes`]. The elastic
    /// repartitioner uses this when a promotion changes exactly one
    /// tenant's route: purging the whole cache would charge every
    /// unaffected tenant a rebuild for one tenant's promotion.
    ///
    /// Lock order mirrors [`SessionCache::warm_keyed`]: the hint entry
    /// is read and removed under its stripe lock, which is released
    /// before the segment lock is taken — never both at once. Returns
    /// `true` when a warm entry was actually purged (a dangling or
    /// unknown hint returns `false`).
    pub fn invalidate_hint(&self, hint: &str) -> bool {
        let hi = self.hint_stripe(hint);
        let key = self.hints[hi].lock().unwrap().remove(hint);
        let Some(key) = key else {
            return false;
        };
        let mut seg = self.segments[self.segment_of(key)].lock().unwrap();
        let purged = seg.by_fp.remove(&key).is_some();
        if let Some(i) = seg.lru.iter().position(|&k| k == key) {
            seg.lru.remove(i);
        }
        purged
    }

    /// Distinct graphs currently warm (summed over segments).
    pub fn len(&self) -> usize {
        self.segments
            .iter()
            .map(|s| s.lock().unwrap().by_fp.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of lock stripes (segments).
    pub fn stripes(&self) -> usize {
        self.segments.len()
    }

    /// Warm state for `g` at the cache's default level: a hit returns
    /// the cached entry; a miss pays optimize + `Program::compile` +
    /// place/partition once and interns the result. The flag is `true`
    /// on a hit.
    pub fn warm(&self, g: &Graph) -> (Arc<WarmState>, bool) {
        self.warm_at(g, self.level)
    }

    /// [`SessionCache::warm`] at an explicit [`OptLevel`]. The level is
    /// part of the cache key: the same graph at a different level is a
    /// miss with its own entry.
    pub fn warm_at(&self, g: &Graph, level: OptLevel) -> (Arc<WarmState>, bool) {
        let key = (g.fingerprint(), level);
        let si = self.segment_of(key);
        {
            let mut seg = self.segments[si].lock().unwrap();
            if let Some(state) = seg.by_fp.get(&key).cloned() {
                touch(&mut seg.lru, key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return (state, true);
            }
        }
        // Build outside the lock: optimize/compile/place can be slow,
        // and the computation is idempotent (a racing builder just
        // loses the insert).
        let state = Arc::new(self.build_state(key, g));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut seg = self.segments[si].lock().unwrap();
        if let Some(existing) = seg.by_fp.get(&key).cloned() {
            touch(&mut seg.lru, key);
            return (existing, false);
        }
        seg.by_fp.insert(key, Arc::clone(&state));
        seg.lru.push_back(key);
        while seg.by_fp.len() > self.seg_cap {
            if let Some(old) = seg.lru.pop_front() {
                seg.by_fp.remove(&old);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                // Purge hints naming the evicted key. Lock order:
                // segment → hint stripe only, never the reverse.
                for h in &self.hints {
                    h.lock().unwrap().retain(|_, v| *v != old);
                }
            }
        }
        (state, false)
    }

    /// [`SessionCache::warm`] through a caller-stable hint key: a hint
    /// hit skips the graph build *and* the fingerprint walk entirely.
    /// The caller must guarantee the hint always names the same graph
    /// content (a benchmark slug or a generator seed does).
    pub fn warm_keyed(
        &self,
        hint: &str,
        build: impl FnOnce() -> Graph,
    ) -> (Arc<WarmState>, bool) {
        let hi = self.hint_stripe(hint);
        // Read the hint under its stripe lock, then RELEASE it before
        // touching any segment — the one-way lock order that keeps the
        // striped cache deadlock-free.
        let known = self.hints[hi].lock().unwrap().get(hint).copied();
        if let Some(key) = known {
            let mut seg = self.segments[self.segment_of(key)].lock().unwrap();
            if let Some(state) = seg.by_fp.get(&key).cloned() {
                touch(&mut seg.lru, key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return (state, true);
            }
        }
        let g = build();
        let (state, hit) = self.warm(&g);
        self.hints[hi]
            .lock()
            .unwrap()
            .insert(hint.to_string(), (state.fingerprint, state.opt_level));
        (state, hit)
    }

    fn build_state(&self, key: Key, g: &Graph) -> WarmState {
        let (fp, level) = key;
        let (og, report) = opt::optimize(g, level);
        let fits_opt = self.topo.fits(&og);
        let route = route_graph(&og, &self.topo, self.pool_size);
        WarmState {
            fingerprint: fp,
            opt_level: level,
            opt_rescued_place: fits_opt && report.changed() && !self.topo.fits(g),
            program: Arc::new(Program::compile(&og)),
            route,
            overlap_safe: overlap_safe(&og),
            opt: report,
            graph: Arc::new(og),
        }
    }

    /// One-line counter summary for logs and reports.
    pub fn summary(&self) -> String {
        format!(
            "session cache: {} warm graph(s), {} hit(s), {} miss(es), {} eviction(s)",
            self.len(),
            self.hits(),
            self.misses(),
            self.evictions()
        )
    }
}

fn touch(lru: &mut VecDeque<Key>, key: Key) {
    if let Some(i) = lru.iter().position(|&x| x == key) {
        lru.remove(i);
    }
    lru.push_back(key);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};

    fn cache(cap: usize) -> SessionCache {
        SessionCache::new(FabricTopology::paper(), 2, cap)
    }

    #[test]
    fn repeat_lookups_hit() {
        let c = cache(8);
        let g = bench_defs::build(BenchId::Fibonacci);
        let (s0, hit0) = c.warm(&g);
        assert!(!hit0);
        let (s1, hit1) = c.warm(&g);
        assert!(hit1);
        assert!(Arc::ptr_eq(&s0, &s1));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!(matches!(s0.route, RoutePlan::Placed));
        assert!(!s0.overlap_safe);
    }

    #[test]
    fn hint_hits_skip_the_build() {
        let c = cache(8);
        let mut builds = 0usize;
        for _ in 0..3 {
            let (state, _) = c.warm_keyed("bench:fibonacci", || {
                builds += 1;
                bench_defs::build(BenchId::Fibonacci)
            });
            assert!(matches!(state.route, RoutePlan::Placed));
        }
        assert_eq!(builds, 1, "only the miss builds the graph");
        assert_eq!(c.hits(), 2);
    }

    #[test]
    fn capacity_evicts_lru() {
        // One stripe = one global LRU: exact whole-cache capacity
        // semantics, the configuration this test pins down.
        let c = SessionCache::with_stripes(FabricTopology::paper(), 2, 2, OptLevel::Default, 1);
        assert_eq!(c.stripes(), 1);
        for b in [BenchId::Fibonacci, BenchId::Max, BenchId::DotProd] {
            c.warm(&bench_defs::build(b));
        }
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        // Fibonacci was evicted; warming it again is a miss.
        c.warm(&bench_defs::build(BenchId::Fibonacci));
        assert_eq!(c.misses(), 4);
        assert!(c.summary().contains("2 warm graph(s)"));
    }

    #[test]
    fn striped_cache_concurrent_warms_converge() {
        // N threads warming the same small graph set race on the
        // stripes; every thread must land on consistent interned state
        // and the cache must end exactly as warm as a serial pass.
        let c = cache(16);
        let benches = [BenchId::Fibonacci, BenchId::Max, BenchId::DotProd];
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for b in benches {
                        let g = bench_defs::build(b);
                        let (state, _) = c.warm(&g);
                        assert_eq!(state.fingerprint, g.fingerprint());
                    }
                });
            }
        });
        assert!(c.stripes() > 1);
        assert_eq!(c.len(), benches.len());
        // 4 threads × 3 graphs = 12 lookups; racing builders may each
        // count a miss, but at least one per graph must.
        assert_eq!(c.hits() + c.misses(), 12);
        assert!(c.misses() >= benches.len() as u64);
        // The interned state is shared: a fresh warm is a pure hit.
        for b in benches {
            let (_, hit) = c.warm(&bench_defs::build(b));
            assert!(hit);
        }
    }

    #[test]
    fn striped_eviction_purges_hints() {
        // stripes=1 + cap=1 forces every new graph to evict the
        // previous one; the hint index must never dangle.
        let c = SessionCache::with_stripes(FabricTopology::paper(), 2, 1, OptLevel::Default, 1);
        let (a, _) = c.warm_keyed("bench:fibonacci", || bench_defs::build(BenchId::Fibonacci));
        let (b, _) = c.warm_keyed("bench:max", || bench_defs::build(BenchId::Max));
        assert_ne!(a.fingerprint, b.fingerprint);
        assert_eq!(c.len(), 1);
        assert!(c.evictions() >= 1);
        // The evicted hint rebuilds (miss), the resident one hits.
        let mut rebuilt = false;
        let (a2, hit) = c.warm_keyed("bench:fibonacci", || {
            rebuilt = true;
            bench_defs::build(BenchId::Fibonacci)
        });
        assert!(rebuilt && !hit);
        assert_eq!(a2.fingerprint, a.fingerprint);
    }

    #[test]
    fn undersized_topology_routes_off_the_placed_path() {
        let g = bench_defs::build(BenchId::Max);
        // Size the fabric against the *optimized* graph — that is what
        // the cache routes, and `sized_for_shards` guarantees it will
        // not fit whole.
        let og = crate::opt::optimize(&g, OptLevel::Default).0;
        let topo = FabricTopology::sized_for_shards(&og, 2);
        // Two instances: spatial sharding.
        let c2 = SessionCache::new(topo.clone(), 4, 8);
        let (s, _) = c2.warm(&g);
        assert!(matches!(s.route, RoutePlan::Sharded(_)));
        // One instance: time-multiplexing.
        let c1 = SessionCache::new(topo, 1, 8);
        let (s, _) = c1.warm(&g);
        assert!(matches!(s.route, RoutePlan::Reconfig(_)));
    }

    #[test]
    fn opt_level_participates_in_the_cache_key() {
        let c = cache(8);
        let g = bench_defs::build(BenchId::DotProd);
        let (_, h0) = c.warm_at(&g, OptLevel::Default);
        assert!(!h0);
        let (_, h1) = c.warm_at(&g, OptLevel::Default);
        assert!(h1);
        let (s2, h2) = c.warm_at(&g, OptLevel::Aggressive);
        assert!(!h2, "changing the level must be a miss");
        assert_eq!(s2.opt_level, OptLevel::Aggressive);
        assert_eq!(c.misses(), 2);
        assert_eq!(c.len(), 2, "both levels stay warm side by side");
        assert_eq!(c.opt_level(), OptLevel::Default);
    }

    #[test]
    fn cache_key_is_the_pre_opt_fingerprint() {
        // The same raw submission always hits the same entry even
        // though the cached graph is the optimized one; a
        // pre-optimized submission is different content, hence its own
        // key.
        let c = cache(8);
        let raw = crate::frontend::compile_with(
            "fib",
            bench_defs::c_source(BenchId::Fibonacci),
            OptLevel::None,
        )
        .unwrap();
        let (s, hit) = c.warm(&raw);
        assert!(!hit);
        assert_eq!(s.fingerprint, raw.fingerprint());
        assert!(s.graph.n_nodes() < raw.n_nodes(), "lowered fib must shrink");
        assert_ne!(s.graph.fingerprint(), raw.fingerprint());
        let (s2, hit2) = c.warm(&raw);
        assert!(hit2);
        assert!(Arc::ptr_eq(&s, &s2));
        let (s3, hit3) = c.warm(&s.graph);
        assert!(!hit3, "optimized content is a different key");
        assert_eq!(s3.fingerprint, s.graph.fingerprint());
    }

    #[test]
    fn optimization_rescues_placement_on_tight_fabrics() {
        let raw = crate::frontend::compile_with(
            "fib",
            bench_defs::c_source(BenchId::Fibonacci),
            OptLevel::None,
        )
        .unwrap();
        let og = crate::opt::optimize(&raw, OptLevel::Default).0;
        assert!(og.n_nodes() < raw.n_nodes());
        // A fabric sized exactly for the optimized graph: the raw graph
        // overflows it (strictly more nodes ⇒ strictly more arcs than
        // the channel pool), the optimized graph places whole.
        let topo = FabricTopology::sized_for_shards(&og, 1);
        assert!(topo.fits(&og));
        assert!(!topo.fits(&raw));
        let c = SessionCache::new(topo, 2, 8);
        let (s, _) = c.warm(&raw);
        assert!(matches!(s.route, RoutePlan::Placed));
        assert!(s.opt_rescued_place, "placement only succeeds optimized");
        assert!(s.opt.changed());
        // The already-optimal graph places on its own merits.
        let (s2, _) = c.warm(&og);
        assert!(matches!(s2.route, RoutePlan::Placed));
        assert!(!s2.opt_rescued_place);
    }

    #[test]
    fn invalidation_purges_entries_and_hints() {
        let c = cache(8);
        let (warm, _) = c.warm_keyed("bench:fibonacci", || bench_defs::build(BenchId::Fibonacci));
        c.warm(&bench_defs::build(BenchId::Max));
        assert_eq!(c.len(), 2);
        assert_eq!(c.invalidate_routes(), 2);
        assert_eq!(c.invalidations(), 1);
        assert!(c.is_empty());
        // The hint index must not dangle: the next keyed lookup is a
        // full rebuild, not a stale hit.
        let mut rebuilt = false;
        let (again, hit) = c.warm_keyed("bench:fibonacci", || {
            rebuilt = true;
            bench_defs::build(BenchId::Fibonacci)
        });
        assert!(rebuilt && !hit);
        assert_eq!(again.fingerprint, warm.fingerprint);
    }

    #[test]
    fn targeted_invalidation_purges_one_hint_and_spares_the_rest() {
        let c = cache(8);
        let (fib, _) = c.warm_keyed("bench:fibonacci", || bench_defs::build(BenchId::Fibonacci));
        let (max, _) = c.warm_keyed("bench:max", || bench_defs::build(BenchId::Max));
        assert_eq!(c.len(), 2);
        assert!(c.invalidate_hint("bench:fibonacci"));
        assert_eq!(c.len(), 1, "only the named tenant's entry is purged");
        // The spared tenant still hits warm...
        let (max2, hit) = c.warm_keyed("bench:max", || unreachable!("max must stay warm"));
        assert!(hit);
        assert!(Arc::ptr_eq(&max, &max2));
        // ...while the invalidated one rebuilds from cold.
        let mut rebuilt = false;
        let (fib2, hit) = c.warm_keyed("bench:fibonacci", || {
            rebuilt = true;
            bench_defs::build(BenchId::Fibonacci)
        });
        assert!(rebuilt && !hit);
        assert_eq!(fib2.fingerprint, fib.fingerprint);
        // Unknown and already-purged hints are no-ops.
        assert!(!c.invalidate_hint("bench:nope"));
        // Targeted purges are not whole-cache invalidations.
        assert_eq!(c.invalidations(), 0);
    }

    #[test]
    fn route_graph_follows_the_recovery_lattice() {
        let g = bench_defs::build(BenchId::Max);
        let og = crate::opt::optimize(&g, OptLevel::Default).0;
        let full = FabricTopology::paper();
        assert!(matches!(route_graph(&og, &full, 2), RoutePlan::Placed));
        let half = FabricTopology::sized_for_shards(&og, 2);
        assert!(matches!(route_graph(&og, &half, 4), RoutePlan::Sharded(_)));
        assert!(matches!(route_graph(&og, &half, 1), RoutePlan::Reconfig(_)));
        // A zero-capacity topology (a downed instance's effective view)
        // is unpartitionable: the lattice bottoms out at Fallback.
        let dark = crate::fabric::FabricHealth {
            down: true,
            ..Default::default()
        }
        .effective(&full);
        assert!(matches!(route_graph(&og, &dark, 2), RoutePlan::Fallback));
    }

    #[test]
    fn saxpy_is_warm_overlap_safe() {
        let c = cache(4);
        let (s, _) = c.warm(&bench_defs::saxpy::build());
        assert!(s.overlap_safe);
        assert_eq!(s.program.n_nodes(), s.graph.n_nodes());
    }
}
