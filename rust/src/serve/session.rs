//! The warm-state session cache: compile/place results keyed by
//! [`Graph::fingerprint`].
//!
//! Every execution engine in this crate needs per-graph *static* state
//! before the first token moves: the built [`Graph`] itself, the lane
//! tier's compiled [`Program`], and the fabric route (placement check,
//! partition plan). None of that depends on the workload, so a serving
//! tier that rebuilds it per batch wastes the whole cold-start cost on
//! every repeat tenant. [`SessionCache`] interns it once per graph
//! fingerprint; a warm lookup hands back an [`Arc<WarmState>`] and the
//! hot path runs straight into the engines.
//!
//! The resident wave-session state itself (token buffers, FIFOs) is
//! *empty* between batches by construction — serialized admission
//! resets between waves and pipelined admission drains — so a warm
//! session is exactly: cached graph + cached program + cached route +
//! cached admission class, re-wrapped around the engines in O(arcs).
//! The expensive part (graph build, `Program::compile`, `place` /
//! `partition`) is what the cache skips; `hits`/`misses` counters make
//! that observable ([`crate::coordinator::Metrics`] exposes them as
//! `cache_hits`).
//!
//! Invalidation: the fingerprint is content-addressed, so a changed
//! graph *is* a different key — entries are never stale, only cold.
//! Capacity is bounded; least-recently-used entries are evicted.

use crate::dfg::Graph;
use crate::fabric::{self, FabricTopology, PartitionPlan};
use crate::sim::{overlap_safe, Program};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// How a graph maps onto the serving fabric — the router's placed →
/// sharded → reconfig → fallback lattice, computed once per graph
/// fingerprint instead of once per (worker, benchmark).
#[derive(Debug, Clone)]
pub enum RoutePlan {
    /// Fits one fabric instance whole: batched engines apply.
    Placed,
    /// Exceeds one instance; the pool can host one instance per shard.
    Sharded(PartitionPlan),
    /// Exceeds one instance on a pool with too few instances: serve
    /// time-multiplexed (context swapping) on one instance.
    Reconfig(PartitionPlan),
    /// Fits no partition of the topology: serve on the infinite-fabric
    /// simulation rather than failing.
    Fallback,
}

impl RoutePlan {
    pub fn name(&self) -> &'static str {
        match self {
            RoutePlan::Placed => "placed",
            RoutePlan::Sharded(_) => "sharded",
            RoutePlan::Reconfig(_) => "reconfig",
            RoutePlan::Fallback => "fallback",
        }
    }
}

/// Everything the hot path needs that depends only on the graph (not
/// the workload): the one warm, shareable compile/place state.
#[derive(Debug)]
pub struct WarmState {
    pub fingerprint: u64,
    pub graph: Arc<Graph>,
    /// The lane tier's compiled node table ([`Program::compile`]).
    pub program: Arc<Program>,
    pub route: RoutePlan,
    /// Cached [`overlap_safe`] — whether a resident session may overlap
    /// waves (pipelined admission).
    pub overlap_safe: bool,
}

struct Inner {
    by_fp: BTreeMap<u64, Arc<WarmState>>,
    /// Secondary index: a caller-stable hint key (benchmark slug,
    /// generator seed) → fingerprint, so hot-path hits skip even the
    /// graph build.
    by_hint: BTreeMap<String, u64>,
    /// Fingerprints, least recently used first.
    lru: VecDeque<u64>,
}

/// A bounded, thread-safe cache of [`WarmState`] keyed by
/// [`Graph::fingerprint`], for one serving tier (one topology + pool).
pub struct SessionCache {
    topo: FabricTopology,
    pool_size: usize,
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl SessionCache {
    /// A cache for a pool of `pool_size` instances of `topo`, holding
    /// at most `cap` distinct graphs.
    pub fn new(topo: FabricTopology, pool_size: usize, cap: usize) -> Self {
        SessionCache {
            topo,
            pool_size: pool_size.max(1),
            cap: cap.max(1),
            inner: Mutex::new(Inner {
                by_fp: BTreeMap::new(),
                by_hint: BTreeMap::new(),
                lru: VecDeque::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The (shared) topology every route in this cache was computed
    /// against.
    pub fn topology(&self) -> &FabricTopology {
        &self.topo
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Distinct graphs currently warm.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().by_fp.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Warm state for `g`: a hit returns the cached entry; a miss pays
    /// `Program::compile` + place/partition once and interns the
    /// result. The flag is `true` on a hit.
    pub fn warm(&self, g: &Graph) -> (Arc<WarmState>, bool) {
        let fp = g.fingerprint();
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(state) = inner.by_fp.get(&fp).cloned() {
                touch(&mut inner.lru, fp);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return (state, true);
            }
        }
        // Build outside the lock: compile/place can be slow, and the
        // computation is idempotent (a racing builder just loses the
        // insert).
        let state = Arc::new(self.build_state(fp, g));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        if let Some(existing) = inner.by_fp.get(&fp).cloned() {
            touch(&mut inner.lru, fp);
            return (existing, false);
        }
        inner.by_fp.insert(fp, Arc::clone(&state));
        inner.lru.push_back(fp);
        while inner.by_fp.len() > self.cap {
            if let Some(old) = inner.lru.pop_front() {
                inner.by_fp.remove(&old);
                inner.by_hint.retain(|_, v| *v != old);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        (state, false)
    }

    /// [`SessionCache::warm`] through a caller-stable hint key: a hint
    /// hit skips the graph build *and* the fingerprint walk entirely.
    /// The caller must guarantee the hint always names the same graph
    /// content (a benchmark slug or a generator seed does).
    pub fn warm_keyed(
        &self,
        hint: &str,
        build: impl FnOnce() -> Graph,
    ) -> (Arc<WarmState>, bool) {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(&fp) = inner.by_hint.get(hint) {
                if let Some(state) = inner.by_fp.get(&fp).cloned() {
                    touch(&mut inner.lru, fp);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return (state, true);
                }
            }
        }
        let g = build();
        let (state, hit) = self.warm(&g);
        let mut inner = self.inner.lock().unwrap();
        inner.by_hint.insert(hint.to_string(), state.fingerprint);
        (state, hit)
    }

    fn build_state(&self, fp: u64, g: &Graph) -> WarmState {
        let route = if self.topo.fits(g) {
            RoutePlan::Placed
        } else {
            match fabric::partition(g, &self.topo) {
                Ok(plan) if self.pool_size >= plan.n_shards() => RoutePlan::Sharded(plan),
                Ok(plan) => RoutePlan::Reconfig(plan),
                Err(e) => {
                    eprintln!(
                        "serve: `{}` is unpartitionable on `{}` ({e}); \
                         falling back to infinite-fabric simulation",
                        g.name, self.topo.name
                    );
                    RoutePlan::Fallback
                }
            }
        };
        WarmState {
            fingerprint: fp,
            graph: Arc::new(g.clone()),
            program: Arc::new(Program::compile(g)),
            route,
            overlap_safe: overlap_safe(g),
        }
    }

    /// One-line counter summary for logs and reports.
    pub fn summary(&self) -> String {
        format!(
            "session cache: {} warm graph(s), {} hit(s), {} miss(es), {} eviction(s)",
            self.len(),
            self.hits(),
            self.misses(),
            self.evictions()
        )
    }
}

fn touch(lru: &mut VecDeque<u64>, fp: u64) {
    if let Some(i) = lru.iter().position(|&x| x == fp) {
        lru.remove(i);
    }
    lru.push_back(fp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};

    fn cache(cap: usize) -> SessionCache {
        SessionCache::new(FabricTopology::paper(), 2, cap)
    }

    #[test]
    fn repeat_lookups_hit() {
        let c = cache(8);
        let g = bench_defs::build(BenchId::Fibonacci);
        let (s0, hit0) = c.warm(&g);
        assert!(!hit0);
        let (s1, hit1) = c.warm(&g);
        assert!(hit1);
        assert!(Arc::ptr_eq(&s0, &s1));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!(matches!(s0.route, RoutePlan::Placed));
        assert!(!s0.overlap_safe);
    }

    #[test]
    fn hint_hits_skip_the_build() {
        let c = cache(8);
        let mut builds = 0usize;
        for _ in 0..3 {
            let (state, _) = c.warm_keyed("bench:fibonacci", || {
                builds += 1;
                bench_defs::build(BenchId::Fibonacci)
            });
            assert!(matches!(state.route, RoutePlan::Placed));
        }
        assert_eq!(builds, 1, "only the miss builds the graph");
        assert_eq!(c.hits(), 2);
    }

    #[test]
    fn capacity_evicts_lru() {
        let c = cache(2);
        for b in [BenchId::Fibonacci, BenchId::Max, BenchId::DotProd] {
            c.warm(&bench_defs::build(b));
        }
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        // Fibonacci was evicted; warming it again is a miss.
        c.warm(&bench_defs::build(BenchId::Fibonacci));
        assert_eq!(c.misses(), 4);
        assert!(c.summary().contains("2 warm graph(s)"));
    }

    #[test]
    fn undersized_topology_routes_off_the_placed_path() {
        let g = bench_defs::build(BenchId::Max);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        // Two instances: spatial sharding.
        let c2 = SessionCache::new(topo.clone(), 4, 8);
        let (s, _) = c2.warm(&g);
        assert!(matches!(s.route, RoutePlan::Sharded(_)));
        // One instance: time-multiplexing.
        let c1 = SessionCache::new(topo, 1, 8);
        let (s, _) = c1.warm(&g);
        assert!(matches!(s.route, RoutePlan::Reconfig(_)));
    }

    #[test]
    fn saxpy_is_warm_overlap_safe() {
        let c = cache(4);
        let (s, _) = c.warm(&bench_defs::saxpy::build());
        assert!(s.overlap_safe);
        assert_eq!(s.program.n_nodes(), s.graph.n_nodes());
    }
}
