//! The multi-tenant service tier: the front door that admits,
//! schedules, and reuses resident execution state across tenants.
//!
//! PRs 1–3 built the engines — placed/sharded/reconfig fabrics
//! ([`crate::fabric`]), wave-pipelined resident sessions
//! ([`crate::sim::StreamSession`]), 64-wide lane batches
//! ([`crate::sim::lanes`]) — but nothing *served* them: the paper's
//! acceleration story is a resident dataflow fabric fed a sustained
//! operand stream, and the system-level analogue is a service that
//! keeps warm state resident and feeds it a sustained request stream
//! from many tenants. This module is that service:
//!
//! * [`session`] — a bounded, thread-safe cache of warm execution
//!   state (built graph, compiled lane [`Program`](crate::sim::Program),
//!   fabric route) keyed by the content-addressed
//!   [`Graph::fingerprint`](crate::dfg::Graph::fingerprint), so repeat
//!   tenants skip build/compile/place entirely. The coordinator's
//!   router shares the same cache (its `cache_hits` metric).
//! * [`sched`] — an admission queue with per-tenant quotas and a
//!   global bound (oversubscription gets an explicit shed response,
//!   never a silent drop), weighted-fair credit picking across
//!   tenants (bounded starvation), deadline-aware same-graph batch
//!   formation, and per-batch engine selection over the existing
//!   placed → sharded → reconfig → fallback route lattice.
//! * [`loadgen`] — a deterministic seeded closed-loop / open-loop
//!   load generator over mixed workloads: the seven benchmarks plus
//!   random DFGs from [`crate::util::proptest`], organized into
//!   tenant mixes (same seed ⇒ same request trace).
//! * [`stats`] — per-tenant and global latency percentiles over a
//!   fixed-bucket histogram, queue-depth / shed / cache-hit counters.
//!
//! [`crate::report::serve`] renders the summary table and the
//! machine-readable `SERVE_<k>.json`; the `serve` CLI subcommand runs
//! a load profile end to end. DESIGN.md §8 states the invariants.
//!
//! **Parallel dispatch (PR 6).** `run_profile` optionally executes
//! dispatched batches on a [`crate::par::Executor`] work-stealing pool
//! (`ServeOptions::workers`), with the session cache lock-striped so
//! warm lookups don't serialize the dispatch loop. The tick loop's
//! decisions never read execution results, so schedules — and
//! therefore per-request results ([`outcome_digest`]) — are
//! byte-identical at every worker count; the `serve --scale-workers`
//! sweep verifies exactly that before writing `SERVE_6.json`.
//! DESIGN.md §10 states the threading model.
//!
//! **Fault tolerance (PR 8).** [`chaos`] replays a seeded
//! [`crate::fabric::FaultPlan`] against the serving pool while the
//! profile runs: quarantined instances leave the routing rotation,
//! degraded topologies demote warm routes down the lattice, resident
//! wave sessions migrate mid-wave via [`crate::sim::StreamCheckpoint`],
//! and whole-pool outages park batches on a bounded virtual-tick retry
//! schedule. The gate: zero lost requests and byte-identical output
//! digests against the fault-free baseline (`CHAOS_8.json`).
//! DESIGN.md §11 states the fault model.
//!
//! **Elastic repartitioning (PR 10).** [`elastic`] starts the pool on
//! a deliberately scarce slice of the fabric and reshapes it online:
//! an epoch loop snapshots per-tenant demand from the dispatch stream,
//! recomputes per-class slot floors, executes a *rolling* repartition
//! (one instance at a time drained via [`crate::sim::StreamCheckpoint`],
//! retopologized through a [`crate::fabric::FabricHealth`]-style
//! reserve overlay, restored, readmitted), and promotes hot tenants
//! whose graphs now fit up the route lattice with *targeted* session
//! invalidation. The gate: zero lost requests and byte-identical
//! output digests against the static-allocation baseline
//! (`ELASTIC_10.json`). DESIGN.md §13 states the policy.

pub mod chaos;
pub mod elastic;
pub mod loadgen;
pub mod sched;
pub mod session;
pub mod stats;

pub use chaos::{run_profile_chaos, ChaosOutcome};
pub use elastic::{run_profile_elastic, ElasticOutcome, ElasticPolicy};
pub use loadgen::{
    burst_series, fairness_profile, standard_profile, tenant_trace, Arrival, LoadProfile,
    ServeRequest, TenantSpec, WorkKind,
};
pub use sched::{
    choose_engine, execute_batch, execute_batch_par, outcome_digest, output_digest, run_profile,
    Admission, AdmitError, BatchResult, DispatchRec, EngineChoice, ProfileOutcome, Scheduler,
    ServeCfg, ServeOptions,
};
pub use session::{route_graph, RoutePlan, SessionCache, WarmState, DEFAULT_STRIPES};
pub use stats::{
    chaos_metric, elastic_metric, ChaosStats, ElasticStats, Histogram, ServeCollector,
    ServeReport, ShedReason, TenantStats,
};
