//! Service-tier statistics: per-tenant and global latency percentiles
//! over a fixed-bucket histogram (no deps, no post-hoc sorting), plus
//! queue-depth / shed / cache-hit counters. Rendered by
//! [`crate::report::serve`] and serialized into `SERVE_<k>.json`.

use std::collections::BTreeMap;

/// Histogram bucket count: geometric bounds in ~√2 steps starting at
/// 1 µs — bucket `2k` tops out at `1000·2^k` ns and bucket `2k+1` at
/// `1500·2^k` ns, covering 1 µs to ~33 s before the overflow bucket.
pub const BUCKETS: usize = 52;

/// Upper bound (inclusive) of bucket `i`, in nanoseconds.
///
/// Total for any index: once `1000·2^(i/2)` no longer fits in a `u64`
/// the bound saturates at [`u64::MAX`] instead of shifting past the
/// word width (a shift of ≥ 64 is a debug panic and masked garbage in
/// release, which silently broke monotonicity for large `i`).
pub fn bucket_hi(i: usize) -> u64 {
    let base: u64 = if i % 2 == 0 { 1_000 } else { 1_500 };
    let k = (i / 2) as u32;
    if k > base.leading_zeros() {
        u64::MAX
    } else {
        base << k
    }
}

/// A fixed-bucket latency histogram. Recording is O(buckets) with no
/// allocation; percentiles read the cumulative counts and report the
/// bucket's upper bound (≤ one √2 step of overestimate).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum_ns: u64,
    min_ns: u64,
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, ns: u64) {
        let i = (0..BUCKETS)
            .find(|&i| ns <= bucket_hi(i))
            .unwrap_or(BUCKETS - 1);
        self.counts[i] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// No samples recorded. Empty histograms report 0 for every
    /// quantile, mean, min, and max; the report layer marks them
    /// `"empty"` explicitly so a zero-request tenant's row is never
    /// mistaken for one with sub-microsecond latency.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_ns / self.count
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    /// The latency at quantile `q` in `[0, 1]` — the upper bound of the
    /// first bucket whose cumulative count reaches `ceil(q·count)`.
    /// Zero when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        // Rank of the sample to report, clamped to [1, count]: q = 0.0
        // must rank the first sample (not rank 0, which every cumulative
        // count trivially reaches) and float rounding at q = 1.0 must
        // never rank past the last.
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // Never report past the observed maximum.
                return bucket_hi(i).min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn p50_ns(&self) -> u64 {
        self.quantile_ns(0.50)
    }

    pub fn p95_ns(&self) -> u64 {
        self.quantile_ns(0.95)
    }

    pub fn p99_ns(&self) -> u64 {
        self.quantile_ns(0.99)
    }

    /// Non-empty buckets as `(lo_ns, hi_ns, count)` rows, ascending.
    ///
    /// This is the **single source of bucket labels**: both the JSON
    /// serializer and the table renderer consume these rows, so bounds can
    /// never drift between the two (they used to be recomputed ad hoc).
    /// Bounds come from the same [`bucket_hi`] table [`Histogram::record`]
    /// buckets with; `lo` is the previous bound + 1 (0 for the first).
    pub fn buckets(&self) -> Vec<(u64, u64, u64)> {
        let mut rows = Vec::new();
        let mut lo = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let hi = bucket_hi(i);
            if c > 0 {
                rows.push((lo, hi, c));
            }
            lo = hi.saturating_add(1);
        }
        rows
    }

    /// Fold `other` into `self` (used to build the global view).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Fault-injection and recovery counters for one chaos run
/// ([`crate::serve::chaos`]). All zeros under an empty fault plan.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosStats {
    /// Slot-failure events applied.
    pub slot_faults: u64,
    /// Bus-channel-failure events applied.
    pub bus_faults: u64,
    /// Whole-instance outage events applied.
    pub outages: u64,
    /// Repair events applied.
    pub repairs: u64,
    /// Resident stream sessions moved between instances by
    /// checkpoint/restore.
    pub migrations: u64,
    /// Waves alive inside a migrated checkpoint — work that would have
    /// been lost without the checkpoint image.
    pub rescued_waves: u64,
    /// Virtual-tick retry probes taken while the whole pool was dark.
    pub retries: u64,
    /// Batches re-routed down the placed → sharded → reconfig →
    /// fallback lattice because their warm route no longer fit the
    /// degraded (or dark) fabric.
    pub demotions: u64,
    /// Whole-cache warm-route purges triggered by topology changes.
    pub route_invalidations: u64,
}

/// Counter indices for the chaos family's [`crate::obs::CounterSet`]
/// (`obs::registry`) — the chaos path increments these, and
/// [`ChaosStats::from_counters`] builds the public report view.
pub mod chaos_metric {
    pub const SLOT_FAULTS: usize = 0;
    pub const BUS_FAULTS: usize = 1;
    pub const OUTAGES: usize = 2;
    pub const REPAIRS: usize = 3;
    pub const MIGRATIONS: usize = 4;
    pub const RESCUED_WAVES: usize = 5;
    pub const RETRIES: usize = 6;
    pub const DEMOTIONS: usize = 7;
    pub const ROUTE_INVALIDATIONS: usize = 8;

    pub const NAMES: [&str; 9] = [
        "slot_faults",
        "bus_faults",
        "outages",
        "repairs",
        "migrations",
        "rescued_waves",
        "retries",
        "demotions",
        "route_invalidations",
    ];
}

impl ChaosStats {
    /// Fault events injected (repairs are recovery, not faults).
    pub fn faults_injected(&self) -> u64 {
        self.slot_faults + self.bus_faults + self.outages
    }

    /// Thin view over a `"chaos"` [`crate::obs::CounterSet`] indexed by
    /// [`chaos_metric`].
    pub fn from_counters(c: &crate::obs::CounterSet) -> ChaosStats {
        ChaosStats {
            slot_faults: c.get(chaos_metric::SLOT_FAULTS),
            bus_faults: c.get(chaos_metric::BUS_FAULTS),
            outages: c.get(chaos_metric::OUTAGES),
            repairs: c.get(chaos_metric::REPAIRS),
            migrations: c.get(chaos_metric::MIGRATIONS),
            rescued_waves: c.get(chaos_metric::RESCUED_WAVES),
            retries: c.get(chaos_metric::RETRIES),
            demotions: c.get(chaos_metric::DEMOTIONS),
            route_invalidations: c.get(chaos_metric::ROUTE_INVALIDATIONS),
        }
    }
}

/// Rolling-repartition counters for one elastic run
/// ([`crate::serve::elastic`]). All zeros when the epoch loop never
/// fired (static allocation).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ElasticStats {
    /// Repartition epochs evaluated (demand snapshots taken).
    pub epochs: u64,
    /// Epochs whose demand snapshot changed the slot plan and triggered
    /// a rolling repartition.
    pub repartitions: u64,
    /// Instances drained (checkpoint taken) during rolling repartitions.
    pub drains: u64,
    /// Instances restored and readmitted after retopologizing.
    pub restores: u64,
    /// Waves alive inside a drain checkpoint — resident work carried
    /// across the repartition instead of being lost.
    pub migrated_waves: u64,
    /// Batches whose dispatch landed on an instance mid-drain and were
    /// charged the drain window as extra queue wait.
    pub delayed_waves: u64,
    /// Tenants promoted up the route lattice (fallback/sharded →
    /// placed) after a repartition made their graphs fit.
    pub promotions: u64,
    /// Warm routes invalidated *individually* for promoted tenants —
    /// targeted, never the wholesale purge the chaos path uses.
    pub targeted_invalidations: u64,
}

/// Counter indices for the elastic family's [`crate::obs::CounterSet`]
/// — the repartitioner increments these, and
/// [`ElasticStats::from_counters`] builds the public report view.
pub mod elastic_metric {
    pub const EPOCHS: usize = 0;
    pub const REPARTITIONS: usize = 1;
    pub const DRAINS: usize = 2;
    pub const RESTORES: usize = 3;
    pub const MIGRATED_WAVES: usize = 4;
    pub const DELAYED_WAVES: usize = 5;
    pub const PROMOTIONS: usize = 6;
    pub const TARGETED_INVALIDATIONS: usize = 7;

    pub const NAMES: [&str; 8] = [
        "epochs",
        "repartitions",
        "drains",
        "restores",
        "migrated_waves",
        "delayed_waves",
        "promotions",
        "targeted_invalidations",
    ];
}

impl ElasticStats {
    /// Thin view over an `"elastic"` [`crate::obs::CounterSet`] indexed
    /// by [`elastic_metric`].
    pub fn from_counters(c: &crate::obs::CounterSet) -> ElasticStats {
        ElasticStats {
            epochs: c.get(elastic_metric::EPOCHS),
            repartitions: c.get(elastic_metric::REPARTITIONS),
            drains: c.get(elastic_metric::DRAINS),
            restores: c.get(elastic_metric::RESTORES),
            migrated_waves: c.get(elastic_metric::MIGRATED_WAVES),
            delayed_waves: c.get(elastic_metric::DELAYED_WAVES),
            promotions: c.get(elastic_metric::PROMOTIONS),
            targeted_invalidations: c.get(elastic_metric::TARGETED_INVALIDATIONS),
        }
    }
}

/// Why a request was shed at admission (always explicit — the
/// scheduler never silently drops).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The global admission queue was at capacity.
    QueueFull,
    /// The tenant's own queued-request quota was exhausted.
    TenantQuota,
}

/// One tenant's (or the global) counter set.
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    pub name: String,
    pub submitted: u64,
    pub shed_queue_full: u64,
    pub shed_quota: u64,
    pub completed: u64,
    pub verified: u64,
    pub batches: u64,
    /// Requests served per engine name (`lanes`, `streamed`, …).
    pub engine_requests: BTreeMap<&'static str, u64>,
    /// End-to-end wall latency (submit → result), nanoseconds.
    pub latency: Histogram,
    /// Sum of scheduler-tick queue waits (admit → dispatch), for the
    /// mean; tick waits are deterministic where wall latency is not.
    pub wait_ticks: u64,
    pub fabric_cycles: u64,
}

impl TenantStats {
    pub fn named(name: impl Into<String>) -> Self {
        TenantStats {
            name: name.into(),
            ..TenantStats::default()
        }
    }

    pub fn shed(&self) -> u64 {
        self.shed_queue_full + self.shed_quota
    }

    /// Requests neither completed nor explicitly shed. The service
    /// invariant is that this is zero once a profile drains.
    pub fn lost(&self) -> u64 {
        self.submitted
            .saturating_sub(self.completed)
            .saturating_sub(self.shed())
    }

    pub fn mean_wait_ticks(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.wait_ticks as f64 / self.completed as f64
        }
    }
}

/// The full result of one load profile: per-tenant stats, the global
/// roll-up, and service-level gauges.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub tenants: Vec<TenantStats>,
    pub global: TenantStats,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    /// High-water mark of the total admission queue depth.
    pub max_queue_depth: usize,
    /// Scheduler ticks the profile took to drain.
    pub ticks: u64,
    /// Lane items re-run on the scalar engine (lanes→scalar fallback).
    pub lane_scalar_reruns: u64,
    /// Dispatch workers the profile ran with (1 = inline loop).
    pub workers: usize,
    /// Wall time of the whole profile run.
    pub wall_ns: u64,
    /// Summed batch-execution time across all workers — can exceed
    /// `wall_ns` by up to a factor of `workers`.
    pub busy_ns: u64,
    /// Tasks the executor's workers obtained by stealing.
    pub steals: u64,
    /// Total output tokens across every completed request.
    pub tokens_out: u64,
    /// Fault-injection counters when the profile ran under a chaos
    /// schedule ([`crate::serve::chaos`]); `None` on fault-free runs.
    pub chaos: Option<ChaosStats>,
    /// Rolling-repartition counters when the profile ran under the
    /// elastic epoch loop ([`crate::serve::elastic`]); `None` on
    /// statically-allocated runs.
    pub elastic: Option<ElasticStats>,
}

impl ServeReport {
    /// Output tokens per wall-clock second — the scaling curve's
    /// throughput axis.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.tokens_out as f64 / (self.wall_ns as f64 * 1e-9)
        }
    }

    /// Mean fraction of the pool kept busy (`busy / (wall × workers)`).
    pub fn utilization(&self) -> f64 {
        if self.wall_ns == 0 || self.workers == 0 {
            0.0
        } else {
            self.busy_ns as f64 / (self.wall_ns as f64 * self.workers as f64)
        }
    }
}

/// The mutable collector the scheduler writes into while a profile
/// runs; [`ServeCollector::finish`] produces the immutable report.
#[derive(Debug, Default)]
pub struct ServeCollector {
    tenants: Vec<TenantStats>,
    max_queue_depth: usize,
    lane_scalar_reruns: u64,
}

impl ServeCollector {
    pub fn new(tenant_names: &[String]) -> Self {
        ServeCollector {
            tenants: tenant_names
                .iter()
                .map(|n| TenantStats::named(n.clone()))
                .collect(),
            max_queue_depth: 0,
            lane_scalar_reruns: 0,
        }
    }

    pub fn submitted(&mut self, tenant: usize) {
        self.tenants[tenant].submitted += 1;
    }

    pub fn shed(&mut self, tenant: usize, reason: ShedReason) {
        match reason {
            ShedReason::QueueFull => self.tenants[tenant].shed_queue_full += 1,
            ShedReason::TenantQuota => self.tenants[tenant].shed_quota += 1,
        }
    }

    pub fn batch(&mut self, tenant: usize, engine: &'static str, requests: usize) {
        let t = &mut self.tenants[tenant];
        t.batches += 1;
        *t.engine_requests.entry(engine).or_insert(0) += requests as u64;
    }

    pub fn completed(
        &mut self,
        tenant: usize,
        verified: bool,
        latency_ns: u64,
        wait_ticks: u64,
        fabric_cycles: u64,
    ) {
        let t = &mut self.tenants[tenant];
        t.completed += 1;
        if verified {
            t.verified += 1;
        }
        t.latency.record(latency_ns);
        t.wait_ticks += wait_ticks;
        t.fabric_cycles += fabric_cycles;
    }

    pub fn lane_scalar_reruns(&mut self, n: u64) {
        self.lane_scalar_reruns += n;
    }

    pub fn queue_depth(&mut self, depth: usize) {
        self.max_queue_depth = self.max_queue_depth.max(depth);
    }

    /// Roll up the global view and freeze the report.
    pub fn finish(self, cache: &super::SessionCache, ticks: u64) -> ServeReport {
        let mut global = TenantStats::named("global");
        for t in &self.tenants {
            global.submitted += t.submitted;
            global.shed_queue_full += t.shed_queue_full;
            global.shed_quota += t.shed_quota;
            global.completed += t.completed;
            global.verified += t.verified;
            global.batches += t.batches;
            global.wait_ticks += t.wait_ticks;
            global.fabric_cycles += t.fabric_cycles;
            global.latency.merge(&t.latency);
            for (e, n) in &t.engine_requests {
                *global.engine_requests.entry(e).or_insert(0) += n;
            }
        }
        ServeReport {
            tenants: self.tenants,
            global,
            cache_hits: cache.hits(),
            cache_misses: cache.misses(),
            cache_evictions: cache.evictions(),
            max_queue_depth: self.max_queue_depth,
            ticks,
            lane_scalar_reruns: self.lane_scalar_reruns,
            // The run harness (`run_profile`) fills the threading
            // fields in after the freeze; a bare collector reports
            // the single inline worker.
            workers: 1,
            wall_ns: 0,
            busy_ns: 0,
            steals: 0,
            tokens_out: 0,
            chaos: None,
            elastic: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_cover_microseconds_to_seconds() {
        for i in 1..BUCKETS {
            assert!(bucket_hi(i) > bucket_hi(i - 1), "bucket {i}");
        }
        assert_eq!(bucket_hi(0), 1_000);
        assert!(bucket_hi(BUCKETS - 1) > 30_000_000_000);
    }

    #[test]
    fn bucket_hi_saturates_instead_of_overflowing() {
        // Pre-fix this shifted by 65 — a shift-overflow panic in debug
        // builds and masked garbage (non-monotone bounds) in release.
        assert_eq!(bucket_hi(130), u64::MAX);
        for i in 1..=256 {
            assert!(bucket_hi(i) >= bucket_hi(i - 1), "bucket {i}");
        }
        // The largest exactly-representable bound, then saturation.
        assert_eq!(bucket_hi(108), 1_000u64 << 54);
        assert_eq!(bucket_hi(109), u64::MAX);
    }

    #[test]
    fn quantile_edges_are_well_defined() {
        let mut h = Histogram::new();
        h.record(5_000);
        // One sample: every quantile is that sample (clamped to the
        // observed max), never a zero or out-of-range rank.
        assert_eq!(h.quantile_ns(0.0), 5_000);
        assert_eq!(h.quantile_ns(0.5), 5_000);
        assert_eq!(h.quantile_ns(1.0), 5_000);

        let mut h = Histogram::new();
        h.record(1_000);
        h.record(2_000_000);
        // q = 0.0 ranks the first sample, q = 1.0 the last; out-of-range
        // q clamps rather than ranking past either end.
        assert_eq!(h.quantile_ns(0.0), 1_000);
        assert_eq!(h.quantile_ns(-3.0), 1_000);
        assert_eq!(h.quantile_ns(1.0), 2_000_000);
        assert_eq!(h.quantile_ns(7.0), 2_000_000);
    }

    #[test]
    fn percentiles_track_recorded_values() {
        let mut h = Histogram::new();
        assert_eq!(h.p50_ns(), 0);
        for ns in [1_000u64, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000, 128_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 8);
        let p50 = h.p50_ns();
        assert!((4_000..=8_000).contains(&p50), "p50 {p50}");
        let p99 = h.p99_ns();
        assert!(p99 >= 128_000, "p99 {p99}");
        assert!(p99 <= h.max_ns());
        assert_eq!(h.min_ns(), 1_000);
        assert!(h.p50_ns() <= h.p95_ns() && h.p95_ns() <= h.p99_ns());
    }

    #[test]
    fn overflow_lands_in_last_bucket() {
        let mut h = Histogram::new();
        h.record(u64::MAX / 2);
        assert_eq!(h.count(), 1);
        // Clamped to the overflow bucket's bound, not the raw value.
        assert_eq!(h.p50_ns(), bucket_hi(BUCKETS - 1));
    }

    #[test]
    fn merge_equals_recording_everything_in_one() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for (i, ns) in [900u64, 5_000, 77_000, 2_000_000, 400].iter().enumerate() {
            if i % 2 == 0 {
                a.record(*ns);
            } else {
                b.record(*ns);
            }
            whole.record(*ns);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.p50_ns(), whole.p50_ns());
        assert_eq!(a.p99_ns(), whole.p99_ns());
        assert_eq!(a.min_ns(), whole.min_ns());
        assert_eq!(a.max_ns(), whole.max_ns());
    }

    #[test]
    fn empty_histogram_reports_zero_everywhere() {
        // A zero-request tenant's histogram: every statistic is 0 and
        // `is_empty` lets the report layer say so explicitly, instead
        // of the garbage min (`u64::MAX`) or an accidental "p99 = 0 ns"
        // claim.
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile_ns(q), 0, "q={q}");
        }
        assert_eq!(h.mean_ns(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
        let mut nonempty = Histogram::new();
        nonempty.record(1);
        assert!(!nonempty.is_empty());
        // Merging an empty histogram must not poison min/max.
        nonempty.merge(&Histogram::new());
        assert_eq!(nonempty.min_ns(), 1);
        assert_eq!(nonempty.p99_ns(), 1);
    }

    #[test]
    fn bucket_rows_are_monotone_disjoint_and_complete() {
        let mut h = Histogram::new();
        for ns in [500u64, 900, 1_200, 5_000, 5_100, 2_000_000, u64::MAX / 2] {
            h.record(ns);
        }
        let rows = h.buckets();
        assert!(!rows.is_empty());
        // Bounds ascend, ranges never overlap, every sample is counted.
        let mut prev_hi = None;
        let mut total = 0u64;
        for &(lo, hi, c) in &rows {
            assert!(lo <= hi, "bucket [{lo}, {hi}]");
            assert!(c > 0, "buckets() must skip empty buckets");
            if let Some(p) = prev_hi {
                assert!(lo > p, "bucket [{lo}, {hi}] overlaps previous hi {p}");
            }
            prev_hi = Some(hi);
            total += c;
        }
        assert_eq!(total, h.count());
        // Rows come straight from the bucket_hi table record() used.
        for &(_, hi, _) in &rows {
            assert!((0..BUCKETS).any(|i| bucket_hi(i) == hi), "hi {hi}");
        }
        assert!(Histogram::new().buckets().is_empty());
    }

    #[test]
    fn chaos_stats_is_a_view_over_the_chaos_counter_family() {
        let c = crate::obs::CounterSet::new("chaos", &chaos_metric::NAMES);
        c.add(chaos_metric::SLOT_FAULTS, 2);
        c.incr(chaos_metric::MIGRATIONS);
        c.add(chaos_metric::RETRIES, 5);
        let s = ChaosStats::from_counters(&c);
        assert_eq!(s.slot_faults, 2);
        assert_eq!(s.migrations, 1);
        assert_eq!(s.retries, 5);
        assert_eq!(s.bus_faults, 0);
        assert_eq!(s.faults_injected(), 2);
        // Index constants and export names stay aligned.
        let last = chaos_metric::NAMES[chaos_metric::ROUTE_INVALIDATIONS];
        assert_eq!(last, "route_invalidations");
        assert_eq!(c.snapshot().get("retries"), 5);
    }

    #[test]
    fn elastic_stats_is_a_view_over_the_elastic_counter_family() {
        let c = crate::obs::CounterSet::new("elastic", &elastic_metric::NAMES);
        c.add(elastic_metric::EPOCHS, 4);
        c.incr(elastic_metric::REPARTITIONS);
        c.add(elastic_metric::DRAINS, 2);
        c.add(elastic_metric::RESTORES, 2);
        c.add(elastic_metric::PROMOTIONS, 1);
        c.add(elastic_metric::TARGETED_INVALIDATIONS, 1);
        let s = ElasticStats::from_counters(&c);
        assert_eq!(s.epochs, 4);
        assert_eq!(s.repartitions, 1);
        assert_eq!(s.drains, 2);
        assert_eq!(s.restores, 2);
        assert_eq!(s.promotions, 1);
        assert_eq!(s.targeted_invalidations, 1);
        assert_eq!(s.migrated_waves, 0);
        assert_eq!(s.delayed_waves, 0);
        // Index constants and export names stay aligned.
        let last = elastic_metric::NAMES[elastic_metric::TARGETED_INVALIDATIONS];
        assert_eq!(last, "targeted_invalidations");
        assert_eq!(c.snapshot().get("epochs"), 4);
    }

    #[test]
    fn chaos_counters_roll_up() {
        let c = ChaosStats {
            slot_faults: 1,
            bus_faults: 2,
            outages: 3,
            repairs: 4,
            ..ChaosStats::default()
        };
        assert_eq!(c.faults_injected(), 6);
        assert_eq!(ChaosStats::default().faults_injected(), 0);
    }

    #[test]
    fn lost_is_zero_when_everything_is_accounted() {
        let mut t = TenantStats::named("t");
        t.submitted = 10;
        t.completed = 7;
        t.shed_queue_full = 2;
        t.shed_quota = 1;
        assert_eq!(t.shed(), 3);
        assert_eq!(t.lost(), 0);
        t.submitted = 12;
        assert_eq!(t.lost(), 2);
    }
}
