//! Time-multiplexed execution: one physical fabric, many shard contexts.
//!
//! When only a single fabric instance is available, an oversized graph
//! can still run by treating each shard as an FPGA *context*: load shard
//! A, run it until it stalls, swap in shard B (charging the partial-
//! reconfiguration cost), and so on — the classic area/time tradeoff the
//! paper motivates for reconfigurable systems. Tokens crossing a cut
//! while a shard is swapped out wait in the inter-context buffers
//! exactly as they would in external FIFOs next to the FPGA.
//!
//! The scheduler is round-robin over non-idle contexts, which is
//! deadlock-free for the same confluence reason `shard::run_sharded` is:
//! any globally enabled firing belongs to some shard, and that shard is
//! eventually activated. Output streams remain byte-identical to
//! whole-graph [`crate::sim::TokenSim`].

use super::partition::PartitionPlan;
use super::shard::{merge_outcomes, shard_configs};
use super::topology::FabricTopology;
use crate::obs::{EngineProfile, ProfileLevel};
use crate::sim::{SimConfig, SimOutcome, TokenSim};

/// What time-multiplexing cost on top of the pure dataflow rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReconfigStats {
    /// Context loads, including the initial configuration.
    pub swaps: u64,
    /// Cycles charged for those loads (`swaps × topo.reconfig_cycles`).
    pub reconfig_cycles: u64,
    /// Dataflow rounds actually executed on the fabric.
    pub active_cycles: u64,
}

/// Drive the round-robin context scheduler until every context is out
/// of work or `cycle_budget` active cycles have been spent. `active`
/// and `swaps` persist across calls so a resident rack keeps its loaded
/// context between waves (no gratuitous reload at a wave boundary).
/// Returns the active cycles consumed by this call.
fn drive_contexts(
    sims: &mut [TokenSim],
    plan: &PartitionPlan,
    cycle_budget: u64,
    active: &mut usize,
    swaps: &mut u64,
    mut cut_traffic: Option<&mut [u64]>,
) -> u64 {
    let n = sims.len();
    let mut active_cycles = 0u64;
    let mut stalled_rotation = 0usize;
    // Resolve each cut's destination injection slot once per call; the
    // per-rotation forwarding below is then index-only.
    let cut_slots: Vec<usize> = plan
        .cuts
        .iter()
        .map(|cut| {
            sims[cut.to].port_slot(&cut.name).unwrap_or_else(|| {
                panic!(
                    "partition plan is inconsistent: cut arc `{}` has no \
                     input half in consuming context {}",
                    cut.name, cut.to
                )
            })
        })
        .collect();

    loop {
        // Run the active context until it stops firing; the final zero-
        // firing step also drains its output ports.
        let mut shard_fired = 0u64;
        while active_cycles < cycle_budget {
            let f = sims[*active].step();
            active_cycles += 1;
            shard_fired += f;
            if f == 0 {
                break;
            }
        }
        // Flush this context's cut outputs into the inter-context buffers.
        for (ci, (cut, &slot)) in plan.cuts.iter().zip(&cut_slots).enumerate() {
            if cut.from != *active {
                continue;
            }
            let vals = sims[cut.from].take_stream(&cut.name);
            if let Some(t) = cut_traffic.as_deref_mut() {
                t[ci] += vals.len() as u64;
            }
            for v in vals {
                sims[cut.to].enqueue_at(slot, v);
            }
        }
        if shard_fired == 0 {
            stalled_rotation += 1;
        } else {
            stalled_rotation = 0;
        }
        // A context has work when it is non-idle OR still holds unfired
        // const reset tokens (idle() cannot see those).
        let has_work = |s: &TokenSim| !s.idle() || s.consts_pending();
        if active_cycles >= cycle_budget
            || stalled_rotation >= n
            || !sims.iter().any(has_work)
        {
            break;
        }
        // Next context with work, round-robin.
        match (1..=n)
            .map(|d| (*active + d) % n)
            .find(|&i| has_work(&sims[i]))
        {
            Some(i) => {
                if i != *active {
                    *swaps += 1;
                    *active = i;
                }
            }
            None => break,
        }
    }
    active_cycles
}

/// Run every shard of `plan` on ONE fabric by context swapping. The
/// returned outcome's `cycles` includes the reconfiguration charge.
pub fn run_reconfig(
    plan: &PartitionPlan,
    topo: &FabricTopology,
    cfg: &SimConfig,
) -> (SimOutcome, ReconfigStats) {
    let cut_names = plan.cut_names();
    let shard_cfgs = shard_configs(plan, cfg);
    let mut sims: Vec<TokenSim> = plan
        .shards
        .iter()
        .zip(&shard_cfgs)
        .map(|(sh, c)| TokenSim::new(&sh.graph, c))
        .collect();

    let mut active = 0usize;
    let mut swaps = 1u64; // the initial context load
    let active_cycles =
        drive_contexts(&mut sims, plan, cfg.max_cycles, &mut active, &mut swaps, None);

    let quiescent = sims.iter().all(|s| s.idle() && !s.consts_pending());
    let stats = ReconfigStats {
        swaps,
        reconfig_cycles: swaps * topo.reconfig_cycles,
        active_cycles,
    };
    let total_cycles = active_cycles + stats.reconfig_cycles;
    let outcome = merge_outcomes(sims, &cut_names, total_cycles, quiescent);
    (outcome, stats)
}

/// [`run_reconfig`] with profiling: per-context `TokenSim` profiles
/// (labeled `ctx<i>`) plus one `reconfig` profile carrying the token
/// traffic through each inter-context buffer — how much state crosses
/// the fabric boundary per swap cycle.
pub fn run_reconfig_profiled(
    plan: &PartitionPlan,
    topo: &FabricTopology,
    cfg: &SimConfig,
    level: ProfileLevel,
) -> (SimOutcome, ReconfigStats, Vec<(String, EngineProfile)>) {
    let cut_names = plan.cut_names();
    let shard_cfgs = shard_configs(plan, cfg);
    let mut sims: Vec<TokenSim> = plan
        .shards
        .iter()
        .zip(&shard_cfgs)
        .map(|(sh, c)| TokenSim::new(&sh.graph, c))
        .collect();
    for sim in sims.iter_mut() {
        sim.enable_profiling(level);
    }

    let mut active = 0usize;
    let mut swaps = 1u64; // the initial context load
    let mut cut_traffic = vec![0u64; plan.cuts.len()];
    let active_cycles = drive_contexts(
        &mut sims,
        plan,
        cfg.max_cycles,
        &mut active,
        &mut swaps,
        Some(&mut cut_traffic),
    );

    let quiescent = sims.iter().all(|s| s.idle() && !s.consts_pending());
    let stats = ReconfigStats {
        swaps,
        reconfig_cycles: swaps * topo.reconfig_cycles,
        active_cycles,
    };
    let mut profiles = Vec::new();
    for (si, sim) in sims.iter_mut().enumerate() {
        if let Some(p) = sim.take_profile() {
            profiles.push((format!("ctx{si}"), p));
        }
    }
    let mut fabric = EngineProfile::new("reconfig", level, 0, 0);
    fabric.cycles = active_cycles;
    for (ci, &t) in cut_traffic.iter().enumerate() {
        fabric.cut(ci, t);
    }
    fabric.total_firings = profiles.iter().map(|(_, p)| p.total_firings).sum();
    profiles.push(("buffers".to_string(), fabric));
    let total_cycles = active_cycles + stats.reconfig_cycles;
    let outcome = merge_outcomes(sims, &cut_names, total_cycles, quiescent);
    (outcome, stats, profiles)
}

/// Streamed injection for the time-multiplexed executor: run every wave
/// of `waves` through ONE resident context rack, re-arming const reset
/// tokens and purging residue at wave boundaries. The rack keeps its
/// currently loaded context across the boundary, so a wave whose first
/// enabled shard is already resident costs no swap. Returns one outcome
/// per wave plus the cumulative swap statistics; each outcome's
/// `cycles` includes its share of the reconfiguration charge.
pub fn run_reconfig_waves(
    plan: &PartitionPlan,
    topo: &FabricTopology,
    waves: &[crate::sim::WaveInput],
    max_cycles_per_wave: u64,
) -> (Vec<SimOutcome>, ReconfigStats) {
    let cut_names = plan.cut_names();
    let empty = SimConfig::new();
    let mut sims: Vec<TokenSim> = plan
        .shards
        .iter()
        .map(|sh| TokenSim::new(&sh.graph, &empty))
        .collect();
    let out_ports = super::shard::true_out_ports(plan, &cut_names);

    let mut active = 0usize;
    let mut swaps = 1u64; // the initial context load
    let mut total_active = 0u64;
    let mut firings_before = 0u64;
    let mut outcomes = Vec::with_capacity(waves.len());
    for wave in waves {
        let swaps_before = swaps;
        super::shard::reset_and_route_wave(&mut sims, &cut_names, wave);
        let spent =
            drive_contexts(&mut sims, plan, max_cycles_per_wave, &mut active, &mut swaps, None);
        total_active += spent;

        let quiescent = sims.iter().all(|s| s.idle() && !s.consts_pending());
        let outputs = super::shard::collect_wave_outputs(&mut sims, &out_ports);
        let firings_now: u64 = sims.iter().map(|s| s.firings()).sum();
        // The initial context load is billed to the first wave; later
        // waves pay only for the swaps they themselves trigger.
        let loads_this_wave = (swaps - swaps_before) + u64::from(outcomes.is_empty());
        outcomes.push(SimOutcome {
            outputs,
            cycles: spent + loads_this_wave * topo.reconfig_cycles,
            firings: firings_now - firings_before,
            quiescent,
        });
        firings_before = firings_now;
    }

    let stats = ReconfigStats {
        swaps,
        reconfig_cycles: swaps * topo.reconfig_cycles,
        active_cycles: total_active,
    };
    (outcomes, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};
    use crate::fabric::{partition, FabricTopology};
    use crate::sim::run_token;

    #[test]
    fn reconfig_agrees_with_whole_graph_on_dot_prod() {
        let g = bench_defs::build(BenchId::DotProd);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = partition(&g, &topo).unwrap();
        assert!(plan.n_shards() >= 2);
        let wl = bench_defs::workload(BenchId::DotProd, 5, 17);
        let cfg = wl.sim_config();
        let whole = run_token(&g, &cfg);
        let (out, stats) = run_reconfig(&plan, &topo, &cfg);
        assert_eq!(out.outputs, whole.outputs);
        assert!(out.quiescent);
        assert!(stats.swaps >= 2);
        assert_eq!(stats.reconfig_cycles, stats.swaps * topo.reconfig_cycles);
        assert_eq!(out.cycles, stats.active_cycles + stats.reconfig_cycles);
    }

    #[test]
    fn reconfig_cost_scales_with_swap_price() {
        let g = bench_defs::build(BenchId::PopCount);
        let mut topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = partition(&g, &topo).unwrap();
        let cfg = bench_defs::workload(BenchId::PopCount, 4, 1).sim_config();
        let (_, cheap) = run_reconfig(&plan, &topo, &cfg);
        topo.reconfig_cycles *= 10;
        let (_, dear) = run_reconfig(&plan, &topo, &cfg);
        assert_eq!(cheap.swaps, dear.swaps, "schedule must not depend on price");
        assert_eq!(dear.reconfig_cycles, cheap.reconfig_cycles * 10);
    }

    #[test]
    fn streamed_waves_match_whole_graph_under_reconfig() {
        let g = bench_defs::build(BenchId::Max);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = partition(&g, &topo).unwrap();
        let wls: Vec<_> = (0..3)
            .map(|i| bench_defs::workload(BenchId::Max, 2 + i, 7 + i as u64))
            .collect();
        let waves: Vec<crate::sim::WaveInput> =
            wls.iter().map(|w| w.inject.clone()).collect();
        let max = wls.iter().map(|w| w.max_cycles).max().unwrap();
        let (outs, stats) = run_reconfig_waves(&plan, &topo, &waves, max);
        assert_eq!(outs.len(), waves.len());
        for (i, wl) in wls.iter().enumerate() {
            let whole = run_token(&g, &wl.sim_config());
            assert_eq!(outs[i].outputs, whole.outputs, "wave {i}");
            for (port, want) in &wl.expect {
                assert_eq!(outs[i].stream(port), want.as_slice(), "wave {i} `{port}`");
            }
        }
        assert!(stats.swaps >= 2, "multi-shard waves must swap contexts");
        assert_eq!(stats.reconfig_cycles, stats.swaps * topo.reconfig_cycles);
        // Per-wave reconfig charges sum to the cumulative charge.
        let charged: u64 = outs.iter().map(|o| o.cycles).sum();
        assert_eq!(charged, stats.active_cycles + stats.reconfig_cycles);
    }

    #[test]
    fn profiled_reconfig_counts_buffer_traffic_without_perturbing() {
        let g = bench_defs::build(BenchId::DotProd);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = partition(&g, &topo).unwrap();
        let cfg = bench_defs::workload(BenchId::DotProd, 5, 17).sim_config();
        let (plain, plain_stats) = run_reconfig(&plan, &topo, &cfg);
        let (profiled, stats, profiles) =
            run_reconfig_profiled(&plan, &topo, &cfg, crate::obs::ProfileLevel::Counters);
        assert_eq!(profiled.outputs, plain.outputs);
        assert_eq!(profiled.firings, plain.firings);
        assert_eq!(profiled.cycles, plain.cycles);
        assert_eq!(stats, plain_stats);
        let (label, buffers) = profiles.last().unwrap();
        assert_eq!(label, "buffers");
        assert_eq!(buffers.engine, "reconfig");
        assert_eq!(buffers.cut_traffic.len(), plan.cuts.len());
        let crossed: u64 = buffers.cut_traffic.iter().sum();
        assert!(crossed > 0, "tokens crossed the inter-context buffers");
        assert_eq!(buffers.total_firings, plain.firings);
    }

    #[test]
    fn single_context_needs_one_load() {
        let g = bench_defs::build(BenchId::Fibonacci);
        let topo = FabricTopology::paper();
        let plan = partition(&g, &topo).unwrap();
        let cfg = bench_defs::workload(BenchId::Fibonacci, 7, 0).sim_config();
        let (out, stats) = run_reconfig(&plan, &topo, &cfg);
        assert_eq!(stats.swaps, 1);
        assert!(out.quiescent);
    }
}
