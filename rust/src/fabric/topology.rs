//! The physical fabric model.
//!
//! The paper's prototype is a *finite* reconfigurable fabric: a pool of
//! operator instances (one FSM + datapath each, Figs. 5/6) wired through
//! parallel 16-bit buses with `str`/`ack` pairs (Fig. 3). A
//! [`FabricTopology`] captures that finiteness: how many operator slots
//! of each [`OpClass`] one fabric instance provides, how many physical
//! bus channels it can route, and how many cycles a full context swap
//! (FPGA partial reconfiguration) costs. The placer ([`super::place`])
//! maps a DFG onto these slots; graphs that do not fit are split by the
//! partitioner ([`super::partition`]) and run sharded
//! ([`super::shard`]) or time-multiplexed ([`super::reconfig`]).

use crate::dfg::{Graph, OpClass};
use crate::estimate::{op_resources, Resources, WORD_BITS};
use std::collections::BTreeMap;

/// One reconfigurable fabric instance: per-class operator slot counts, a
/// bounded pool of parallel bus channels, and a context-swap cost.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricTopology {
    pub name: String,
    /// Operator slots per class. A class missing from the map has zero
    /// slots.
    pub slots: BTreeMap<OpClass, usize>,
    /// Physical 16-bit bus channels (each arc of a placed graph occupies
    /// one: the paper's channels are point-to-point, §3).
    pub channels: usize,
    /// Cycles charged per context swap by the time-multiplexing
    /// scheduler (FPGA partial-reconfiguration cost).
    pub reconfig_cycles: u64,
}

impl FabricTopology {
    pub fn new(
        name: impl Into<String>,
        slots: BTreeMap<OpClass, usize>,
        channels: usize,
        reconfig_cycles: u64,
    ) -> Self {
        FabricTopology {
            name: name.into(),
            slots,
            channels,
            reconfig_cycles,
        }
    }

    /// Slots provisioned for `class` (zero when absent).
    pub fn slot_count(&self, class: OpClass) -> usize {
        self.slots.get(&class).copied().unwrap_or(0)
    }

    /// Total operator slots across all classes.
    pub fn total_slots(&self) -> usize {
        self.slots.values().sum()
    }

    /// Per-class operator demand of a graph — what the placer matches
    /// against the slot table.
    pub fn demand(g: &Graph) -> BTreeMap<OpClass, usize> {
        let mut m = BTreeMap::new();
        for n in &g.nodes {
            *m.entry(n.op.class()).or_insert(0) += 1;
        }
        m
    }

    /// The smallest per-class slot table and channel pool covering
    /// every graph in `graphs` *individually* (one batch occupies an
    /// instance at a time, so the cover is a per-class max, not a
    /// sum). [`FabricTopology::paper`] sizes the production fabric
    /// with it; the elastic repartitioner
    /// ([`crate::serve::elastic`]) sizes the slice of the fabric it
    /// un-reserves for the hot tenants' graphs.
    pub fn demand_cover<'a>(
        graphs: impl IntoIterator<Item = &'a Graph>,
    ) -> (BTreeMap<OpClass, usize>, usize) {
        let mut slots: BTreeMap<OpClass, usize> = BTreeMap::new();
        let mut channels = 0usize;
        for g in graphs {
            for (c, n) in Self::demand(g) {
                let e = slots.entry(c).or_insert(0);
                *e = (*e).max(n);
            }
            channels = channels.max(g.n_arcs());
        }
        (slots, channels)
    }

    /// Whether `g` fits on a single instance (slots and channels).
    pub fn fits(&self, g: &Graph) -> bool {
        g.n_arcs() <= self.channels
            && Self::demand(g)
                .iter()
                .all(|(c, need)| *need <= self.slot_count(*c))
    }

    /// The silicon a fully provisioned instance occupies, from the
    /// `estimate` resource model: every slot is charged the cost of its
    /// class's widest member opcode, and every bus channel one
    /// word-wide register. `fmax_mhz` is zero — a topology has no
    /// netlist, hence no critical path.
    pub fn resources(&self) -> Resources {
        let mut r = Resources::default();
        for (&class, &count) in &self.slots {
            let unit = op_resources(class.widest_member());
            for _ in 0..count {
                r.add(&unit);
            }
        }
        r.ff += self.channels as u32 * WORD_BITS;
        r
    }

    /// The default production fabric: provisioned from the estimate
    /// resource model so every paper benchmark places on one instance,
    /// with ~25% headroom per class and on the channel pool.
    pub fn paper() -> FabricTopology {
        let graphs: Vec<Graph> = crate::bench_defs::BenchId::ALL
            .into_iter()
            .map(crate::bench_defs::build)
            .collect();
        let (mut slots, mut channels) = Self::demand_cover(&graphs);
        for v in slots.values_mut() {
            *v += (*v + 3) / 4;
        }
        channels += (channels + 3) / 4;
        FabricTopology::new("paper-virtex7", slots, channels, 256)
    }

    /// A topology sized so `g` needs roughly `k` shards: each class gets
    /// `ceil(demand / k)` slots and the channel pool is left unbounded
    /// (equal to the arc count plus cut headroom), so partitioning is
    /// driven by operator capacity alone. Used by tests and by the
    /// `place --shards` CLI path to study the reconfiguration tradeoff.
    pub fn sized_for_shards(g: &Graph, k: usize) -> FabricTopology {
        let k = k.max(1);
        let slots: BTreeMap<OpClass, usize> = Self::demand(g)
            .into_iter()
            .map(|(c, need)| (c, ((need + k - 1) / k).max(1)))
            .collect();
        // Generous channel pool: every shard may carry its internal arcs
        // plus both halves of every cut, so the original arc count always
        // suffices per shard.
        FabricTopology::new(
            format!("{}-k{}", g.name, k),
            slots,
            g.n_arcs(),
            256,
        )
    }

    /// Whether `g` fits this instance after its current fault `health`
    /// is subtracted — the serve tier's per-dispatch fit probe. An
    /// instance in outage fits nothing.
    pub fn fits_healthy(&self, g: &Graph, health: &super::fault::FabricHealth) -> bool {
        !health.down && health.effective(self).fits(g)
    }

    /// The multi-tenant serving fabric: the paper instance scaled with
    /// per-class headroom for workloads *outside* the six benchmarks.
    /// `paper()` is demand-derived, so classes no benchmark uses get
    /// zero slots (e.g. `alu1` — no benchmark contains a `not`), which
    /// would push every random-DFG tenant off the placed path. The
    /// serving preset floors every class at [`SERVING_CLASS_FLOOR`]
    /// slots and widens the channel pool so the conformance
    /// generator's graphs ([`crate::util::proptest::random_dfg`])
    /// place whole; partitioned/reconfig serving is still reachable by
    /// handing the serve tier a smaller explicit topology.
    pub fn serving() -> FabricTopology {
        let mut t = Self::paper();
        t.name = "paper-virtex7-serving".to_string();
        for class in OpClass::ALL {
            let e = t.slots.entry(class).or_insert(0);
            *e = (*e).max(SERVING_CLASS_FLOOR);
        }
        t.channels = t.channels.max(SERVING_CHANNELS);
        t
    }
}

/// Slots per operator class the serving fabric guarantees — an upper
/// bound on the per-class demand of the random-DFG generator (≤ 12 op
/// arms plus the loop schema and port terminators).
pub const SERVING_CLASS_FLOOR: usize = 40;

/// Bus channels the serving fabric guarantees (generator graphs stay
/// well under 200 arcs).
pub const SERVING_CHANNELS: usize = 320;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{build, BenchId};

    #[test]
    fn paper_fabric_fits_all_benchmarks() {
        let topo = FabricTopology::paper();
        for b in BenchId::ALL {
            assert!(topo.fits(&build(b)), "{} must fit the paper fabric", b.slug());
        }
    }

    #[test]
    fn serving_fabric_fits_benchmarks_and_random_dfgs() {
        let topo = FabricTopology::serving();
        for b in BenchId::ALL {
            assert!(topo.fits(&build(b)), "{}", b.slug());
        }
        assert!(topo.fits(&crate::bench_defs::saxpy::build()));
        assert!(topo.slot_count(crate::dfg::OpClass::Alu1) >= SERVING_CLASS_FLOOR);
        let mut r = crate::util::Rng::new(0x5E41);
        for case in 0..64 {
            let gg = crate::util::proptest::random_dfg(&mut r, case % 2 == 0);
            assert!(
                topo.fits(&gg.graph),
                "random graph (case {case}) exceeds the serving fabric: {:?}",
                FabricTopology::demand(&gg.graph)
            );
        }
    }

    #[test]
    fn demand_matches_census_total() {
        for b in BenchId::ALL {
            let g = build(b);
            let total: usize = FabricTopology::demand(&g).values().sum();
            assert_eq!(total, g.n_nodes(), "{}", b.slug());
        }
    }

    #[test]
    fn sized_for_shards_rejects_whole_graph() {
        // A k=2 topology must NOT fit the whole graph in one instance.
        for b in BenchId::ALL {
            let g = build(b);
            let topo = FabricTopology::sized_for_shards(&g, 2);
            assert!(!topo.fits(&g), "{} should not fit a half fabric", b.slug());
        }
    }

    #[test]
    fn resources_scale_with_slots() {
        let g = build(BenchId::Fibonacci);
        let small = FabricTopology::sized_for_shards(&g, 2);
        let big = FabricTopology::sized_for_shards(&g, 1);
        let rs = small.resources();
        let rb = big.resources();
        assert!(rb.ff > rs.ff);
        assert!(rb.lut >= rs.lut);
    }

    #[test]
    fn fits_healthy_tracks_fault_state() {
        use crate::fabric::fault::{FabricHealth, FaultKind};
        let topo = FabricTopology::serving();
        let g = build(BenchId::DotProd);
        let mut health = FabricHealth::default();
        assert!(topo.fits_healthy(&g, &health));
        // Losing more alu2 slots than the fabric has clamps the class to
        // zero: the graph no longer fits the degraded instance.
        health.apply(FaultKind::SlotFail {
            class: crate::dfg::OpClass::Alu2,
            count: topo.total_slots() + 1,
        });
        assert!(!topo.fits_healthy(&g, &health));
        health.apply(FaultKind::Repair);
        assert!(topo.fits_healthy(&g, &health));
        health.apply(FaultKind::Outage);
        assert!(!topo.fits_healthy(&g, &health));
    }

    #[test]
    fn empty_class_has_zero_slots() {
        let topo = FabricTopology::new("t", BTreeMap::new(), 4, 0);
        assert_eq!(topo.slot_count(crate::dfg::OpClass::Alu2), 0);
        assert_eq!(topo.total_slots(), 0);
    }
}
