//! The placer: DFG nodes → operator slots, arcs → bus channels.
//!
//! Placement on the paper's fabric is a pure capacity problem: operators
//! are interchangeable within a class (every `add` slot is the same
//! hardware) and every channel is a point-to-point 16-bit bus, so a
//! valid placement exists iff per-class demand fits the slot table and
//! the arc count fits the channel pool. The placer checks both and
//! produces the concrete slot/channel assignment the report layer and
//! the VHDL floorplan annotations consume; graphs that do not fit are
//! rejected with a descriptive [`PlaceError`] (the partitioner's cue).

use super::fault::FabricHealth;
use super::topology::FabricTopology;
use crate::dfg::{Graph, OpClass};
use std::collections::BTreeMap;
use std::fmt;

/// Why a graph cannot be placed on a topology.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlaceError {
    /// Demand for one operator class exceeds the slot pool.
    InsufficientSlots {
        class: OpClass,
        need: usize,
        have: usize,
    },
    /// The graph has more arcs than the fabric has bus channels.
    InsufficientChannels { need: usize, have: usize },
    /// The instance is in outage — nothing places until repair.
    InstanceDown,
}

impl fmt::Display for PlaceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlaceError::InsufficientSlots { class, need, have } => write!(
                f,
                "graph needs {need} `{}` operator slots but the fabric provides only {have}",
                class.name()
            ),
            PlaceError::InsufficientChannels { need, have } => write!(
                f,
                "graph needs {need} bus channels but the fabric provides only {have}"
            ),
            PlaceError::InstanceDown => {
                write!(f, "fabric instance is in outage; wait for repair")
            }
        }
    }
}

impl std::error::Error for PlaceError {}

/// A concrete assignment of one graph onto one fabric instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    /// Name of the topology placed onto.
    pub fabric: String,
    /// Per node (graph index order): its class and the physical slot
    /// index within that class's pool.
    pub slots: Vec<(OpClass, usize)>,
    /// Per arc (graph index order): the physical bus channel.
    pub channels: Vec<usize>,
}

impl Placement {
    /// Per-class `(class, used, provisioned)` rows, provisioned classes
    /// first — the utilization table.
    pub fn utilization(&self, topo: &FabricTopology) -> Vec<(OpClass, usize, usize)> {
        let mut used: BTreeMap<OpClass, usize> = BTreeMap::new();
        for (c, _) in &self.slots {
            *used.entry(*c).or_insert(0) += 1;
        }
        let mut rows = Vec::new();
        for &class in OpClass::ALL.iter() {
            let u = used.get(&class).copied().unwrap_or(0);
            let total = topo.slot_count(class);
            if u > 0 || total > 0 {
                rows.push((class, u, total));
            }
        }
        rows
    }

    /// `(used, provisioned)` bus channels.
    pub fn channel_utilization(&self, topo: &FabricTopology) -> (usize, usize) {
        (self.channels.len(), topo.channels)
    }
}

/// Assign every node of `g` to an operator slot and every arc to a bus
/// channel of `topo`, or explain why that is impossible.
pub fn place(g: &Graph, topo: &FabricTopology) -> Result<Placement, PlaceError> {
    let demand = FabricTopology::demand(g);
    for (&class, &need) in &demand {
        let have = topo.slot_count(class);
        if need > have {
            return Err(PlaceError::InsufficientSlots { class, need, have });
        }
    }
    if g.n_arcs() > topo.channels {
        return Err(PlaceError::InsufficientChannels {
            need: g.n_arcs(),
            have: topo.channels,
        });
    }
    // Greedy is optimal here: slots within a class are interchangeable,
    // so "next free slot of the class, in node order" is a valid (and
    // deterministic) placement; likewise channels in arc order.
    let mut next: BTreeMap<OpClass, usize> = BTreeMap::new();
    let slots = g
        .nodes
        .iter()
        .map(|n| {
            let class = n.op.class();
            let e = next.entry(class).or_insert(0);
            let slot = *e;
            *e += 1;
            (class, slot)
        })
        .collect();
    let channels = (0..g.n_arcs()).collect();
    Ok(Placement {
        fabric: topo.name.clone(),
        slots,
        channels,
    })
}

/// Fault-aware placement: place `g` on what is left of `topo` after the
/// instance's current `health` is subtracted. An instance in outage
/// rejects everything with [`PlaceError::InstanceDown`]; a degraded
/// instance places against the reduced slot/channel pools, so the serve
/// tier's recovery lattice sees the same descriptive errors the cold
/// placer would produce on a genuinely smaller fabric.
pub fn place_healthy(
    g: &Graph,
    topo: &FabricTopology,
    health: &FabricHealth,
) -> Result<Placement, PlaceError> {
    if health.down {
        return Err(PlaceError::InstanceDown);
    }
    place(g, &health.effective(topo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{build, BenchId};
    use std::collections::BTreeMap;

    #[test]
    fn paper_fabric_places_every_benchmark() {
        let topo = FabricTopology::paper();
        for b in BenchId::ALL {
            let g = build(b);
            let p = place(&g, &topo).unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
            assert_eq!(p.slots.len(), g.n_nodes());
            assert_eq!(p.channels.len(), g.n_arcs());
            // Slot indices stay inside each class pool and never repeat.
            let mut seen: BTreeMap<_, Vec<usize>> = BTreeMap::new();
            for (c, s) in &p.slots {
                assert!(*s < topo.slot_count(*c), "{}: slot overflow", b.slug());
                let v = seen.entry(*c).or_default();
                assert!(!v.contains(s), "{}: duplicate slot", b.slug());
                v.push(*s);
            }
        }
    }

    #[test]
    fn rejects_missing_class_with_descriptive_error() {
        let g = build(BenchId::DotProd);
        let topo = FabricTopology::new(
            "no-alu",
            BTreeMap::from([(crate::dfg::OpClass::Copy, 100)]),
            1000,
            0,
        );
        let err = place(&g, &topo).unwrap_err();
        match err {
            PlaceError::InsufficientSlots { have, need, .. } => {
                assert_eq!(have, 0);
                assert!(need > 0);
            }
            other => panic!("wrong error: {other:?}"),
        }
        let msg = err.to_string();
        assert!(msg.contains("operator slots"), "{msg}");
        assert!(msg.contains("provides only 0"), "{msg}");
    }

    #[test]
    fn rejects_channel_exhaustion() {
        let g = build(BenchId::Fibonacci);
        let mut topo = FabricTopology::paper();
        topo.channels = 1;
        let err = place(&g, &topo).unwrap_err();
        assert_eq!(
            err,
            PlaceError::InsufficientChannels {
                need: g.n_arcs(),
                have: 1
            }
        );
        assert!(err.to_string().contains("bus channels"));
    }

    #[test]
    fn health_aware_placement_degrades_and_recovers() {
        use crate::fabric::fault::{FabricHealth, FaultKind};
        let topo = FabricTopology::serving();
        let g = build(BenchId::DotProd);
        let mut health = FabricHealth::default();
        // Healthy instance: identical placement to the plain placer.
        assert_eq!(place_healthy(&g, &topo, &health), place(&g, &topo));
        // An outage rejects everything, whatever the graph.
        health.apply(FaultKind::Outage);
        assert_eq!(place_healthy(&g, &topo, &health), Err(PlaceError::InstanceDown));
        assert!(PlaceError::InstanceDown.to_string().contains("outage"));
        // Repair restores the full pools.
        health.apply(FaultKind::Repair);
        assert!(place_healthy(&g, &topo, &health).is_ok());
        // A slot fault bigger than the provisioned pool clamps the class
        // to zero and surfaces as the placer's own descriptive error.
        health.apply(FaultKind::SlotFail {
            class: crate::dfg::OpClass::Alu2,
            count: topo.total_slots() + 1,
        });
        match place_healthy(&g, &topo, &health) {
            Err(PlaceError::InsufficientSlots { have, .. }) => assert_eq!(have, 0),
            other => panic!("wrong result: {other:?}"),
        }
        // A bus fault exhausts the channel pool the same way.
        health.apply(FaultKind::Repair);
        health.apply(FaultKind::BusFail {
            channels: topo.channels + 1,
        });
        match place_healthy(&g, &topo, &health) {
            Err(PlaceError::InsufficientChannels { have, .. }) => assert_eq!(have, 0),
            other => panic!("wrong result: {other:?}"),
        }
    }

    #[test]
    fn utilization_rows_cover_demand() {
        let topo = FabricTopology::paper();
        let g = build(BenchId::Max);
        let p = place(&g, &topo).unwrap();
        let rows = p.utilization(&topo);
        let used: usize = rows.iter().map(|(_, u, _)| u).sum();
        assert_eq!(used, g.n_nodes());
        for (_, u, total) in rows {
            assert!(u <= total);
        }
        assert_eq!(p.channel_utilization(&topo).0, g.n_arcs());
    }
}
