//! The partitioner: split a DFG that exceeds one fabric instance into
//! shards that each fit, minimizing cut arcs.
//!
//! The approach mirrors the classic two-step used by reconfigurable-array
//! schedulers (and the GraphyFlow DFG-IR mapping stage): seed with
//! balanced contiguous blocks in node-creation order (the builder emits
//! nodes in rough dataflow order, so contiguous blocks already cut few
//! arcs on loop-schema graphs), then run bounded Kernighan–Lin-style
//! refinement passes that move boundary nodes to a neighboring shard
//! whenever that strictly reduces the cut and per-class slot capacity
//! allows it.
//!
//! A cut arc keeps its original label in both shards: the producing
//! shard gets an *output port* half, the consuming shard an *input
//! port* half, and the sharded executor ([`super::shard`]) forwards
//! tokens between the halves — the software analogue of the paper's
//! inter-fabric bus channels.

use super::place::PlaceError;
use super::topology::FabricTopology;
use crate::dfg::{Arc, ArcId, Graph, Node, NodeId, OpClass};
use std::collections::{BTreeMap, BTreeSet};

/// An arc severed by the partition: produced in shard `from`, consumed
/// in shard `to`, carried between them under its original `name`.
#[derive(Debug, Clone, PartialEq)]
pub struct CutArc {
    /// Arc id in the original graph.
    pub arc: ArcId,
    /// Label shared by the output-port half (shard `from`) and the
    /// input-port half (shard `to`).
    pub name: String,
    pub from: usize,
    pub to: usize,
}

/// One shard: a self-contained, valid [`Graph`] plus the bookkeeping
/// back to the original graph.
#[derive(Debug, Clone)]
pub struct Shard {
    pub index: usize,
    pub graph: Graph,
    /// Original node id per shard node index.
    pub orig_nodes: Vec<NodeId>,
    /// Original arc id per shard arc index (cut arcs appear in both of
    /// their home shards).
    pub orig_arcs: Vec<ArcId>,
}

/// The full partition: every shard fits the topology it was built for.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    pub graph_name: String,
    pub shards: Vec<Shard>,
    pub cuts: Vec<CutArc>,
}

impl PartitionPlan {
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Labels of all cut arcs (the forwarding table's key set).
    pub fn cut_names(&self) -> BTreeSet<String> {
        self.cuts.iter().map(|c| c.name.clone()).collect()
    }
}

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Split `g` into shards that each fit `topo`. A graph that already fits
/// yields a single shard. Fails only when no shard count can ever work:
/// a used operator class with zero slots, or a channel pool smaller than
/// some single node's arc degree.
pub fn partition(g: &Graph, topo: &FabricTopology) -> Result<PartitionPlan, PlaceError> {
    assert!(!g.nodes.is_empty(), "cannot partition an empty graph");
    let demand = FabricTopology::demand(g);
    // Feasibility independent of shard count.
    for (&class, &need) in &demand {
        if need > 0 && topo.slot_count(class) == 0 {
            return Err(PlaceError::InsufficientSlots {
                class,
                need,
                have: 0,
            });
        }
    }
    let max_node_degree = g
        .nodes
        .iter()
        .map(|n| n.ins.len() + n.outs.len())
        .max()
        .unwrap_or(0);
    if topo.channels < max_node_degree {
        return Err(PlaceError::InsufficientChannels {
            need: max_node_degree,
            have: topo.channels,
        });
    }

    if topo.fits(g) {
        let assign = vec![0usize; g.n_nodes()];
        return Ok(build_plan(g, &assign, 1));
    }

    // Lower bound on the shard count from slot pressure and channel
    // pressure; grow until the per-shard channel budget holds.
    let slot_bound = demand
        .iter()
        .map(|(&c, &need)| ceil_div(need, topo.slot_count(c)))
        .max()
        .unwrap_or(1);
    let chan_bound = ceil_div(g.n_arcs(), topo.channels.max(1));
    let mut k = slot_bound.max(chan_bound).max(2);
    while k <= g.n_nodes() {
        let (mut assign, n_shards) = assign_contiguous(g, topo, k, &demand);
        refine(g, topo, &mut assign, n_shards);
        let counts = shard_arc_counts(g, &assign, n_shards);
        if counts.iter().all(|&c| c <= topo.channels) {
            return Ok(build_plan(g, &assign, n_shards));
        }
        k += 1;
    }
    // Last resort: one node per shard. Slot capacity holds (every used
    // class has ≥ 1 slot) and so does the channel budget (≥ the largest
    // node degree, checked above).
    let assign: Vec<usize> = (0..g.n_nodes()).collect();
    Ok(build_plan(g, &assign, g.n_nodes()))
}

/// Seed assignment: contiguous blocks in node order, each limited to a
/// balanced per-class quota (`ceil(demand / k)`, clamped to capacity).
fn assign_contiguous(
    g: &Graph,
    topo: &FabricTopology,
    k: usize,
    demand: &BTreeMap<OpClass, usize>,
) -> (Vec<usize>, usize) {
    let quota: BTreeMap<OpClass, usize> = demand
        .iter()
        .map(|(&c, &need)| {
            let cap = topo.slot_count(c);
            (c, ceil_div(need, k).min(cap).max(1))
        })
        .collect();
    let mut shard = 0usize;
    let mut counts: BTreeMap<OpClass, usize> = BTreeMap::new();
    let mut assign = Vec::with_capacity(g.n_nodes());
    for n in &g.nodes {
        let class = n.op.class();
        if counts.get(&class).copied().unwrap_or(0) >= quota[&class] {
            shard += 1;
            counts.clear();
        }
        *counts.entry(class).or_insert(0) += 1;
        assign.push(shard);
    }
    (assign, shard + 1)
}

/// Bounded KL-style refinement: move a node to a neighboring shard when
/// that strictly reduces its incident cut and the target shard has a
/// free slot of its class.
fn refine(g: &Graph, topo: &FabricTopology, assign: &mut [usize], n_shards: usize) {
    let mut counts: Vec<BTreeMap<OpClass, usize>> = vec![BTreeMap::new(); n_shards];
    for (ni, &s) in assign.iter().enumerate() {
        *counts[s].entry(g.nodes[ni].op.class()).or_insert(0) += 1;
    }
    let mut others = Vec::new();
    for _pass in 0..4 {
        let mut improved = false;
        for ni in 0..g.n_nodes() {
            let s = assign[ni];
            let node = &g.nodes[ni];
            let class = node.op.class();
            // Graph neighbors (skip environment endpoints and self-loops).
            others.clear();
            for &a in &node.ins {
                if let Some((src, _)) = g.arc(a).src {
                    if src.0 as usize != ni {
                        others.push(src.0 as usize);
                    }
                }
            }
            for &a in &node.outs {
                if let Some((dst, _)) = g.arc(a).dst {
                    if dst.0 as usize != ni {
                        others.push(dst.0 as usize);
                    }
                }
            }
            let cur_cut = others.iter().filter(|&&o| assign[o] != s).count();
            if cur_cut == 0 {
                continue;
            }
            let mut best: Option<(usize, usize)> = None; // (cut after move, target)
            for idx in 0..others.len() {
                let t = assign[others[idx]];
                if t == s {
                    continue;
                }
                let cut_t = others.iter().filter(|&&o| assign[o] != t).count();
                let has_slot =
                    counts[t].get(&class).copied().unwrap_or(0) < topo.slot_count(class);
                if cut_t < cur_cut && has_slot && best.map_or(true, |(bc, _)| cut_t < bc) {
                    best = Some((cut_t, t));
                }
            }
            if let Some((_, t)) = best {
                *counts[s]
                    .get_mut(&class)
                    .expect("refine: moved node's class is absent from its home shard census") -= 1;
                *counts[t].entry(class).or_insert(0) += 1;
                assign[ni] = t;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
}

/// Bus channels each shard would occupy under `assign`: internal arcs
/// once, cut arcs once in each home shard, environment ports in their
/// node's shard (fully disconnected arcs live in shard 0).
fn shard_arc_counts(g: &Graph, assign: &[usize], n_shards: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n_shards];
    for a in &g.arcs {
        let s = a.src.map(|(n, _)| assign[n.0 as usize]);
        let d = a.dst.map(|(n, _)| assign[n.0 as usize]);
        match (s, d) {
            (Some(x), Some(y)) if x == y => counts[x] += 1,
            (Some(x), Some(y)) => {
                counts[x] += 1;
                counts[y] += 1;
            }
            (Some(x), None) | (None, Some(x)) => counts[x] += 1,
            (None, None) => counts[0] += 1,
        }
    }
    counts
}

/// Materialize shard graphs and the cut list from a node→shard map.
/// Empty shards are compacted away; shard ids are renumbered in first-
/// appearance order.
fn build_plan(g: &Graph, assign: &[usize], n_shards: usize) -> PartitionPlan {
    // Compact empty shards.
    let mut node_count = vec![0usize; n_shards];
    for &s in assign {
        node_count[s] += 1;
    }
    let mut remap = vec![usize::MAX; n_shards];
    let mut used = 0usize;
    for s in 0..n_shards {
        if node_count[s] > 0 {
            remap[s] = used;
            used += 1;
        }
    }
    let assign: Vec<usize> = assign.iter().map(|&s| remap[s]).collect();
    let n_shards = used;

    let mut node_map = vec![0usize; g.n_nodes()];
    let mut shard_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); n_shards];
    for (ni, &s) in assign.iter().enumerate() {
        node_map[ni] = shard_nodes[s].len();
        shard_nodes[s].push(NodeId(ni as u32));
    }

    let mut cuts = Vec::new();
    for a in &g.arcs {
        if let (Some((sn, _)), Some((dn, _))) = (a.src, a.dst) {
            let (x, y) = (assign[sn.0 as usize], assign[dn.0 as usize]);
            if x != y {
                cuts.push(CutArc {
                    arc: a.id,
                    name: a.name.clone(),
                    from: x,
                    to: y,
                });
            }
        }
    }

    let mut shards = Vec::new();
    for si in 0..n_shards {
        let mut graph = Graph::new(format!("{}.s{si}", g.name));
        let mut orig_arcs = Vec::new();
        let mut amap: BTreeMap<u32, ArcId> = BTreeMap::new();
        for a in &g.arcs {
            let s = a.src.map(|(n, _)| assign[n.0 as usize]);
            let d = a.dst.map(|(n, _)| assign[n.0 as usize]);
            let here =
                s == Some(si) || d == Some(si) || (s.is_none() && d.is_none() && si == 0);
            if !here {
                continue;
            }
            let new_id = ArcId(graph.arcs.len() as u32);
            amap.insert(a.id.0, new_id);
            graph.arcs.push(Arc {
                id: new_id,
                src: a.src.and_then(|(n, p)| {
                    (assign[n.0 as usize] == si)
                        .then(|| (NodeId(node_map[n.0 as usize] as u32), p))
                }),
                dst: a.dst.and_then(|(n, p)| {
                    (assign[n.0 as usize] == si)
                        .then(|| (NodeId(node_map[n.0 as usize] as u32), p))
                }),
                name: a.name.clone(),
            });
            orig_arcs.push(a.id);
        }
        for &orig in &shard_nodes[si] {
            let n = g.node(orig);
            graph.nodes.push(Node {
                id: NodeId(graph.nodes.len() as u32),
                op: n.op,
                ins: n.ins.iter().map(|a| amap[&a.0]).collect(),
                outs: n.outs.iter().map(|a| amap[&a.0]).collect(),
            });
        }
        debug_assert!(
            crate::dfg::validate(&graph).is_ok(),
            "shard {si} of `{}` is structurally invalid: {:?}",
            g.name,
            crate::dfg::validate(&graph)
        );
        shards.push(Shard {
            index: si,
            graph,
            orig_nodes: shard_nodes[si].clone(),
            orig_arcs,
        });
    }
    PartitionPlan {
        graph_name: g.name.clone(),
        shards,
        cuts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{build, BenchId};
    use crate::fabric::place;

    #[test]
    fn fitting_graph_yields_one_shard() {
        let g = build(BenchId::Fibonacci);
        let topo = FabricTopology::paper();
        let plan = partition(&g, &topo).unwrap();
        assert_eq!(plan.n_shards(), 1);
        assert!(plan.cuts.is_empty());
        assert_eq!(plan.shards[0].graph.n_nodes(), g.n_nodes());
        assert_eq!(plan.shards[0].graph.n_arcs(), g.n_arcs());
    }

    #[test]
    fn oversized_graph_splits_and_each_shard_places() {
        for b in BenchId::ALL {
            let g = build(b);
            let topo = FabricTopology::sized_for_shards(&g, 2);
            let plan = partition(&g, &topo).unwrap_or_else(|e| panic!("{}: {e}", b.slug()));
            assert!(plan.n_shards() >= 2, "{}: expected ≥2 shards", b.slug());
            for sh in &plan.shards {
                place::place(&sh.graph, &topo)
                    .unwrap_or_else(|e| panic!("{} shard {}: {e}", b.slug(), sh.index));
            }
        }
    }

    #[test]
    fn zero_slot_class_is_unpartitionable() {
        let g = build(BenchId::DotProd);
        let mut topo = FabricTopology::sized_for_shards(&g, 2);
        topo.slots.remove(&OpClass::Alu2);
        let err = partition(&g, &topo).unwrap_err();
        assert!(matches!(
            err,
            PlaceError::InsufficientSlots {
                class: OpClass::Alu2,
                have: 0,
                ..
            }
        ));
    }

    #[test]
    fn starving_channels_is_unpartitionable() {
        let g = build(BenchId::Max);
        let mut topo = FabricTopology::sized_for_shards(&g, 2);
        topo.channels = 1; // below any node's arc degree
        let err = partition(&g, &topo).unwrap_err();
        assert!(matches!(err, PlaceError::InsufficientChannels { have: 1, .. }));
    }

    #[test]
    fn cut_labels_match_port_halves() {
        let g = build(BenchId::VectorSum);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = partition(&g, &topo).unwrap();
        for cut in &plan.cuts {
            let from = &plan.shards[cut.from].graph;
            let to = &plan.shards[cut.to].graph;
            let out_half = from.arc_by_name(&cut.name).expect("output half exists");
            let in_half = to.arc_by_name(&cut.name).expect("input half exists");
            assert!(from.arc(out_half).is_output_port());
            assert!(to.arc(in_half).is_input_port());
        }
    }
}
