//! Deterministic fault injection for the fabric.
//!
//! The paper's accelerator is physical hardware: operator slots lose
//! timing closure, bus channels develop stuck-at faults, and a whole
//! instance can drop off the rack. A production serve tier has to keep
//! answering through all of that, so this module gives the chaos
//! harness a *seeded, deterministic* fault source:
//!
//! * [`FaultPlan`] — a schedule of [`FaultEvent`]s pinned to virtual
//!   scheduler ticks (never wall time). The same seed always yields the
//!   same schedule, so a chaos run is exactly as reproducible as the
//!   load profile it torments.
//! * [`FabricHealth`] — the mutable health view of one instance's
//!   [`FabricTopology`]: quarantined slots/channels and a whole-instance
//!   `down` flag. [`FabricHealth::effective`] projects the degraded
//!   topology the placer must route against; a [`FaultKind::Repair`]
//!   restores the instance wholesale (the technician swaps the board).
//!
//! The health timeline is a pure function of `(plan, tick)`:
//! [`FaultPlan::healthy_at`] replays the schedule, which is what lets
//! the serve tier's bounded-retry policy *probe the future* — backoff
//! decisions depend only on the plan and the virtual clock, never on
//! execution timing, keeping chaos runs schedule-invariant (DESIGN.md
//! §11).

use super::topology::FabricTopology;
use crate::dfg::OpClass;
use crate::util::Rng;
use std::collections::BTreeMap;

/// One way an instance degrades (or recovers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `count` operator slots of `class` are quarantined.
    SlotFail { class: OpClass, count: usize },
    /// `channels` bus channels are quarantined.
    BusFail { channels: usize },
    /// The whole instance goes dark (mid-wave sessions die with it).
    Outage,
    /// Full repair: every quarantine on the instance is lifted.
    Repair,
}

/// One scheduled fault: at the start of virtual tick `tick`, `kind`
/// applies to instance `instance`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub tick: u64,
    pub instance: usize,
    pub kind: FaultKind,
}

/// Per-kind event census of a plan (the chaos gate requires at least
/// one slot, one bus, and one outage fault).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub slot: u64,
    pub bus: u64,
    pub outage: u64,
    pub repair: u64,
}

impl FaultCounts {
    /// Faults injected (repairs are recoveries, not faults).
    pub fn injected(&self) -> u64 {
        self.slot + self.bus + self.outage
    }
}

/// A deterministic schedule of fabric faults, sorted chronologically.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

/// Canonical same-tick ordering: faults land before the technician's
/// wholesale [`FaultKind::Repair`], so a Repair scheduled at the same
/// tick as a fault on the same instance wins. Replay (`healthy_at`,
/// `health_at`) and the live overlay both fold events in this order,
/// so they can never disagree about a tick's net health.
fn kind_rank(kind: FaultKind) -> u8 {
    match kind {
        FaultKind::SlotFail { .. } => 0,
        FaultKind::BusFail { .. } => 1,
        FaultKind::Outage => 2,
        FaultKind::Repair => 3,
    }
}

impl FaultPlan {
    /// No faults — the baseline run.
    pub fn empty() -> Self {
        FaultPlan { events: Vec::new() }
    }

    /// A plan from explicit events, sorted into canonical chronological
    /// order: by tick, then instance, then [`kind_rank`]. Replay used
    /// to depend on push order for same-tick events — a Repair pushed
    /// before the Outage it was meant to end folded in the wrong order
    /// and left the instance dark.
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| (e.tick, e.instance, kind_rank(e.kind)));
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Events scheduled for the start of `tick`.
    pub fn events_at(&self, tick: u64) -> impl Iterator<Item = &FaultEvent> {
        self.events.iter().filter(move |e| e.tick == tick)
    }

    /// Per-kind census.
    pub fn counts(&self) -> FaultCounts {
        let mut c = FaultCounts::default();
        for e in &self.events {
            match e.kind {
                FaultKind::SlotFail { .. } => c.slot += 1,
                FaultKind::BusFail { .. } => c.bus += 1,
                FaultKind::Outage => c.outage += 1,
                FaultKind::Repair => c.repair += 1,
            }
        }
        c
    }

    /// Is `instance` up at the start of tick `tick` (after that tick's
    /// events apply)? Pure replay of the schedule — the bounded-retry
    /// policy probes future ticks through this. Events fold in the
    /// canonical chronological order established by [`FaultPlan::new`],
    /// so a same-tick Repair ends the outage it overlaps.
    pub fn healthy_at(&self, tick: u64, instance: usize) -> bool {
        !self.health_at(tick, instance).down
    }

    /// The full [`FabricHealth`] view of `instance` at the start of
    /// tick `tick` (after that tick's events apply): a pure replay
    /// folding **every** event kind — slot and bus quarantines, not
    /// just outages — in canonical chronological order. The retry
    /// probe routes against this, so an instance that comes back up
    /// still degraded is rerouted through its effective topology
    /// instead of being treated as whole.
    pub fn health_at(&self, tick: u64, instance: usize) -> FabricHealth {
        let mut health = FabricHealth::healthy();
        for e in &self.events {
            if e.tick > tick {
                break;
            }
            if e.instance == instance {
                health.apply(e.kind);
            }
        }
        health
    }

    /// The canonical seeded chaos schedule for a pool of `instances`:
    /// guaranteed to contain at least one slot failure, one bus-channel
    /// failure, and one whole-instance outage, every fault inside the
    /// tick window [2, 8] (early enough that even a quick profile is
    /// still dispatching), and a repair for every faulted instance by
    /// tick 10. Only one instance is ever in outage at a time, so a
    /// pool of ≥ 2 instances always has a healthy member, and a pool of
    /// 1 recovers within the bounded-retry window (T+1/T+3/T+7).
    pub fn seeded(seed: u64, instances: usize) -> Self {
        let instances = instances.max(1);
        let mut r = Rng::new(seed ^ 0xFA01_7B1A_D5EE_DCAB);
        let mut events = Vec::new();
        // Slot failure: quarantine more slots than any class provisions
        // (the health view clamps), so placed graphs genuinely stop
        // fitting the degraded instance and demote down the lattice.
        let t_slot = 2 + r.below(3) as u64; // 2..=4
        let i_slot = r.below(instances);
        events.push(FaultEvent {
            tick: t_slot,
            instance: i_slot,
            kind: FaultKind::SlotFail {
                class: OpClass::Alu2,
                count: (1 << 10) + r.below(64),
            },
        });
        events.push(FaultEvent {
            tick: t_slot + 3,
            instance: i_slot,
            kind: FaultKind::Repair,
        });
        // Bus failure on a (possibly different) instance.
        let t_bus = 3 + r.below(3) as u64; // 3..=5
        let i_bus = r.below(instances);
        events.push(FaultEvent {
            tick: t_bus,
            instance: i_bus,
            kind: FaultKind::BusFail {
                channels: (1 << 10) + r.below(64),
            },
        });
        events.push(FaultEvent {
            tick: t_bus + 3,
            instance: i_bus,
            kind: FaultKind::Repair,
        });
        // Whole-instance outage — the mid-wave killer. Repair after 2
        // ticks keeps a single-instance pool inside the retry window.
        let t_out = 3 + r.below(6) as u64; // 3..=8
        let i_out = r.below(instances);
        events.push(FaultEvent {
            tick: t_out,
            instance: i_out,
            kind: FaultKind::Outage,
        });
        events.push(FaultEvent {
            tick: t_out + 2,
            instance: i_out,
            kind: FaultKind::Repair,
        });
        FaultPlan::new(events)
    }
}

/// The mutable health view of one fabric instance. All-healthy by
/// default; [`FabricHealth::apply`] folds in fault events and
/// [`FabricHealth::effective`] projects the topology the placer and
/// router must respect.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FabricHealth {
    /// Quarantined operator slots per class.
    pub lost_slots: BTreeMap<OpClass, usize>,
    /// Quarantined bus channels.
    pub lost_channels: usize,
    /// Whole instance dark (outage).
    pub down: bool,
}

impl FabricHealth {
    pub fn healthy() -> Self {
        FabricHealth::default()
    }

    pub fn is_degraded(&self) -> bool {
        self.down || self.lost_channels > 0 || self.lost_slots.values().any(|&n| n > 0)
    }

    /// Fold one fault event into the view.
    pub fn apply(&mut self, kind: FaultKind) {
        match kind {
            FaultKind::SlotFail { class, count } => {
                *self.lost_slots.entry(class).or_insert(0) += count;
            }
            FaultKind::BusFail { channels } => {
                self.lost_channels += channels;
            }
            FaultKind::Outage => self.down = true,
            FaultKind::Repair => *self = FabricHealth::healthy(),
        }
    }

    /// The topology this instance effectively offers right now:
    /// `base` minus quarantined resources (saturating at zero); a
    /// down instance offers nothing.
    pub fn effective(&self, base: &FabricTopology) -> FabricTopology {
        if self.down {
            return FabricTopology::new(base.name.clone(), BTreeMap::new(), 0, base.reconfig_cycles);
        }
        let slots: BTreeMap<OpClass, usize> = base
            .slots
            .iter()
            .map(|(&c, &n)| (c, n.saturating_sub(self.lost_slots.get(&c).copied().unwrap_or(0))))
            .collect();
        FabricTopology::new(
            base.name.clone(),
            slots,
            base.channels.saturating_sub(self.lost_channels),
            base.reconfig_cycles,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{build, BenchId};

    #[test]
    fn seeded_plans_are_deterministic_and_complete() {
        for seed in [0u64, 7, 42, 0xDEAD] {
            let a = FaultPlan::seeded(seed, 2);
            let b = FaultPlan::seeded(seed, 2);
            assert_eq!(a.events(), b.events(), "seed {seed} not reproducible");
            let c = a.counts();
            assert!(c.slot >= 1, "seed {seed}: no slot failure");
            assert!(c.bus >= 1, "seed {seed}: no bus failure");
            assert!(c.outage >= 1, "seed {seed}: no outage");
            assert!(c.repair >= c.injected().min(3), "seed {seed}: unrepaired");
            for e in a.events() {
                match e.kind {
                    FaultKind::Repair => assert!(e.tick <= 10, "late repair: {e:?}"),
                    _ => assert!((2..=8).contains(&e.tick), "fault outside window: {e:?}"),
                }
                assert!(e.instance < 2);
            }
            // Sorted by tick.
            assert!(a.events().windows(2).all(|w| w[0].tick <= w[1].tick));
        }
    }

    #[test]
    fn seeded_plan_never_downs_the_whole_pool() {
        for seed in 0u64..32 {
            let plan = FaultPlan::seeded(seed, 2);
            let horizon = plan.events().iter().map(|e| e.tick).max().unwrap() + 2;
            for tick in 0..=horizon {
                assert!(
                    (0..2).any(|i| plan.healthy_at(tick, i)),
                    "seed {seed}: whole pool dark at tick {tick}"
                );
            }
        }
    }

    #[test]
    fn single_instance_outage_repairs_inside_the_retry_window() {
        for seed in 0u64..32 {
            let plan = FaultPlan::seeded(seed, 1);
            for tick in 0..=12u64 {
                if !plan.healthy_at(tick, 0) {
                    // The T+1/T+3/T+7 probes from this tick must find it up.
                    assert!(
                        [1u64, 3, 7].iter().any(|d| plan.healthy_at(tick + d, 0)),
                        "seed {seed}: outage at tick {tick} outlives the retry window"
                    );
                }
            }
        }
    }

    #[test]
    fn health_view_degrades_and_repairs() {
        let base = FabricTopology::paper();
        let mut h = FabricHealth::healthy();
        assert!(!h.is_degraded());
        assert_eq!(h.effective(&base), base);

        h.apply(FaultKind::SlotFail {
            class: OpClass::Alu2,
            count: 1 << 10,
        });
        let degraded = h.effective(&base);
        assert_eq!(degraded.slot_count(OpClass::Alu2), 0, "clamped at zero");
        for b in BenchId::ALL {
            assert!(
                !degraded.fits(&build(b)),
                "{} still fits with every ALU slot dark",
                b.slug()
            );
        }

        h.apply(FaultKind::BusFail { channels: 3 });
        assert_eq!(h.effective(&base).channels, base.channels - 3);

        h.apply(FaultKind::Outage);
        let dark = h.effective(&base);
        assert_eq!(dark.total_slots(), 0);
        assert_eq!(dark.channels, 0);

        h.apply(FaultKind::Repair);
        assert!(!h.is_degraded());
        assert_eq!(h.effective(&base), base);
    }

    #[test]
    fn healthy_at_replays_the_outage_window() {
        let plan = FaultPlan::new(vec![
            FaultEvent {
                tick: 4,
                instance: 1,
                kind: FaultKind::Outage,
            },
            FaultEvent {
                tick: 6,
                instance: 1,
                kind: FaultKind::Repair,
            },
        ]);
        assert!(plan.healthy_at(3, 1));
        assert!(!plan.healthy_at(4, 1));
        assert!(!plan.healthy_at(5, 1));
        assert!(plan.healthy_at(6, 1));
        assert!(plan.healthy_at(3, 0), "other instances untouched");
    }

    #[test]
    fn replay_folds_same_tick_events_chronologically_not_in_push_order() {
        // Regression: a Repair pushed *before* the Outage it overlaps
        // (here both land at tick 5 on instance 0 — the slot/bus pair's
        // repair ticking inside a later-pushed outage window). The old
        // tick-only stable sort preserved push order within the tick,
        // so replay folded Repair → Outage and reported the instance
        // dark forever; canonical order folds the fault first and the
        // Repair wins the tick.
        let plan = FaultPlan::new(vec![
            FaultEvent {
                tick: 5,
                instance: 0,
                kind: FaultKind::Repair,
            },
            FaultEvent {
                tick: 3,
                instance: 0,
                kind: FaultKind::Outage,
            },
        ]);
        assert!(!plan.healthy_at(3, 0));
        assert!(!plan.healthy_at(4, 0));
        assert!(
            plan.healthy_at(5, 0),
            "same-tick Repair must end the outage window (pre-fix this replayed in push order and stayed down)"
        );
        assert!(plan.healthy_at(6, 0));
        // The canonical order is observable in the sorted event list:
        // within a tick, faults precede Repair.
        let same_tick = FaultPlan::new(vec![
            FaultEvent {
                tick: 5,
                instance: 0,
                kind: FaultKind::Repair,
            },
            FaultEvent {
                tick: 5,
                instance: 0,
                kind: FaultKind::Outage,
            },
        ]);
        assert_eq!(same_tick.events()[0].kind, FaultKind::Outage);
        assert_eq!(same_tick.events()[1].kind, FaultKind::Repair);
        assert!(same_tick.healthy_at(5, 0), "Repair wins its own tick");
    }

    #[test]
    fn health_at_carries_slot_and_bus_quarantine_not_just_outages() {
        // A degraded-but-up instance: the outage is repaired, then a
        // slot and a bus fault land after the wholesale repair. In that
        // window `healthy_at` says "up", and `health_at` must still
        // report the quarantine — the retry probe used to conjure
        // `FabricHealth::default()` here and treat the instance as
        // whole.
        let plan = FaultPlan::new(vec![
            FaultEvent {
                tick: 2,
                instance: 0,
                kind: FaultKind::Outage,
            },
            FaultEvent {
                tick: 4,
                instance: 0,
                kind: FaultKind::Repair,
            },
            FaultEvent {
                tick: 5,
                instance: 0,
                kind: FaultKind::SlotFail {
                    class: OpClass::Alu2,
                    count: 1 << 10,
                },
            },
            FaultEvent {
                tick: 5,
                instance: 0,
                kind: FaultKind::BusFail { channels: 7 },
            },
        ]);
        assert!(plan.healthy_at(6, 0), "instance is up...");
        let h = plan.health_at(6, 0);
        assert!(!h.down);
        assert!(h.is_degraded(), "...but not whole");
        assert_eq!(h.lost_slots.get(&OpClass::Alu2), Some(&(1 << 10)));
        assert_eq!(h.lost_channels, 7);
        // Before the faults: whole. During the outage: down.
        assert_eq!(plan.health_at(1, 0), FabricHealth::healthy());
        assert!(plan.health_at(3, 0).down);
        // Wholesale repair really was wholesale at tick 4.
        assert_eq!(plan.health_at(4, 0), FabricHealth::healthy());
        // Other instances never touched.
        assert_eq!(plan.health_at(9, 1), FabricHealth::healthy());
    }
}
