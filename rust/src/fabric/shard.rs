//! The sharded executor: run a [`PartitionPlan`] across multiple fabric
//! instances in lockstep, forwarding tokens over cut arcs.
//!
//! Each shard runs its own [`TokenSim`]. After every synchronous round,
//! tokens that surfaced on a cut arc's output-port half are drained and
//! enqueued onto the matching input-port half in the consuming shard —
//! the software model of the paper's inter-fabric bus channels, which
//! are ordinary 16-bit `str`/`ack` buses and therefore preserve FIFO
//! order per channel.
//!
//! Forwarding adds latency (a cut token spends extra rounds in flight)
//! but cannot change what the graph computes: token-by-token outputs are
//! confluent under any scheduling because every operator's firing rule
//! is deterministic and the loop schema's `ndmerge` nodes never hold
//! two competing tokens (`dfg::schema` documents why). The property
//! tests in `tests/fabric.rs` enforce byte-identical output streams
//! against whole-graph [`TokenSim`] on all six paper benchmarks.

use super::partition::PartitionPlan;
use crate::obs::{EngineProfile, ProfileLevel};
use crate::sim::{SimConfig, SimOutcome, TokenSim};
use std::collections::{BTreeMap, BTreeSet};

/// Per-shard simulation configs: each shard receives the injection
/// streams for the true input ports it owns; cut-arc input halves start
/// empty (the executor feeds them).
pub(crate) fn shard_configs(plan: &PartitionPlan, cfg: &SimConfig) -> Vec<SimConfig> {
    let cut_names = plan.cut_names();
    plan.shards
        .iter()
        .map(|sh| {
            let mut c = SimConfig::new().max_cycles(cfg.max_cycles);
            for a in sh.graph.input_ports() {
                let name = sh.graph.arc(a).name.clone();
                if cut_names.contains(&name) {
                    continue;
                }
                if let Some(stream) = cfg.inject.get(&name) {
                    c = c.inject(&name, stream.clone());
                }
            }
            c
        })
        .collect()
}

/// Merge per-shard outcomes into one whole-graph outcome, dropping the
/// cut-arc port halves (they are internal wiring, not real outputs).
pub(crate) fn merge_outcomes(
    sims: Vec<TokenSim>,
    cut_names: &BTreeSet<String>,
    cycles: u64,
    quiescent: bool,
) -> SimOutcome {
    let mut outputs = BTreeMap::new();
    let mut firings = 0u64;
    for sim in sims {
        let o = sim.into_outcome(cycles, quiescent);
        firings += o.firings;
        for (name, stream) in o.outputs {
            if cut_names.contains(&name) {
                continue;
            }
            outputs.insert(name, stream);
        }
    }
    SimOutcome {
        outputs,
        cycles,
        firings,
        quiescent,
    }
}

/// Run the shard rack in lockstep — one synchronous round per shard,
/// then cut-arc forwarding — until two consecutive idle rounds (one
/// drains output ports, one confirms silence) or the round budget.
/// Returns the rounds consumed. Shared by [`run_sharded`] and
/// [`run_sharded_waves`] so the forwarding/stop rules cannot diverge.
pub(crate) fn drive_lockstep(sims: &mut [TokenSim], plan: &PartitionPlan, budget: u64) -> u64 {
    drive_lockstep_counted(sims, plan, budget, None)
}

/// [`drive_lockstep`] with an optional per-cut traffic accumulator:
/// `cut_traffic[ci]` (indexed like `plan.cuts`) accrues every token
/// forwarded over that cut. `None` keeps the unprofiled path free.
pub(crate) fn drive_lockstep_counted(
    sims: &mut [TokenSim],
    plan: &PartitionPlan,
    budget: u64,
    mut cut_traffic: Option<&mut [u64]>,
) -> u64 {
    // Resolve each cut's destination injection slot once; the per-round
    // forwarding below is then index-only (no per-token label lookup).
    let cut_slots: Vec<usize> = plan
        .cuts
        .iter()
        .map(|cut| {
            sims[cut.to].port_slot(&cut.name).unwrap_or_else(|| {
                panic!(
                    "partition plan is inconsistent: cut arc `{}` has no \
                     input half in consuming shard {}",
                    cut.name, cut.to
                )
            })
        })
        .collect();
    let mut rounds = 0u64;
    let mut idle_rounds = 0u32;
    while rounds < budget {
        let mut fired = 0u64;
        for sim in sims.iter_mut() {
            fired += sim.step();
        }
        let mut moved = 0usize;
        for (ci, (cut, &slot)) in plan.cuts.iter().zip(&cut_slots).enumerate() {
            let vals = sims[cut.from].take_stream(&cut.name);
            moved += vals.len();
            if let Some(t) = cut_traffic.as_deref_mut() {
                t[ci] += vals.len() as u64;
            }
            for v in vals {
                sims[cut.to].enqueue_at(slot, v);
            }
        }
        rounds += 1;
        if fired == 0 && moved == 0 {
            idle_rounds += 1;
            if idle_rounds >= 2 {
                break;
            }
        } else {
            idle_rounds = 0;
        }
    }
    rounds
}

/// True output ports of the partitioned graph: `(owning shard, label)`
/// for every output-port arc that is not a cut half.
pub(crate) fn true_out_ports(
    plan: &PartitionPlan,
    cut_names: &BTreeSet<String>,
) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (si, sh) in plan.shards.iter().enumerate() {
        for a in sh.graph.output_ports() {
            let name = sh.graph.arc(a).name.clone();
            if !cut_names.contains(&name) {
                out.push((si, name));
            }
        }
    }
    out
}

/// Wave boundary on a resident rack: purge residue, re-arm const reset
/// tokens, and route the wave's streams to the shards owning each true
/// input port.
pub(crate) fn reset_and_route_wave(
    sims: &mut [TokenSim],
    cut_names: &BTreeSet<String>,
    wave: &crate::sim::WaveInput,
) {
    for sim in sims.iter_mut() {
        sim.purge();
        sim.rearm_consts();
    }
    for (port, stream) in wave {
        if cut_names.contains(port) {
            continue;
        }
        for sim in sims.iter_mut() {
            if stream.iter().all(|&v| sim.enqueue(port, v)) {
                break;
            }
        }
    }
}

/// Drain each true output port's collected stream into one map.
pub(crate) fn collect_wave_outputs(
    sims: &mut [TokenSim],
    out_ports: &[(usize, String)],
) -> BTreeMap<String, Vec<crate::dfg::Word>> {
    let mut outputs = BTreeMap::new();
    for (si, name) in out_ports {
        outputs.insert(name.clone(), sims[*si].take_stream(name));
    }
    outputs
}

/// Execute a partitioned graph to quiescence (or the round budget),
/// forwarding cut-arc tokens between shards after every round. Output
/// streams are byte-identical to whole-graph `TokenSim` on the same
/// `cfg`.
pub fn run_sharded(plan: &PartitionPlan, cfg: &SimConfig) -> SimOutcome {
    let cut_names = plan.cut_names();
    let shard_cfgs = shard_configs(plan, cfg);
    let mut sims: Vec<TokenSim> = plan
        .shards
        .iter()
        .zip(&shard_cfgs)
        .map(|(sh, c)| TokenSim::new(&sh.graph, c))
        .collect();
    let rounds = drive_lockstep(&mut sims, plan, cfg.max_cycles);
    let quiescent = sims.iter().all(|s| s.idle());
    merge_outcomes(sims, &cut_names, rounds, quiescent)
}

/// [`run_sharded`] with profiling: each shard's `TokenSim` profiles at
/// `level` (shard-local node ids, labeled `shard<i>`), and one extra
/// `sharded` profile carries the per-cut-arc token traffic — the
/// inter-fabric bus pressure the placement tier wants to see.
pub fn run_sharded_profiled(
    plan: &PartitionPlan,
    cfg: &SimConfig,
    level: ProfileLevel,
) -> (SimOutcome, Vec<(String, EngineProfile)>) {
    let cut_names = plan.cut_names();
    let shard_cfgs = shard_configs(plan, cfg);
    let mut sims: Vec<TokenSim> = plan
        .shards
        .iter()
        .zip(&shard_cfgs)
        .map(|(sh, c)| TokenSim::new(&sh.graph, c))
        .collect();
    for sim in sims.iter_mut() {
        sim.enable_profiling(level);
    }
    let mut cut_traffic = vec![0u64; plan.cuts.len()];
    let rounds = drive_lockstep_counted(&mut sims, plan, cfg.max_cycles, Some(&mut cut_traffic));
    let quiescent = sims.iter().all(|s| s.idle());
    let mut profiles = Vec::new();
    for (si, sim) in sims.iter_mut().enumerate() {
        if let Some(p) = sim.take_profile() {
            profiles.push((format!("shard{si}"), p));
        }
    }
    let mut fabric = EngineProfile::new("sharded", level, 0, 0);
    fabric.cycles = rounds;
    for (ci, &t) in cut_traffic.iter().enumerate() {
        fabric.cut(ci, t);
    }
    fabric.total_firings = profiles.iter().map(|(_, p)| p.total_firings).sum();
    profiles.push(("cuts".to_string(), fabric));
    let outcome = merge_outcomes(sims, &cut_names, rounds, quiescent);
    (outcome, profiles)
}

/// Streamed injection over a resident shard rack: run every wave of
/// `waves` through ONE set of per-shard `TokenSim`s, re-arming const
/// reset tokens and purging residue at wave boundaries instead of
/// tearing the rack down and rebuilding it per input set. Returns one
/// outcome per wave; output streams are byte-identical to running each
/// wave alone through [`run_sharded`] (and therefore through whole-
/// graph `TokenSim`).
pub fn run_sharded_waves(
    plan: &PartitionPlan,
    waves: &[crate::sim::WaveInput],
    max_cycles_per_wave: u64,
) -> Vec<SimOutcome> {
    let cut_names = plan.cut_names();
    let empty = SimConfig::new();
    let mut sims: Vec<TokenSim> = plan
        .shards
        .iter()
        .map(|sh| TokenSim::new(&sh.graph, &empty))
        .collect();
    let out_ports = true_out_ports(plan, &cut_names);

    let mut outcomes = Vec::with_capacity(waves.len());
    let mut firings_before = 0u64;
    for wave in waves {
        reset_and_route_wave(&mut sims, &cut_names, wave);
        let rounds = drive_lockstep(&mut sims, plan, max_cycles_per_wave);
        let quiescent = sims.iter().all(|s| s.idle());
        let outputs = collect_wave_outputs(&mut sims, &out_ports);
        let firings_now: u64 = sims.iter().map(|s| s.firings()).sum();
        outcomes.push(SimOutcome {
            outputs,
            cycles: rounds,
            firings: firings_now - firings_before,
            quiescent,
        });
        firings_before = firings_now;
    }
    outcomes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};
    use crate::fabric::{partition, FabricTopology};
    use crate::sim::run_token;

    #[test]
    fn single_shard_plan_matches_plain_run() {
        let g = bench_defs::build(BenchId::Fibonacci);
        let topo = FabricTopology::paper();
        let plan = partition(&g, &topo).unwrap();
        assert_eq!(plan.n_shards(), 1);
        let wl = bench_defs::workload(BenchId::Fibonacci, 9, 3);
        let cfg = wl.sim_config();
        let whole = run_token(&g, &cfg);
        let sharded = run_sharded(&plan, &cfg);
        assert_eq!(sharded.outputs, whole.outputs);
        assert_eq!(sharded.firings, whole.firings);
        assert!(sharded.quiescent);
    }

    #[test]
    fn two_shards_agree_on_vector_sum() {
        let g = bench_defs::build(BenchId::VectorSum);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = partition(&g, &topo).unwrap();
        assert!(plan.n_shards() >= 2);
        let wl = bench_defs::workload(BenchId::VectorSum, 6, 11);
        let cfg = wl.sim_config();
        let whole = run_token(&g, &cfg);
        let sharded = run_sharded(&plan, &cfg);
        assert_eq!(sharded.outputs, whole.outputs);
        assert!(sharded.quiescent);
        for (port, want) in &wl.expect {
            assert_eq!(sharded.stream(port), want.as_slice());
        }
    }

    #[test]
    fn streamed_waves_match_isolated_sharded_runs() {
        let g = bench_defs::build(BenchId::DotProd);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = partition(&g, &topo).unwrap();
        let wls: Vec<_> = (0..4)
            .map(|i| bench_defs::workload(BenchId::DotProd, 3 + i, i as u64))
            .collect();
        let waves: Vec<crate::sim::WaveInput> =
            wls.iter().map(|w| w.inject.clone()).collect();
        let max = wls.iter().map(|w| w.max_cycles).max().unwrap();
        let streamed = run_sharded_waves(&plan, &waves, max);
        assert_eq!(streamed.len(), waves.len());
        for (i, wl) in wls.iter().enumerate() {
            let cfg = wl.sim_config();
            let alone = run_sharded(&plan, &cfg);
            assert_eq!(streamed[i].outputs, alone.outputs, "wave {i}");
            let whole = run_token(&g, &cfg);
            assert_eq!(streamed[i].outputs, whole.outputs, "wave {i} vs whole");
            assert!(streamed[i].quiescent, "wave {i}");
        }
    }

    #[test]
    fn profiled_sharded_run_counts_cut_traffic_without_perturbing() {
        let g = bench_defs::build(BenchId::VectorSum);
        let topo = FabricTopology::sized_for_shards(&g, 2);
        let plan = partition(&g, &topo).unwrap();
        let cfg = bench_defs::workload(BenchId::VectorSum, 6, 11).sim_config();
        let plain = run_sharded(&plan, &cfg);
        let (profiled, profiles) = run_sharded_profiled(&plan, &cfg, ProfileLevel::Counters);
        assert_eq!(profiled.outputs, plain.outputs);
        assert_eq!(profiled.firings, plain.firings);
        assert_eq!(profiled.cycles, plain.cycles);
        let (label, cuts) = profiles.last().unwrap();
        assert_eq!(label, "cuts");
        assert_eq!(cuts.engine, "sharded");
        assert_eq!(cuts.cut_traffic.len(), plan.cuts.len());
        let crossed: u64 = cuts.cut_traffic.iter().sum();
        assert!(crossed > 0, "tokens crossed the cuts");
        assert_eq!(cuts.total_firings, plain.firings);
        let shard_total: u64 = profiles
            .iter()
            .filter(|(l, _)| l.starts_with("shard"))
            .map(|(_, p)| p.total_firings)
            .sum();
        assert_eq!(shard_total, plain.firings);
    }

    #[test]
    fn cut_ports_are_not_reported_as_outputs() {
        let g = bench_defs::build(BenchId::Max);
        let topo = FabricTopology::sized_for_shards(&g, 3);
        let plan = partition(&g, &topo).unwrap();
        let wl = bench_defs::workload(BenchId::Max, 5, 2);
        let sharded = run_sharded(&plan, &wl.sim_config());
        for name in plan.cut_names() {
            assert!(
                !sharded.outputs.contains_key(&name),
                "cut `{name}` leaked into outputs"
            );
        }
    }
}
