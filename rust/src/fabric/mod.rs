//! The physical fabric layer: finite topologies, placement, partitioning,
//! sharded execution, and time-multiplexed reconfiguration.
//!
//! The paper's accelerator is a *physical* static dataflow fabric — a
//! finite pool of operator instances joined by parallel 16-bit buses —
//! but the simulation layers above ([`crate::sim`], [`crate::coordinator`])
//! historically treated the fabric as infinite. This module closes that
//! gap:
//!
//! * [`topology`] — one fabric instance: per-class operator slot counts,
//!   a bounded bus-channel pool, and a context-swap cost, all derived
//!   from the [`crate::estimate`] resource model.
//! * [`place`] — DFG nodes → operator slots, arcs → bus channels;
//!   graphs that exceed capacity are rejected with a descriptive error.
//! * [`partition`] — a min-cut-flavored splitter that turns an oversized
//!   DFG into shards that each fit, cut arcs becoming inter-shard
//!   channels.
//! * [`shard`] — lockstep execution of all shards on separate instances
//!   with cut-arc token forwarding; output streams are byte-identical to
//!   whole-graph [`crate::sim::TokenSim`].
//! * [`reconfig`] — the same plan on ONE instance by context swapping,
//!   charging the FPGA reconfiguration cost the paper motivates.
//!
//! [`FabricPool`] models a rack of `N` identical instances for spatial
//! sharding; the coordinator's router round-robins request batches over
//! it and falls back to sharded execution when a graph does not fit one
//! instance.

pub mod fault;
pub mod partition;
pub mod place;
pub mod reconfig;
pub mod shard;
pub mod topology;

pub use fault::{FabricHealth, FaultCounts, FaultEvent, FaultKind, FaultPlan};
pub use partition::{partition, CutArc, PartitionPlan, Shard};
pub use place::{place, place_healthy, PlaceError, Placement};
pub use reconfig::{run_reconfig, run_reconfig_profiled, run_reconfig_waves, ReconfigStats};
pub use shard::{run_sharded, run_sharded_profiled, run_sharded_waves};
pub use topology::FabricTopology;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// A pool of `N` identical fabric instances — the spatial-sharding tier.
/// Routing is round-robin (every instance is interchangeable hardware);
/// per-instance dispatch counters feed the utilization report. Each
/// instance carries a quarantine flag ([`FabricPool::set_down`]) so the
/// fault layer can take it out of rotation and re-admit it on repair.
#[derive(Debug)]
pub struct FabricPool {
    topo: FabricTopology,
    next: AtomicUsize,
    dispatched: Vec<AtomicU64>,
    down: Vec<AtomicBool>,
}

impl FabricPool {
    pub fn new(topo: FabricTopology, instances: usize) -> Self {
        let n = instances.max(1);
        FabricPool {
            topo,
            next: AtomicUsize::new(0),
            dispatched: (0..n).map(|_| AtomicU64::new(0)).collect(),
            down: (0..n).map(|_| AtomicBool::new(false)).collect(),
        }
    }

    /// Number of fabric instances in the pool.
    pub fn size(&self) -> usize {
        self.dispatched.len()
    }

    /// The (shared) topology of every instance.
    pub fn topology(&self) -> &FabricTopology {
        &self.topo
    }

    /// Quarantine (`down = true`) or re-admit (`down = false`) one
    /// instance. The fault layer ([`crate::serve::chaos`]) uses this
    /// for outages, and the elastic repartitioner
    /// ([`crate::serve::elastic`]) for rolling drain windows — both
    /// route around quarantined instances the same way. Returns
    /// `false` when `instance` is out of range (the pool is left
    /// untouched).
    pub fn set_down(&self, instance: usize, down: bool) -> bool {
        match self.down.get(instance) {
            Some(flag) => {
                flag.store(down, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Is `instance` currently quarantined? Out-of-range instances
    /// read as down (they can never serve traffic).
    pub fn is_down(&self, instance: usize) -> bool {
        self.down
            .get(instance)
            .map(|f| f.load(Ordering::Relaxed))
            .unwrap_or(true)
    }

    /// Instances currently in rotation.
    pub fn healthy_count(&self) -> usize {
        self.down
            .iter()
            .filter(|f| !f.load(Ordering::Relaxed))
            .count()
    }

    /// Route the next batch: returns the chosen instance id and bumps its
    /// dispatch counter.
    pub fn route(&self) -> usize {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.dispatched.len();
        self.dispatched[i].fetch_add(1, Ordering::Relaxed);
        i
    }

    /// Health-aware [`FabricPool::route`]: round-robin over instances
    /// *in rotation*, skipping quarantined ones. Identical to `route`
    /// while the pool is fully healthy (the cursor advances the same
    /// way), `None` when every instance is down.
    pub fn route_healthy(&self) -> Option<usize> {
        for _ in 0..self.dispatched.len() {
            let i = self.next.fetch_add(1, Ordering::Relaxed) % self.dispatched.len();
            if !self.down[i].load(Ordering::Relaxed) {
                self.dispatched[i].fetch_add(1, Ordering::Relaxed);
                return Some(i);
            }
        }
        None
    }

    /// Batches dispatched to `instance` so far; `None` when the pool
    /// has no such instance (instead of the out-of-bounds panic this
    /// used to be).
    pub fn dispatched(&self, instance: usize) -> Option<u64> {
        self.dispatched
            .get(instance)
            .map(|c| c.load(Ordering::Relaxed))
    }

    /// One-line utilization summary for logs and the sweep report.
    pub fn summary(&self) -> String {
        let counts: Vec<String> = self
            .dispatched
            .iter()
            .map(|c| c.load(Ordering::Relaxed).to_string())
            .collect();
        format!(
            "fabric pool `{}`: {} instance(s), dispatch [{}]",
            self.topo.name,
            self.size(),
            counts.join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_round_robins() {
        let pool = FabricPool::new(FabricTopology::paper(), 3);
        let picks: Vec<usize> = (0..6).map(|_| pool.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        for i in 0..3 {
            assert_eq!(pool.dispatched(i), Some(2));
        }
        assert!(pool.summary().contains("3 instance(s)"));
    }

    #[test]
    fn pool_never_empty() {
        let pool = FabricPool::new(FabricTopology::paper(), 0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.route(), 0);
    }

    #[test]
    fn dispatched_is_total_over_instance_ids() {
        // Regression: this indexed `self.dispatched[instance]` and
        // panicked on any id ≥ size (reachable from report callers fed
        // a stale pool size).
        let pool = FabricPool::new(FabricTopology::paper(), 2);
        pool.route();
        assert_eq!(pool.dispatched(0), Some(1));
        assert_eq!(pool.dispatched(7), None);
    }

    #[test]
    fn route_healthy_skips_quarantined_and_readmits() {
        let pool = FabricPool::new(FabricTopology::paper(), 3);
        // Fully healthy: identical to plain round-robin.
        let picks: Vec<usize> = (0..3).map(|_| pool.route_healthy().unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2]);
        assert!(pool.set_down(1, true));
        assert!(pool.is_down(1));
        assert_eq!(pool.healthy_count(), 2);
        for _ in 0..4 {
            let i = pool.route_healthy().unwrap();
            assert_ne!(i, 1, "routed to a quarantined instance");
        }
        // All dark → no route, never a panic.
        pool.set_down(0, true);
        pool.set_down(2, true);
        assert_eq!(pool.route_healthy(), None);
        // Repair re-admits.
        pool.set_down(1, false);
        assert_eq!(pool.route_healthy(), Some(1));
        // Unknown instances are rejected and read as down.
        assert!(!pool.set_down(9, true));
        assert!(pool.is_down(9));
    }
}
