//! The physical fabric layer: finite topologies, placement, partitioning,
//! sharded execution, and time-multiplexed reconfiguration.
//!
//! The paper's accelerator is a *physical* static dataflow fabric — a
//! finite pool of operator instances joined by parallel 16-bit buses —
//! but the simulation layers above ([`crate::sim`], [`crate::coordinator`])
//! historically treated the fabric as infinite. This module closes that
//! gap:
//!
//! * [`topology`] — one fabric instance: per-class operator slot counts,
//!   a bounded bus-channel pool, and a context-swap cost, all derived
//!   from the [`crate::estimate`] resource model.
//! * [`place`] — DFG nodes → operator slots, arcs → bus channels;
//!   graphs that exceed capacity are rejected with a descriptive error.
//! * [`partition`] — a min-cut-flavored splitter that turns an oversized
//!   DFG into shards that each fit, cut arcs becoming inter-shard
//!   channels.
//! * [`shard`] — lockstep execution of all shards on separate instances
//!   with cut-arc token forwarding; output streams are byte-identical to
//!   whole-graph [`crate::sim::TokenSim`].
//! * [`reconfig`] — the same plan on ONE instance by context swapping,
//!   charging the FPGA reconfiguration cost the paper motivates.
//!
//! [`FabricPool`] models a rack of `N` identical instances for spatial
//! sharding; the coordinator's router round-robins request batches over
//! it and falls back to sharded execution when a graph does not fit one
//! instance.

pub mod partition;
pub mod place;
pub mod reconfig;
pub mod shard;
pub mod topology;

pub use partition::{partition, CutArc, PartitionPlan, Shard};
pub use place::{place, PlaceError, Placement};
pub use reconfig::{run_reconfig, run_reconfig_waves, ReconfigStats};
pub use shard::{run_sharded, run_sharded_waves};
pub use topology::FabricTopology;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// A pool of `N` identical fabric instances — the spatial-sharding tier.
/// Routing is round-robin (every instance is interchangeable hardware);
/// per-instance dispatch counters feed the utilization report.
#[derive(Debug)]
pub struct FabricPool {
    topo: FabricTopology,
    next: AtomicUsize,
    dispatched: Vec<AtomicU64>,
}

impl FabricPool {
    pub fn new(topo: FabricTopology, instances: usize) -> Self {
        FabricPool {
            topo,
            next: AtomicUsize::new(0),
            dispatched: (0..instances.max(1)).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Number of fabric instances in the pool.
    pub fn size(&self) -> usize {
        self.dispatched.len()
    }

    /// The (shared) topology of every instance.
    pub fn topology(&self) -> &FabricTopology {
        &self.topo
    }

    /// Route the next batch: returns the chosen instance id and bumps its
    /// dispatch counter.
    pub fn route(&self) -> usize {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.dispatched.len();
        self.dispatched[i].fetch_add(1, Ordering::Relaxed);
        i
    }

    /// Batches dispatched to `instance` so far.
    pub fn dispatched(&self, instance: usize) -> u64 {
        self.dispatched[instance].load(Ordering::Relaxed)
    }

    /// One-line utilization summary for logs and the sweep report.
    pub fn summary(&self) -> String {
        let counts: Vec<String> = self
            .dispatched
            .iter()
            .map(|c| c.load(Ordering::Relaxed).to_string())
            .collect();
        format!(
            "fabric pool `{}`: {} instance(s), dispatch [{}]",
            self.topo.name,
            self.size(),
            counts.join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_round_robins() {
        let pool = FabricPool::new(FabricTopology::paper(), 3);
        let picks: Vec<usize> = (0..6).map(|_| pool.route()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        for i in 0..3 {
            assert_eq!(pool.dispatched(i), 2);
        }
        assert!(pool.summary().contains("3 instance(s)"));
    }

    #[test]
    fn pool_never_empty() {
        let pool = FabricPool::new(FabricTopology::paper(), 0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.route(), 0);
    }
}
