//! Ergonomic graph construction.
//!
//! The builder lets callers create arcs lazily: `node(op, ins, outs)` wires
//! the given arcs; output slots not supplied are created as fresh internal
//! arcs retrievable with [`GraphBuilder::out_arc`]. `finish` runs
//! [`validate`](super::validate::validate).

use super::graph::{Arc, ArcId, Graph, Node, NodeId};
use super::op::Op;
use super::validate::{validate, ValidateError};

#[derive(Debug, Clone)]
pub struct GraphBuilder {
    g: Graph,
    next_label: u32,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        GraphBuilder {
            g: Graph::new(name),
            next_label: 1,
        }
    }

    fn fresh_arc(&mut self, name: Option<String>) -> ArcId {
        let id = ArcId(self.g.arcs.len() as u32);
        let name = name.unwrap_or_else(|| {
            let n = self.next_label;
            self.next_label += 1;
            format!("s{n}")
        });
        self.g.arcs.push(Arc {
            id,
            src: None,
            dst: None,
            name,
        });
        id
    }

    /// Create a named environment→fabric port arc.
    pub fn input_port(&mut self, name: &str) -> ArcId {
        self.fresh_arc(Some(name.to_string()))
    }

    /// Create a named fabric→environment port arc.
    pub fn output_port(&mut self, name: &str) -> ArcId {
        self.fresh_arc(Some(name.to_string()))
    }

    /// Create an anonymous internal arc (label `sN`).
    pub fn wire(&mut self) -> ArcId {
        self.fresh_arc(None)
    }

    /// Add an operator. `ins` must supply exactly `op.n_in()` arcs; `outs`
    /// may supply up to `op.n_out()` arcs — missing outputs become fresh
    /// internal wires.
    pub fn node(&mut self, op: Op, ins: &[ArcId], outs: &[ArcId]) -> NodeId {
        assert_eq!(
            ins.len(),
            op.n_in(),
            "{op:?} takes {} inputs, got {}",
            op.n_in(),
            ins.len()
        );
        assert!(
            outs.len() <= op.n_out(),
            "{op:?} drives {} outputs, got {}",
            op.n_out(),
            outs.len()
        );
        let id = NodeId(self.g.nodes.len() as u32);
        let mut all_outs = outs.to_vec();
        while all_outs.len() < op.n_out() {
            let w = self.wire();
            all_outs.push(w);
        }
        for (port, &a) in ins.iter().enumerate() {
            let arc = &mut self.g.arcs[a.0 as usize];
            assert!(
                arc.dst.is_none(),
                "arc {} already has a consumer",
                arc.name
            );
            arc.dst = Some((id, port as u8));
        }
        for (port, &a) in all_outs.iter().enumerate() {
            let arc = &mut self.g.arcs[a.0 as usize];
            assert!(arc.src.is_none(), "arc {} already has a driver", arc.name);
            arc.src = Some((id, port as u8));
        }
        self.g.nodes.push(Node {
            id,
            op,
            ins: ins.to_vec(),
            outs: all_outs,
        });
        id
    }

    /// Convenience: a 2-input operator with a fresh output wire; returns
    /// the output arc.
    pub fn op2(&mut self, op: Op, a: ArcId, b: ArcId) -> ArcId {
        let n = self.node(op, &[a, b], &[]);
        self.out_arc(n, 0)
    }

    /// Convenience: copy an arc into two fresh wires.
    pub fn copy(&mut self, a: ArcId) -> (ArcId, ArcId) {
        let n = self.node(Op::Copy, &[a], &[]);
        (self.out_arc(n, 0), self.out_arc(n, 1))
    }

    /// Convenience: copy an arc into `k ≥ 1` wires via a copy chain (the
    /// paper's copy duplicates to exactly two consumers, so wider fan-out
    /// is a tree of copies, as in Fig. 7).
    pub fn copy_n(&mut self, a: ArcId, k: usize) -> Vec<ArcId> {
        assert!(k >= 1);
        let mut leaves = vec![a];
        while leaves.len() < k {
            let head = leaves.remove(0);
            let (x, y) = self.copy(head);
            leaves.push(x);
            leaves.push(y);
        }
        leaves
    }

    /// Convenience: a constant-token source feeding a fresh wire.
    pub fn constant(&mut self, v: i16) -> ArcId {
        let n = self.node(Op::Const(v), &[], &[]);
        self.out_arc(n, 0)
    }

    /// The arc driven by output port `port` of node `n`.
    pub fn out_arc(&self, n: NodeId, port: usize) -> ArcId {
        self.g.nodes[n.0 as usize].outs[port]
    }

    /// Rename an arc (used to give loop-exit wires their port names, e.g.
    /// the paper's `fibo` / `pf` output signals).
    pub fn rename_arc(&mut self, a: ArcId, name: &str) {
        self.g.arcs[a.0 as usize].name = name.to_string();
    }

    /// Validate and return the finished graph.
    pub fn finish(self) -> Result<Graph, ValidateError> {
        validate(&self.g)?;
        Ok(self.g)
    }

    /// Access the graph under construction (used by the frontend's loop
    /// schema generator for diagnostics).
    pub fn graph(&self) -> &Graph {
        &self.g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_simple_adder() {
        let mut b = GraphBuilder::new("adder");
        let a = b.input_port("a");
        let bb = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, bb], &[z]);
        let g = b.finish().unwrap();
        assert_eq!(g.n_nodes(), 1);
        assert_eq!(g.n_arcs(), 3);
    }

    #[test]
    fn copy_n_builds_tree() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let leaves = b.copy_n(a, 5);
        assert_eq!(leaves.len(), 5);
        // 5 leaves needs 4 copy nodes (binary tree).
        assert_eq!(b.graph().nodes.len(), 4);
        // Terminate leaves so the graph validates.
        let mut leaves = leaves.into_iter();
        let first = leaves.next().unwrap();
        let mut acc = first;
        for l in leaves {
            acc = b.op2(Op::Add, acc, l);
        }
        let z = b.output_port("z");
        b.node(Op::Not, &[acc], &[z]);
        b.finish().unwrap();
    }

    #[test]
    #[should_panic(expected = "already has a consumer")]
    fn rejects_double_consumer() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let z1 = b.output_port("z1");
        let z2 = b.output_port("z2");
        b.node(Op::Not, &[a], &[z1]);
        b.node(Op::Not, &[a], &[z2]); // `a` consumed twice → panic
    }

    #[test]
    #[should_panic(expected = "takes 2 inputs")]
    fn rejects_bad_arity() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        b.node(Op::Add, &[a], &[]);
    }
}
