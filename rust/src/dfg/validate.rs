//! Structural validation.
//!
//! Enforces the paper's channel discipline (§3: each channel has exactly
//! one sender and one receiver) and operator arities (§3.2.1).

use super::graph::{Graph, NodeId};

#[derive(Debug, PartialEq)]
pub enum ValidateError {
    BadInArity(NodeId, String, usize, usize),
    BadOutArity(NodeId, String, usize, usize),
    Dangling(String),
    Inconsistent(String),
    DuplicateLabel(String),
    Empty,
}

impl std::fmt::Display for ValidateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValidateError::BadInArity(id, op, want, found) => {
                write!(f, "node {id:?} ({op}): expected {want} inputs, found {found}")
            }
            ValidateError::BadOutArity(id, op, want, found) => {
                write!(f, "node {id:?} ({op}): expected {want} outputs, found {found}")
            }
            ValidateError::Dangling(name) => {
                write!(f, "anonymous wire `{name}` has no driver and no consumer")
            }
            ValidateError::Inconsistent(name) => {
                write!(f, "arc `{name}` driver/consumer bookkeeping is inconsistent")
            }
            ValidateError::DuplicateLabel(name) => write!(f, "duplicate arc label `{name}`"),
            ValidateError::Empty => write!(f, "graph has no nodes"),
        }
    }
}

impl std::error::Error for ValidateError {}

/// Check structural invariants. The builder maintains most of these by
/// construction; the assembler parser and deserialized graphs rely on this
/// as their only line of defence.
pub fn validate(g: &Graph) -> Result<(), ValidateError> {
    if g.nodes.is_empty() {
        return Err(ValidateError::Empty);
    }
    let mut seen = std::collections::HashSet::new();
    for a in &g.arcs {
        if !seen.insert(a.name.as_str()) {
            return Err(ValidateError::DuplicateLabel(a.name.clone()));
        }
        if a.src.is_none() && a.dst.is_none() {
            // A named port with no connection is legal hardware (an
            // unused top-level pin, e.g. a declared-but-unread input);
            // an unconnected anonymous wire (`sN`) is a builder bug.
            if super::graph::is_anon_label(&a.name) {
                return Err(ValidateError::Dangling(a.name.clone()));
            }
        }
        if let Some((nid, port)) = a.src {
            let n = g.node(nid);
            if n.outs.get(port as usize) != Some(&a.id) {
                return Err(ValidateError::Inconsistent(a.name.clone()));
            }
        }
        if let Some((nid, port)) = a.dst {
            let n = g.node(nid);
            if n.ins.get(port as usize) != Some(&a.id) {
                return Err(ValidateError::Inconsistent(a.name.clone()));
            }
        }
    }
    for n in &g.nodes {
        if n.ins.len() != n.op.n_in() {
            return Err(ValidateError::BadInArity(
                n.id,
                n.op.mnemonic().to_string(),
                n.op.n_in(),
                n.ins.len(),
            ));
        }
        if n.outs.len() != n.op.n_out() {
            return Err(ValidateError::BadOutArity(
                n.id,
                n.op.mnemonic().to_string(),
                n.op.n_out(),
                n.outs.len(),
            ));
        }
        for (port, &a) in n.ins.iter().enumerate() {
            if g.arc(a).dst != Some((n.id, port as u8)) {
                return Err(ValidateError::Inconsistent(g.arc(a).name.clone()));
            }
        }
        for (port, &a) in n.outs.iter().enumerate() {
            if g.arc(a).src != Some((n.id, port as u8)) {
                return Err(ValidateError::Inconsistent(g.arc(a).name.clone()));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{GraphBuilder, Op};
    use super::*;

    #[test]
    fn valid_graph_passes() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        assert!(b.finish().is_ok());
    }

    #[test]
    fn empty_graph_fails() {
        let g = Graph::new("empty");
        assert_eq!(validate(&g), Err(ValidateError::Empty));
    }

    #[test]
    fn corrupted_bookkeeping_fails() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let c = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        let mut g = b.finish().unwrap();
        // Corrupt: point the node's input somewhere else.
        g.nodes[0].ins[0] = z;
        assert!(validate(&g).is_err());
    }

    #[test]
    fn duplicate_labels_fail() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("x");
        let c = b.input_port("x");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, c], &[z]);
        assert_eq!(
            b.finish().unwrap_err(),
            ValidateError::DuplicateLabel("x".into())
        );
    }
}
