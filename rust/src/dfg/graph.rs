//! Graph storage: nodes, arcs, ports.

use super::op::Op;

use std::collections::BTreeMap;

/// Index of a node in a [`Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// Index of an arc in a [`Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArcId(pub u32);

/// Direction of an external port.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PortDir {
    /// Environment → fabric (the paper's `dadoa..dadoj` signals).
    Input,
    /// Fabric → environment (the paper's `fibo` / `pf` signals).
    Output,
}

/// One operator instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    /// Input arcs, in operator-port order. For [`Op::DMerge`] port 0 is the
    /// control input; for [`Op::Branch`] port 0 is the control input.
    pub ins: Vec<ArcId>,
    /// Output arcs, in operator-port order. For [`Op::Branch`] port 0 is
    /// the true output and port 1 the false output.
    pub outs: Vec<ArcId>,
}

/// One point-to-point connection: a 16-bit data bus + `str`/`ack` pair.
///
/// `src == None` makes this an input port (driven by the environment);
/// `dst == None` makes it an output port (read by the environment). The
/// paper's channels allow exactly one sender and one receiver (§3).
#[derive(Debug, Clone, PartialEq)]
pub struct Arc {
    pub id: ArcId,
    /// Driving node and its output-port index.
    pub src: Option<(NodeId, u8)>,
    /// Consuming node and its input-port index.
    pub dst: Option<(NodeId, u8)>,
    /// Label: `sN` for internal arcs, a signal name for ports.
    pub name: String,
}

impl Arc {
    pub fn is_input_port(&self) -> bool {
        self.src.is_none()
    }
    pub fn is_output_port(&self) -> bool {
        self.dst.is_none()
    }
}

/// A static dataflow graph.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub arcs: Vec<Arc>,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Graph {
            name: name.into(),
            nodes: Vec::new(),
            arcs: Vec::new(),
        }
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn arc(&self, id: ArcId) -> &Arc {
        &self.arcs[id.0 as usize]
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_arcs(&self) -> usize {
        self.arcs.len()
    }

    /// Arcs with no driving node, in id order (environment injects here).
    pub fn input_ports(&self) -> Vec<ArcId> {
        self.arcs
            .iter()
            .filter(|a| a.is_input_port())
            .map(|a| a.id)
            .collect()
    }

    /// Arcs with no consuming node, in id order (environment collects here).
    pub fn output_ports(&self) -> Vec<ArcId> {
        self.arcs
            .iter()
            .filter(|a| a.is_output_port())
            .map(|a| a.id)
            .collect()
    }

    /// Look up an arc by label — a linear scan; fine for one-off
    /// lookups (labels are unique per graph; `validate` rejects
    /// duplicates). Repeated lookups on hot paths go through an index
    /// built once at construction instead: the parser interns labels in
    /// its own map, and the executors resolve forwarding targets via
    /// [`TokenSim::port_slot`](crate::sim::TokenSim::port_slot).
    pub fn arc_by_name(&self, name: &str) -> Option<ArcId> {
        self.arcs.iter().find(|a| a.name == name).map(|a| a.id)
    }

    /// Operator census by mnemonic — the input to the resource estimator.
    pub fn op_census(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for n in &self.nodes {
            *m.entry(n.op.mnemonic()).or_insert(0) += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::super::GraphBuilder;

    use crate::dfg::Op;

    #[test]
    fn ports_are_classified() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let bb = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, bb], &[z]);
        let g = b.finish().unwrap();
        assert_eq!(g.input_ports().len(), 2);
        assert_eq!(g.output_ports().len(), 1);
        assert!(g.arc(a).is_input_port());
        assert!(g.arc(z).is_output_port());
        assert!(!g.arc(z).is_input_port());
    }

    #[test]
    fn census_counts_ops() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let (x, y) = {
            let c = b.node(Op::Copy, &[a], &[]);
            (b.out_arc(c, 0), b.out_arc(c, 1))
        };
        let z = b.output_port("z");
        b.node(Op::Add, &[x, y], &[z]);
        let g = b.finish().unwrap();
        assert_eq!(g.op_census()["copy"], 1);
        assert_eq!(g.op_census()["add"], 1);
    }

    #[test]
    fn arc_by_name_finds_ports() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("north");
        let z = b.output_port("south");
        b.node(Op::Not, &[a], &[z]);
        let g = b.finish().unwrap();
        assert_eq!(g.arc_by_name("north"), Some(a));
        assert_eq!(g.arc_by_name("south"), Some(z));
        assert_eq!(g.arc_by_name("missing"), None);
    }
}
