//! Graph storage: nodes, arcs, ports.

use super::op::Op;

use std::collections::BTreeMap;

/// Index of a node in a [`Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// Index of an arc in a [`Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArcId(pub u32);

/// Direction of an external port.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PortDir {
    /// Environment → fabric (the paper's `dadoa..dadoj` signals).
    Input,
    /// Fabric → environment (the paper's `fibo` / `pf` signals).
    Output,
}

/// One operator instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    /// Input arcs, in operator-port order. For [`Op::DMerge`] port 0 is the
    /// control input; for [`Op::Branch`] port 0 is the control input.
    pub ins: Vec<ArcId>,
    /// Output arcs, in operator-port order. For [`Op::Branch`] port 0 is
    /// the true output and port 1 the false output.
    pub outs: Vec<ArcId>,
}

/// One point-to-point connection: a 16-bit data bus + `str`/`ack` pair.
///
/// `src == None` makes this an input port (driven by the environment);
/// `dst == None` makes it an output port (read by the environment). The
/// paper's channels allow exactly one sender and one receiver (§3).
#[derive(Debug, Clone, PartialEq)]
pub struct Arc {
    pub id: ArcId,
    /// Driving node and its output-port index.
    pub src: Option<(NodeId, u8)>,
    /// Consuming node and its input-port index.
    pub dst: Option<(NodeId, u8)>,
    /// Label: `sN` for internal arcs, a signal name for ports.
    pub name: String,
}

impl Arc {
    pub fn is_input_port(&self) -> bool {
        self.src.is_none()
    }
    pub fn is_output_port(&self) -> bool {
        self.dst.is_none()
    }
}

/// Whether a label is an anonymous internal wire (`s1`, `s42`, ...) as
/// opposed to a caller-chosen port/signal name. Anonymous dangling arcs
/// are drain wires with no interface meaning — the optimizer may remove
/// them, while named ports are part of the graph's external contract.
pub fn is_anon_label(name: &str) -> bool {
    name.starts_with('s') && name.len() > 1 && name[1..].chars().all(|c| c.is_ascii_digit())
}

/// A static dataflow graph.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub arcs: Vec<Arc>,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Graph {
            name: name.into(),
            nodes: Vec::new(),
            arcs: Vec::new(),
        }
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn arc(&self, id: ArcId) -> &Arc {
        &self.arcs[id.0 as usize]
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_arcs(&self) -> usize {
        self.arcs.len()
    }

    /// Arcs with no driving node, in id order (environment injects here).
    pub fn input_ports(&self) -> Vec<ArcId> {
        self.arcs
            .iter()
            .filter(|a| a.is_input_port())
            .map(|a| a.id)
            .collect()
    }

    /// Arcs with no consuming node, in id order (environment collects here).
    pub fn output_ports(&self) -> Vec<ArcId> {
        self.arcs
            .iter()
            .filter(|a| a.is_output_port())
            .map(|a| a.id)
            .collect()
    }

    /// Look up an arc by label — a linear scan; fine for one-off
    /// lookups (labels are unique per graph; `validate` rejects
    /// duplicates). Repeated lookups on hot paths go through an index
    /// built once at construction instead: the parser interns labels in
    /// its own map, and the executors resolve forwarding targets via
    /// [`TokenSim::port_slot`](crate::sim::TokenSim::port_slot).
    pub fn arc_by_name(&self, name: &str) -> Option<ArcId> {
        self.arcs.iter().find(|a| a.name == name).map(|a| a.id)
    }

    /// Operator census by mnemonic — the input to the resource estimator.
    pub fn op_census(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for n in &self.nodes {
            *m.entry(n.op.mnemonic()).or_insert(0) += 1;
        }
        m
    }

    /// Content-addressed identity: a stable FNV-1a 64-bit hash over the
    /// graph's *computational* content — operators (with their `const` /
    /// `fifo` parameters) in node order, arc endpoints (node index +
    /// port index on each side), and the labels of environment-facing
    /// port arcs (they name the injection/collection interface).
    ///
    /// Deliberately excluded: the graph's display `name` and the labels
    /// of *internal* arcs — renaming `s3` to `tmp` changes neither what
    /// the graph computes nor how it places, so it must not change the
    /// fingerprint (the session cache keys warm compile/place state by
    /// this hash). Changing an op, rewiring a port, or renaming an
    /// input/output port all change it.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, &(self.nodes.len() as u32).to_le_bytes());
        h = fnv1a(h, &(self.arcs.len() as u32).to_le_bytes());
        for n in &self.nodes {
            h = fnv1a(h, n.op.mnemonic().as_bytes());
            match n.op {
                Op::Const(v) => h = fnv1a(h, &v.to_le_bytes()),
                Op::Fifo(k) => h = fnv1a(h, &k.to_le_bytes()),
                _ => {}
            }
            h = fnv1a(h, &[0xFE]);
        }
        for a in &self.arcs {
            h = fnv1a_endpoint(h, a.src);
            h = fnv1a_endpoint(h, a.dst);
            if a.is_input_port() || a.is_output_port() {
                h = fnv1a(h, a.name.as_bytes());
            }
            h = fnv1a(h, &[0xFE]);
        }
        h
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fold one arc endpoint: `(node index, port index)` or an environment
/// marker distinct from any node index.
fn fnv1a_endpoint(h: u64, ep: Option<(NodeId, u8)>) -> u64 {
    match ep {
        Some((n, port)) => {
            let h = fnv1a(h, &n.0.to_le_bytes());
            fnv1a(h, &[port])
        }
        None => fnv1a(h, &[0xFF; 5]),
    }
}

#[cfg(test)]
mod tests {
    use super::super::GraphBuilder;

    use crate::dfg::Op;

    #[test]
    fn ports_are_classified() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let bb = b.input_port("b");
        let z = b.output_port("z");
        b.node(Op::Add, &[a, bb], &[z]);
        let g = b.finish().unwrap();
        assert_eq!(g.input_ports().len(), 2);
        assert_eq!(g.output_ports().len(), 1);
        assert!(g.arc(a).is_input_port());
        assert!(g.arc(z).is_output_port());
        assert!(!g.arc(z).is_input_port());
    }

    #[test]
    fn census_counts_ops() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("a");
        let (x, y) = {
            let c = b.node(Op::Copy, &[a], &[]);
            (b.out_arc(c, 0), b.out_arc(c, 1))
        };
        let z = b.output_port("z");
        b.node(Op::Add, &[x, y], &[z]);
        let g = b.finish().unwrap();
        assert_eq!(g.op_census()["copy"], 1);
        assert_eq!(g.op_census()["add"], 1);
    }

    #[test]
    fn fingerprint_ignores_internal_arc_names_and_graph_name() {
        let build = |gname: &str, internal: &str| {
            let mut b = GraphBuilder::new(gname);
            let a = b.input_port("a");
            let c = b.input_port("b");
            let s = b.op2(Op::Add, a, c);
            b.rename_arc(s, internal);
            let z = b.output_port("z");
            b.node(Op::Not, &[s], &[z]);
            b.finish().unwrap()
        };
        let g1 = build("first", "s_sum");
        let g2 = build("second", "totally_different_label");
        assert_eq!(g1.fingerprint(), g2.fingerprint());
    }

    #[test]
    fn fingerprint_sees_op_changes() {
        let build = |op: Op| {
            let mut b = GraphBuilder::new("t");
            let a = b.input_port("a");
            let c = b.input_port("b");
            let z = b.output_port("z");
            b.node(op, &[a, c], &[z]);
            b.finish().unwrap()
        };
        assert_ne!(build(Op::Add).fingerprint(), build(Op::Sub).fingerprint());
        // Parameterized ops hash their parameter too.
        let fifo = |k: u16| {
            let mut b = GraphBuilder::new("t");
            let a = b.input_port("a");
            let z = b.output_port("z");
            b.node(Op::Fifo(k), &[a], &[z]);
            b.finish().unwrap()
        };
        assert_ne!(fifo(2).fingerprint(), fifo(3).fingerprint());
        let konst = |v: i16| {
            let mut b = GraphBuilder::new("t");
            let c = b.constant(v);
            let a = b.input_port("a");
            let z = b.output_port("z");
            b.node(Op::Add, &[c, a], &[z]);
            b.finish().unwrap()
        };
        assert_ne!(konst(1).fingerprint(), konst(2).fingerprint());
    }

    #[test]
    fn fingerprint_sees_port_renames_and_rewiring() {
        let build = |in0: &str, swap: bool| {
            let mut b = GraphBuilder::new("t");
            let a = b.input_port(in0);
            let c = b.input_port("b");
            let z = b.output_port("z");
            let (x, y) = if swap { (c, a) } else { (a, c) };
            b.node(Op::Sub, &[x, y], &[z]);
            b.finish().unwrap()
        };
        // Renaming an environment-facing port changes the interface.
        assert_ne!(
            build("a", false).fingerprint(),
            build("a2", false).fingerprint()
        );
        // Swapping which port feeds which operand rewires the arcs.
        assert_ne!(
            build("a", false).fingerprint(),
            build("a", true).fingerprint()
        );
        // Identical construction is a fixpoint.
        assert_eq!(
            build("a", false).fingerprint(),
            build("a", false).fingerprint()
        );
    }

    #[test]
    fn benchmark_fingerprints_are_distinct() {
        use std::collections::BTreeSet;
        let fps: BTreeSet<u64> = crate::bench_defs::BenchId::ALL
            .iter()
            .map(|&b| crate::bench_defs::build(b).fingerprint())
            .collect();
        assert_eq!(fps.len(), crate::bench_defs::BenchId::ALL.len());
    }

    #[test]
    fn arc_by_name_finds_ports() {
        let mut b = GraphBuilder::new("t");
        let a = b.input_port("north");
        let z = b.output_port("south");
        b.node(Op::Not, &[a], &[z]);
        let g = b.finish().unwrap();
        assert_eq!(g.arc_by_name("north"), Some(a));
        assert_eq!(g.arc_by_name("south"), Some(z));
        assert_eq!(g.arc_by_name("missing"), None);
    }
}
