//! Graph optimization: dead-copy elimination.
//!
//! The frontend's lazy-copy discipline (one `copy` per variable *use*)
//! leaves copies whose second output dangles — pure fan-out overhead the
//! paper's hand-drawn graphs don't have. A copy with one anonymous,
//! unconsumed output is semantically a wire (the dangling side always
//! drains), so it can be removed and its input fused with its live
//! output. Applied to a fixpoint this shrinks compiled graphs by
//! 20–30% (toward the hand-built sizes) and removes one handshake hop
//! of latency per eliminated node; results are unchanged (tested on
//! every benchmark under every engine).

use super::graph::{Graph, Node, NodeId};
use super::op::Op;

fn is_anon_wire(name: &str) -> bool {
    name.starts_with('s') && name.len() > 1 && name[1..].chars().all(|c| c.is_ascii_digit())
}

/// One elimination pass; returns `None` when no candidate exists.
fn eliminate_one(g: &Graph) -> Option<Graph> {
    // Find a copy whose output `dead` is an unconsumed anonymous wire.
    let (victim, live_out, in_arc) = g.nodes.iter().find_map(|n| {
        if n.op != Op::Copy {
            return None;
        }
        let (o0, o1) = (n.outs[0], n.outs[1]);
        let dead0 = g.arc(o0).dst.is_none() && is_anon_wire(&g.arc(o0).name);
        let dead1 = g.arc(o1).dst.is_none() && is_anon_wire(&g.arc(o1).name);
        match (dead0, dead1) {
            (true, false) => Some((n.id, o1, n.ins[0])),
            (_, true) => Some((n.id, o0, n.ins[0])),
            _ => None,
        }
    })?;

    let dead_out = {
        let n = g.node(victim);
        if n.outs[0] == live_out {
            n.outs[1]
        } else {
            n.outs[0]
        }
    };

    // Rebuild without `victim`, `live_out` and `dead_out`; `in_arc`
    // absorbs `live_out`'s consumer (and its name, if `in_arc` is an
    // anonymous wire and `live_out` carries a port name).
    let mut ng = Graph::new(g.name.clone());
    let mut arc_map = vec![u32::MAX; g.n_arcs()];
    let mut next_arc = 0u32;
    for a in &g.arcs {
        if a.id == live_out || a.id == dead_out {
            continue;
        }
        arc_map[a.id.0 as usize] = next_arc;
        next_arc += 1;
    }
    let live = g.arc(live_out);
    for a in &g.arcs {
        if a.id == live_out || a.id == dead_out {
            continue;
        }
        let mut na = a.clone();
        na.id = super::graph::ArcId(arc_map[a.id.0 as usize]);
        if a.id == in_arc {
            // Fuse: the copy's input now feeds the live consumer.
            na.dst = live.dst;
            if is_anon_wire(&na.name) && !is_anon_wire(&live.name) {
                na.name = live.name.clone();
            }
        }
        ng.arcs.push(na);
    }

    let mut node_map = vec![u32::MAX; g.n_nodes()];
    let mut next_node = 0u32;
    for n in &g.nodes {
        if n.id == victim {
            continue;
        }
        node_map[n.id.0 as usize] = next_node;
        next_node += 1;
    }
    for n in &g.nodes {
        if n.id == victim {
            continue;
        }
        let remap = |arc: super::graph::ArcId| {
            let a = if arc == live_out { in_arc } else { arc };
            super::graph::ArcId(arc_map[a.0 as usize])
        };
        ng.nodes.push(Node {
            id: NodeId(node_map[n.id.0 as usize]),
            op: n.op,
            ins: n.ins.iter().map(|&a| remap(a)).collect(),
            outs: n.outs.iter().map(|&a| remap(a)).collect(),
        });
    }
    // Fix arc endpoint node ids.
    for a in &mut ng.arcs {
        if let Some((nid, p)) = a.src {
            a.src = Some((NodeId(node_map[nid.0 as usize]), p));
        }
        if let Some((nid, p)) = a.dst {
            a.dst = Some((NodeId(node_map[nid.0 as usize]), p));
        }
    }
    Some(ng)
}

/// Eliminate dead copies to a fixpoint. The result is validated.
pub fn eliminate_dead_copies(g: &Graph) -> Graph {
    let mut cur = g.clone();
    while let Some(next) = eliminate_one(&cur) {
        cur = next;
    }
    super::validate(&cur).expect("optimizer preserves structural validity");
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_defs::{self, BenchId};
    use crate::frontend;
    use crate::sim::{run_fsm, run_token};

    #[test]
    fn removes_dangling_copy() {
        let mut b = crate::dfg::GraphBuilder::new("t");
        let a = b.input_port("a");
        let (u, _rest) = b.copy(a); // rest dangles
        let k = b.constant(1);
        let z = b.output_port("z");
        b.node(Op::Add, &[u, k], &[z]);
        let g = b.finish().unwrap();
        let opt = eliminate_dead_copies(&g);
        assert_eq!(opt.n_nodes(), g.n_nodes() - 1);
        assert!(opt.op_census().get("copy").is_none());
        let cfg = crate::sim::SimConfig::new().inject("a", vec![41]);
        assert_eq!(run_token(&opt, &cfg).stream("z"), &[42]);
    }

    #[test]
    fn preserves_port_names_through_fusion() {
        // `r = x;` lowers to copy(x) with the out renamed `r`; eliminating
        // the copy must keep the port name (`x` is named, so the copy
        // stays — fuse only when the input side is anonymous).
        let g = frontend::compile("t", "in int x; out int r; r = x + 0;").unwrap();
        let opt = eliminate_dead_copies(&g);
        assert!(opt.arc_by_name("r").is_some());
        assert!(opt.arc_by_name("x").is_some());
        let cfg = crate::sim::SimConfig::new().inject("x", vec![9]);
        assert_eq!(run_token(&opt, &cfg).stream("r"), &[9]);
    }

    #[test]
    fn shrinks_all_compiled_benchmarks_semantics_preserved() {
        for bench in BenchId::ALL {
            let g = frontend::compile(bench.slug(), bench_defs::c_source(bench)).unwrap();
            let opt = eliminate_dead_copies(&g);
            assert!(
                opt.n_nodes() <= g.n_nodes(),
                "{}: {} > {}",
                bench.slug(),
                opt.n_nodes(),
                g.n_nodes()
            );
            let wl = bench_defs::workload(bench, 6, 17);
            let mut cfg = wl.sim_config();
            cfg.max_cycles *= 4;
            let tok = run_token(&opt, &cfg);
            let fsm = run_fsm(&opt, &cfg);
            for (port, want) in &wl.expect {
                assert_eq!(tok.stream(port), want.as_slice(), "{} token", bench.slug());
                assert_eq!(fsm.stream(port), want.as_slice(), "{} fsm", bench.slug());
            }
        }
    }

    #[test]
    fn optimized_graphs_approach_hand_built_size() {
        // Aggregate: the optimizer recovers a large share of the lazy-copy
        // overhead the frontend introduces vs the hand-built graphs.
        let mut raw = 0usize;
        let mut opt_total = 0usize;
        let mut hand = 0usize;
        for bench in BenchId::ALL {
            let g = frontend::compile(bench.slug(), bench_defs::c_source(bench)).unwrap();
            raw += g.n_nodes();
            opt_total += eliminate_dead_copies(&g).n_nodes();
            hand += bench_defs::build(bench).n_nodes();
        }
        assert!(opt_total < raw, "optimizer removed nothing");
        let overhead_before = raw as f64 / hand as f64;
        let overhead_after = opt_total as f64 / hand as f64;
        assert!(
            overhead_after < overhead_before,
            "{overhead_after:.2} !< {overhead_before:.2}"
        );
    }

    #[test]
    fn idempotent() {
        let g = frontend::compile("fib", bench_defs::c_source(BenchId::Fibonacci)).unwrap();
        let o1 = eliminate_dead_copies(&g);
        let o2 = eliminate_dead_copies(&o1);
        assert_eq!(o1.n_nodes(), o2.n_nodes());
    }
}
